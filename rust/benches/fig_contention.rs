//! Bench: regenerate Fig. 3 (memory contention) and time the DES
//! bandwidth arbiter under co-execution churn.

use agent_xpu::config::default_soc;
use agent_xpu::figures::fig_contention;
use agent_xpu::model::gemv_cost;
use agent_xpu::soc::{KernelClass, LaunchSpec, SocSim};
use agent_xpu::util::bench::{bench, black_box};

fn main() {
    let soc = default_soc();
    black_box(fig_contention(&soc));

    // DES event throughput: repeatedly co-launch & drain two GEMVs
    let s = bench("DES co-exec launch+drain (2 kernels)", 20, 2000, || {
        let mut sim = SocSim::new(&soc);
        let t0 = sim.xpus[0].timing(&gemv_cost(2048, 2048));
        let t1 = sim.xpus[1].timing(&gemv_cost(2048, 2048));
        sim.launch(0, LaunchSpec { timing: t0, class: KernelClass::Proactive });
        sim.launch(1, LaunchSpec { timing: t1, class: KernelClass::Proactive });
        while sim.next_event_in().is_some() {
            black_box(sim.advance_until(sim.now_us + 1e12));
        }
    });
    println!("\n{}", s.report());
}
