//! L3 hot-path bench: real PJRT kernel execution costs — the request
//! path of the serving frontend.  This is the §Perf target of
//! EXPERIMENTS.md: prefill chunk, single-lane decode, batched decode.
//!
//! Requires `make artifacts` (skips politely otherwise).

use std::sync::Arc;

use agent_xpu::runtime::{KvCache, ModelExecutor, Runtime};
use agent_xpu::util::bench::{bench, black_box};

fn main() -> anyhow::Result<()> {
    for cfg in ["tiny", "small"] {
        let dir = format!("artifacts/{cfg}");
        if !std::path::Path::new(&dir).join("manifest.json").exists() {
            eprintln!("skipping {cfg}: run `make artifacts`");
            continue;
        }
        let rt = Arc::new(Runtime::load(&dir)?);
        let geo = rt.geo.clone();
        let exec = ModelExecutor::new(rt);
        let chunk = geo.max_chunk();
        let prompt: Vec<i32> =
            (0..chunk).map(|i| (i as i32 * 7 + 1) % geo.vocab as i32).collect();

        println!("== runtime hot path [{cfg}] ({} layers, d={}) ==", geo.n_layers, geo.d_model);
        let mut cache = KvCache::new(&geo);
        let s = bench(&format!("[{cfg}] prefill chunk c{chunk} (all layers)"), 2, 12, || {
            let mut c = KvCache::new(&geo);
            black_box(exec.prefill(&prompt, chunk, &mut c).unwrap());
        });
        println!("{}", s.report());

        let hidden = exec.prefill(&prompt, chunk, &mut cache)?;
        let mut c1 = cache.clone();
        let h1 = hidden.clone();
        let s = bench(&format!("[{cfg}] decode iteration b=1"), 2, 12, || {
            let mut h = h1.clone();
            let tok = exec.head(&h).unwrap()[0];
            h = exec.embed(&[tok], 1).unwrap();
            for l in 0..geo.n_layers {
                h = exec.layer_decode(l, &h, &mut [&mut c1]).unwrap();
            }
            black_box(h);
        });
        println!("{}", s.report());

        let b = geo.max_batch();
        let mut caches: Vec<KvCache> = (0..b).map(|_| cache.clone()).collect();
        let toks: Vec<i32> = (0..b as i32).collect();
        let s = bench(&format!("[{cfg}] decode iteration b={b}"), 2, 12, || {
            let mut h = exec.embed(&toks, b).unwrap();
            for l in 0..geo.n_layers {
                let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
                h = exec.layer_decode(l, &h, &mut refs).unwrap();
            }
            black_box(exec.head(&h).unwrap());
        });
        println!("{}", s.report());
    }
    Ok(())
}
