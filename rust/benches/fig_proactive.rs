//! Bench: regenerate Fig. 6 (proactive-only) at a reduced sweep.

use agent_xpu::config::default_soc;
use agent_xpu::figures::fig_proactive;
use agent_xpu::util::bench::black_box;

fn main() {
    let rates = [0.25, 1.0, 3.0];
    black_box(fig_proactive(&default_soc(), &rates, 45.0, 7).unwrap());
}
