//! Bench: regenerate the design-choice ablation table.

use agent_xpu::config::default_soc;
use agent_xpu::figures::fig_ablation;
use agent_xpu::util::bench::black_box;

fn main() {
    black_box(fig_ablation(&default_soc(), 45.0, 7).unwrap());
}
