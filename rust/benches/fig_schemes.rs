//! Bench: regenerate Fig. 4 (co-scheduling schemes) and time one full
//! scheme-(d) DES run.

use agent_xpu::config::{SchedulerConfig, default_soc, llama32_3b};
use agent_xpu::coordinator::AgentXpuEngine;
use agent_xpu::engine::Engine;
use agent_xpu::figures::{fig_schemes, mixed_trace};
use agent_xpu::util::bench::{bench, black_box};

fn main() {
    let soc = default_soc();
    black_box(fig_schemes(&soc).unwrap());

    let geo = llama32_3b();
    let trace = mixed_trace(1.0, 12.0, 30.0, 7, &geo);
    println!("\n[{} requests per engine run]", trace.len());
    let s = bench("agent.xpu full DES run (30s trace)", 2, 20, || {
        let mut e = AgentXpuEngine::synthetic(
            geo.clone(),
            soc.clone(),
            SchedulerConfig::default(),
        );
        black_box(e.run(trace.clone()).unwrap());
    });
    println!("{}", s.report());
}
