//! Bench: regenerate the §3.1 op-XPU affinity roofline and time the
//! annotation path that feeds it (`cargo bench --bench fig_affinity`).

use agent_xpu::config::{default_soc, llama32_3b};
use agent_xpu::figures::fig_affinity;
use agent_xpu::heg::{Annotator, ChunkSpec};
use agent_xpu::soc::XpuModel;
use agent_xpu::util::bench::{bench, black_box};

fn main() {
    let soc = default_soc();
    let j = fig_affinity(&soc);
    black_box(j);

    let ann = Annotator::new(
        llama32_3b(),
        soc.xpus.iter().cloned().map(XpuModel::new).collect(),
    );
    let chunk =
        ChunkSpec { variant: 256, valid: 256, pos: 512, dynamic: false, co_run: false };
    let s = bench("annotate prefill kernel (all XPUs)", 100, 5000, || {
        black_box(ann.prefill_kernel(&chunk));
    });
    println!("\n{}", s.report());
    let s = bench("annotate decode iter b=8", 100, 5000, || {
        black_box(ann.decode_iter(8, 512));
    });
    println!("{}", s.report());
}
