//! Bench: regenerate the §3.2 batching-effects table.

use agent_xpu::config::default_soc;
use agent_xpu::figures::fig_batching;
use agent_xpu::util::bench::black_box;

fn main() {
    black_box(fig_batching(&default_soc()));
}
