//! Bench: regenerate Fig. 7 (proactive-reactive mixed) at a reduced sweep.

use agent_xpu::config::default_soc;
use agent_xpu::figures::fig_mixed;
use agent_xpu::util::bench::black_box;

fn main() {
    let intervals = [6.0, 24.0];
    let rates = [0.5, 2.0];
    black_box(fig_mixed(&default_soc(), &intervals, &rates, 45.0, 7).unwrap());
}
