//! Scheduler micro-benchmarks (§6.5 "Synchronization Cost
//! Minimization"): the coordinator's per-decision costs must be
//! negligible next to kernel durations (ms).  The measured trajectory
//! lives in DESIGN.md §8; each case also lands as a strict-JSON row in
//! `results/BENCH_micro.json` (see `BenchStats::to_json`) so runs can
//! be diffed.  `bench macro` is the whole-run companion harness.

use agent_xpu::config::{SchedulerConfig, default_soc, llama32_3b};
use agent_xpu::coordinator::{AgentXpuEngine, decode_lanes, dispatch_check, resume_order};
use agent_xpu::engine::{EngineClock, EngineCore, ExecBridge, Phase, States, registry};
use agent_xpu::heg::{Annotator, ChunkSpec, ElasticPlan, plan_chunks};
use agent_xpu::model::gemv_cost;
use agent_xpu::soc::{KernelClass, LaunchSpec, SocSim, XpuModel};
use agent_xpu::util::bench::{BenchStats, bench, black_box};
use agent_xpu::util::json::Json;
use agent_xpu::workload::{Priority, Request};

fn main() {
    let mut rows: Vec<BenchStats> = vec![];
    let mut case = |s: BenchStats| {
        println!("{}", s.report());
        rows.push(s);
    };
    let soc = default_soc();
    let cfg = SchedulerConfig::default();
    let geo = llama32_3b();
    let ann = Annotator::new(
        geo.clone(),
        soc.xpus.iter().cloned().map(XpuModel::new).collect(),
    );

    // Algorithm 1 decision latency under an active kernel
    let mut sim = SocSim::new(&soc);
    let t = sim.xpus[1].timing(&gemv_cost(4096, 4096));
    sim.launch(1, LaunchSpec { timing: t, class: KernelClass::Proactive });
    let cand = ann.prefill_kernel(&ChunkSpec {
        variant: 256,
        valid: 256,
        pos: 0,
        dynamic: false,
        co_run: false,
    });
    let ct = *cand.timing_on(0);
    case(bench("dispatch_check (Algorithm 1)", 1000, 100_000, || {
        black_box(dispatch_check(&sim, &cfg, &ct, false));
    }));

    // decode batch formation over a 64-request state table
    let bridge = ExecBridge::synthetic(geo.clone());
    let mut states = States::default();
    for i in 0..64u64 {
        let req = Request {
            id: i,
            priority: if i % 7 == 0 { Priority::Reactive } else { Priority::Proactive },
            arrival_us: i as f64,
            prompt: vec![1; 200],
            max_new_tokens: 8,
            profile: "bench".into(),
            flow: None,
        };
        let mut st = bridge.init_state(req, 512);
        if i % 2 == 0 {
            st.phase = Phase::Decoding;
        }
        states.insert(i, st);
    }
    let mut lanes: Vec<u64> = vec![];
    case(bench("decode_lanes over 64 requests (reused lane buf)", 1000, 50_000, || {
        black_box(decode_lanes(&states, 8, true, &mut lanes));
    }));

    let mut cands: Vec<u64> =
        states.values().filter(|s| s.phase == Phase::Prefilling).map(|s| s.id()).collect();
    case(bench("resume_order over 32 candidates", 200, 10_000, || {
        resume_order(&states, &mut cands, &ann, 0, 1e6, 2e9, true);
        black_box(&cands);
    }));

    // resume_order at backlog scale: ETC is now precomputed once per
    // candidate (a keyed vec) instead of re-derived inside the sort
    // comparator — O(n) chunk walks, not O(n log n) — so even a deep
    // proactive backlog ranks within the §8 5 µs decision budget.
    let mut big_states = States::default();
    for i in 0..256u64 {
        let req = Request {
            id: i,
            priority: Priority::Proactive,
            arrival_us: i as f64,
            prompt: vec![1; 100 + (i as usize * 53) % 1500],
            max_new_tokens: 8,
            profile: "bench".into(),
            flow: None,
        };
        let mut st = bridge.init_state(req, 512);
        st.enqueued_at_us = i as f64 * 17.0;
        big_states.insert(i, st);
    }
    let mut big_cands: Vec<u64> = big_states.keys().copied().collect();
    big_cands.sort_unstable();
    case(bench("resume_order over 256 candidates (ETC precomputed)", 100, 5_000, || {
        resume_order(&big_states, &mut big_cands, &ann, 0, 1e6, 2e9, true);
        black_box(&big_cands);
    }));

    case(bench("plan_chunks (2048-token prompt)", 1000, 100_000, || {
        black_box(plan_chunks(&geo, 2048, 512));
    }));

    // ElasticPlan::replan — the mid-flight re-tiling step the rebind
    // hook pays on every fold/split decision; must stay in the same
    // nanosecond class as plan_chunks (it is a plan rebuild + cursor
    // reset, no allocation beyond the chunk vec).
    let mut ep = ElasticPlan::plan(&geo, 512, 128, 0);
    case(bench("ElasticPlan::replan (512-token plan)", 1000, 100_000, || {
        ep.replan(&geo, 0, 128);
        black_box(&ep);
    }));

    // DES throughput: one kernel launch+finish cycle
    case(bench("DES launch+advance cycle", 1000, 100_000, || {
        let mut sim = SocSim::new(&soc);
        let t = sim.xpus[0].timing(&gemv_cost(512, 512));
        sim.launch(0, LaunchSpec { timing: t, class: KernelClass::Proactive });
        black_box(sim.advance_until(sim.now_us + 1e9));
    }));

    // control-path JSON (UDS protocol)
    let msg = r#"{"type":"generate","priority":"reactive","prompt":[1,2,3,4,5,6,7,8],"max_new_tokens":16}"#;
    case(bench("UDS request JSON parse", 1000, 100_000, || {
        black_box(Json::parse(msg).unwrap());
    }));

    // EngineCore::step() — one full decision point of the streaming
    // API (admissions + scheduling pass + event advance) on a live
    // 32-request mix.  This is the serving loop's inner cost and must
    // stay inside the §8 dispatch budget (< 5 µs).
    let mk_trace = || -> Vec<agent_xpu::workload::Request> {
        (0..32u64)
            .map(|i| Request {
                id: i,
                priority: if i % 4 == 0 { Priority::Reactive } else { Priority::Proactive },
                arrival_us: i as f64 * 50.0,
                prompt: vec![1; 64 + (i as usize * 37) % 400],
                max_new_tokens: 4 + (i as usize % 8),
                profile: "bench".into(),
                flow: None,
            })
            .collect()
    };
    let mut eng = AgentXpuEngine::synthetic(geo.clone(), soc.clone(), cfg.clone());
    eng.start(EngineClock::Virtual).unwrap();
    for r in mk_trace() {
        eng.submit(r).unwrap();
    }
    case(bench("EngineCore::step (agent.xpu, 32-req mix)", 500, 50_000, || {
        if !eng.has_work() {
            eng.start(EngineClock::Virtual).unwrap();
            for r in mk_trace() {
                eng.submit(r).unwrap();
            }
        }
        black_box(eng.step().unwrap());
    }));

    // Same decision point through the policy registry's boxed
    // `PolicyEngine` — the one dynamic-dispatch hop (`dyn EngineCore`
    // + the policy's hook calls) every harness and the server now pay.
    // Must stay indistinguishable from the concrete-type step above
    // (both inside the §8 5 µs budget).
    let mut dyn_eng: Box<dyn EngineCore + Send> =
        registry::build("agent-xpu", geo.clone(), soc.clone(), cfg.clone()).unwrap();
    dyn_eng.start(EngineClock::Virtual).unwrap();
    for r in mk_trace() {
        dyn_eng.submit(r).unwrap();
    }
    case(bench("PolicyEngine::step via dyn EngineCore (registry)", 500, 50_000, || {
        if !dyn_eng.has_work() {
            dyn_eng.start(EngineClock::Virtual).unwrap();
            for r in mk_trace() {
                dyn_eng.submit(r).unwrap();
            }
        }
        black_box(dyn_eng.step().unwrap());
    }));

    // Land every case as a strict-JSON row next to the macro bench's
    // BENCH_sched.json so micro runs can be diffed over time
    // (`--out <dir>`, default `results`).
    let out = agent_xpu::util::cli::Args::from_env()
        .map(|a| a.str_or("out", "results"))
        .unwrap_or_else(|_| "results".to_string());
    let doc = Json::obj()
        .set("name", "BENCH_micro")
        .set("rows", rows.iter().map(BenchStats::to_json).collect::<Vec<_>>());
    if let Err(e) = std::fs::create_dir_all(&out)
        .map_err(anyhow::Error::from)
        .and_then(|()| {
            let path = std::path::Path::new(&out).join("BENCH_micro.json");
            std::fs::write(&path, doc.to_string())?;
            println!("[written {path:?}]");
            Ok(())
        })
    {
        eprintln!("BENCH_micro.json not written: {e:#}");
    }
}
