//! Quickstart: load AOT artifacts, run one reactive request end-to-end
//! on the real PJRT runtime, print tokens + timings.
//!
//! ```sh
//! make artifacts            # once
//! cargo run --release --example quickstart [-- artifacts/tiny]
//! ```

use std::sync::Arc;
use std::time::Instant;

use agent_xpu::runtime::{KvCache, ModelExecutor, Runtime};

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts/tiny".into());
    println!("loading {dir} ...");
    let t0 = Instant::now();
    let rt = Arc::new(Runtime::load(&dir)?);
    println!(
        "loaded {} ({:.1}M params, {} compiled kernels) in {:.1}s",
        rt.geo.name,
        rt.geo.n_params() as f64 / 1e6,
        rt.manifest.artifacts.len(),
        t0.elapsed().as_secs_f64()
    );

    let exec = ModelExecutor::new(rt.clone());
    // a synthetic "user prompt" of token ids (no tokenizer — DESIGN.md §1)
    let prompt: Vec<i32> = (0..37).map(|i| (i * 13 + 5) % rt.geo.vocab as i32).collect();
    let chunk = rt.geo.chunk_sizes[rt.geo.chunk_sizes.len() - 1];

    let mut cache = KvCache::new(&rt.geo);
    let t1 = Instant::now();
    let hidden = exec.prefill(&prompt, chunk, &mut cache)?;
    let ttft = t1.elapsed();
    let t2 = Instant::now();
    let out = exec.decode(hidden, &mut cache, 16)?;
    let decode = t2.elapsed();

    println!("prompt ({} tokens): {prompt:?}", prompt.len());
    println!("generated (16 tokens): {out:?}");
    println!(
        "TTFT {:.1} ms  |  TPOT {:.1} ms  |  wall {:.1} ms",
        ttft.as_secs_f64() * 1e3,
        decode.as_secs_f64() * 1e3 / 15.0,
        (ttft + decode).as_secs_f64() * 1e3
    );
    println!("(timings here are real PJRT-CPU wall-clock; the paper-scale");
    println!(" virtual-SoC numbers come from `agent-xpu fig ...` — DESIGN.md §1)");
    Ok(())
}
