//! End-to-end validation driver (DESIGN.md §7, EXPERIMENTS.md): load the
//! small *real* model artifacts, replay a mixed agentic trace through
//! the full Agent.xpu stack with **real PJRT compute** (the DES provides
//! virtual SoC timing; every token is really generated), and report
//! reactive latency, proactive throughput, and energy.  A timing-only
//! run of the identical trace verifies that real compute does not change
//! scheduling decisions, and determinism is checked by replaying.
//!
//! ```sh
//! make artifacts
//! cargo run --release --example mixed_serving [-- artifacts/small]
//! ```

use std::sync::Arc;
use std::time::Instant;

use agent_xpu::config::{SchedulerConfig, default_soc};
use agent_xpu::coordinator::AgentXpuEngine;
use agent_xpu::engine::Engine;
use agent_xpu::runtime::{ModelExecutor, Runtime};
use agent_xpu::workload::{Priority, Request, WorkloadSpec, merge_traces, proactive_trace, profile, reactive_trace};

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts/small".into());
    println!("loading {dir} ...");
    let rt = Arc::new(Runtime::load(&dir)?);
    let geo = rt.geo.clone();
    println!(
        "model {} ({:.1}M params, max_seq {})",
        geo.name,
        geo.n_params() as f64 / 1e6,
        geo.max_seq
    );

    // a small real mixed workload (virtual-time arrivals)
    let trace: Vec<Request> = merge_traces(vec![
        proactive_trace(
            &WorkloadSpec {
                profile: profile("samsum").unwrap(),
                rate_per_s: 1.0,
                duration_s: 20.0,
                seed: 11,
                max_seq: geo.max_seq.min(256), // keep prompts modest for CPU wall-clock
            },
            geo.vocab,
            1,
        ),
        reactive_trace(
            &WorkloadSpec {
                profile: profile("bfcl").unwrap(),
                rate_per_s: 0.2,
                duration_s: 20.0,
                seed: 12,
                max_seq: geo.max_seq.min(256),
            },
            geo.vocab,
            1000,
        ),
    ]);
    let n_req = trace.len();
    let total_prompt: usize = trace.iter().map(|r| r.prompt_len()).sum();
    let total_out: usize = trace.iter().map(|r| r.max_new_tokens).sum();
    println!("trace: {n_req} requests, {total_prompt} prompt tokens, {total_out} output tokens");

    let soc = default_soc();
    let sched = SchedulerConfig::default();

    // 1) real-compute run: every kernel executes on PJRT
    let exec = Arc::new(ModelExecutor::new(rt));
    let mut real = AgentXpuEngine::real(exec, soc.clone(), sched.clone());
    let t0 = Instant::now();
    let rep_real = real.run(trace.clone())?;
    let wall = t0.elapsed().as_secs_f64();

    // 2) timing-only replay of the same trace: scheduling must agree
    let mut synth = AgentXpuEngine::synthetic(geo, soc, sched);
    let rep_synth = synth.run(trace.clone())?;

    // 3) determinism: a second real run yields identical virtual timing
    let dir2 = std::env::args().nth(1).unwrap_or_else(|| "artifacts/small".into());
    let rt2 = Arc::new(Runtime::load(&dir2)?);
    let mut real2 = AgentXpuEngine::real(Arc::new(ModelExecutor::new(rt2)), default_soc(), SchedulerConfig::default());
    let rep_real2 = real2.run(trace)?;

    let r = rep_real.class(Priority::Reactive);
    let p = rep_real.class(Priority::Proactive);
    println!("\n== end-to-end results (virtual SoC time; real numerics) ==");
    println!("reactive : {} reqs, norm-lat {:.2} ms/tok, TTFT {:.1} ms, TPOT {:.2} ms",
        r.finished, r.mean_norm_latency_ms, r.mean_ttft_ms, r.mean_tpot_ms);
    println!("proactive: {} reqs, norm-lat {:.2} ms/tok, {:.1} tok/s",
        p.finished, p.mean_norm_latency_ms, p.tokens_per_s);
    println!("energy   : {:.1} J total, {:.3} J/tok, peak {:.1} W",
        rep_real.total_energy_j, rep_real.joules_per_token(), rep_real.peak_power_w);
    println!("preempts : {}, backfills: {}", rep_real.preemptions, rep_real.backfills);
    println!("wall     : {wall:.1}s for {} generated tokens ({:.1} tok/s real PJRT-CPU)",
        rep_real.total_tokens(), rep_real.total_tokens() as f64 / wall);

    // consistency checks
    let dv = (rep_real.makespan_us - rep_synth.makespan_us).abs();
    anyhow::ensure!(
        dv < 1e-3,
        "real vs timing-only makespan diverged by {dv} µs"
    );
    anyhow::ensure!(
        (rep_real.makespan_us - rep_real2.makespan_us).abs() < 1e-3,
        "re-run not deterministic"
    );
    for (a, b) in rep_real.reqs.iter().zip(&rep_real2.reqs) {
        anyhow::ensure!(a.first_token_us == b.first_token_us, "ttft mismatch req {}", a.id);
    }
    println!("\n[checks] real==timing-only schedule: OK; deterministic replay: OK");
    Ok(())
}
