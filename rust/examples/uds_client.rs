//! UDS client for the serving frontend (paper §7).
//!
//! ```sh
//! # terminal 1:
//! cargo run --release --bin agent-xpu -- serve --artifacts artifacts/tiny
//! # terminal 2:
//! cargo run --release --example uds_client [-- /tmp/agent-xpu.sock]
//! ```

use agent_xpu::server::client_generate;
use agent_xpu::workload::Priority;

fn main() -> anyhow::Result<()> {
    let socket = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "/tmp/agent-xpu.sock".into());
    // a reactive question...
    let prompt: Vec<i32> = (0..24).map(|i| (i * 11 + 2) % 512).collect();
    let (tokens, ttft, total) =
        client_generate(&socket, &prompt, Priority::Reactive, 12)?;
    println!("reactive: {} tokens in {total:.1} ms (TTFT {ttft:.1} ms)", tokens.len());
    println!("tokens: {tokens:?}");
    // ...and a background proactive call
    let prompt: Vec<i32> = (0..64).map(|i| (i * 7 + 9) % 512).collect();
    let (tokens, ttft, total) =
        client_generate(&socket, &prompt, Priority::Proactive, 8)?;
    println!("proactive: {} tokens in {total:.1} ms (TTFT {ttft:.1} ms)", tokens.len());
    Ok(())
}
