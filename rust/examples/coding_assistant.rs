//! The paper's motivating scenario (§1): an LLM-powered coding
//! assistant.  A *proactive* agent silently indexes the repository and
//! drafts summaries in the background; a *reactive* agent answers the
//! developer's questions on demand.  Both hit the same on-device LLM.
//!
//! This example replays the scenario against the virtual SoC at
//! Llama-3.2-3B scale and contrasts Agent.xpu with the llama.cpp-like
//! baseline and the continuous-batching scheme, printing the
//! interference each developer question experiences.
//!
//! ```sh
//! cargo run --release --example coding_assistant
//! ```

use agent_xpu::baselines::{CpuFcfsEngine, Scheme, SingleXpuEngine};
use agent_xpu::config::{SchedulerConfig, default_soc, llama32_3b};
use agent_xpu::coordinator::AgentXpuEngine;
use agent_xpu::engine::Engine;
use agent_xpu::workload::{Priority, Request};

fn scenario() -> Vec<Request> {
    let mut trace = vec![];
    // proactive: the indexer wakes every ~2.5s to digest a source file
    // (long context, short summary)
    for i in 0..12u64 {
        trace.push(Request {
            id: i,
            priority: Priority::Proactive,
            arrival_us: i as f64 * 2.5e6,
            prompt: vec![7; 900],
            max_new_tokens: 40,
            profile: "repo-indexer".into(),
            flow: None,
        });
    }
    // reactive: the developer asks three questions while the indexer runs
    for (k, (t, plen, out)) in
        [(4.0e6, 420usize, 60usize), (14.0e6, 250, 40), (24.0e6, 610, 80)]
            .iter()
            .enumerate()
    {
        trace.push(Request {
            id: 100 + k as u64,
            priority: Priority::Reactive,
            arrival_us: *t,
            prompt: vec![3; *plen],
            max_new_tokens: *out,
            profile: "dev-question".into(),
            flow: None,
        });
    }
    trace
}

fn main() -> anyhow::Result<()> {
    let geo = llama32_3b();
    let soc = default_soc();
    println!("coding-assistant scenario: 12 proactive indexing calls + 3 developer questions\n");
    println!(
        "{:<30} {:>14} {:>14} {:>14} {:>12} {:>10}",
        "engine", "Q1 TTFT (ms)", "Q2 TTFT (ms)", "Q3 TTFT (ms)", "indexer tok/s", "J/tok"
    );
    let mut run = |name: &str, rep: agent_xpu::metrics::RunReport| {
        let q = |id: u64| {
            rep.reqs
                .iter()
                .find(|m| m.id == id)
                .and_then(|m| m.ttft_us())
                .map(|t| format!("{:.0}", t / 1e3))
                .unwrap_or_else(|| "-".into())
        };
        let pro = rep.class(Priority::Proactive);
        println!(
            "{:<30} {:>14} {:>14} {:>14} {:>12.1} {:>10.2}",
            name,
            q(100),
            q(101),
            q(102),
            pro.tokens_per_s,
            rep.joules_per_token()
        );
    };

    run(
        "agent.xpu",
        AgentXpuEngine::synthetic(geo.clone(), soc.clone(), SchedulerConfig::default())
            .run(scenario())?,
    );
    run(
        "llama.cpp-like (CPU FCFS)",
        CpuFcfsEngine::new(geo.clone(), soc.clone(), 4).run(scenario())?,
    );
    run(
        "continuous batching (iGPU)",
        SingleXpuEngine::new(geo, soc, Scheme::ContinuousBatching).run(scenario())?,
    );
    println!("\nAgent.xpu answers the developer at interactive latency while the");
    println!("indexer keeps its throughput — the paper's Fig. 1 promise.");
    Ok(())
}
