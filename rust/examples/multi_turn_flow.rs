//! Multi-turn flow demo: a reactive chat session whose turns reuse the
//! session KV cache over the engine API — turn *k+1* prefills only its
//! delta tokens — compared against the single-XPU continuous-batching
//! baseline running the *same* flow trace with full-prefix recompute.
//!
//! ```sh
//! cargo run --release --example multi_turn_flow
//! ```
//!
//! Timing-only DES: no artifacts needed (DESIGN.md §1).

use agent_xpu::baselines::{Scheme, SingleXpuEngine};
use agent_xpu::config::{SchedulerConfig, default_soc, llama32_3b};
use agent_xpu::coordinator::AgentXpuEngine;
use agent_xpu::engine::Engine;
use agent_xpu::workload::{FlowSpec, Priority, flatten_flows, flow_trace, profile};

fn main() -> anyhow::Result<()> {
    let geo = llama32_3b();
    // one stream of lmsys-shaped chat flows: 3-5 turns each, ~8 s of
    // user think-time between turns
    let flows = flow_trace(
        &FlowSpec {
            profile: profile("lmsys").unwrap(),
            flow_rate_per_s: 0.05,
            think_time_s: 8.0,
            turns: (3, 5),
            duration_s: 120.0,
            seed: 7,
            max_seq: geo.max_seq,
        },
        Priority::Reactive,
        geo.vocab,
        0,
        0,
    );
    println!(
        "{} flows, {} turns total",
        flows.len(),
        flows.iter().map(|f| f.total_turns()).sum::<usize>()
    );
    let trace = flatten_flows(flows);

    let mut agent =
        AgentXpuEngine::synthetic(geo.clone(), default_soc(), SchedulerConfig::default());
    let ra = agent.run(trace.clone())?;
    let mut single = SingleXpuEngine::new(geo, default_soc(), Scheme::ContinuousBatching);
    let rs = single.run(trace)?;

    for rep in [&ra, &rs] {
        println!(
            "\n[{}]\n  flows finished:      {}\n  mean flow e2e:       {:.0} ms \
             (incl. think-time)\n  mean turn TTFT:      {:.1} ms\n  \
             prefix-cache hits:   {:.0}%\n  reused prefix toks:  {}\n  \
             recomputed toks:     {}",
            rep.engine,
            rep.flows().iter().filter(|f| f.finished).count(),
            rep.mean_flow_e2e_ms(),
            rep.flows().iter().map(|f| f.mean_turn_ttft_ms).sum::<f64>()
                / rep.flows().len().max(1) as f64,
            rep.prefix_cache_hit_rate() * 100.0,
            rep.reused_prefix_tokens(),
            rep.recomputed_prefill_tokens(),
        );
    }
    let saved = rs.recomputed_prefill_tokens() as f64 - ra.recomputed_prefill_tokens() as f64;
    println!(
        "\ncross-turn KV reuse skipped {:.0}% of the baseline's prefill work",
        100.0 * saved / rs.recomputed_prefill_tokens().max(1) as f64
    );
    // per-turn view of the first flow
    if let Some(f) = ra.flows().first() {
        println!("\nfirst flow (id {}):", f.flow_id);
        for m in ra.reqs.iter().filter(|m| m.flow_id == Some(f.flow_id)) {
            println!(
                "  turn {}: prompt {:>4} tok, cached {:>4}, prefilled {:>4}, \
                 TTFT {:>6.1} ms",
                m.turn_idx,
                m.input_len,
                m.cached_prefix_len,
                m.prefill_tokens,
                m.ttft_us().unwrap_or(f64::NAN) / 1e3,
            );
        }
    }
    Ok(())
}
