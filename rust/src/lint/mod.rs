//! `agent-xpu lint` — the repo-native architectural lint pass
//! (DESIGN.md §10).
//!
//! Statically enforces the invariants every correctness claim in this
//! reproduction rests on: the deterministic core never reads wall
//! clocks or iterates unordered maps order-sensitively, locks are
//! poison-safe, the scheduler hot path cannot panic, `unsafe` carries
//! `// SAFETY:` justifications, serializers cannot leak non-finite
//! JSON, and every `SchedPolicy`/`RoutePolicy` impl is wired into its
//! registry so the property-test loops cover it.
//!
//! Zero new dependencies, in the crate's own-your-tools style
//! (`util/json.rs`, `util/fxhash.rs`): a token-level scanner
//! ([`lexer`]), a rule engine over short token patterns ([`rules`]),
//! and a checked-in module-scope config ([`config`], `rust/lint.json`).
//! Per-site escapes are `lint:allow` comments — the marker, the rule
//! name in parentheses, then a reason — on the offending line or the
//! line above.  The reason is mandatory and the report records every
//! use, so the allowlist cannot grow silently.

pub mod config;
pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Json;

pub use config::LintConfig;
pub use rules::{AllowRec, Diag, RULES};

/// An allow that suppressed at least one diagnostic.
#[derive(Debug, Clone)]
pub struct UsedAllow {
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub reason: String,
}

pub struct LintReport {
    pub files_scanned: usize,
    /// Un-allowlisted violations, sorted by (file, line).
    pub violations: Vec<Diag>,
    /// Allows that suppressed a diagnostic.
    pub allowed: Vec<UsedAllow>,
    /// Allow comments that matched nothing (stale escapes — reported,
    /// not fatal).
    pub unused_allows: Vec<AllowRec>,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }

    /// Strict-JSON report for the CI gate (RFC 8259 — `Json` cannot
    /// emit NaN/Infinity).
    pub fn to_json(&self) -> Json {
        let viol: Vec<Json> = self
            .violations
            .iter()
            .map(|v| {
                Json::obj()
                    .set("file", v.file.as_str())
                    .set("line", v.line as i64)
                    .set("rule", v.rule)
                    .set("message", v.msg.as_str())
            })
            .collect();
        let allowed: Vec<Json> = self
            .allowed
            .iter()
            .map(|a| {
                Json::obj()
                    .set("file", a.file.as_str())
                    .set("line", a.line as i64)
                    .set("rule", a.rule.as_str())
                    .set("reason", a.reason.as_str())
            })
            .collect();
        let unused: Vec<Json> = self
            .unused_allows
            .iter()
            .map(|a| {
                Json::obj()
                    .set("file", a.file.as_str())
                    .set("line", a.line as i64)
                    .set("rule", a.rule.as_str())
            })
            .collect();
        let rules: Vec<Json> = RULES.iter().map(|r| Json::Str(r.to_string())).collect();
        Json::obj()
            .set("files_scanned", self.files_scanned as i64)
            .set("rules", Json::Arr(rules))
            .set("violation_count", self.violations.len() as i64)
            .set("violations", Json::Arr(viol))
            .set("allow_count", self.allowed.len() as i64)
            .set("allowed", Json::Arr(allowed))
            .set("unused_allow_count", self.unused_allows.len() as i64)
            .set("unused_allows", Json::Arr(unused))
    }
}

/// Scan one source string as if it lived at `rel` — the unit the
/// fixture tests drive directly.
pub fn scan_source(rel: &str, src: &str, cfg: &LintConfig) -> rules::FileScan {
    rules::scan_file(rel, src, cfg)
}

/// Walk `paths` under `root`, run every rule, resolve the cross-file
/// registry-coverage rule, and apply the allowlist.
pub fn run(root: &Path, paths: &[String], cfg: &LintConfig) -> Result<LintReport> {
    let mut files: Vec<String> = Vec::new();
    for p in paths {
        collect_rs(root, p, cfg, &mut files)?;
    }
    files.sort();
    files.dedup();

    let mut diags: Vec<Diag> = Vec::new();
    let mut allows: Vec<AllowRec> = Vec::new();
    let mut impls: Vec<rules::ImplRec> = Vec::new();
    for rel in &files {
        let src = std::fs::read_to_string(root.join(rel))
            .with_context(|| format!("reading {rel}"))?;
        let mut scan = rules::scan_file(rel, &src, cfg);
        diags.append(&mut scan.diags);
        allows.append(&mut scan.allows);
        impls.append(&mut scan.impls);
    }

    // registry-coverage: every policy/router impl must be named in its
    // registry file, or the property-test loops silently skip it.
    let sched = registry_idents(root, &cfg.sched_registry)?;
    let route = registry_idents(root, &cfg.route_registry)?;
    for imp in &impls {
        let (set, reg) = if imp.trait_name == "SchedPolicy" {
            (&sched, cfg.sched_registry.as_str())
        } else {
            (&route, cfg.route_registry.as_str())
        };
        if !set.contains(&imp.type_name) {
            diags.push(Diag {
                file: imp.file.clone(),
                line: imp.line,
                rule: "registry-coverage",
                msg: format!(
                    "`{}` implements `{}` but is not named in {reg} — register it \
                     so the registry-driven test loops cover it",
                    imp.type_name, imp.trait_name
                ),
            });
        }
    }

    // allowlist resolution: an allow covers its own line and the line
    // below (comment-above style), for its named rule only.
    let mut used = vec![false; allows.len()];
    let mut violations: Vec<Diag> = Vec::new();
    let mut allowed: Vec<UsedAllow> = Vec::new();
    for d in diags {
        let hit = allows.iter().position(|a| {
            a.file == d.file
                && a.rule == d.rule
                && (a.line == d.line || a.line + 1 == d.line)
        });
        match hit {
            Some(ix) => {
                if !used[ix] {
                    used[ix] = true;
                    allowed.push(UsedAllow {
                        file: allows[ix].file.clone(),
                        line: allows[ix].line,
                        rule: allows[ix].rule.clone(),
                        reason: allows[ix].reason.clone(),
                    });
                }
            }
            None => violations.push(d),
        }
    }
    let unused_allows: Vec<AllowRec> = allows
        .iter()
        .enumerate()
        .filter(|(ix, _)| !used[*ix])
        .map(|(_, a)| a.clone())
        .collect();
    violations.sort_by(|a, b| {
        a.file.cmp(&b.file).then(a.line.cmp(&b.line)).then(a.rule.cmp(b.rule))
    });
    Ok(LintReport { files_scanned: files.len(), violations, allowed, unused_allows })
}

/// Run with the checked-in config (`<root>/lint.json`) over its
/// default paths.
pub fn run_default(root: &Path) -> Result<LintReport> {
    let cfg = LintConfig::load_or_default(root)?;
    let paths = cfg.paths.clone();
    run(root, &paths, &cfg)
}

fn registry_idents(
    root: &Path,
    rel: &str,
) -> Result<std::collections::BTreeSet<String>> {
    let src = std::fs::read_to_string(root.join(rel))
        .with_context(|| format!("reading registry {rel}"))?;
    Ok(rules::ident_set(&src))
}

/// Recursively collect `.rs` files under `root/sub` as `/`-normalized
/// root-relative paths, honoring the exclude list.
fn collect_rs(
    root: &Path,
    sub: &str,
    cfg: &LintConfig,
    out: &mut Vec<String>,
) -> Result<()> {
    let full = root.join(sub);
    if full.is_file() {
        if sub.ends_with(".rs") && !excluded(sub, cfg) {
            out.push(sub.to_string());
        }
        return Ok(());
    }
    if !full.is_dir() {
        anyhow::bail!("lint path {sub:?} is neither a file nor a directory");
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(&full)
        .with_context(|| format!("walking {}", full.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        let name = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n,
            None => continue,
        };
        let rel = format!("{sub}/{name}");
        if excluded(&rel, cfg) {
            continue;
        }
        if path.is_dir() {
            collect_rs(root, &rel, cfg, out)?;
        } else if name.ends_with(".rs") {
            out.push(rel);
        }
    }
    Ok(())
}

fn excluded(rel: &str, cfg: &LintConfig) -> bool {
    cfg.exclude.iter().any(|e| rel.starts_with(e.as_str()) || rel.contains(e.as_str()))
}
