//! The rule catalog: seven architectural invariants checked over the
//! token stream of one file, plus the cross-file registry-coverage
//! records the runner resolves at the end.  See DESIGN.md §10 for the
//! catalog rationale and the how-to-add-a-rule walkthrough.

use std::collections::BTreeSet;

use super::config::{LintConfig, path_in};
use super::lexer::{Kind, Lexed, Tok, lex};

/// Every rule name the allowlist accepts.  `lint-allow` is the meta
/// rule for malformed allow comments and is not allowlistable itself.
pub const RULES: &[&str] = &[
    "no-wall-clock",
    "no-unordered-iteration",
    "lock-hygiene",
    "panic-free-hot-path",
    "safety-comments",
    "json-hygiene",
    "registry-coverage",
];

#[derive(Debug, Clone)]
pub struct Diag {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

/// A parsed per-site allow comment: the `lint:allow` marker, a rule
/// name in parentheses, and a mandatory reason.
#[derive(Debug, Clone)]
pub struct AllowRec {
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub reason: String,
}

/// An `impl SchedPolicy for X` / `impl RoutePolicy for X` site, checked
/// against the registries once every file has been scanned.
#[derive(Debug, Clone)]
pub struct ImplRec {
    pub file: String,
    pub line: u32,
    pub trait_name: String,
    pub type_name: String,
}

pub struct FileScan {
    pub diags: Vec<Diag>,
    pub allows: Vec<AllowRec>,
    pub impls: Vec<ImplRec>,
}

/// Run every per-file rule over `src` (at `/`-normalized path `rel`).
pub fn scan_file(rel: &str, src: &str, cfg: &LintConfig) -> FileScan {
    let lx = lex(src);
    let regions = test_regions(&lx.toks);
    let mut scan = FileScan { diags: Vec::new(), allows: Vec::new(), impls: Vec::new() };
    parse_allows(rel, &lx, &mut scan);
    rule_wall_clock(rel, &lx, &regions, cfg, &mut scan.diags);
    rule_unordered_iteration(rel, &lx, &regions, cfg, &mut scan.diags);
    rule_lock_hygiene(rel, &lx, &mut scan.diags);
    rule_panic_free(rel, &lx, &regions, cfg, &mut scan.diags);
    rule_safety_comments(rel, &lx, &mut scan.diags);
    rule_json_hygiene(rel, &lx, &regions, cfg, &mut scan.diags);
    collect_impls(rel, &lx, &regions, &mut scan.impls);
    scan
}

/// Collect the identifier set of a registry file (for coverage checks).
pub fn ident_set(src: &str) -> BTreeSet<String> {
    lex(src)
        .toks
        .into_iter()
        .filter(|t| t.kind == Kind::Ident)
        .map(|t| t.text)
        .collect()
}

// -- shared token helpers --------------------------------------------------

fn is(t: &[Tok], i: usize, s: &str) -> bool {
    t.get(i).map_or(false, |x| x.text == s)
}

fn ident_at(t: &[Tok], i: usize) -> Option<&str> {
    match t.get(i) {
        Some(x) if x.kind == Kind::Ident => Some(x.text.as_str()),
        _ => None,
    }
}

fn in_test(regions: &[(usize, usize)], i: usize) -> bool {
    regions.iter().any(|&(a, b)| i >= a && i <= b)
}

/// `i` points at `(`; returns the index just past its matching `)`.
fn skip_parens(t: &[Tok], i: usize) -> usize {
    let mut depth = 0i32;
    let mut j = i;
    while j < t.len() {
        match t[j].text.as_str() {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
            _ => {}
        }
        j += 1;
    }
    j
}

/// Token-index spans of `#[cfg(test)] mod …` bodies and `#[test] fn …`
/// bodies — code the determinism/panic rules exempt.
fn test_regions(t: &[Tok]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let mut i = 0usize;
    while i < t.len() {
        if !(is(t, i, "#") && is(t, i + 1, "[")) {
            i += 1;
            continue;
        }
        // span of this attribute
        let mut j = i + 2;
        let mut depth = 1i32;
        let mut has_test = false;
        let mut has_not = false;
        while j < t.len() && depth > 0 {
            match t[j].text.as_str() {
                "[" => depth += 1,
                "]" => depth -= 1,
                "test" if t[j].kind == Kind::Ident => has_test = true,
                "not" if t[j].kind == Kind::Ident => has_not = true,
                _ => {}
            }
            j += 1;
        }
        if !has_test || has_not {
            i = j;
            continue;
        }
        // skip further attributes, then modifiers, to the item keyword
        let mut k = j;
        while is(t, k, "#") && is(t, k + 1, "[") {
            let mut d = 1i32;
            k += 2;
            while k < t.len() && d > 0 {
                match t[k].text.as_str() {
                    "[" => d += 1,
                    "]" => d -= 1,
                    _ => {}
                }
                k += 1;
            }
        }
        let mut steps = 0;
        while k < t.len() && steps < 8 {
            match t[k].text.as_str() {
                "mod" | "fn" => break,
                "pub" | "async" | "unsafe" | "const" | "extern" | "(" | ")" | "crate"
                | "super" | "in" => {
                    k += 1;
                    steps += 1;
                }
                _ => break,
            }
        }
        if !(is(t, k, "mod") || is(t, k, "fn")) {
            i = j;
            continue;
        }
        // body: first `{` before any `;` (a `mod x;` has no body here)
        let mut e = k;
        while e < t.len() && t[e].text != "{" && t[e].text != ";" {
            e += 1;
        }
        if e < t.len() && t[e].text == "{" {
            let mut d = 0i32;
            let mut m = e;
            while m < t.len() {
                match t[m].text.as_str() {
                    "{" => d += 1,
                    "}" => {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                m += 1;
            }
            regions.push((k, m));
        }
        i = j;
    }
    regions
}

// -- lint:allow parsing ----------------------------------------------------

const ALLOW_MARK: &str = "lint:allow(";

fn parse_allows(rel: &str, lx: &Lexed, scan: &mut FileScan) {
    for (&line, text) in &lx.comment_text {
        let starts: Vec<usize> = text.match_indices(ALLOW_MARK).map(|(p, _)| p).collect();
        for (n, &start) in starts.iter().enumerate() {
            let after = &text[start + ALLOW_MARK.len()..];
            let close = match after.find(')') {
                Some(c) => c,
                None => {
                    scan.diags.push(Diag {
                        file: rel.to_string(),
                        line,
                        rule: "lint-allow",
                        msg: "malformed allow comment: missing `)`".to_string(),
                    });
                    continue;
                }
            };
            let rule = after[..close].trim().to_string();
            let mut tail = &after[close + 1..];
            if let Some(&next) = starts.get(n + 1) {
                let rel_next = next - (start + ALLOW_MARK.len());
                if rel_next > close {
                    tail = &after[close + 1..rel_next];
                }
            }
            let reason = tail.trim().trim_end_matches("*/").trim().to_string();
            if !RULES.contains(&rule.as_str()) {
                scan.diags.push(Diag {
                    file: rel.to_string(),
                    line,
                    rule: "lint-allow",
                    msg: format!("allow names unknown rule {rule:?}"),
                });
                continue;
            }
            if reason.is_empty() {
                scan.diags.push(Diag {
                    file: rel.to_string(),
                    line,
                    rule: "lint-allow",
                    msg: format!("allow for {rule:?} must carry a reason"),
                });
                continue;
            }
            scan.allows.push(AllowRec { file: rel.to_string(), line, rule, reason });
        }
    }
}

// -- no-wall-clock ---------------------------------------------------------

fn rule_wall_clock(
    rel: &str,
    lx: &Lexed,
    regions: &[(usize, usize)],
    cfg: &LintConfig,
    out: &mut Vec<Diag>,
) {
    if !cfg.in_core(rel) || path_in(rel, &cfg.wall_clock_allowed) {
        return;
    }
    let t = &lx.toks;
    for i in 0..t.len() {
        let name = match ident_at(t, i) {
            Some(n @ ("Instant" | "SystemTime")) => n,
            _ => continue,
        };
        if !(is(t, i + 1, ":") && is(t, i + 2, ":") && is(t, i + 3, "now")) {
            continue;
        }
        if in_test(regions, i) {
            continue;
        }
        out.push(Diag {
            file: rel.to_string(),
            line: t[i].line,
            rule: "no-wall-clock",
            msg: format!(
                "`{name}::now()` in the deterministic core — schedules must read \
                 the engine clock, never the wall"
            ),
        });
    }
}

// -- no-unordered-iteration ------------------------------------------------

/// Iterator adapters that preserve the (un)orderedness question.
const TRANSPARENT: &[&str] =
    &["filter", "map", "filter_map", "copied", "cloned", "flat_map", "flatten", "inspect"];
/// Terminals whose result is independent of iteration order.
const ORDER_FREE: &[&str] = &["any", "all", "count", "sum", "product", "min", "max"];
/// Map/set iteration entry points.
const ITER_METHODS: &[&str] = &[
    "keys",
    "values",
    "values_mut",
    "into_keys",
    "into_values",
    "iter",
    "iter_mut",
    "into_iter",
    "drain",
];

/// Names declared with an unordered map/set type anywhere in this file
/// (struct fields, fn params, let bindings — `name: …MapType…`).
fn map_typed_names(t: &[Tok], cfg: &LintConfig) -> BTreeSet<String> {
    let mut names = BTreeSet::new();
    for i in 1..t.len() {
        if t[i].text != ":" || is(t, i + 1, ":") || t[i - 1].text == ":" {
            continue;
        }
        let name = match ident_at(t, i - 1) {
            Some(n) => n,
            None => continue,
        };
        let mut angle = 0i32;
        let mut j = i + 1;
        let mut steps = 0;
        while j < t.len() && steps < 60 {
            let s = t[j].text.as_str();
            match s {
                "<" => angle += 1,
                ">" => {
                    if angle == 0 {
                        break;
                    }
                    angle -= 1;
                }
                "," | ";" | "=" | "{" | "}" | ")" if angle == 0 => break,
                _ => {
                    if t[j].kind == Kind::Ident && cfg.is_map_type(s) {
                        names.insert(name.to_string());
                        break;
                    }
                }
            }
            j += 1;
            steps += 1;
        }
    }
    names
}

fn rule_unordered_iteration(
    rel: &str,
    lx: &Lexed,
    regions: &[(usize, usize)],
    cfg: &LintConfig,
    out: &mut Vec<Diag>,
) {
    if !cfg.in_core(rel) {
        return;
    }
    let t = &lx.toks;
    let names = map_typed_names(t, cfg);
    // method-call iteration: `recv.values()…`
    for i in 1..t.len() {
        if t[i].text != "." {
            continue;
        }
        let method = match ident_at(t, i + 1) {
            Some(m) if ITER_METHODS.contains(&m) => m,
            _ => continue,
        };
        if !is(t, i + 2, "(") {
            continue;
        }
        let recv = match ident_at(t, i - 1) {
            Some(r) if names.contains(r) => r,
            _ => continue,
        };
        if in_test(regions, i) {
            continue;
        }
        if chain_is_order_free(t, skip_parens(t, i + 2)) {
            continue;
        }
        out.push(Diag {
            file: rel.to_string(),
            line: t[i + 1].line,
            rule: "no-unordered-iteration",
            msg: format!(
                "`{recv}.{method}()` iterates an unordered map in the deterministic \
                 core — sort by a total key or reduce order-insensitively"
            ),
        });
    }
    // for-loop iteration: `for x in &recv {`
    for i in 0..t.len() {
        if ident_at(t, i) != Some("for") || is(t, i + 1, "<") {
            continue;
        }
        if (i.saturating_sub(12)..i).any(|b| t[b].text == "impl") {
            continue; // `impl Trait for Type`
        }
        if in_test(regions, i) {
            continue;
        }
        // find `in` at depth 0 (the pattern may contain parens/tuples)
        let mut j = i + 1;
        let mut depth = 0i32;
        let mut found_in = false;
        while j < t.len() && j < i + 40 {
            match t[j].text.as_str() {
                "(" | "[" => depth += 1,
                ")" | "]" => depth -= 1,
                "in" if depth == 0 && t[j].kind == Kind::Ident => {
                    found_in = true;
                    break;
                }
                "{" | ";" => break,
                _ => {}
            }
            j += 1;
        }
        if !found_in {
            continue;
        }
        // the iterated expression, up to its `{`; call chains are
        // handled by the method pass above
        let mut last_ident: Option<&str> = None;
        let mut has_call = false;
        let mut k = j + 1;
        while k < t.len() && k < j + 40 {
            match t[k].text.as_str() {
                "{" | ";" => break,
                "(" => has_call = true,
                _ => {
                    if t[k].kind == Kind::Ident {
                        last_ident = Some(t[k].text.as_str());
                    }
                }
            }
            k += 1;
        }
        if has_call {
            continue;
        }
        if let Some(name) = last_ident {
            if names.contains(name) {
                out.push(Diag {
                    file: rel.to_string(),
                    line: t[i].line,
                    rule: "no-unordered-iteration",
                    msg: format!(
                        "`for … in {name}` iterates an unordered map in the \
                         deterministic core — sort by a total key first"
                    ),
                });
            }
        }
    }
}

/// Follow a method chain from token `k` (just past a `)`); true when it
/// reduces through order-preserving adapters to an order-free terminal.
fn chain_is_order_free(t: &[Tok], mut k: usize) -> bool {
    loop {
        if !is(t, k, ".") {
            return false;
        }
        let name = match ident_at(t, k + 1) {
            Some(n) => n,
            None => return false,
        };
        if !is(t, k + 2, "(") {
            return false;
        }
        if ORDER_FREE.contains(&name) {
            return true;
        }
        if !TRANSPARENT.contains(&name) {
            return false;
        }
        k = skip_parens(t, k + 2);
    }
}

// -- lock-hygiene ----------------------------------------------------------

fn rule_lock_hygiene(rel: &str, lx: &Lexed, out: &mut Vec<Diag>) {
    let t = &lx.toks;
    for i in 0..t.len() {
        if t[i].text == "."
            && is(t, i + 1, "lock")
            && is(t, i + 2, "(")
            && is(t, i + 3, ")")
            && is(t, i + 4, ".")
            && is(t, i + 5, "unwrap")
            && is(t, i + 6, "(")
            && is(t, i + 7, ")")
        {
            out.push(Diag {
                file: rel.to_string(),
                line: t[i + 5].line,
                rule: "lock-hygiene",
                msg: "`.lock().unwrap()` dies on a poisoned mutex — use \
                      `server::rt::relock`"
                    .to_string(),
            });
        }
    }
}

// -- panic-free-hot-path ---------------------------------------------------

fn rule_panic_free(
    rel: &str,
    lx: &Lexed,
    regions: &[(usize, usize)],
    cfg: &LintConfig,
    out: &mut Vec<Diag>,
) {
    if !path_in(rel, &cfg.panic_free) {
        return;
    }
    let t = &lx.toks;
    for i in 0..t.len() {
        if in_test(regions, i) {
            continue;
        }
        let (line, what) = if t[i].text == "."
            && is(t, i + 1, "unwrap")
            && is(t, i + 2, "(")
            && is(t, i + 3, ")")
        {
            (t[i + 1].line, "`.unwrap()`")
        } else if t[i].text == "." && is(t, i + 1, "expect") && is(t, i + 2, "(") {
            (t[i + 1].line, "`.expect()`")
        } else if ident_at(t, i) == Some("panic") && is(t, i + 1, "!") {
            (t[i].line, "`panic!`")
        } else if ident_at(t, i) == Some("todo") && is(t, i + 1, "!") {
            (t[i].line, "`todo!`")
        } else {
            continue;
        };
        out.push(Diag {
            file: rel.to_string(),
            line,
            rule: "panic-free-hot-path",
            msg: format!(
                "{what} on the scheduler hot path — return an error or encode the \
                 invariant, and allowlist only with the invariant spelled out"
            ),
        });
    }
}

// -- safety-comments -------------------------------------------------------

fn rule_safety_comments(rel: &str, lx: &Lexed, out: &mut Vec<Diag>) {
    let t = &lx.toks;
    // lines that an upward scan may step over: `unsafe impl` headers
    // (a shared SAFETY comment may cover a Send+Sync pair) and
    // attribute lines.
    let mut skippable: BTreeSet<u32> = BTreeSet::new();
    for i in 0..t.len() {
        if ident_at(t, i) == Some("unsafe") && is(t, i + 1, "impl") {
            skippable.insert(t[i].line);
        }
        if t[i].text == "#" && is(t, i + 1, "[") {
            let end = skip_brackets(t, i + 1);
            let last_line = t.get(end.saturating_sub(1)).map_or(t[i].line, |x| x.line);
            for l in t[i].line..=last_line {
                skippable.insert(l);
            }
        }
    }
    for i in 0..t.len() {
        if ident_at(t, i) != Some("unsafe") {
            continue;
        }
        let target = if is(t, i + 1, "{") {
            "block"
        } else if is(t, i + 1, "impl") {
            "impl"
        } else {
            continue;
        };
        if has_safety_comment(lx, &skippable, t[i].line) {
            continue;
        }
        out.push(Diag {
            file: rel.to_string(),
            line: t[i].line,
            rule: "safety-comments",
            msg: format!("`unsafe {target}` without a `// SAFETY:` justification"),
        });
    }
}

/// `i` points at `[`; returns the index just past its matching `]`.
fn skip_brackets(t: &[Tok], i: usize) -> usize {
    let mut depth = 0i32;
    let mut j = i;
    while j < t.len() {
        match t[j].text.as_str() {
            "[" => depth += 1,
            "]" => {
                depth -= 1;
                if depth == 0 {
                    return j + 1;
                }
            }
            _ => {}
        }
        j += 1;
    }
    j
}

fn has_safety_comment(lx: &Lexed, skippable: &BTreeSet<u32>, line: u32) -> bool {
    // trailing comment on the unsafe line itself
    if lx.comment_on(line).is_some_and(|c| c.contains("SAFETY:")) {
        return true;
    }
    let mut l = line.saturating_sub(1);
    while l >= 1 {
        let is_comment = lx.comment_lines.contains(&l) && !lx.code_lines.contains(&l);
        if is_comment {
            if lx.comment_on(l).is_some_and(|c| c.contains("SAFETY:")) {
                return true;
            }
        } else if !skippable.contains(&l) {
            return false;
        }
        l -= 1;
    }
    false
}

// -- json-hygiene ----------------------------------------------------------

fn rule_json_hygiene(
    rel: &str,
    lx: &Lexed,
    regions: &[(usize, usize)],
    cfg: &LintConfig,
    out: &mut Vec<Diag>,
) {
    if !path_in(rel, &cfg.json_hygiene) {
        return;
    }
    let t = &lx.toks;
    for i in 0..t.len() {
        if ident_at(t, i) == Some("Json")
            && is(t, i + 1, ":")
            && is(t, i + 2, ":")
            && is(t, i + 3, "Num")
            && is(t, i + 4, "(")
            && !in_test(regions, i)
        {
            out.push(Diag {
                file: rel.to_string(),
                line: t[i].line,
                rule: "json-hygiene",
                msg: "raw `Json::Num(…)` in a serializer — route floats through \
                      `Json::num_or_null` so NaN/Infinity degrade to null"
                    .to_string(),
            });
        }
    }
}

// -- registry-coverage (collection half) -----------------------------------

fn collect_impls(rel: &str, lx: &Lexed, regions: &[(usize, usize)], out: &mut Vec<ImplRec>) {
    let t = &lx.toks;
    for i in 0..t.len() {
        if ident_at(t, i) != Some("impl") || in_test(regions, i) {
            continue;
        }
        let mut j = i + 1;
        if is(t, j, "<") {
            let mut angle = 0i32;
            while j < t.len() {
                match t[j].text.as_str() {
                    "<" => angle += 1,
                    ">" => {
                        angle -= 1;
                        if angle == 0 {
                            j += 1;
                            break;
                        }
                    }
                    _ => {}
                }
                j += 1;
            }
        }
        // trait path up to `for`
        let mut trait_last: Option<&str> = None;
        let mut found_for = false;
        let mut steps = 0;
        while j < t.len() && steps < 16 {
            match t[j].text.as_str() {
                "for" if t[j].kind == Kind::Ident => {
                    found_for = true;
                    j += 1;
                    break;
                }
                "{" | "where" | "<" => break,
                _ => {
                    if t[j].kind == Kind::Ident {
                        trait_last = Some(t[j].text.as_str());
                    }
                }
            }
            j += 1;
            steps += 1;
        }
        let trait_name = match trait_last {
            Some(tr @ ("SchedPolicy" | "RoutePolicy")) if found_for => tr,
            _ => continue,
        };
        // implementing type: last path segment before `{`/`<`/`where`
        let mut ty: Option<&str> = None;
        let mut steps = 0;
        while j < t.len() && steps < 12 {
            match t[j].text.as_str() {
                "{" | "where" | "<" => break,
                _ => {
                    if t[j].kind == Kind::Ident && t[j].text != "dyn" {
                        ty = Some(t[j].text.as_str());
                    }
                }
            }
            j += 1;
            steps += 1;
        }
        if let Some(ty) = ty {
            out.push(ImplRec {
                file: rel.to_string(),
                line: t[i].line,
                trait_name: trait_name.to_string(),
                type_name: ty.to_string(),
            });
        }
    }
}
