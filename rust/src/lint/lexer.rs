//! Token-level Rust scanner for the architectural lint pass.
//!
//! In the crate's own-your-tools style (`util/json.rs`,
//! `util/fxhash.rs`): a small, dependency-free lexer that is exact
//! about the things the rules need — comments (kept out of the token
//! stream but retained per line, for `SAFETY:` and `lint:allow`
//! detection), string/char/lifetime disambiguation, nested block
//! comments, raw strings — and deliberately shallow about everything
//! else.  It is not a parser; the rule layer pattern-matches short
//! token sequences and tracks brace/bracket depth where needed.

use std::collections::{BTreeMap, BTreeSet};

/// Coarse token class — enough to tell identifiers from punctuation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Num,
    /// String, byte-string, or char literal.  The text is a fixed
    /// sentinel so literal contents can never spoof an identifier
    /// match in a rule.
    Str,
    Lifetime,
    Punct,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub line: u32,
    pub kind: Kind,
    pub text: String,
}

/// Lexed view of one source file.
pub struct Lexed {
    pub toks: Vec<Tok>,
    /// Comment text per *starting* line (concatenated if several).
    pub comment_text: BTreeMap<u32, String>,
    /// Every line any comment touches (block comments span many).
    pub comment_lines: BTreeSet<u32>,
    /// Every line holding at least one code token.
    pub code_lines: BTreeSet<u32>,
}

impl Lexed {
    pub fn comment_on(&self, line: u32) -> Option<&str> {
        self.comment_text.get(&line).map(|s| s.as_str())
    }
}

const STR_SENTINEL: &str = "\u{ab}str\u{bb}";

pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut out = Lexed {
        toks: Vec::new(),
        comment_text: BTreeMap::new(),
        comment_lines: BTreeSet::new(),
        code_lines: BTreeSet::new(),
    };
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // line comment (incl. doc comments)
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
            let start = i;
            while i < b.len() && b[i] != b'\n' {
                i += 1;
            }
            record_comment(&mut out, line, &src[start..i]);
            continue;
        }
        // block comment, nested
        if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
            let start = i;
            let start_line = line;
            let mut depth = 1;
            i += 2;
            while i < b.len() && depth > 0 {
                if b[i] == b'\n' {
                    line += 1;
                    out.comment_lines.insert(line);
                    i += 1;
                } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            record_comment(&mut out, start_line, &src[start..i]);
            continue;
        }
        // string-ish prefixes: "…", b"…", r"…", r#"…"#, br#"…"#, b'…'
        if c == b'"' {
            i = lex_string(b, i, &mut line);
            push(&mut out, line, Kind::Str, STR_SENTINEL);
            continue;
        }
        if c == b'b' && i + 1 < b.len() && b[i + 1] == b'"' {
            i = lex_string(b, i + 1, &mut line);
            push(&mut out, line, Kind::Str, STR_SENTINEL);
            continue;
        }
        if c == b'b' && i + 1 < b.len() && b[i + 1] == b'\'' {
            i = lex_char(b, i + 1, &mut line);
            push(&mut out, line, Kind::Str, STR_SENTINEL);
            continue;
        }
        if (c == b'r' || c == b'b') && is_raw_string_start(b, i) {
            i = lex_raw_string(b, i, &mut line);
            push(&mut out, line, Kind::Str, STR_SENTINEL);
            continue;
        }
        // raw identifier r#name (not a raw string: next is not a quote)
        if c == b'r'
            && i + 2 < b.len()
            && b[i + 1] == b'#'
            && (b[i + 2].is_ascii_alphabetic() || b[i + 2] == b'_')
        {
            let s = i + 2;
            let mut j = s;
            while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            push(&mut out, line, Kind::Ident, &src[s..j]);
            i = j;
            continue;
        }
        // lifetime or char literal
        if c == b'\'' {
            let next_ident = i + 1 < b.len()
                && (b[i + 1].is_ascii_alphabetic() || b[i + 1] == b'_');
            let char_lit = next_ident && i + 2 < b.len() && b[i + 2] == b'\'';
            if next_ident && !char_lit {
                let s = i;
                let mut j = i + 1;
                while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                    j += 1;
                }
                push(&mut out, line, Kind::Lifetime, &src[s..j]);
                i = j;
                continue;
            }
            i = lex_char(b, i, &mut line);
            push(&mut out, line, Kind::Str, STR_SENTINEL);
            continue;
        }
        if c.is_ascii_alphabetic() || c == b'_' {
            let s = i;
            while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            push(&mut out, line, Kind::Ident, &src[s..i]);
            continue;
        }
        if c.is_ascii_digit() {
            let s = i;
            while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                i += 1;
            }
            // fraction: `1.5` but not the range `1..n`
            if i + 1 < b.len() && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                i += 1;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
                    i += 1;
                }
            }
            // signed exponent: `1e-3`, `2.5E+7`
            if i > s
                && i < b.len()
                && (b[i] == b'+' || b[i] == b'-')
                && (b[i - 1] == b'e' || b[i - 1] == b'E')
            {
                i += 1;
                while i < b.len() && b[i].is_ascii_digit() {
                    i += 1;
                }
            }
            push(&mut out, line, Kind::Num, &src[s..i]);
            continue;
        }
        if c >= 0x80 {
            // non-ASCII outside strings/comments: skip the whole char
            i += utf8_width(c);
            continue;
        }
        let text = [c];
        push(&mut out, line, Kind::Punct, std::str::from_utf8(&text).unwrap_or("?"));
        i += 1;
    }
    out
}

fn push(out: &mut Lexed, line: u32, kind: Kind, text: &str) {
    out.code_lines.insert(line);
    out.toks.push(Tok { line, kind, text: text.to_string() });
}

fn record_comment(out: &mut Lexed, line: u32, text: &str) {
    out.comment_lines.insert(line);
    let e = out.comment_text.entry(line).or_default();
    if !e.is_empty() {
        e.push(' ');
    }
    e.push_str(text);
}

/// `i` points at the opening quote; returns the index past the close.
fn lex_string(b: &[u8], i: usize, line: &mut u32) -> usize {
    let mut j = i + 1;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            b'\n' => {
                *line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    j
}

/// `i` points at the opening single quote.
fn lex_char(b: &[u8], i: usize, line: &mut u32) -> usize {
    let mut j = i + 1;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'\'' => return j + 1,
            b'\n' => {
                *line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    j
}

/// Is `b[i..]` the start of `r"`, `r#…#"`, `br"`, or `br#…#"`?
fn is_raw_string_start(b: &[u8], i: usize) -> bool {
    let mut j = i + 1;
    if b[i] == b'b' {
        if j >= b.len() || b[j] != b'r' {
            return false;
        }
        j += 1;
    }
    while j < b.len() && b[j] == b'#' {
        j += 1;
    }
    j < b.len() && b[j] == b'"' && (j > i + 1 || b[i] == b'r')
}

/// `i` points at the `r`/`b` prefix; returns the index past the close.
fn lex_raw_string(b: &[u8], i: usize, line: &mut u32) -> usize {
    let mut j = i + 1;
    if b[i] == b'b' {
        j += 1; // past the 'r'
    }
    let mut hashes = 0usize;
    while j < b.len() && b[j] == b'#' {
        hashes += 1;
        j += 1;
    }
    j += 1; // past the opening quote
    while j < b.len() {
        if b[j] == b'\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if b[j] == b'"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < b.len() && b[k] == b'#' && seen < hashes {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return k;
            }
        }
        j += 1;
    }
    j
}

fn utf8_width(c: u8) -> usize {
    match c {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn comments_stay_out_of_the_stream() {
        let lx = lex("let x = 1; // trailing\n/* block\nspans */ fn f() {}\n");
        assert!(lx.toks.iter().all(|t| !t.text.contains("trailing")));
        assert!(lx.comment_on(1).unwrap().contains("trailing"));
        assert!(lx.comment_on(2).unwrap().contains("spans"));
        assert!(lx.comment_lines.contains(&3));
        // `fn` lands on line 3, after the block comment closes
        let f = lx.toks.iter().find(|t| t.text == "fn").unwrap();
        assert_eq!(f.line, 3);
    }

    #[test]
    fn strings_cannot_spoof_identifiers() {
        let ts = texts("let s = \"Instant::now() .lock().unwrap()\";");
        assert!(!ts.contains(&"Instant".to_string()));
        assert!(!ts.contains(&"unwrap".to_string()));
    }

    #[test]
    fn raw_strings_and_escapes() {
        let ts = texts(r##"let s = r#"quote " inside"#; let t = "a\"b"; done"##);
        assert_eq!(ts.iter().filter(|t| t.as_str() == "let").count(), 2);
        assert!(ts.contains(&"done".to_string()));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let lx = lex("fn f<'a>(x: &'a str) { let c = 'x'; let nl = '\\n'; }");
        let kinds: Vec<Kind> = lx.toks.iter().map(|t| t.kind).collect();
        assert_eq!(kinds.iter().filter(|k| **k == Kind::Lifetime).count(), 2);
        assert_eq!(kinds.iter().filter(|k| **k == Kind::Str).count(), 2);
    }

    #[test]
    fn numbers_do_not_swallow_ranges() {
        let ts = texts("for i in 0..n { let x = 1.5e-3; }");
        assert!(ts.contains(&"0".to_string()));
        assert!(ts.contains(&"n".to_string()));
        assert!(ts.contains(&"1.5e-3".to_string()));
    }

    #[test]
    fn nested_block_comments() {
        let ts = texts("/* outer /* inner */ still comment */ real");
        assert_eq!(ts, vec!["real".to_string()]);
    }
}
