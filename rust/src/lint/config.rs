//! Lint configuration: which modules are deterministic-core, where
//! each module-scoped rule applies, and where the policy registries
//! live.  The checked-in `rust/lint.json` is the source of truth the
//! CLI loads; [`LintConfig::default_config`] mirrors it so library
//! callers (tests, fixtures) can build scoped variants directly.

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct LintConfig {
    /// Default scan roots, relative to the crate dir.
    pub paths: Vec<String>,
    /// Path fragments excluded from the walk (the fixture corpus holds
    /// deliberate violations).
    pub exclude: Vec<String>,
    /// The deterministic core: modules whose schedules the fingerprint
    /// gates pin bit-for-bit.  `no-wall-clock` and
    /// `no-unordered-iteration` apply here.
    pub deterministic_core: Vec<String>,
    /// Core paths where wall-clock reads are nonetheless sanctioned
    /// (none today — prefer a per-site `lint:allow` with a reason).
    pub wall_clock_allowed: Vec<String>,
    /// Type names treated as unordered maps/sets by
    /// `no-unordered-iteration`.
    pub map_types: Vec<String>,
    /// Files under the `panic-free-hot-path` rule (the per-step
    /// decision path).
    pub panic_free: Vec<String>,
    /// Figure/report serializer paths under the `json-hygiene` rule.
    pub json_hygiene: Vec<String>,
    /// Registry files for `registry-coverage`.
    pub sched_registry: String,
    pub route_registry: String,
}

impl LintConfig {
    /// Mirrors the checked-in `lint.json`.
    pub fn default_config() -> Self {
        LintConfig {
            paths: vec!["src".into(), "tests".into()],
            exclude: vec!["tests/lint_fixtures".into()],
            deterministic_core: vec![
                "src/engine/".into(),
                "src/coordinator/".into(),
                "src/heg/".into(),
                "src/soc/".into(),
                "src/fleet/".into(),
                "src/workload/".into(),
                "src/baselines/".into(),
            ],
            wall_clock_allowed: vec![],
            map_types: vec![
                "HashMap".into(),
                "HashSet".into(),
                "FxHashMap".into(),
                "FxHashSet".into(),
                "States".into(),
            ],
            panic_free: vec![
                "src/coordinator/dispatch.rs".into(),
                "src/coordinator/select.rs".into(),
                "src/engine/driver.rs".into(),
            ],
            json_hygiene: vec!["src/figures/".into(), "src/metrics/".into()],
            sched_registry: "src/engine/registry.rs".into(),
            route_registry: "src/fleet/route.rs".into(),
        }
    }

    /// Load `<root>/lint.json` if present, else the built-in default.
    pub fn load_or_default(root: &Path) -> Result<Self> {
        let path = root.join("lint.json");
        if !path.exists() {
            return Ok(Self::default_config());
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&j)
    }

    /// Build from a parsed `lint.json`; missing keys fall back to the
    /// built-in default.
    pub fn from_json(j: &Json) -> Result<Self> {
        let mut cfg = Self::default_config();
        read_strs(j, "paths", &mut cfg.paths)?;
        read_strs(j, "exclude", &mut cfg.exclude)?;
        read_strs(j, "deterministic_core", &mut cfg.deterministic_core)?;
        read_strs(j, "wall_clock_allowed", &mut cfg.wall_clock_allowed)?;
        read_strs(j, "map_types", &mut cfg.map_types)?;
        read_strs(j, "panic_free", &mut cfg.panic_free)?;
        read_strs(j, "json_hygiene", &mut cfg.json_hygiene)?;
        if let Some(v) = j.opt("sched_registry") {
            cfg.sched_registry = v.as_str()?.to_string();
        }
        if let Some(v) = j.opt("route_registry") {
            cfg.route_registry = v.as_str()?.to_string();
        }
        Ok(cfg)
    }

    pub fn in_core(&self, rel: &str) -> bool {
        path_in(rel, &self.deterministic_core)
    }

    pub fn is_map_type(&self, name: &str) -> bool {
        self.map_types.iter().any(|m| m == name)
    }
}

/// Prefix match on `/`-normalized relative paths.
pub fn path_in(rel: &str, prefixes: &[String]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p.as_str()))
}

fn read_strs(j: &Json, key: &str, out: &mut Vec<String>) -> Result<()> {
    if let Some(v) = j.opt(key) {
        let mut items = Vec::new();
        for e in v.as_arr()? {
            items.push(e.as_str()?.to_string());
        }
        *out = items;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scopes_the_core() {
        let cfg = LintConfig::default_config();
        assert!(cfg.in_core("src/engine/driver.rs"));
        assert!(cfg.in_core("src/fleet/route.rs"));
        assert!(!cfg.in_core("src/server/rt.rs"));
        assert!(!cfg.in_core("src/util/bench.rs"));
        assert!(cfg.is_map_type("States"));
        assert!(!cfg.is_map_type("BTreeMap"));
    }

    #[test]
    fn json_overrides_apply_and_missing_keys_default() {
        let j = Json::parse(
            r#"{"deterministic_core": ["src/x/"], "sched_registry": "src/r.rs"}"#,
        )
        .unwrap();
        let cfg = LintConfig::from_json(&j).unwrap();
        assert!(cfg.in_core("src/x/mod.rs"));
        assert!(!cfg.in_core("src/engine/driver.rs"));
        assert_eq!(cfg.sched_registry, "src/r.rs");
        // untouched keys keep the built-in default
        assert_eq!(cfg.route_registry, "src/fleet/route.rs");
        assert!(cfg.exclude.iter().any(|e| e.contains("lint_fixtures")));
    }
}
