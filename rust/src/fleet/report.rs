//! Fleet-level metrics: per-device [`RunReport`]s plus the cross-device
//! rollups the routing comparison keys on (DESIGN.md §9).

use crate::metrics::RunReport;
use crate::util::bench::percentile;
use crate::util::json::Json;
use crate::workload::Priority;

/// Per-device request ledger the fleet maintains from its own event
/// stream — the conservation invariant is `submitted == done +
/// cancelled` on every device once the fleet drains.
#[derive(Debug, Clone, Copy, Default)]
pub struct DeviceLedger {
    /// Requests the fleet submitted to this device's engine.
    pub submitted: u64,
    /// `TurnDone` events observed from this device.
    pub done: u64,
    /// `Cancelled` events observed from this device (deliberate
    /// migration cancels + displacement sheds + flow propagation).
    pub cancelled: u64,
}

/// Fleet-level counters accumulated while routing.
#[derive(Debug, Clone, Copy, Default)]
pub struct FleetCounters {
    /// Flows fed to the fleet.
    pub flows: u64,
    /// Flows whose every turn finished.
    pub flows_finished: u64,
    /// Flows killed mid-run (displacement shed or propagated cancel).
    pub flows_dead: u64,
    /// Continuation turns placed on a device other than the one holding
    /// the flow's session KV — each one prefills cache-cold.
    pub migrations: u64,
    /// Placements that succeeded only via a router `on_overload` hop.
    pub overload_reroutes: u64,
    /// Turns every device refused — parked and re-placed
    /// `retry_after_ms` later ([`RouteError::Rejected`]).
    ///
    /// [`RouteError::Rejected`]: super::route::RouteError
    pub rejections: u64,
    /// Parked-turn placement re-attempts.
    pub retries: u64,
    /// Queued proactive requests displaced to seat reactive arrivals.
    pub displaced: u64,
    /// Turns of dead flows that were never submitted anywhere.
    pub shed_turns: u64,
    /// Logical continuation turns (original `turn_idx > 0`) finished.
    pub continuation_turns: u64,
    /// Of those, turns admitted with a warm session prefix.
    pub continuation_warm: u64,
    /// Forced-placement directives issued by `rebalance()`.
    pub rebalance_directives: u64,
}

/// Everything a fleet run produced: one [`RunReport`] + ledger per
/// device, the routing counters, and derived rollups.
#[derive(Debug)]
pub struct FleetReport {
    pub router: String,
    pub policy: String,
    pub devices: Vec<RunReport>,
    pub ledgers: Vec<DeviceLedger>,
    pub counters: FleetCounters,
}

impl FleetReport {
    /// Fleet makespan: the last completion on any device (µs).
    pub fn makespan_us(&self) -> f64 {
        self.devices.iter().map(|d| d.makespan_us).fold(0.0, f64::max)
    }

    /// Sum of per-device `total_energy_j`.
    pub fn total_energy_j(&self) -> f64 {
        self.devices.iter().map(|d| d.total_energy_j).sum()
    }

    /// Energy imbalance: max device energy over mean device energy
    /// (1.0 = perfectly balanced; NaN only for an empty fleet).
    pub fn energy_imbalance(&self) -> f64 {
        let n = self.devices.len() as f64;
        let mean = self.total_energy_j() / n;
        let max = self.devices.iter().map(|d| d.total_energy_j).fold(0.0, f64::max);
        if mean > 0.0 { max / mean } else { 1.0 }
    }

    /// Reactive p99 TTFT across every device (ms; NaN when no reactive
    /// LLM turn finished).
    pub fn reactive_p99_ttft_ms(&self) -> f64 {
        let mut ttfts: Vec<f64> = self
            .devices
            .iter()
            .flat_map(|d| d.reqs.iter())
            .filter(|m| m.priority == Priority::Reactive && !m.tool)
            .filter_map(|m| m.first_token_us.map(|t| (t - m.arrival_us) / 1e3))
            .collect();
        if ttfts.is_empty() {
            return f64::NAN;
        }
        ttfts.sort_by(f64::total_cmp);
        percentile(&ttfts, 0.99)
    }

    /// Proactive output tokens per second of fleet makespan.
    pub fn proactive_tokens_per_s(&self) -> f64 {
        let toks: usize = self
            .devices
            .iter()
            .flat_map(|d| d.reqs.iter())
            .filter(|m| m.priority == Priority::Proactive && m.done_us.is_some())
            .map(|m| m.output_tokens)
            .sum();
        let span_s = self.makespan_us() / 1e6;
        if span_s > 0.0 { toks as f64 / span_s } else { f64::NAN }
    }

    /// Fleet-level session-cache hit rate over *logical* continuation
    /// turns.  Per-device `RunReport::prefix_cache_hit_rate` cannot see
    /// migrations — a migrated continuation re-roots as a device-local
    /// flow and would be miscounted as ineligible — so the fleet counts
    /// warmth from its own event stream instead.
    pub fn cache_hit_rate(&self) -> f64 {
        if self.counters.continuation_turns == 0 {
            return f64::NAN;
        }
        self.counters.continuation_warm as f64 / self.counters.continuation_turns as f64
    }

    /// Requests finished across the fleet.
    pub fn finished(&self) -> u64 {
        self.ledgers.iter().map(|l| l.done).sum()
    }

    /// Strict-JSON serialisation (figure harnesses; `NaN` → `null`).
    pub fn to_json(&self) -> Json {
        let c = &self.counters;
        let devices: Vec<Json> = self
            .devices
            .iter()
            .zip(&self.ledgers)
            .map(|(d, l)| {
                Json::obj()
                    .set("submitted", l.submitted as f64)
                    .set("done", l.done as f64)
                    .set("cancelled", l.cancelled as f64)
                    .set("makespan_us", Json::num_or_null(d.makespan_us))
                    .set("total_energy_j", Json::num_or_null(d.total_energy_j))
                    .set("finished", d.reqs.iter().filter(|m| m.done_us.is_some()).count())
            })
            .collect();
        Json::obj()
            .set("router", self.router.as_str())
            .set("policy", self.policy.as_str())
            .set("n_devices", self.devices.len())
            .set("makespan_us", Json::num_or_null(self.makespan_us()))
            .set("total_energy_j", Json::num_or_null(self.total_energy_j()))
            .set("energy_imbalance", Json::num_or_null(self.energy_imbalance()))
            .set("reactive_p99_ttft_ms", Json::num_or_null(self.reactive_p99_ttft_ms()))
            .set("proactive_tok_s", Json::num_or_null(self.proactive_tokens_per_s()))
            .set("cache_hit_rate", Json::num_or_null(self.cache_hit_rate()))
            .set("flows", c.flows as f64)
            .set("flows_finished", c.flows_finished as f64)
            .set("flows_dead", c.flows_dead as f64)
            .set("migrations", c.migrations as f64)
            .set("overload_reroutes", c.overload_reroutes as f64)
            .set("rejections", c.rejections as f64)
            .set("retries", c.retries as f64)
            .set("displaced", c.displaced as f64)
            .set("shed_turns", c.shed_turns as f64)
            .set("continuation_turns", c.continuation_turns as f64)
            .set("continuation_warm", c.continuation_warm as f64)
            .set("rebalance_directives", c.rebalance_directives as f64)
            .set("devices", Json::Arr(devices))
    }
}
