//! Pluggable session routing (DESIGN.md §9): which device of the fleet
//! serves the next turn of a user's flow.
//!
//! A [`RoutePolicy`] mirrors the `SchedPolicy` split one layer up: the
//! [`Fleet`](super::Fleet) owns the event loop, per-device engines, and
//! conservation bookkeeping; a router is only the placement decision.
//! Like scheduling policies, routers live in a string-keyed registry so
//! harnesses and the CLI select them by name — a new router registered
//! here is automatically covered by `fig fleet` and the fleet property
//! suite.
//!
//! Canonical names:
//!
//! | name | placement rule |
//! |---|---|
//! | `sticky-session` | user-hash roots, continuations stay on the KV-holding device |
//! | `least-loaded` | roots to the min (queue depth + XPU duty) device, sticky continuations |
//! | `energy-budget` | proactive work steered off devices near their joule budget |
//! | `random` | seeded uniform placement of every turn (migration-heavy baseline) |

use anyhow::{Result, bail};

use crate::util::rng::Rng;
use crate::workload::{FlowId, Priority};

/// Index of a device within its fleet.
pub type DeviceId = usize;

/// Why the fleet could not place a turn anywhere right now.  The
/// rejected turn is *not* dropped: the fleet parks it and re-places it
/// `retry_after_ms` later (the fleet-wide extension of the PR 7 serving
/// invariant — no admitted turn is silently lost).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RouteError {
    /// Every device's `OverloadGate` refused the turn.
    Rejected { retry_after_ms: f64 },
}

/// Per-device load snapshot a router reads at each decision point.
#[derive(Debug, Clone, Copy, Default)]
pub struct DeviceLoad {
    /// Requests admitted by the device's gate without a terminal event
    /// yet (the admission queue depth the gate bounds).
    pub queue_depth: usize,
    /// Engine-level outstanding work (queued + held turns + in-flight).
    pub unfinished: usize,
    /// Windowed NPU duty cycle in [0, 1].
    pub npu_duty: f64,
    /// Windowed iGPU duty cycle in [0, 1].
    pub igpu_duty: f64,
    /// Cumulative energy drawn by the device this run (J).
    pub energy_j: f64,
    /// Per-device joule budget (0 = unlimited).
    pub energy_budget_j: f64,
    /// Device virtual time (µs).
    pub now_us: f64,
}

impl DeviceLoad {
    /// Scalar congestion score: queue depth dominates, windowed XPU
    /// duty breaks ties between shallow queues.
    pub fn congestion(&self) -> f64 {
        self.queue_depth as f64 + 2.0 * (self.npu_duty + self.igpu_duty)
    }

    /// Joules left under the budget (`f64::INFINITY` when unlimited).
    pub fn energy_headroom_j(&self) -> f64 {
        if self.energy_budget_j <= 0.0 {
            f64::INFINITY
        } else {
            self.energy_budget_j - self.energy_j
        }
    }
}

/// Everything a router sees at one placement decision.
#[derive(Debug)]
pub struct RouteCtx<'a> {
    /// The user the flow belongs to (routers may hash it for affinity).
    pub user: u64,
    /// Fleet-level flow id.
    pub flow: FlowId,
    /// Original turn index within the flow (0 = flow root).
    pub turn_idx: usize,
    pub priority: Priority,
    /// Device currently holding the flow's session KV (`None` for
    /// roots).  Placing elsewhere migrates the flow: the new device
    /// prefills the whole conversation cache-cold.
    pub bound: Option<DeviceId>,
    /// One load snapshot per device, indexed by [`DeviceId`].
    pub loads: &'a [DeviceLoad],
}

/// A fleet routing policy: pure placement decisions over [`RouteCtx`]
/// snapshots.  The fleet owns admission (per-device `OverloadGate`s)
/// and all conservation bookkeeping — a router can place badly but
/// cannot lose work.
pub trait RoutePolicy {
    /// Registry name of this router.
    fn name(&self) -> &'static str;

    /// Place one turn.  Called for every flow root and at every turn
    /// completion for the flow's next turn (`ctx.bound` names the
    /// device whose `SessionCachePool` holds the flow's KV; returning a
    /// different device migrates the flow cache-cold).
    fn route(&mut self, ctx: &RouteCtx) -> DeviceId;

    /// The chosen device's gate rejected the turn — pick an alternate
    /// (`tried` lists every device already refused this attempt).
    /// Returning `None`, or only already-tried devices, surfaces
    /// [`RouteError::Rejected`] to the fleet.  Default: the first
    /// untried device by id.
    fn on_overload(&mut self, ctx: &RouteCtx, tried: &[DeviceId]) -> Option<DeviceId> {
        (0..ctx.loads.len()).find(|d| !tried.contains(d))
    }

    /// Periodic load audit: the fleet calls this every
    /// `FleetConfig::rebalance_every` turn completions with fresh
    /// loads.  Returned `(flow, device)` directives force the *next*
    /// turn of each named flow onto the given device (a deliberate
    /// migration).  Default: never rebalance.
    fn rebalance(&mut self, _loads: &[DeviceLoad]) -> Vec<(FlowId, DeviceId)> {
        vec![]
    }
}

/// Stable 64-bit user → device hash (splitmix64 finalizer) — the same
/// user always roots on the same device for a given fleet size.
fn user_hash(user: u64) -> u64 {
    let mut x = user.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Argmin over device loads with a deterministic lowest-id tie-break.
fn argmin_by<F: Fn(&DeviceLoad) -> f64>(loads: &[DeviceLoad], key: F) -> DeviceId {
    let mut best = 0;
    let mut best_k = f64::INFINITY;
    for (i, l) in loads.iter().enumerate() {
        let k = key(l);
        if k < best_k {
            best = i;
            best_k = k;
        }
    }
    best
}

/// Session affinity: a user's flows root on `hash(user) % N`, and every
/// continuation stays on the device holding the flow's KV — maximum
/// cache warmth, no load awareness (a hot user's device saturates).
pub struct StickySession;

impl RoutePolicy for StickySession {
    fn name(&self) -> &'static str {
        "sticky-session"
    }

    fn route(&mut self, ctx: &RouteCtx) -> DeviceId {
        ctx.bound
            .unwrap_or_else(|| (user_hash(ctx.user) % ctx.loads.len() as u64) as usize)
    }
}

/// Load-aware rooting: flow roots go to the least-congested device
/// (queue depth + windowed XPU duty); continuations stay sticky so the
/// balance win does not cost cache warmth.
pub struct LeastLoaded;

impl RoutePolicy for LeastLoaded {
    fn name(&self) -> &'static str {
        "least-loaded"
    }

    fn route(&mut self, ctx: &RouteCtx) -> DeviceId {
        match ctx.bound {
            Some(d) => d,
            None => argmin_by(ctx.loads, DeviceLoad::congestion),
        }
    }

    fn on_overload(&mut self, ctx: &RouteCtx, tried: &[DeviceId]) -> Option<DeviceId> {
        // least-congested untried device, not merely the first by id
        (0..ctx.loads.len())
            .filter(|d| !tried.contains(d))
            .min_by(|&a, &b| {
                ctx.loads[a]
                    .congestion()
                    .total_cmp(&ctx.loads[b].congestion())
                    .then(a.cmp(&b))
            })
    }
}

/// Joule-budget steering: proactive work avoids devices near their
/// per-device energy budget — roots go to the device with the most
/// joule headroom, and a proactive continuation whose bound device has
/// crossed `ENERGY_STEER_FRAC` of its budget migrates away (cache-cold
/// by design: spending a full recompute beats busting the budget).
/// Reactive flows route like `least-loaded` roots + sticky
/// continuations: latency work is never displaced for energy.
pub struct EnergyBudget;

/// Budget fraction past which proactive continuations migrate off.
pub const ENERGY_STEER_FRAC: f64 = 0.9;

impl RoutePolicy for EnergyBudget {
    fn name(&self) -> &'static str {
        "energy-budget"
    }

    fn route(&mut self, ctx: &RouteCtx) -> DeviceId {
        let most_headroom = || {
            argmin_by(ctx.loads, |l| {
                // min over (-headroom), i.e. max headroom; congestion
                // breaks ties between unlimited-budget devices
                let h = l.energy_headroom_j();
                if h.is_infinite() { l.energy_j + l.congestion() } else { -h }
            })
        };
        match (ctx.priority, ctx.bound) {
            (Priority::Reactive, Some(d)) => d,
            (Priority::Reactive, None) => argmin_by(ctx.loads, DeviceLoad::congestion),
            (Priority::Proactive, Some(d)) => {
                let l = &ctx.loads[d];
                let near_budget = l.energy_budget_j > 0.0
                    && l.energy_j >= ENERGY_STEER_FRAC * l.energy_budget_j;
                if near_budget { most_headroom() } else { d }
            }
            (Priority::Proactive, None) => most_headroom(),
        }
    }
}

/// Seeded uniform placement of *every* turn — the migration-heavy
/// baseline the acceptance claims compare against: continuations
/// usually land off the KV-holding device and prefill cache-cold.
pub struct RandomRoute {
    rng: Rng,
}

impl RoutePolicy for RandomRoute {
    fn name(&self) -> &'static str {
        "random"
    }

    fn route(&mut self, ctx: &RouteCtx) -> DeviceId {
        self.rng.usize(0, ctx.loads.len())
    }

    fn on_overload(&mut self, ctx: &RouteCtx, tried: &[DeviceId]) -> Option<DeviceId> {
        let open: Vec<DeviceId> =
            (0..ctx.loads.len()).filter(|d| !tried.contains(d)).collect();
        if open.is_empty() { None } else { Some(*self.rng.choice(&open)) }
    }
}

/// Canonical names of every registered router, in comparison order.
pub fn names() -> &'static [&'static str] {
    &["sticky-session", "least-loaded", "energy-budget", "random"]
}

/// Resolve a user-facing name or alias to its canonical key.
pub fn canonical(name: &str) -> Result<&'static str> {
    Ok(match name {
        "sticky-session" | "sticky" | "session-affinity" => "sticky-session",
        "least-loaded" | "least-load" | "balance" => "least-loaded",
        "energy-budget" | "energy" => "energy-budget",
        "random" | "uniform" => "random",
        other => bail!(
            "unknown router {other:?} (registered: {})",
            names().join(", ")
        ),
    })
}

/// Build a router by name.  `seed` feeds the seeded baselines (only
/// `random` draws from it); deterministic routers ignore it.
pub fn build(name: &str, seed: u64) -> Result<Box<dyn RoutePolicy + Send>> {
    Ok(match canonical(name)? {
        "sticky-session" => Box::new(StickySession),
        "least-loaded" => Box::new(LeastLoaded),
        "energy-budget" => Box::new(EnergyBudget),
        // the xor keeps the router's RNG stream distinct from workload
        // generators seeded from the same root seed
        "random" => Box::new(RandomRoute { rng: Rng::new(seed ^ 0x5157_0000_7e77) }),
        _ => unreachable!("canonical() covers every registered name"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(n: usize) -> Vec<DeviceLoad> {
        (0..n)
            .map(|i| DeviceLoad {
                queue_depth: i,
                unfinished: i,
                npu_duty: 0.1 * i as f64,
                igpu_duty: 0.0,
                energy_j: i as f64,
                energy_budget_j: 0.0,
                now_us: 0.0,
            })
            .collect()
    }

    fn ctx<'a>(
        user: u64,
        bound: Option<DeviceId>,
        priority: Priority,
        loads: &'a [DeviceLoad],
    ) -> RouteCtx<'a> {
        RouteCtx { user, flow: 1, turn_idx: bound.map(|_| 1).unwrap_or(0), priority, bound, loads }
    }

    #[test]
    fn every_registered_name_round_trips_through_build() {
        for &name in names() {
            let mut r = build(name, 7).unwrap();
            assert_eq!(r.name(), name, "build({name}) yields the canonical router");
            let ls = loads(4);
            let d = r.route(&ctx(3, None, Priority::Reactive, &ls));
            assert!(d < 4, "{name}: route stays in range");
        }
        assert!(build("no-such-router", 7).is_err());
        assert_eq!(canonical("sticky").unwrap(), "sticky-session");
        assert_eq!(canonical("balance").unwrap(), "least-loaded");
        assert_eq!(canonical("uniform").unwrap(), "random");
    }

    #[test]
    fn sticky_keeps_bound_device_and_hashes_users_stably() {
        let ls = loads(8);
        let mut r = StickySession;
        let root = r.route(&ctx(42, None, Priority::Reactive, &ls));
        assert_eq!(root, r.route(&ctx(42, None, Priority::Reactive, &ls)));
        for bound in 0..8 {
            assert_eq!(
                r.route(&ctx(42, Some(bound), Priority::Reactive, &ls)),
                bound,
                "continuations never leave the KV device"
            );
        }
        // different users spread across devices (not all on one)
        let placed: std::collections::HashSet<usize> = (0..64)
            .map(|u| r.route(&ctx(u, None, Priority::Reactive, &ls)))
            .collect();
        assert!(placed.len() > 1, "user hash must spread across the fleet");
    }

    #[test]
    fn least_loaded_roots_to_min_congestion() {
        let ls = loads(4); // device 0 is least congested by construction
        let mut r = LeastLoaded;
        assert_eq!(r.route(&ctx(9, None, Priority::Reactive, &ls)), 0);
        assert_eq!(
            r.route(&ctx(9, Some(3), Priority::Reactive, &ls)),
            3,
            "continuations stay sticky"
        );
        // overload fallback prefers the least-congested untried device
        assert_eq!(r.on_overload(&ctx(9, None, Priority::Reactive, &ls), &[0]), Some(1));
    }

    #[test]
    fn energy_budget_steers_proactive_off_hot_devices() {
        let mut ls = loads(3);
        for (i, l) in ls.iter_mut().enumerate() {
            l.energy_budget_j = 10.0;
            l.energy_j = [9.5, 2.0, 5.0][i];
        }
        let mut r = EnergyBudget;
        // bound device 0 is past 90% of budget: migrate to max headroom
        assert_eq!(r.route(&ctx(1, Some(0), Priority::Proactive, &ls)), 1);
        // bound device 2 is under the steer threshold: stay
        assert_eq!(r.route(&ctx(1, Some(2), Priority::Proactive, &ls)), 2);
        // proactive roots go to the most headroom
        assert_eq!(r.route(&ctx(1, None, Priority::Proactive, &ls)), 1);
        // reactive work is never energy-steered
        assert_eq!(r.route(&ctx(1, Some(0), Priority::Reactive, &ls)), 0);
    }

    #[test]
    fn random_is_seeded_and_covers_devices() {
        let ls = loads(4);
        let seq = |seed| -> Vec<usize> {
            let mut r = build("random", seed).unwrap();
            (0..32).map(|u| r.route(&ctx(u, Some(0), Priority::Reactive, &ls))).collect()
        };
        assert_eq!(seq(7), seq(7), "same seed, same placements");
        assert_ne!(seq(7), seq(8), "different seeds diverge");
        let placed: std::collections::HashSet<usize> = seq(7).into_iter().collect();
        assert!(placed.len() > 1, "uniform placement spreads");
    }
}
