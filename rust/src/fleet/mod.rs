//! The fleet layer (DESIGN.md §9): N simulated SoC devices behind a
//! pluggable session router — the first layer of the codebase above a
//! single SoC.
//!
//! A [`Fleet`] owns one [`PolicyEngine`] per device (each with its own
//! `SocSim`, memory governor, session pool, and optional graphics
//! workload) plus one per-device [`OverloadGate`], and steps devices in
//! shared-virtual-clock event order: the laggard busy device always
//! steps next, so cross-device causality (a turn completing on device A
//! routing its successor to device B) is respected without a global
//! event queue.
//!
//! Routing is a [`RoutePolicy`] decision; everything stateful stays in
//! the fleet:
//!
//! - **Session affinity / migration.**  When turn `j` of a flow is
//!   submitted to a device, turn `j+1` is *pre-held* on the same device
//!   (a held DAG node behind `j`), so the driver's one-turn lookahead
//!   keeps the flow's `SessionCachePool` entry retained across the
//!   think-time gap — a sticky continuation prefills warm.  At `j`'s
//!   completion the router re-decides: staying pre-holds `j+2`; moving
//!   cancels the pre-held copy (the old device drops the session) and
//!   re-roots the chain on the new device, which prefills the whole
//!   conversation cache-cold — the migration penalty is emergent, not
//!   modelled.
//! - **Overload re-placement.**  A turn a device's gate refuses bounces
//!   back to the router (`on_overload`) and tries other devices; only
//!   when *every* device refuses is it parked and retried
//!   `retry_after_ms` later ([`RouteError::Rejected`]) — no admitted
//!   turn is ever silently dropped, the fleet-wide extension of the
//!   PR 7 serving invariant.
//! - **Conservation.**  Per-device ledgers (`submitted == done +
//!   cancelled`) and per-flow turn counts are checked when the fleet
//!   drains; violations are loud errors, not skewed metrics.
//!
//! [`PolicyEngine`]: crate::engine::PolicyEngine
//! [`OverloadGate`]: crate::server::OverloadGate
//! [`RoutePolicy`]: route::RoutePolicy
//! [`RouteError::Rejected`]: route::RouteError

pub mod report;
pub mod route;

use anyhow::{Context, Result, bail, ensure};

use crate::config::{ModelGeometry, OverloadConfig, SchedulerConfig, SocConfig};
use crate::engine::{EngineClock, EngineCore, EngineEvent, ShedLevel, registry};
use crate::server::{AdmissionDecision, OverloadGate};
use crate::soc::GraphicsConfig;
use crate::util::{FxHashMap, FxHashSet};
use crate::workload::{FlowBinding, FlowId, Priority, ReqId, UserFlow};

pub use report::{DeviceLedger, FleetCounters, FleetReport};
pub use route::{DeviceId, DeviceLoad, RouteCtx, RouteError, RoutePolicy};

/// Everything needed to stand up a fleet.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub n_devices: usize,
    /// Router registry name ([`route::names`]).
    pub router: String,
    /// Per-device scheduling policy ([`registry::names`]); only
    /// `agent-xpu` retains sessions, so session-affinity routing is
    /// meaningful there.
    pub policy: String,
    pub geo: ModelGeometry,
    pub soc: SocConfig,
    pub sched: SchedulerConfig,
    /// Per-device admission gate config (`retry_after_ms` also paces
    /// fleet-level retry parking).
    pub overload: OverloadConfig,
    /// Per-device joule budget surfaced to routers (0 = unlimited).
    pub energy_budget_j: f64,
    /// Seeds the seeded routers (`random`).
    pub seed: u64,
    /// Optional per-device display workload.
    pub graphics: Option<GraphicsConfig>,
    /// Call `RoutePolicy::rebalance` every this many turn completions
    /// (0 = never).
    pub rebalance_every: usize,
}

impl FleetConfig {
    pub fn new(n_devices: usize, router: &str, geo: ModelGeometry, soc: SocConfig) -> Self {
        Self {
            n_devices,
            router: router.to_string(),
            policy: "agent-xpu".to_string(),
            geo,
            soc,
            sched: SchedulerConfig::default(),
            overload: OverloadConfig::default(),
            energy_budget_j: 0.0,
            seed: 0,
            graphics: None,
            rebalance_every: 0,
        }
    }
}

/// One device of the fleet: engine + admission gate + ledger.
struct Device {
    engine: Box<dyn EngineCore + Send>,
    gate: OverloadGate,
    ledger: DeviceLedger,
    /// Device virtual time, refreshed after every step (cheaper than
    /// calling `engine.load()` once per device per loop iteration).
    now_us: f64,
}

/// Fleet-side runtime state of one input flow.
struct FlowRt {
    user: u64,
    flow_id: FlowId,
    priority: Priority,
    turns: Vec<crate::workload::Request>,
    /// Device holding the flow's session KV (None before rooting).
    bound: Option<DeviceId>,
    /// Flow id of the current device-local chain (the original id for
    /// the first chain, a fresh one after each migration).
    local_flow: FlowId,
    /// Original turn index the current local chain re-rooted at.
    local_base: usize,
    /// The local chain on `bound` had a node cancelled — the next
    /// placement must re-root even on the same device.
    chain_broken: bool,
    /// Next original turn index not yet submitted anywhere.
    next_submit: usize,
    done_turns: usize,
    dead: bool,
    /// Forced placement for the next turn (a `rebalance` directive).
    forced: Option<DeviceId>,
}

impl FlowRt {
    fn single_shot(&self) -> bool {
        self.turns.len() == 1 && self.turns[0].flow.is_none()
    }
}

/// A turn every device refused, parked for re-placement.
struct Parked {
    fi: usize,
    turn: usize,
    arrival_us: f64,
    at_us: f64,
}

/// N per-device engines behind one router — see the module docs.
pub struct Fleet {
    cfg: FleetConfig,
    devices: Vec<Device>,
    router: Box<dyn RoutePolicy + Send>,
    flows: Vec<FlowRt>,
    flow_index: FxHashMap<FlowId, usize>,
    /// Request id → (flow index, original turn index).
    req_map: FxHashMap<ReqId, (usize, usize)>,
    /// Ids the fleet cancelled deliberately (migration): their
    /// `Cancelled` events are bookkeeping, not flow deaths.
    expected_cancels: FxHashSet<ReqId>,
    next_local_flow: FlowId,
    parked: Vec<Parked>,
    completions: u64,
    counters: FleetCounters,
    started: bool,
    /// Per-`step_device` wall-clock samples (ns) when timing is on —
    /// feeds the macrobench fleet-overhead gate.
    timing: Option<Vec<f64>>,
}

impl Fleet {
    pub fn new(cfg: FleetConfig) -> Result<Self> {
        ensure!(cfg.n_devices > 0, "a fleet needs at least one device");
        let router = route::build(&cfg.router, cfg.seed)?;
        let mut devices = Vec::with_capacity(cfg.n_devices);
        for i in 0..cfg.n_devices {
            let mut engine =
                registry::build(&cfg.policy, cfg.geo.clone(), cfg.soc.clone(), cfg.sched.clone())
                    .with_context(|| format!("building device {i}"))?;
            engine.set_graphics(cfg.graphics.clone());
            devices.push(Device {
                engine,
                gate: OverloadGate::new(cfg.overload.clone()),
                ledger: DeviceLedger::default(),
                now_us: 0.0,
            });
        }
        Ok(Self {
            cfg,
            devices,
            router,
            flows: vec![],
            flow_index: FxHashMap::default(),
            req_map: FxHashMap::default(),
            expected_cancels: FxHashSet::default(),
            next_local_flow: 0,
            parked: vec![],
            completions: 0,
            counters: FleetCounters::default(),
            started: false,
            timing: None,
        })
    }

    /// Record per-step wall-clock samples (macrobench overhead gate).
    pub fn enable_step_timing(&mut self) {
        self.timing = Some(vec![]);
    }

    /// Samples recorded by [`Self::enable_step_timing`] (ns per
    /// `step_device`, including event routing).
    pub fn step_samples(&self) -> Option<&[f64]> {
        self.timing.as_deref()
    }

    /// Drive the whole fleet over a multi-user trace and drain it.
    pub fn run(&mut self, inputs: Vec<UserFlow>) -> Result<FleetReport> {
        ensure!(!self.started, "Fleet::run is single-shot; build a fresh fleet");
        self.started = true;
        self.ingest(inputs)?;
        for d in &mut self.devices {
            d.engine.start(EngineClock::Virtual)?;
        }

        // Roots sorted descending by (arrival, flow id): pop() yields
        // the earliest — deterministic regardless of input order.
        let mut roots: Vec<usize> = (0..self.flows.len()).collect();
        roots.sort_by(|&a, &b| {
            self.flows[b].turns[0]
                .arrival_us
                .total_cmp(&self.flows[a].turns[0].arrival_us)
                .then(self.flows[b].flow_id.cmp(&self.flows[a].flow_id))
        });

        loop {
            // The laggard busy device defines the horizon: any arrival
            // at or before it must be placed before that device steps,
            // or routing would read stale loads / place into the past.
            let lag = self
                .devices
                .iter()
                .enumerate()
                .filter(|(_, d)| d.engine.has_work())
                .map(|(i, d)| (i, d.now_us))
                .min_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
            let horizon = lag.map_or(f64::INFINITY, |(_, t)| t);
            let next_root = roots.last().map(|&fi| self.flows[fi].turns[0].arrival_us);
            let next_park = self.parked.first().map(|p| p.at_us);
            let root_due = next_root.is_some_and(|t| t <= horizon);
            let park_due = next_park.is_some_and(|t| t <= horizon);

            if root_due && next_root.unwrap() <= next_park.unwrap_or(f64::INFINITY) {
                let fi = roots.pop().unwrap();
                let arrival = self.flows[fi].turns[0].arrival_us;
                self.place_turn(fi, 0, arrival, None)?;
            } else if park_due {
                let idle = lag.is_none();
                if idle {
                    // Nothing is running: the overload that parked this
                    // turn has drained, but the shed detector only
                    // updates on steps — clear its stale pause.
                    for d in &mut self.devices {
                        d.gate.set_paused(false);
                    }
                }
                let p = self.parked.remove(0);
                self.counters.retries += 1;
                if !self.flows[p.fi].dead
                    && !self.place_turn(p.fi, p.turn, p.arrival_us, None)?
                    && idle
                {
                    bail!("fleet livelock: turn re-rejected on an idle fleet");
                }
            } else if let Some((di, _)) = lag {
                self.step_device(di)?;
            } else {
                break;
            }
        }

        // Drain checks: every flow fully served or loudly accounted.
        for f in &self.flows {
            if !f.dead && f.done_turns != f.turns.len() {
                bail!(
                    "flow {} lost turns: {}/{} done with no shed record — conservation violated",
                    f.flow_id,
                    f.done_turns,
                    f.turns.len()
                );
            }
        }
        let mut reports = Vec::with_capacity(self.devices.len());
        let mut ledgers = Vec::with_capacity(self.devices.len());
        for (i, d) in self.devices.iter_mut().enumerate() {
            let l = d.ledger;
            if l.submitted != l.done + l.cancelled {
                bail!(
                    "device {i} ledger violated: submitted {} != done {} + cancelled {}",
                    l.submitted,
                    l.done,
                    l.cancelled
                );
            }
            reports.push(d.engine.finish().with_context(|| format!("finishing device {i}"))?);
            ledgers.push(l);
        }
        Ok(FleetReport {
            router: self.router.name().to_string(),
            policy: self.cfg.policy.clone(),
            devices: reports,
            ledgers,
            counters: self.counters,
        })
    }

    /// Validate inputs and build the per-flow runtime state.  The fleet
    /// routes linear LLM chains (and bare single-shot requests);
    /// workflow DAGs with tool nodes stay single-device for now.
    fn ingest(&mut self, inputs: Vec<UserFlow>) -> Result<()> {
        let mut max_flow: FlowId = 0;
        for uf in &inputs {
            ensure!(!uf.flow.turns.is_empty(), "flow {} has no turns", uf.flow.id);
            for (t, req) in uf.flow.turns.iter().enumerate() {
                match &req.flow {
                    None => ensure!(
                        uf.flow.turns.len() == 1,
                        "flow {}: unbound turn inside a multi-turn flow",
                        uf.flow.id
                    ),
                    Some(b) => ensure!(
                        !b.is_tool() && b.deps.is_empty() && b.turn_idx == t,
                        "fleet routes linear LLM chains only (flow {} node {})",
                        uf.flow.id,
                        t
                    ),
                }
            }
            max_flow = max_flow.max(uf.flow.id);
        }
        self.next_local_flow = max_flow + 1_000_000;
        self.counters.flows = inputs.len() as u64;
        for uf in inputs {
            let fi = self.flows.len();
            ensure!(
                self.flow_index.insert(uf.flow.id, fi).is_none(),
                "duplicate flow id {}",
                uf.flow.id
            );
            self.flows.push(FlowRt {
                user: uf.user,
                flow_id: uf.flow.id,
                priority: uf.flow.priority,
                turns: uf.flow.turns,
                bound: None,
                local_flow: uf.flow.id,
                local_base: 0,
                chain_broken: false,
                next_submit: 0,
                done_turns: 0,
                dead: false,
                forced: None,
            });
        }
        Ok(())
    }

    /// Fresh per-device load snapshot for one routing decision.
    fn loads(&self) -> Vec<DeviceLoad> {
        self.devices
            .iter()
            .map(|d| {
                let l = d.engine.load();
                DeviceLoad {
                    queue_depth: d.gate.live(),
                    unfinished: l.unfinished,
                    npu_duty: l.npu_duty,
                    igpu_duty: l.igpu_duty,
                    energy_j: l.energy_j,
                    energy_budget_j: self.cfg.energy_budget_j,
                    now_us: l.now_us,
                }
            })
            .collect()
    }

    /// Route + admit + submit one turn.  Returns `false` when every
    /// device refused ([`RouteError::Rejected`]) and the turn was
    /// parked for a retry `retry_after_ms` later — never dropped.
    fn place_turn(
        &mut self,
        fi: usize,
        turn: usize,
        arrival_us: f64,
        preferred: Option<DeviceId>,
    ) -> Result<bool> {
        match self.route_and_admit(fi, turn, preferred)? {
            Ok(dev) => {
                self.admit_and_submit(fi, turn, dev, arrival_us)?;
                Ok(true)
            }
            Err(RouteError::Rejected { retry_after_ms }) => {
                self.counters.rejections += 1;
                self.park(Parked {
                    fi,
                    turn,
                    arrival_us,
                    at_us: arrival_us + retry_after_ms.max(1.0) * 1e3,
                });
                Ok(false)
            }
        }
    }

    /// Walk the router across devices until one admits: the chosen
    /// device first, then `on_overload` alternates; a reactive turn may
    /// displace a queued proactive request as the last resort (mirrors
    /// the single-device `run_governed` path).  `Err(RouteError)` is
    /// the typed every-device-refused outcome — the outer `Result` is
    /// for real failures only.
    fn route_and_admit(
        &mut self,
        fi: usize,
        turn: usize,
        preferred: Option<DeviceId>,
    ) -> Result<std::result::Result<DeviceId, RouteError>> {
        let n = self.devices.len();
        let loads = self.loads();
        let (user, flow_id, priority, bound, single) = {
            let f = &self.flows[fi];
            (f.user, f.flow_id, f.turns[turn].priority, f.bound, f.single_shot())
        };
        let ctx = RouteCtx {
            user,
            flow: flow_id,
            turn_idx: turn,
            priority,
            bound: if turn == 0 { None } else { bound },
            loads: &loads,
        };
        let tag = (!single).then(|| format!("flow:{flow_id}"));

        let mut tried: Vec<DeviceId> = vec![];
        let mut displace: Option<(DeviceId, ReqId)> = None;
        let mut cand = match preferred {
            Some(d) => d,
            None => self.router.route(&ctx),
        };
        let placed = loop {
            ensure!(cand < n, "router {} placed device {cand} of {n}", self.router.name());
            match self.devices[cand].gate.try_admit(priority, tag.as_deref()) {
                AdmissionDecision::Admit => break Some(cand),
                AdmissionDecision::Displace(v) => {
                    displace.get_or_insert((cand, v));
                    tried.push(cand);
                }
                AdmissionDecision::Reject => tried.push(cand),
            }
            match self.router.on_overload(&ctx, &tried) {
                Some(d) if !tried.contains(&d) => cand = d,
                _ => break None,
            }
        };

        if let Some(dev) = placed {
            if !tried.is_empty() {
                self.counters.overload_reroutes += 1;
            }
            return Ok(Ok(dev));
        }
        if priority == Priority::Reactive {
            if let Some((dev, victim)) = displace {
                self.counters.displaced += 1;
                self.devices[dev].gate.forget_waiting(victim);
                if let Some(&(vfi, _)) = self.req_map.get(&victim) {
                    self.mark_flow_dead(vfi);
                }
                self.devices[dev].engine.cancel(victim)?;
                return Ok(Ok(dev));
            }
        }
        Ok(Err(RouteError::Rejected { retry_after_ms: self.cfg.overload.retry_after_ms }))
    }

    /// Insert into the park list, kept sorted by (retry time, flow).
    fn park(&mut self, p: Parked) {
        let pos = self
            .parked
            .partition_point(|q| (q.at_us, q.fi, q.turn) < (p.at_us, p.fi, p.turn));
        self.parked.insert(pos, p);
    }

    /// Submit turn `turn` of flow `fi` to `dev` (the gate already said
    /// yes) and pre-hold the following turn on the same device.
    fn admit_and_submit(
        &mut self,
        fi: usize,
        turn: usize,
        dev: DeviceId,
        arrival_us: f64,
    ) -> Result<()> {
        let (single, prev_bound, chain_broken, flow_id, n_turns) = {
            let f = &self.flows[fi];
            (f.single_shot(), f.bound, f.chain_broken, f.flow_id, f.turns.len())
        };
        let as_root = turn == 0 || Some(dev) != prev_bound || chain_broken;
        let local_flow = if !as_root {
            self.flows[fi].local_flow
        } else if turn == 0 {
            flow_id
        } else {
            let v = self.next_local_flow;
            self.next_local_flow += 1;
            v
        };
        if turn > 0 && prev_bound.is_some() && Some(dev) != prev_bound {
            self.counters.migrations += 1;
        }

        let f = &mut self.flows[fi];
        if as_root {
            f.local_flow = local_flow;
            f.local_base = turn;
        }
        let mut req = f.turns[turn].clone();
        req.arrival_us = arrival_us;
        if !single {
            let ob = f.turns[turn].flow.as_ref().unwrap();
            let local_total = n_turns - f.local_base;
            req.flow = Some(if as_root {
                // Re-rooted chain: self-contained prompt, no local
                // predecessor — the new device prefills it cache-cold.
                FlowBinding::linear(local_flow, 0, local_total, 0.0, 0)
            } else {
                FlowBinding::linear(
                    local_flow,
                    turn - f.local_base,
                    local_total,
                    ob.think_time_us,
                    ob.delta_start,
                )
            });
        }
        f.bound = Some(dev);
        f.chain_broken = false;
        f.next_submit = turn + 1;
        let (id, priority) = (req.id, req.priority);
        let tag = (!single).then(|| format!("flow:{flow_id}"));

        self.devices[dev].engine.submit(req)?;
        self.devices[dev].ledger.submitted += 1;
        self.devices[dev].gate.admit(id, priority, tag.as_deref());
        self.req_map.insert(id, (fi, turn));
        self.try_pre_hold(fi, dev)?;
        Ok(())
    }

    /// Submit the flow's next turn to `dev` as a *held* DAG node behind
    /// its (not yet finished) predecessor, so the driver retains the
    /// session across the think-time gap and the continuation prefills
    /// warm.  Skipped when the gate has no seat — the turn is then
    /// placed normally at its predecessor's completion (and the session
    /// may go cold: correct under-pressure semantics).
    fn try_pre_hold(&mut self, fi: usize, dev: DeviceId) -> Result<()> {
        let (turn, flow_id, eligible) = {
            let f = &self.flows[fi];
            let turn = f.next_submit;
            let eligible = !f.dead
                && !f.single_shot()
                && turn < f.turns.len()
                && Some(dev) == f.bound
                && !f.chain_broken;
            (turn, f.flow_id, eligible)
        };
        if !eligible {
            return Ok(());
        }
        let priority = self.flows[fi].turns[turn].priority;
        let tag = format!("flow:{flow_id}");
        if self.devices[dev].gate.try_admit(priority, Some(&tag)) != AdmissionDecision::Admit {
            return Ok(());
        }
        let f = &mut self.flows[fi];
        let ob = f.turns[turn].flow.as_ref().unwrap();
        let binding = FlowBinding::linear(
            f.local_flow,
            turn - f.local_base,
            f.turns.len() - f.local_base,
            ob.think_time_us,
            ob.delta_start,
        );
        let mut req = f.turns[turn].clone();
        // arrival is a placeholder: the driver stamps the real one
        // (predecessor completion + think time) at release
        req.flow = Some(binding);
        f.next_submit = turn + 1;
        let id = req.id;
        self.devices[dev].engine.submit(req)?;
        self.devices[dev].ledger.submitted += 1;
        self.devices[dev].gate.admit(id, priority, Some(&tag));
        self.req_map.insert(id, (fi, turn));
        Ok(())
    }

    /// Step one device and fold its events into fleet state.
    fn step_device(&mut self, di: usize) -> Result<()> {
        // lint:allow(no-wall-clock) opt-in overhead instrumentation — never feeds scheduling decisions
        let t0 = self.timing.as_ref().map(|_| std::time::Instant::now());
        let events = self.devices[di].engine.step()?;
        for ev in &events {
            self.devices[di].gate.on_event(ev);
        }
        for ev in events {
            match ev {
                EngineEvent::TurnDone { id, at_us, cached_prefix, .. } => {
                    self.on_turn_done(di, id, at_us, cached_prefix)?;
                }
                EngineEvent::Cancelled { id, .. } => {
                    self.devices[di].ledger.cancelled += 1;
                    if !self.expected_cancels.remove(&id) {
                        // Not one of ours: a displacement shed or a
                        // propagated flow kill — the whole flow is gone.
                        if let Some(&(fi, _)) = self.req_map.get(&id) {
                            self.mark_flow_dead(fi);
                        }
                    }
                }
                _ => {}
            }
        }
        // Shed ladder, mirrored from the single-device serving loop:
        // pause proactive intake, then shed newest queued proactive.
        let now = self.devices[di].engine.load().now_us;
        self.devices[di].now_us = now;
        let sig = self.devices[di].gate.signal(now);
        let level = self.devices[di].engine.overload_response(&sig);
        self.devices[di].gate.set_paused(level >= ShedLevel::PauseProactive);
        if level >= ShedLevel::CancelQueuedProactive {
            if let Some(v) = self.devices[di].gate.take_newest_waiting_proactive() {
                if let Some(&(vfi, _)) = self.req_map.get(&v) {
                    self.mark_flow_dead(vfi);
                }
                self.devices[di].engine.cancel(v)?;
            }
        }
        if let Some(t0) = t0 {
            let ns = t0.elapsed().as_nanos() as f64;
            if let Some(samples) = self.timing.as_mut() {
                samples.push(ns);
            }
        }
        Ok(())
    }

    /// One logical turn finished on `di`: account it, then decide where
    /// the flow's next turn runs (stay warm vs migrate cache-cold).
    fn on_turn_done(
        &mut self,
        di: usize,
        id: ReqId,
        at_us: f64,
        cached_prefix: usize,
    ) -> Result<()> {
        self.devices[di].ledger.done += 1;
        let Some(&(fi, turn)) = self.req_map.get(&id) else {
            bail!("TurnDone for unmapped request {id} on device {di}");
        };
        {
            let f = &mut self.flows[fi];
            f.done_turns += 1;
            if !f.dead && f.done_turns == f.turns.len() {
                self.counters.flows_finished += 1;
            }
        }
        if turn > 0 {
            self.counters.continuation_turns += 1;
            if cached_prefix > 0 {
                self.counters.continuation_warm += 1;
            }
        }
        self.completions += 1;
        if self.cfg.rebalance_every > 0 && self.completions % self.cfg.rebalance_every as u64 == 0 {
            let loads = self.loads();
            let dirs = self.router.rebalance(&loads);
            self.counters.rebalance_directives += dirs.len() as u64;
            for (flow, dev) in dirs {
                if let Some(&fi2) = self.flow_index.get(&flow) {
                    if !self.flows[fi2].dead && dev < self.devices.len() {
                        self.flows[fi2].forced = Some(dev);
                    }
                }
            }
        }

        let (dead, n_turns, next_submit, bound, forced) = {
            let f = &mut self.flows[fi];
            (f.dead, f.turns.len(), f.next_submit, f.bound, f.forced.take())
        };
        let next = turn + 1;
        if dead || next >= n_turns {
            return Ok(());
        }
        let think =
            self.flows[fi].turns[next].flow.as_ref().map_or(0.0, |b| b.think_time_us);
        let arrival = at_us + think;
        if next_submit == next + 1 {
            // `next` is pre-held on `bound` (the driver just released
            // it): ask the router whether the flow stays or migrates.
            let loads = self.loads();
            let ctx = RouteCtx {
                user: self.flows[fi].user,
                flow: self.flows[fi].flow_id,
                turn_idx: next,
                priority: self.flows[fi].turns[next].priority,
                bound,
                loads: &loads,
            };
            let target = forced.unwrap_or_else(|| self.router.route(&ctx));
            drop(loads);
            ensure!(
                target < self.devices.len(),
                "router {} placed device {target} of {}",
                self.router.name(),
                self.devices.len()
            );
            if Some(target) == bound {
                self.try_pre_hold(fi, target)?;
            } else {
                // Migration: cancel the pre-held copy (the old device
                // drops the flow's session) and re-root elsewhere.
                let old = bound.expect("pre-held turn implies a bound device");
                let held_id = self.flows[fi].turns[next].id;
                self.expected_cancels.insert(held_id);
                if self.devices[old].engine.cancel(held_id)? {
                    self.flows[fi].chain_broken = true;
                    self.flows[fi].next_submit = next;
                    self.place_turn(fi, next, arrival, Some(target))?;
                } else {
                    // The copy already retired inside this very step —
                    // its own TurnDone later in the batch drives on.
                    self.expected_cancels.remove(&held_id);
                }
            }
        } else if next_submit == next {
            // Never pre-held (the gate was full at submit time).
            self.place_turn(fi, next, arrival, None)?;
        } else {
            bail!("flow {} lookahead invariant broken at turn {next}", self.flows[fi].flow_id);
        }
        Ok(())
    }

    /// The flow is gone (displacement shed or propagated cancel): stop
    /// submitting its turns and account the never-submitted tail.
    fn mark_flow_dead(&mut self, fi: usize) {
        let f = &mut self.flows[fi];
        if f.dead {
            return;
        }
        f.dead = true;
        self.counters.flows_dead += 1;
        self.counters.shed_turns += (f.turns.len() - f.next_submit) as u64;
    }
}
