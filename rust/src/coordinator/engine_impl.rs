//! The Agent.xpu scheduling policy: the XPU-coordinator decision
//! pipeline over the shared DES driver.  This is the paper's system
//! contribution wired together — see module docs in
//! `coordinator/mod.rs`.
//!
//! Since the `SchedPolicy` redesign (DESIGN.md §7) this file contains
//! *no* engine lifecycle: [`PolicyEngine`] owns start/submit/step/
//! cancel/finish, tracing, and event emission for every policy.  What
//! lives here is
//!
//! - [`XpuCoordinator`] — the reusable §5/§6 decision pipeline
//!   (hetero-disaggregation, kernel-level preemption, margin chunks,
//!   slack-aware backfill, memory-aware dispatch, the deadlock guard).
//!   It consults the policy's narrower hooks (`admission_order`,
//!   `resume_order`, `decode_batch`, `eviction_victim`) at every
//!   ranking point, so a policy that only wants a different *ordering*
//!   — like `deadline` — overrides one hook and reuses the pipeline.
//! - [`AgentXpuPolicy`] — the paper's policy: the pipeline with every
//!   hook at its §6 default.
//!
//! `AgentXpuEngine` remains the engine type the harnesses and the
//! server name — now an alias for `PolicyEngine<AgentXpuPolicy>`.

use std::sync::Arc;

use crate::config::{ModelGeometry, SchedulerConfig, SocConfig};
use crate::engine::{
    Action, ExecBridge, IgpuGateCtx, KernelTag, Phase, PolicyCtx, PolicyEngine,
    RebindCtx, RebindDecision, ResumeCtx, SchedPolicy, States,
};
use crate::heg::{Annotator, ChunkSpec, max_chunk_within_budget};
use crate::runtime::ModelExecutor;
use crate::soc::{CO_RUN_DDR_PENALTY_IGPU, CO_RUN_DDR_PENALTY_NPU, KernelClass, XpuModel};
use crate::workload::ReqId;

use super::dispatch::{DispatchDecision, dispatch_check};
use super::memory::MemoryGovernor;

/// The Agent.xpu serving engine: the coordinator policy behind the one
/// generic [`PolicyEngine`].
pub type AgentXpuEngine = PolicyEngine<AgentXpuPolicy>;

impl PolicyEngine<AgentXpuPolicy> {
    /// Timing-only engine at a given geometry (figure sweeps).
    pub fn synthetic(geo: ModelGeometry, soc: SocConfig, sched: SchedulerConfig) -> Self {
        let bridge = ExecBridge::synthetic(geo.clone());
        PolicyEngine::with_policy(AgentXpuPolicy::new(geo, &soc, sched), soc, bridge)
    }

    /// Real-compute engine over loaded artifacts.
    pub fn real(exec: Arc<ModelExecutor>, soc: SocConfig, sched: SchedulerConfig) -> Self {
        let geo = exec.geo().clone();
        let bridge = ExecBridge::real(exec);
        PolicyEngine::with_policy(AgentXpuPolicy::new(geo, &soc, sched), soc, bridge)
    }
}

// -- Reference scans ----------------------------------------------------
//
// Full-`states` scans the driver's incrementally maintained phase
// index replaced.  They survive only inside `debug_assert_eq!` parity
// checks: every index read below is asserted bit-identical to the scan
// it displaced (same membership, same sorted id order), so release
// builds trust the index and debug builds prove the schedules are
// unchanged.

/// Reference scan for the waiting-proactive-prefill index.
fn scan_waiting_proactive(states: &States) -> Vec<ReqId> {
    let mut v: Vec<ReqId> = states
        .values() // lint:allow(no-unordered-iteration) collected then sorted by id below
        .filter(|s| s.phase == Phase::Prefilling && !s.running && !s.is_reactive())
        .map(|s| s.id())
        .collect();
    v.sort_unstable();
    v
}

/// Reference scan for the waiting-reactive-prefill index.
fn scan_waiting_reactive(states: &States) -> Vec<ReqId> {
    let mut v: Vec<ReqId> = states
        .values() // lint:allow(no-unordered-iteration) collected then sorted by id below
        .filter(|s| s.phase == Phase::Prefilling && !s.running && s.is_reactive())
        .map(|s| s.id())
        .collect();
    v.sort_unstable();
    v
}

/// Reference scan for the waiting-prefill union (deadlock guard).
fn scan_waiting_prefills(states: &States) -> Vec<ReqId> {
    let mut v: Vec<ReqId> = states
        .values() // lint:allow(no-unordered-iteration) collected then sorted by id below
        .filter(|s| s.phase == Phase::Prefilling && !s.running)
        .map(|s| s.id())
        .collect();
    v.sort_unstable();
    v
}

/// Reference scan for the dynamic-margin-chunk index, per class.
fn scan_dynamic_chunks(states: &States, reactive: bool) -> Vec<ReqId> {
    let mut v: Vec<ReqId> = states
        .values() // lint:allow(no-unordered-iteration) collected then sorted by id below
        .filter(|s| {
            s.phase == Phase::Prefilling
                && !s.running
                && s.is_reactive() == reactive
                && s.current_chunk().map(|c| c.dynamic).unwrap_or(false)
        })
        .map(|s| s.id())
        .collect();
    v.sort_unstable();
    v
}

/// Reference scan for the idle-decoder indexes.
fn scan_idle_decoder(states: &States, reactive_only: bool) -> bool {
    states.values().any(|s| {
        s.phase == Phase::Decoding && !s.running && (!reactive_only || s.is_reactive())
    })
}

/// Reference scan for the live-reactive index: reactive requests
/// currently mid-system (prefilling or decoding).
fn reactive_active(states: &States) -> bool {
    states.values().any(|s| s.is_reactive() && s.phase != Phase::Done)
}

/// Reference scan for preemption victims, sorted like the index walk.
fn scan_preemption_victims(states: &States) -> Vec<ReqId> {
    let mut v: Vec<ReqId> = states
        .values() // lint:allow(no-unordered-iteration) collected then sorted by id below
        .filter(|s| {
            !s.is_reactive()
                && s.phase == Phase::Prefilling
                && !s.running
                && !s.preempt_counted
                && s.prefill_started()
        })
        .map(|s| s.id())
        .collect();
    v.sort_unstable();
    v
}

/// Reference scan for the split-eligible proactive index: proactive
/// prefills waiting at a *fresh* (layer 0) static chunk big enough to
/// cut in two (§5.2 elastic splitting).
fn scan_split_candidates(states: &States) -> Vec<ReqId> {
    let mut v: Vec<ReqId> = states
        .values() // lint:allow(no-unordered-iteration) collected then sorted by id below
        .filter(|s| {
            !s.is_reactive()
                && s.phase == Phase::Prefilling
                && !s.running
                && s.layer_idx() == 0
                && s.current_chunk().map(|c| !c.dynamic && c.valid >= 2).unwrap_or(false)
        })
        .map(|s| s.id())
        .collect();
    v.sort_unstable();
    v
}

/// Preemption accounting (§6.2): whenever a reactive prefill kernel
/// launches while a mid-prefill proactive task waits at its
/// kernel-boundary checkpoint, that task is preempted — counted once
/// per wait episode (the flag clears when the victim runs again).
/// Victims come from the waiting-proactive index narrowed by the
/// progress/counted flags, in ascending id order (the counters this
/// feeds are order-independent).
fn account_preemption(ctx: &mut PolicyCtx<'_>) {
    let mut victims = ctx.take_id_buf();
    ctx.waiting_proactive_prefills_into(&mut victims);
    victims.retain(|id| {
        let s = ctx.state(*id);
        !s.preempt_counted && s.prefill_started()
    });
    debug_assert_eq!(
        victims,
        scan_preemption_victims(ctx.states()),
        "preemption-victim set diverged from a state scan"
    );
    for k in 0..victims.len() {
        ctx.mark_preempted(victims[k]);
    }
    ctx.put_id_buf(victims);
}

/// The reusable XPU-coordinator decision pipeline (§5/§6): one
/// `schedule` pass per engine step, ranking points delegated to the
/// policy's hooks.  Stateless across steps — all run state lives in
/// the driver, all knobs in [`SchedulerConfig`].
pub struct XpuCoordinator {
    pub sched: SchedulerConfig,
    ann: Annotator,
    geo: ModelGeometry,
    max_chunk: usize,
    npu: usize,
    igpu: usize,
    /// DRAM-budget admission control (§6.5 memory management).
    governor: MemoryGovernor,
}

impl XpuCoordinator {
    pub fn new(geo: ModelGeometry, soc: &SocConfig, sched: SchedulerConfig) -> Self {
        let xpus: Vec<XpuModel> = soc.xpus.iter().cloned().map(XpuModel::new).collect();
        let ann = Annotator::new(geo.clone(), xpus);
        let npu = ann.xpu_index("npu").expect("soc needs an npu");
        let igpu = ann.xpu_index("igpu").expect("soc needs an igpu");
        let max_chunk = max_chunk_within_budget(
            &geo,
            &[&ann.xpus[npu], &ann.xpus[igpu]],
            sched.chunk_latency_budget_ms,
        );
        let governor = MemoryGovernor::new(&geo, soc);
        Self { sched, ann, geo, max_chunk, npu, igpu, governor }
    }

    /// Chunk-size cap for `Driver::admit_ready` (elastic planning
    /// within the §6.2 latency budget).
    pub fn max_chunk(&self) -> usize {
        self.max_chunk
    }

    /// The "prefill XPU" under disaggregation is the NPU; colocated mode
    /// (ablation) funnels everything through the iGPU.
    fn prefill_xpu(&self) -> usize {
        if self.sched.disaggregation { self.npu } else { self.igpu }
    }

    /// §6.5 memory management: may `id`'s prefill start (allocate its
    /// KV) right now?  Started requests always continue (their KV is
    /// already resident).  Under pressure the governor sheds residency
    /// cheapest-first: idle retained session caches go LRU-first (a
    /// dropped session only costs one conversation-prefix recompute),
    /// then a reactive request that still does not fit evicts the
    /// policy's preferred waiting prefill victim (graceful degradation
    /// — its context is recomputed later, like scheme (a)).
    fn memory_admit<H: SchedPolicy + ?Sized>(
        &self,
        ctx: &mut PolicyCtx<'_>,
        id: ReqId,
        hooks: &H,
    ) -> bool {
        // A claimed session cache counts as already-resident KV: the
        // slot moved from the pool's books onto this request at
        // admission, so "starting" it allocates nothing new.
        let (started, reactive) = {
            let st = ctx.state(id);
            (st.prefill_started() || st.cached_prefix_len > 0, st.is_reactive())
        };
        if started
            || self
                .governor
                .can_start_with_sessions(ctx.states(), ctx.retained_sessions())
        {
            return true;
        }
        if !reactive {
            // Defer the proactive start until memory frees — without
            // shedding sessions: evicting reactive chat state to admit
            // background work would invert the priority order, and a
            // deferred start gains nothing from the eviction anyway.
            return false;
        }
        // First valve for reactive arrivals: drop idle sessions,
        // least-recently-used first (cheapest residency to rebuild).
        while ctx.evict_lru_session().is_some() {
            if self
                .governor
                .can_start_with_sessions(ctx.states(), ctx.retained_sessions())
            {
                return true;
            }
        }
        if let Some(victim) = hooks.eviction_victim(&self.governor, ctx.states()) {
            ctx.evict_prefill(victim, &self.geo); // RunReport::kv_evictions
            return true;
        }
        true // nothing evictable: admit anyway (paper's moderate-density assumption)
    }

    fn resume_ctx<'a>(&'a self, ctx: &'a PolicyCtx<'_>, xpu: usize) -> ResumeCtx<'a> {
        ResumeCtx {
            states: ctx.states(),
            ann: &self.ann,
            xpu,
            now_us: ctx.now(),
            starvation_age_us: self.sched.starvation_age_ms * 1e3,
            critical_path: self.sched.critical_path_priority,
        }
    }

    /// Assemble the iGPU duty governor's question for a candidate
    /// proactive kernel of `nominal_us` (see
    /// [`SchedPolicy::igpu_proactive_grant`]).
    fn igpu_gate_ctx(&self, ctx: &PolicyCtx<'_>, nominal_us: f64) -> IgpuGateCtx {
        IgpuGateCtx {
            duty_cap: self.sched.igpu_duty_cap,
            yield_to_graphics: self.sched.yield_to_graphics,
            duty: ctx.windowed_duty(self.igpu),
            frame_pending: ctx.would_delay_next_frame(nominal_us),
            now_us: ctx.now(),
        }
    }

    /// §6.5 aging valve for the duty governor: a proactive candidate
    /// that has made no progress for a full starvation age bypasses
    /// the gate — a veto defers work, it can never starve it.  Keyed
    /// off `last_progress_us`, not `enqueued_at_us`: a decode lane
    /// served every iteration keeps a fresh progress stamp (so an old
    /// enqueue time cannot permanently un-gate the governor), while a
    /// genuinely vetoed candidate ages to the valve.
    fn starved(&self, ctx: &PolicyCtx<'_>, id: ReqId) -> bool {
        let st = ctx.state(id);
        let since = st.enqueued_at_us.max(st.last_progress_us);
        ctx.now() - since > self.sched.starvation_age_ms * 1e3
    }

    /// A governor veto is time-gated (window decay, frame cadence,
    /// starvation aging), not evented: schedule the retry pass, or a
    /// vetoed-and-otherwise-idle DES would end with unfinished work.
    fn governor_retry(&self, ctx: &mut PolicyCtx<'_>) {
        ctx.request_wakeup(ctx.now() + crate::soc::DUTY_WINDOW_US / 8.0);
    }

    /// Annotate one decode iteration over `lanes` (mean context).
    fn decode_annotation(
        &self,
        ctx: &PolicyCtx<'_>,
        lanes: &[ReqId],
    ) -> crate::heg::Annotated {
        let avg_ctx = (lanes.iter().map(|id| ctx.state(*id).pos).sum::<usize>()
            / lanes.len())
        .max(1);
        self.ann.decode_iter(lanes.len(), avg_ctx)
    }

    /// Co-run DDR-penalty factor for launching `chunk` on `xpu` (§5.3
    /// asymmetric contention model): `1.0` for plan-time chunks — the
    /// launch path stays bit-identical to the pre-elastic engine — and
    /// the per-XPU penalty for the parts of a mid-flight split, whose
    /// memory phases contend with the sibling part's traffic.
    fn co_run_factor(&self, chunk: &ChunkSpec, xpu: usize) -> f64 {
        if !chunk.co_run {
            1.0
        } else if xpu == self.npu {
            CO_RUN_DDR_PENALTY_NPU
        } else {
            CO_RUN_DDR_PENALTY_IGPU
        }
    }

    /// Elastic fold (§5.2): a *proactive* dynamic margin waiting on a
    /// duty-squeezed iGPU may re-bind to the idle prefill NPU — padded
    /// up to the next compiled static variant — instead of holding the
    /// whole request until the governor's window decays.  Consults the
    /// policy's [`SchedPolicy::rebind`] hook; returns true if the
    /// folded chunk launched.
    fn try_fold_margin<H: SchedPolicy + ?Sized>(
        &self,
        ctx: &mut PolicyCtx<'_>,
        id: ReqId,
        chunk: &ChunkSpec,
        hooks: &H,
    ) -> bool {
        let Some(variant) = self.geo.chunk_for(chunk.valid) else { return false };
        let igpu_t = *self.ann.prefill_kernel(chunk).timing_on(self.igpu);
        let squeezed = !self.starved(ctx, id)
            && !hooks.igpu_proactive_grant(&self.igpu_gate_ctx(ctx, igpu_t.nominal_us));
        let folded_spec = ChunkSpec { variant, dynamic: false, ..*chunk };
        let npu_t = *self.ann.prefill_kernel(&folded_spec).timing_on(self.npu);
        let r = RebindCtx {
            margin: true,
            igpu_squeezed: squeezed,
            npu_pinned_reactive: false,
            npu_margin_us: npu_t.nominal_us,
            igpu_margin_us: igpu_t.nominal_us,
            whole_igpu_us: igpu_t.nominal_us,
            npu_wait_us: 0.0,
            split_ratio: 0.0,
            split_us: f64::INFINITY,
            now_us: ctx.now(),
        };
        if hooks.rebind(&r) != RebindDecision::FoldToNpu {
            return false;
        }
        let Some(folded) = ctx.fold_margin(id, &self.geo) else { return false };
        let timing = *self.ann.prefill_kernel(&folded).timing_on(self.npu);
        if dispatch_check(ctx.sim(), &self.sched, &timing, false)
            == DispatchDecision::Defer
        {
            // Folded but deferred: the chunk is static now, so the
            // normal prefill pipeline launches it on a later pass.
            return false;
        }
        ctx.launch_with_factor(
            self.npu,
            timing,
            false,
            KernelTag::Prefill { req: id },
            self.co_run_factor(&folded, self.npu),
        );
        true
    }

    /// Elastic split (§5.2): before committing a whole static chunk to
    /// the slower iGPU as inter-XPU backfill, ask the policy whether to
    /// cut it — co-run a slice here *now*, leaving the rest as a static
    /// NPU chunk for when the reactive prefill drains.  The proposed
    /// ratio sizes the iGPU slice to roughly half the NPU's pinned
    /// window, so the slice (with its co-run DDR penalty) finishes
    /// comfortably inside it.  Returns true if this candidate was
    /// consumed (split launched, or split applied but deferred).
    fn try_split_backfill<H: SchedPolicy + ?Sized>(
        &self,
        ctx: &mut PolicyCtx<'_>,
        id: ReqId,
        chunk: &ChunkSpec,
        whole_igpu_us: f64,
        hooks: &H,
    ) -> bool {
        if chunk.co_run || chunk.valid < 2 || ctx.state(id).layer_idx() != 0 {
            return false; // only an unstarted, never-split static head can split
        }
        let npu_pinned_reactive =
            ctx.sim().running_class(self.npu) == Some(KernelClass::Reactive);
        let npu_wait_us = ctx.sim().remaining_on(self.npu).unwrap_or(0.0);
        let ratio = (0.5 * npu_wait_us / whole_igpu_us).clamp(0.25, 0.5);
        // Predict the slice's co-run duration exactly as the simulator
        // will model it (mirrors `ElasticPlan::split`'s token count).
        let k = ((chunk.valid as f64 * ratio).round() as usize).clamp(1, chunk.valid - 1);
        let slice = ChunkSpec {
            variant: k,
            valid: k,
            pos: chunk.pos,
            dynamic: true,
            co_run: true,
        };
        let split_us =
            self.ann.prefill_kernel(&slice).co_run_us(self.igpu, CO_RUN_DDR_PENALTY_IGPU);
        let r = RebindCtx {
            margin: false,
            igpu_squeezed: false,
            npu_pinned_reactive,
            npu_margin_us: 0.0,
            igpu_margin_us: 0.0,
            whole_igpu_us,
            npu_wait_us,
            split_ratio: ratio,
            split_us,
            now_us: ctx.now(),
        };
        let RebindDecision::Split { ratio } = hooks.rebind(&r) else { return false };
        let Some((_npu_part, igpu_part)) = ctx.split_head(id, &self.geo, ratio) else {
            return false;
        };
        let timing = *self.ann.prefill_kernel(&igpu_part).timing_on(self.igpu);
        if dispatch_check(ctx.sim(), &self.sched, &timing, false)
            == DispatchDecision::Defer
        {
            // Split applied but deferred: the dynamic co-run part is now
            // the current chunk, so the margin path picks it up later.
            return true;
        }
        ctx.note_backfill();
        ctx.launch_with_factor(
            self.igpu,
            timing,
            false,
            KernelTag::Prefill { req: id },
            self.co_run_factor(&igpu_part, self.igpu),
        );
        true
    }

    // -- NPU side: the prefill pipeline ---------------------------------

    fn schedule_prefill_pipeline<H: SchedPolicy + ?Sized>(
        &self,
        ctx: &mut PolicyCtx<'_>,
        hooks: &H,
    ) {
        let pxpu = self.prefill_xpu();
        if ctx.busy(pxpu) {
            return;
        }
        // Reactive first (kernel-level preemption: we are at a kernel
        // boundary by construction — the pipeline is idle).  Both
        // candidate lists come from the driver's phase index through
        // pooled scratch buffers — no per-step `states` scan and no
        // allocation on the steady-state path.
        let mut reactive = ctx.take_id_buf();
        ctx.waiting_reactive_prefills_into(&mut reactive);
        debug_assert_eq!(
            reactive,
            scan_waiting_reactive(ctx.states()),
            "waiting-reactive-prefill index diverged from a state scan"
        );
        hooks.admission_order(ctx.states(), &mut reactive);
        let mut proactive = ctx.take_id_buf();
        ctx.waiting_proactive_prefills_into(&mut proactive);
        debug_assert_eq!(
            proactive,
            scan_waiting_proactive(ctx.states()),
            "waiting-proactive-prefill index diverged from a state scan"
        );
        hooks.resume_order(self.resume_ctx(ctx, pxpu), &mut proactive);

        let pick = if self.sched.preemption {
            reactive.first().copied().or_else(|| proactive.first().copied())
        } else {
            // no-preemption ablation: FCFS across classes
            let mut all = [reactive.as_slice(), proactive.as_slice()].concat();
            let states = ctx.states();
            all.sort_by(|a, b| {
                states[a]
                    .req
                    .arrival_us
                    .total_cmp(&states[b].req.arrival_us)
                    .then(a.cmp(b))
            });
            all.first().copied()
        };
        ctx.put_id_buf(reactive);
        ctx.put_id_buf(proactive);
        let Some(id) = pick else { return };
        if !self.memory_admit(ctx, id, hooks) {
            return;
        }

        let (chunk, reactive_k) = {
            let st = ctx.state(id);
            (*st.current_chunk().expect("prefilling has a chunk"), st.is_reactive())
        };
        // Elastic binding: dynamic margin chunks prefer the iGPU (§5.2);
        // if the iGPU is busy they wait for it unless this XPU *is* the
        // iGPU already (colocated mode).  A proactive margin may instead
        // fold back to this (idle) NPU when the policy's rebind hook
        // says the iGPU is squeezed.
        if chunk.dynamic && self.sched.disaggregation {
            if !reactive_k && self.try_fold_margin(ctx, id, &chunk, hooks) {
                return;
            }
            return; // the iGPU side will pick it up
        }
        let annotated = self.ann.prefill_kernel(&chunk);
        let timing = *annotated.timing_on(pxpu);
        if dispatch_check(ctx.sim(), &self.sched, &timing, reactive_k)
            == DispatchDecision::Defer
        {
            return;
        }
        if reactive_k {
            account_preemption(ctx);
        }
        ctx.launch_with_factor(
            pxpu,
            timing,
            reactive_k,
            KernelTag::Prefill { req: id },
            self.co_run_factor(&chunk, pxpu),
        );
    }

    // -- iGPU side: decode pipeline, margins, inter-XPU backfill --------

    fn schedule_decode_pipeline<H: SchedPolicy + ?Sized>(
        &self,
        ctx: &mut PolicyCtx<'_>,
        hooks: &H,
    ) {
        if ctx.busy(self.igpu) {
            return;
        }
        let reactive_present = ctx.reactive_live();
        debug_assert_eq!(
            reactive_present,
            reactive_active(ctx.states()),
            "live-reactive index diverged from a state scan"
        );

        // (1) A reactive dynamic margin chunk gates that request's TTFT:
        // it outranks everything on the iGPU.
        if self.sched.disaggregation && self.try_margin_chunk(ctx, true, hooks) {
            return;
        }

        // (2) Proactive margin chunks outrank proactive-only decode:
        // finishing a prefill feeds the decode batch (the ETC rationale
        // of §6.2's resumption strategy) — but never delay a decode
        // batch that carries a reactive lane.
        let rt_decoding = ctx.has_idle_reactive_decoder();
        debug_assert_eq!(
            rt_decoding,
            scan_idle_decoder(ctx.states(), true),
            "idle-reactive-decoder index diverged from a state scan"
        );
        if self.sched.disaggregation
            && !rt_decoding
            && self.try_margin_chunk(ctx, false, hooks)
        {
            return;
        }

        // (3) Decode iteration with adaptive batching + intra-XPU
        // backfill (proactive lanes join at the boundary when allowed).
        // The idle-decoder index short-circuits the section — and the
        // policy's O(states) lane scan — when nothing can decode; the
        // lane buffer itself is pooled and, on launch, moves into the
        // kernel tag instead of being copied.
        let allow_join = self.sched.backfill || !reactive_present;
        debug_assert_eq!(
            ctx.has_idle_decoder(),
            scan_idle_decoder(ctx.states(), false),
            "idle-decoder index diverged from a state scan"
        );
        if ctx.has_idle_decoder() {
            let mut lanes = ctx.take_id_buf();
            let mut any_rt = hooks.decode_batch(
                ctx.states(),
                self.sched.b_max,
                allow_join,
                ctx.now(),
                &mut lanes,
            );
            if !lanes.is_empty() {
                let mut timing =
                    *self.decode_annotation(ctx, &lanes).timing_on(self.igpu);
                // iGPU duty governor: proactive lanes — joins *and* whole
                // proactive batches — need a grant (unless starved).  A veto
                // drops the proactive lanes; reactive lanes always decode.
                let gated = lanes.iter().any(|id| !ctx.state(*id).is_reactive())
                    && !lanes.iter().any(|id| self.starved(ctx, *id))
                    && !hooks
                        .igpu_proactive_grant(&self.igpu_gate_ctx(ctx, timing.nominal_us));
                if gated {
                    self.governor_retry(ctx);
                    lanes.retain(|id| ctx.state(*id).is_reactive());
                    any_rt = !lanes.is_empty();
                    if !lanes.is_empty() {
                        timing =
                            *self.decode_annotation(ctx, &lanes).timing_on(self.igpu);
                    }
                }
                if !lanes.is_empty()
                    && dispatch_check(ctx.sim(), &self.sched, &timing, any_rt)
                        == DispatchDecision::Launch
                {
                    let backfilled =
                        any_rt && lanes.iter().any(|id| !ctx.state(*id).is_reactive());
                    if backfilled {
                        ctx.note_backfill();
                    }
                    ctx.launch(self.igpu, timing, any_rt, KernelTag::DecodeIter { lanes });
                    return;
                }
                // decode deferred: fall through to cheaper candidates
            }
            ctx.put_id_buf(lanes);
        }

        if !self.sched.disaggregation {
            return; // colocated mode: prefill handled by the other branch
        }

        // (4) Proactive dynamic margin chunks (the non-rt-decoding case
        // was already handled above).
        if self.try_margin_chunk(ctx, false, hooks) {
            return;
        }

        // (5) Inter-XPU backfill (§6.3): proactive (or starved) prefill
        // fills the iGPU bubble while the NPU is held by reactive
        // prefill; also plain structural slack when the NPU is busy.
        if !self.sched.backfill {
            return;
        }
        if !ctx.busy(self.prefill_xpu()) {
            return; // structural slack only
        }
        // Candidates come from the driver's incrementally maintained
        // waiting-proactive-prefill index through a pooled buffer — a
        // full `states` scan (and a fresh Vec) per step was the old hot
        // path; the debug assert proves the index always matches it, so
        // schedules are bit-identical.
        let mut cands = ctx.take_id_buf();
        ctx.waiting_proactive_prefills_into(&mut cands);
        debug_assert_eq!(
            cands,
            scan_waiting_proactive(ctx.states()),
            "waiting-proactive-prefill index diverged from a state scan"
        );
        if cands.is_empty() {
            ctx.put_id_buf(cands);
            return;
        }
        // Ranked by the policy's resumption hook (§6.2 default:
        // starvation age → continuation → critical path → ETC): the
        // candidates share one kernel shape class on the iGPU, so this
        // is the tiebreak that decides which proactive prefill claims
        // the backfill bubble.
        hooks.resume_order(self.resume_ctx(ctx, self.igpu), &mut cands);
        #[cfg(debug_assertions)]
        {
            let mut sc = ctx.take_id_buf();
            ctx.split_candidates_into(&mut sc);
            debug_assert_eq!(
                sc,
                scan_split_candidates(ctx.states()),
                "split-candidate index diverged from a state scan"
            );
            ctx.put_id_buf(sc);
        }
        for k in 0..cands.len() {
            let id = cands[k];
            let chunk = {
                let st = ctx.state(id);
                *st.current_chunk().unwrap()
            };
            if chunk.dynamic {
                continue; // handled by try_margin_chunk
            }
            if !self.memory_admit(ctx, id, hooks) {
                continue;
            }
            let annotated = self.ann.prefill_kernel(&chunk);
            let timing = *annotated.timing_on(self.igpu);
            // iGPU duty governor: inter-XPU backfill is the biggest
            // opportunistic iGPU consumer — gate it first (§8.1
            // controlled iGPU usage), starvation valve excepted.
            if !self.starved(ctx, id)
                && !hooks.igpu_proactive_grant(&self.igpu_gate_ctx(ctx, timing.nominal_us))
            {
                self.governor_retry(ctx);
                continue;
            }
            // Elastic split (§5.2) consult precedes whole-chunk backfill.
            if self.try_split_backfill(ctx, id, &chunk, timing.nominal_us, hooks) {
                ctx.put_id_buf(cands);
                return;
            }
            // Backfill constraints (§6.3): duration within the reactive
            // window (chunking bounds this), memory threshold (Alg. 1).
            if dispatch_check(ctx.sim(), &self.sched, &timing, false)
                == DispatchDecision::Launch
            {
                ctx.note_backfill();
                ctx.launch_with_factor(
                    self.igpu,
                    timing,
                    false,
                    KernelTag::Prefill { req: id },
                    self.co_run_factor(&chunk, self.igpu),
                );
                ctx.put_id_buf(cands);
                return;
            }
        }
        ctx.put_id_buf(cands);
    }

    /// Launch the next *dynamic* (margin) chunk of a reactive/proactive
    /// request on the iGPU.  Returns true if launched.
    fn try_margin_chunk<H: SchedPolicy + ?Sized>(
        &self,
        ctx: &mut PolicyCtx<'_>,
        reactive: bool,
        hooks: &H,
    ) -> bool {
        let mut cands = ctx.take_id_buf();
        ctx.dynamic_chunk_candidates_into(reactive, &mut cands);
        debug_assert_eq!(
            cands,
            scan_dynamic_chunks(ctx.states(), reactive),
            "dynamic-chunk index diverged from a state scan"
        );
        hooks.admission_order(ctx.states(), &mut cands);
        let pick = cands.first().copied();
        ctx.put_id_buf(cands);
        let Some(id) = pick else { return false };
        if !self.memory_admit(ctx, id, hooks) {
            return false;
        }
        let chunk = {
            let st = ctx.state(id);
            *st.current_chunk().unwrap()
        };
        let annotated = self.ann.prefill_kernel(&chunk);
        let timing = *annotated.timing_on(self.igpu);
        // iGPU duty governor: proactive margins are opportunistic iGPU
        // placements like any other (reactive margins are never gated).
        if !reactive
            && !self.starved(ctx, id)
            && !hooks.igpu_proactive_grant(&self.igpu_gate_ctx(ctx, timing.nominal_us))
        {
            self.governor_retry(ctx);
            return false;
        }
        if dispatch_check(ctx.sim(), &self.sched, &timing, reactive)
            == DispatchDecision::Defer
        {
            return false;
        }
        if reactive {
            account_preemption(ctx);
        }
        ctx.launch_with_factor(
            self.igpu,
            timing,
            reactive,
            KernelTag::Prefill { req: id },
            self.co_run_factor(&chunk, self.igpu),
        );
        true
    }

    /// Deadlock guard: if nothing is running, nothing was launched, and
    /// work remains, force-launch the most urgent kernel (WaitForSlot
    /// has nothing to wait for on an idle SoC — dispatch_check already
    /// allows this, so this only fires for margin-vs-busy-iGPU corner
    /// cases).
    fn force_progress(&self, ctx: &mut PolicyCtx<'_>) {
        if !ctx.all_idle() {
            return;
        }
        // any runnable prefill (incl. dynamic margins on the NPU with
        // JIT) — reactive first, then aged proactive
        let mut cands = ctx.take_id_buf();
        ctx.waiting_prefills_into(&mut cands);
        debug_assert_eq!(
            cands,
            scan_waiting_prefills(ctx.states()),
            "waiting-prefill union index diverged from a state scan"
        );
        if cands.is_empty() {
            ctx.put_id_buf(cands);
            return;
        }
        {
            let states = ctx.states();
            cands.sort_by(|a, b| {
                let (sa, sb) = (&states[a], &states[b]);
                sb.is_reactive()
                    .cmp(&sa.is_reactive())
                    .then(sa.req.arrival_us.total_cmp(&sb.req.arrival_us))
                    .then(a.cmp(b))
            });
        }
        let id = cands[0];
        ctx.put_id_buf(cands);
        let (chunk, reactive) = {
            let st = ctx.state(id);
            (*st.current_chunk().unwrap(), st.is_reactive())
        };
        let annotated = self.ann.prefill_kernel(&chunk);
        // run on the iGPU if dynamic, NPU otherwise
        let xpu = if chunk.dynamic { self.igpu } else { self.prefill_xpu() };
        let timing = *annotated.timing_on(xpu);
        ctx.launch_with_factor(
            xpu,
            timing,
            reactive,
            KernelTag::Prefill { req: id },
            self.co_run_factor(&chunk, xpu),
        );
    }

    /// One full coordinator pass: prefill pipeline, decode pipeline,
    /// deadlock guard — consulting `hooks` at every ranking point.
    pub fn schedule<H: SchedPolicy + ?Sized>(&self, ctx: &mut PolicyCtx<'_>, hooks: &H) {
        self.schedule_prefill_pipeline(ctx, hooks);
        self.schedule_decode_pipeline(ctx, hooks);
        self.force_progress(ctx);
    }
}

/// The paper's scheduling policy: the [`XpuCoordinator`] pipeline with
/// every narrower hook at its §6 default.
pub struct AgentXpuPolicy {
    pub coord: XpuCoordinator,
}

impl AgentXpuPolicy {
    pub fn new(geo: ModelGeometry, soc: &SocConfig, sched: SchedulerConfig) -> Self {
        Self { coord: XpuCoordinator::new(geo, soc, sched) }
    }
}

impl SchedPolicy for AgentXpuPolicy {
    fn label(&self) -> String {
        "agent.xpu".into()
    }

    fn max_chunk(&self) -> usize {
        self.coord.max_chunk()
    }

    fn session_capacity(&self) -> usize {
        self.coord.sched.session_capacity
    }

    fn decide(&mut self, mut ctx: PolicyCtx<'_>) -> Vec<Action> {
        let this = &*self;
        this.coord.schedule(&mut ctx, this);
        ctx.take_actions()
    }

    /// §5.2 elastic re-binding, agent.xpu defaults: fold a margin to
    /// the NPU the moment the duty governor squeezes it off the iGPU
    /// (waiting out a governor window idles the prefill pipeline for
    /// nothing); split a head chunk only when the NPU is pinned by
    /// reactive prefill *and* the annotated co-run model predicts the
    /// iGPU slice beats both whole-chunk backfill and plain waiting.
    fn rebind(&self, r: &RebindCtx) -> RebindDecision {
        if r.margin {
            if r.igpu_squeezed {
                return RebindDecision::FoldToNpu;
            }
            return RebindDecision::Never;
        }
        if r.npu_pinned_reactive && r.split_us < r.whole_igpu_us.min(r.npu_wait_us) {
            return RebindDecision::Split { ratio: r.split_ratio };
        }
        RebindDecision::Never
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;
    use crate::engine::Engine;
    use crate::workload::{Priority, Request};

    fn geo() -> ModelGeometry {
        let mut g = crate::config::llama32_3b();
        g.n_layers = 4; // keep DES unit tests fast
        g
    }

    fn engine() -> AgentXpuEngine {
        AgentXpuEngine::synthetic(geo(), default_soc(), SchedulerConfig::default())
    }

    fn req(id: u64, prio: Priority, arrival: f64, plen: usize, out: usize) -> Request {
        Request {
            id,
            priority: prio,
            arrival_us: arrival,
            prompt: vec![1; plen],
            max_new_tokens: out,
            profile: "test".into(),
            flow: None,
        }
    }

    /// A hand-built multi-turn reactive flow (see driver tests).
    fn flow(flow_id: u64, first_id: u64, arrival: f64, turns: usize, think_us: f64) -> Vec<Request> {
        let (p0, out, delta) = (128usize, 6usize, 48usize);
        let mut out_reqs = vec![];
        let mut prompt = vec![1i32; p0];
        for k in 0..turns {
            if k > 0 {
                let ds = prompt.len() + out;
                prompt = vec![2; ds];
                prompt.extend(vec![1; delta]);
            }
            out_reqs.push(Request {
                id: first_id + k as u64,
                priority: Priority::Reactive,
                arrival_us: arrival,
                prompt: prompt.clone(),
                max_new_tokens: out,
                profile: "flow".into(),
                flow: Some(crate::workload::FlowBinding::linear(
                    flow_id,
                    k,
                    turns,
                    if k == 0 { 0.0 } else { think_us },
                    if k == 0 { 0 } else { prompt.len() - delta },
                )),
            });
        }
        out_reqs
    }

    #[test]
    fn completes_a_single_reactive_request() {
        let rep = engine().run(vec![req(1, Priority::Reactive, 0.0, 300, 10)]).unwrap();
        let m = &rep.reqs[0];
        assert!(m.finished());
        assert_eq!(m.output_tokens, 10);
        assert!(m.ttft_us().unwrap() > 0.0);
    }

    #[test]
    fn completes_mixed_load() {
        let mut trace = vec![];
        for i in 0..6 {
            trace.push(req(i, Priority::Proactive, i as f64 * 50_000.0, 256, 12));
        }
        trace.push(req(100, Priority::Reactive, 120_000.0, 128, 8));
        let rep = engine().run(trace).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 7);
    }

    #[test]
    fn reactive_latency_unaffected_by_proactive_load() {
        // the paper's headline property: reactive TTFT stays ~flat as
        // proactive rate grows (Fig. 7)
        let solo = engine()
            .run(vec![req(1, Priority::Reactive, 0.0, 256, 8)])
            .unwrap();
        let solo_ttft = solo.reqs[0].ttft_us().unwrap();

        let mut trace: Vec<Request> = (0..10)
            .map(|i| req(i, Priority::Proactive, i as f64 * 30_000.0, 400, 20))
            .collect();
        trace.push(req(100, Priority::Reactive, 200_000.0, 256, 8));
        let busy = engine().run(trace).unwrap();
        let busy_ttft = busy
            .reqs
            .iter()
            .find(|m| m.id == 100)
            .unwrap()
            .ttft_us()
            .unwrap();
        assert!(
            busy_ttft < 3.0 * solo_ttft,
            "reactive TTFT under load {busy_ttft} vs solo {solo_ttft}"
        );
    }

    #[test]
    fn preemption_is_counted_under_contention() {
        // Two long proactive prefills (4 chunks x 4 layers each) occupy
        // both pipelines; the reactive arrival must displace one of them
        // at a kernel boundary.
        let mut trace: Vec<Request> = (0..2)
            .map(|i| req(i, Priority::Proactive, 0.0, 2048, 4))
            .collect();
        trace.push(req(100, Priority::Reactive, 100_000.0, 256, 4));
        let rep = engine().run(trace).unwrap();
        assert!(rep.preemptions >= 1, "reactive arrival mid-proactive-prefill must preempt");
    }

    #[test]
    fn backfill_happens_with_mixed_decode() {
        let mut trace: Vec<Request> = (0..4)
            .map(|i| req(i, Priority::Proactive, 0.0, 128, 30))
            .collect();
        trace.push(req(100, Priority::Reactive, 10_000.0, 128, 30));
        let rep = engine().run(trace).unwrap();
        assert!(rep.backfills >= 1, "proactive work should backfill");
    }

    #[test]
    fn ablation_engines_still_complete() {
        for (b, p, dg) in
            [(false, true, true), (true, false, true), (true, true, false), (false, false, false)]
        {
            let mut sched = SchedulerConfig::default();
            sched.backfill = b;
            sched.preemption = p;
            sched.disaggregation = dg;
            let mut e = AgentXpuEngine::synthetic(geo(), default_soc(), sched);
            let mut trace: Vec<Request> = (0..4)
                .map(|i| req(i, Priority::Proactive, i as f64 * 40_000.0, 200, 10))
                .collect();
            trace.push(req(100, Priority::Reactive, 100_000.0, 150, 6));
            let rep = e.run(trace).unwrap();
            assert_eq!(
                rep.reqs.iter().filter(|m| m.finished()).count(),
                5,
                "ablation (backfill={b},preempt={p},disagg={dg}) must finish"
            );
        }
    }

    #[test]
    fn flow_turns_reuse_session_kv() {
        let rep = engine().run(flow(7, 0, 0.0, 3, 30_000.0)).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        for m in rep.reqs.iter().filter(|m| m.turn_idx > 0) {
            assert!(
                m.cached_prefix_len > 0,
                "turn {} must admit from the session pool",
                m.turn_idx
            );
            assert_eq!(m.prefill_tokens, m.input_len - m.cached_prefix_len);
        }
        assert!((rep.prefix_cache_hit_rate() - 1.0).abs() < 1e-9);
        let flows = rep.flows();
        assert_eq!(flows.len(), 1);
        assert!(flows[0].finished);
        assert!(flows[0].e2e_us.unwrap() > 0.0);
    }

    #[test]
    fn workflow_dags_complete_with_tool_nodes_on_the_cpu() {
        use crate::workload::{DagShape, DagSpec, dag_flow_trace, flatten_flows, profile};
        let spec = DagSpec {
            profile: profile("proactivebench").unwrap(),
            flow_rate_per_s: 0.05,
            think_time_s: 4.0,
            shape: DagShape::MapReduce { fanout: 3 },
            duration_s: 60.0,
            seed: 11,
            max_seq: 2048,
        };
        let flows = dag_flow_trace(&spec, Priority::Proactive, 2048, 0, 0);
        let trace = flatten_flows(flows);
        assert!(!trace.is_empty());
        let total = trace.len();
        let rep = engine().run(trace).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), total);
        // tool nodes ran on the CPU, LLM turns on NPU/iGPU
        assert!(rep.reqs.iter().any(|m| m.tool));
        assert!(rep.utilization("cpu") > 0.0);
        // every flow's makespan is bounded below by its critical path
        for f in rep.flows() {
            assert!(f.finished);
            assert!(
                f.e2e_us.unwrap() + 1e-6 >= f.critical_path_us.unwrap(),
                "flow {}: makespan below its critical path",
                f.flow_id
            );
        }
    }

    #[test]
    fn session_capacity_zero_disables_reuse() {
        let mut sched = SchedulerConfig::default();
        sched.session_capacity = 0;
        let mut e = AgentXpuEngine::synthetic(geo(), default_soc(), sched);
        let rep = e.run(flow(7, 0, 0.0, 3, 30_000.0)).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        assert!(rep.reqs.iter().all(|m| m.cached_prefix_len == 0));
        assert!(rep.prefix_cache_hit_rate().abs() < 1e-9);
    }

    /// Satellite: reactive arrival under memory pressure evicts the
    /// least-progressed waiting proactive prefill; the victim's
    /// restart_prefill resets its plan and it still completes.
    #[test]
    fn reactive_arrival_under_pressure_evicts_proactive_prefill() {
        let g = geo();
        let mut soc = default_soc();
        // room for weights + ~2 KV slots only
        let weights_gb = g.n_params() as f64 * g.weight_bytes / 1e9;
        let kv_gb = (2 * g.n_layers * g.cache_elems() * 4) as f64 / 1e9;
        soc.dram_gb = weights_gb + 2.2 * kv_gb;
        let mut e = AgentXpuEngine::synthetic(g, soc, SchedulerConfig::default());
        let mut trace: Vec<Request> = (0..3)
            .map(|i| req(i, Priority::Proactive, 0.0, 1800, 4))
            .collect();
        trace.push(req(100, Priority::Reactive, 120_000.0, 256, 4));
        let rep = e.run(trace).unwrap();
        assert!(
            rep.kv_evictions >= 1,
            "reactive under pressure must evict a proactive prefill"
        );
        // nothing is lost: the victim recomputed and finished
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 4);
        // the victim's restart shows up as extra prefilled tokens
        assert!(
            rep.reqs
                .iter()
                .any(|m| m.priority == Priority::Proactive
                    && m.prefill_tokens > m.input_len),
            "a restarted prefill recomputes chunks it had already run"
        );
    }

    /// Satellite: idle retained sessions are the first thing the
    /// governor sheds — LRU-first, before touching any in-flight work.
    #[test]
    fn idle_sessions_evicted_lru_first_under_pressure() {
        let g = geo();
        let mut soc = default_soc();
        let weights_gb = g.n_params() as f64 * g.weight_bytes / 1e9;
        let kv_gb = (2 * g.n_layers * g.cache_elems() * 4) as f64 / 1e9;
        // weights + ~1.5 KV slots: an idle session + a new start can
        // never coexist
        soc.dram_gb = weights_gb + 1.5 * kv_gb;
        let mut e = AgentXpuEngine::synthetic(g, soc, SchedulerConfig::default());
        // flow turn 0 finishes and parks its session; a big single-shot
        // arrives during the think-time window
        let mut trace = flow(7, 0, 0.0, 2, 3_000_000.0);
        trace.push(req(100, Priority::Reactive, 1_000_000.0, 512, 4));
        let rep = e.run(trace).unwrap();
        assert!(
            rep.session_evictions >= 1,
            "the idle session must be dropped to fit the arrival"
        );
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        // the evicted session forces turn 1 back to full recompute
        let turn1 = rep.reqs.iter().find(|m| m.turn_idx == 1).unwrap();
        assert_eq!(turn1.cached_prefix_len, 0);
        assert_eq!(turn1.prefill_tokens, turn1.input_len);
        // no in-flight prefill was harmed
        assert_eq!(rep.kv_evictions, 0);
    }

    #[test]
    fn deterministic_runs() {
        let mk_trace = || {
            let mut t: Vec<Request> = (0..5)
                .map(|i| req(i, Priority::Proactive, i as f64 * 20_000.0, 200, 8))
                .collect();
            t.push(req(9, Priority::Reactive, 70_000.0, 100, 5));
            t
        };
        let a = engine().run(mk_trace()).unwrap();
        let b = engine().run(mk_trace()).unwrap();
        assert_eq!(a.makespan_us, b.makespan_us);
        for (x, y) in a.reqs.iter().zip(&b.reqs) {
            assert_eq!(x.first_token_us, y.first_token_us);
            assert_eq!(x.done_us, y.done_us);
        }
    }

    /// Tentpole: a display workload renders during an agentic run,
    /// frames land in the report, and every request still completes.
    #[test]
    fn graphics_frames_render_during_a_run_and_attribute_energy() {
        use crate::soc::{CLASS_IDLE, GraphicsConfig, KernelClass};
        let mut e = engine();
        e.set_graphics(Some(GraphicsConfig::default()));
        let mut trace: Vec<Request> = (0..3)
            .map(|i| req(i, Priority::Proactive, i as f64 * 30_000.0, 256, 10))
            .collect();
        trace.push(req(100, Priority::Reactive, 50_000.0, 128, 6));
        let rep = e.run(trace).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 4);
        assert!(rep.frames_scheduled > 0, "the display rendered frames");
        assert!(
            rep.energy_by_class[KernelClass::Graphics.idx()] > 0.0,
            "render energy attributed to the graphics class"
        );
        // attribution closes: classes + idle = total
        let sum: f64 = rep.energy_by_class.iter().sum();
        assert!((sum - rep.total_energy_j).abs() < 1e-6 * rep.total_energy_j.max(1.0));
        assert!(rep.energy_by_class[CLASS_IDLE] >= 0.0);
        // per-class J/token are defined and finite
        assert!(rep.joules_per_token_class(Priority::Reactive).is_finite());
        assert!(rep.joules_per_token_class(Priority::Proactive) > 0.0);
    }

    /// Acceptance criterion: with `igpu_duty_cap` engaged the governor
    /// strictly reduces graphics jank vs the uncapped run, without
    /// losing any agentic work (the starvation valve guarantees
    /// liveness even at cap 0).
    #[test]
    fn duty_cap_strictly_reduces_frame_miss_rate() {
        use crate::soc::GraphicsConfig;
        // full paper-scale geometry: one decode iteration (~tens of ms
        // on the iGPU) spans several 60 Hz vsync periods, so an
        // ungoverned decode stream is maximally janky
        let geo = crate::config::llama32_3b();
        let mk_trace = || -> Vec<Request> {
            (0..4).map(|i| req(i, Priority::Proactive, i as f64 * 10_000.0, 512, 40)).collect()
        };
        let run_with = |cap: f64| {
            let mut sched = SchedulerConfig::default();
            sched.igpu_duty_cap = cap;
            let mut e = AgentXpuEngine::synthetic(geo.clone(), default_soc(), sched);
            e.set_graphics(Some(GraphicsConfig::default()));
            e.run(mk_trace()).unwrap()
        };
        let uncapped = run_with(1.0);
        let capped = run_with(0.3);
        assert_eq!(capped.reqs.iter().filter(|m| m.finished()).count(), 4);
        assert!(uncapped.frames_missed > 0, "ungoverned decode must jank");
        assert!(
            capped.frame_miss_rate() < uncapped.frame_miss_rate(),
            "cap engaged: miss rate {:.3} must beat uncapped {:.3}",
            capped.frame_miss_rate(),
            uncapped.frame_miss_rate()
        );
    }

    /// A hard duty cap of 0 cannot starve proactive work: the §6.5
    /// aging valve bypasses the governor once candidates go stale.
    #[test]
    fn zero_duty_cap_still_completes_via_the_starvation_valve() {
        let mut sched = SchedulerConfig::default();
        sched.igpu_duty_cap = 0.0;
        sched.starvation_age_ms = 200.0; // age out quickly in test time
        let mut e = AgentXpuEngine::synthetic(geo(), default_soc(), sched);
        let trace: Vec<Request> =
            (0..3).map(|i| req(i, Priority::Proactive, 0.0, 200, 8)).collect();
        let rep = e.run(trace).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
    }

    /// The redesign's trace-retention satellite: `PolicyEngine` keeps
    /// the kernel trace for every policy, available through the
    /// `EngineCore::last_trace` accessor.
    #[test]
    fn finished_runs_retain_their_kernel_trace() {
        let mut e = engine();
        assert!(e.last_trace().is_none());
        e.run(vec![req(1, Priority::Reactive, 0.0, 200, 4)]).unwrap();
        let t = e.last_trace().expect("trace retained after finish");
        t.assert_serialized();
    }
}
