//! The Agent.xpu engine: the XPU-coordinator scheduling loop over the
//! shared DES driver.  This is the paper's system contribution wired
//! together — see module docs in `coordinator/mod.rs`.

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::config::{ModelGeometry, SchedulerConfig, SocConfig};
use crate::engine::{
    Driver, EngineClock, EngineCore, EngineEvent, ExecBridge, KernelTag, Phase,
};
use crate::heg::{Annotator, max_chunk_within_budget};
use crate::metrics::RunReport;
use crate::runtime::ModelExecutor;
use crate::soc::XpuModel;
use crate::workload::{ReqId, Request};

use super::dispatch::{DispatchDecision, dispatch_check};
use super::memory::MemoryGovernor;
use super::select::{decode_lanes, resume_order};

/// The Agent.xpu serving engine.
pub struct AgentXpuEngine {
    soc: SocConfig,
    pub sched: SchedulerConfig,
    ann: Annotator,
    exec: Option<Arc<ModelExecutor>>,
    geo: ModelGeometry,
    max_chunk: usize,
    npu: usize,
    igpu: usize,
    /// Which request last owned the NPU prefill pipeline (preemption
    /// accounting).
    npu_owner: Option<ReqId>,
    /// Kernel trace of the last `run` (Fig. 4 Gantt, debugging).
    pub last_trace: Option<crate::trace::Trace>,
    /// DRAM-budget admission control (§6.5 memory management).
    governor: MemoryGovernor,
    /// The open run, if `start` has been called (EngineCore lifecycle).
    active: Option<Driver>,
    /// The last `step` made no progress (run idle).
    stalled: bool,
}

impl AgentXpuEngine {
    /// Timing-only engine at a given geometry (figure sweeps).
    pub fn synthetic(geo: ModelGeometry, soc: SocConfig, sched: SchedulerConfig) -> Self {
        Self::build(geo, soc, sched, None)
    }

    /// Real-compute engine over loaded artifacts.
    pub fn real(exec: Arc<ModelExecutor>, soc: SocConfig, sched: SchedulerConfig) -> Self {
        let geo = exec.geo().clone();
        Self::build(geo, soc, sched, Some(exec))
    }

    fn build(
        geo: ModelGeometry,
        soc: SocConfig,
        sched: SchedulerConfig,
        exec: Option<Arc<ModelExecutor>>,
    ) -> Self {
        let xpus: Vec<XpuModel> = soc.xpus.iter().cloned().map(XpuModel::new).collect();
        let ann = Annotator::new(geo.clone(), xpus);
        let npu = ann.xpu_index("npu").expect("soc needs an npu");
        let igpu = ann.xpu_index("igpu").expect("soc needs an igpu");
        let max_chunk = max_chunk_within_budget(
            &geo,
            &[&ann.xpus[npu], &ann.xpus[igpu]],
            sched.chunk_latency_budget_ms,
        );
        let governor = MemoryGovernor::new(&geo, &soc);
        Self {
            soc, sched, ann, exec, geo, max_chunk, npu, igpu,
            npu_owner: None, last_trace: None, governor,
            active: None, stalled: false,
        }
    }

    /// §6.5 memory management: may `id`'s prefill start (allocate its
    /// KV) right now?  Started requests always continue (their KV is
    /// already resident).  Under pressure the governor sheds residency
    /// cheapest-first: idle retained session caches go LRU-first (a
    /// dropped session only costs one conversation-prefix recompute),
    /// then a reactive request that still does not fit evicts the
    /// least-progressed waiting proactive prefill (graceful
    /// degradation — its context is recomputed later, like scheme (a)).
    fn memory_admit(&mut self, d: &mut Driver, id: ReqId) -> bool {
        let st = &d.states[&id];
        // A claimed session cache counts as already-resident KV: the
        // slot moved from the pool's books onto this request at
        // admission, so "starting" it allocates nothing new.
        let started = st.chunk_idx > 0 || st.layer_idx > 0 || st.cached_prefix_len > 0;
        if started
            || self
                .governor
                .can_start_with_sessions(&d.states, d.retained_sessions())
        {
            return true;
        }
        if !st.is_reactive() {
            // Defer the proactive start until memory frees — without
            // shedding sessions: evicting reactive chat state to admit
            // background work would invert the priority order, and a
            // deferred start gains nothing from the eviction anyway.
            return false;
        }
        // First valve for reactive arrivals: drop idle sessions,
        // least-recently-used first (cheapest residency to rebuild).
        while let Some(fid) = d.sessions.as_mut().and_then(|p| p.evict_lru()) {
            d.note_session_eviction(fid);
            if self
                .governor
                .can_start_with_sessions(&d.states, d.retained_sessions())
            {
                return true;
            }
        }
        if let Some(victim) = self.governor.eviction_victim(&d.states) {
            let geo = self.geo.clone();
            let now = d.now();
            let vs = d.states.get_mut(&victim).unwrap();
            vs.restart_prefill(&geo);
            vs.enqueued_at_us = now;
            d.note_kv_eviction(victim); // surfaces in RunReport::kv_evictions
            return true;
        }
        true // nothing evictable: admit anyway (paper's moderate-density assumption)
    }

    fn bridge(&self) -> ExecBridge {
        match &self.exec {
            Some(e) => ExecBridge::real(e.clone()),
            None => ExecBridge::synthetic(self.geo.clone()),
        }
    }

    /// The "prefill XPU" under disaggregation is the NPU; colocated mode
    /// (ablation) funnels everything through the iGPU.
    fn prefill_xpu(&self) -> usize {
        if self.sched.disaggregation { self.npu } else { self.igpu }
    }

    /// Preemption accounting (§6.2): whenever a reactive prefill kernel
    /// launches while a mid-prefill proactive task waits at its
    /// kernel-boundary checkpoint, that task is preempted — counted once
    /// per wait episode (the flag clears when the victim runs again).
    fn account_preemption(d: &mut Driver, _reactive_id: ReqId) {
        let now = d.now();
        let victims: Vec<ReqId> = d
            .states
            .values()
            .filter(|s| {
                !s.is_reactive()
                    && s.phase == Phase::Prefilling
                    && !s.running
                    && !s.preempt_counted
                    && (s.chunk_idx > 0 || s.layer_idx > 0)
            })
            .map(|s| s.id())
            .collect();
        for v in victims {
            let vs = d.states.get_mut(&v).unwrap();
            vs.preempted += 1;
            vs.preempt_counted = true;
            vs.enqueued_at_us = now;
            d.note_preemption(v);
        }
    }

    /// Reference scan for the driver's waiting-proactive-prefill index
    /// (debug-assert parity checks only — release builds trust the
    /// index, and the index's id order matches this sorted scan
    /// exactly, so both feed `resume_order` identical candidate lists).
    fn scan_waiting_proactive(d: &Driver) -> Vec<ReqId> {
        let mut v: Vec<ReqId> = d
            .states
            .values()
            .filter(|s| s.phase == Phase::Prefilling && !s.running && !s.is_reactive())
            .map(|s| s.id())
            .collect();
        v.sort_unstable();
        v
    }

    /// Reactive requests currently mid-system (prefilling or decoding).
    fn reactive_active(d: &Driver) -> bool {
        d.states
            .values()
            .any(|s| s.is_reactive() && s.phase != Phase::Done)
    }

    // -- NPU side: the prefill pipeline ---------------------------------

    fn schedule_prefill_pipeline(&mut self, d: &mut Driver) {
        let pxpu = self.prefill_xpu();
        if d.sim.busy(pxpu) {
            return;
        }
        // Reactive first (kernel-level preemption: we are at a kernel
        // boundary by construction — the pipeline is idle).
        let mut reactive: Vec<ReqId> = d
            .states
            .values()
            .filter(|s| s.phase == Phase::Prefilling && !s.running && s.is_reactive())
            .map(|s| s.id())
            .collect();
        reactive.sort_by(|a, b| {
            d.states[a]
                .req
                .arrival_us
                .total_cmp(&d.states[b].req.arrival_us)
                .then(a.cmp(b))
        });
        let mut proactive: Vec<ReqId> = d.waiting_proactive_prefills();
        debug_assert_eq!(
            proactive,
            Self::scan_waiting_proactive(d),
            "waiting-proactive-prefill index diverged from a state scan"
        );
        resume_order(
            &d.states,
            &mut proactive,
            &self.ann,
            pxpu,
            d.now(),
            self.sched.starvation_age_ms * 1e3,
            self.sched.critical_path_priority,
        );

        let pick = if self.sched.preemption {
            reactive.first().copied().or_else(|| proactive.first().copied())
        } else {
            // no-preemption ablation: FCFS across classes
            let mut all = [reactive.as_slice(), proactive.as_slice()].concat();
            all.sort_by(|a, b| {
                d.states[a]
                    .req
                    .arrival_us
                    .total_cmp(&d.states[b].req.arrival_us)
                    .then(a.cmp(b))
            });
            all.first().copied()
        };
        let Some(id) = pick else { return };
        if !self.memory_admit(d, id) {
            return;
        }

        let st = &d.states[&id];
        let chunk = *st.current_chunk().expect("prefilling has a chunk");
        // Elastic binding: dynamic margin chunks prefer the iGPU (§5.2);
        // if the iGPU is busy they wait for it unless this XPU *is* the
        // iGPU already (colocated mode).
        if chunk.dynamic && self.sched.disaggregation {
            return; // the iGPU side will pick it up
        }
        let annotated = self.ann.prefill_kernel(&chunk);
        let timing = *annotated.timing_on(pxpu);
        let reactive_k = st.is_reactive();
        if dispatch_check(&d.sim, &self.sched, &timing, reactive_k)
            == DispatchDecision::Defer
        {
            return;
        }
        if reactive_k {
            Self::account_preemption(d, id);
        }
        self.npu_owner = Some(id);
        d.launch(pxpu, timing, reactive_k, KernelTag::Prefill { req: id });
    }

    // -- iGPU side: decode pipeline, margins, inter-XPU backfill --------

    fn schedule_decode_pipeline(&mut self, d: &mut Driver) {
        if d.sim.busy(self.igpu) {
            return;
        }
        let reactive_present = Self::reactive_active(d);

        // (1) A reactive dynamic margin chunk gates that request's TTFT:
        // it outranks everything on the iGPU.
        if self.sched.disaggregation {
            if self.try_margin_chunk(d, true) {
                return;
            }
        }

        // (2) Proactive margin chunks outrank proactive-only decode:
        // finishing a prefill feeds the decode batch (the ETC rationale
        // of §6.2's resumption strategy) — but never delay a decode
        // batch that carries a reactive lane.
        let rt_decoding = d
            .states
            .values()
            .any(|s| s.phase == Phase::Decoding && !s.running && s.is_reactive());
        if self.sched.disaggregation && !rt_decoding && self.try_margin_chunk(d, false) {
            return;
        }

        // (3) Decode iteration with adaptive batching + intra-XPU
        // backfill (proactive lanes join at the boundary when allowed).
        let allow_join = self.sched.backfill || !reactive_present;
        let (lanes, any_rt) = decode_lanes(&d.states, self.sched.b_max, allow_join);
        if !lanes.is_empty() {
            let avg_ctx = (lanes.iter().map(|id| d.states[id].pos).sum::<usize>()
                / lanes.len())
            .max(1);
            let annotated = self.ann.decode_iter(lanes.len(), avg_ctx);
            let timing = *annotated.timing_on(self.igpu);
            if dispatch_check(&d.sim, &self.sched, &timing, any_rt)
                == DispatchDecision::Launch
            {
                let backfilled =
                    any_rt && lanes.iter().any(|id| !d.states[id].is_reactive());
                if backfilled {
                    d.backfills += 1;
                }
                d.launch(self.igpu, timing, any_rt, KernelTag::DecodeIter { lanes });
                return;
            }
            // decode deferred: fall through to cheaper candidates
        }

        if !self.sched.disaggregation {
            return; // colocated mode: prefill handled by the other branch
        }

        // (4) Proactive dynamic margin chunks (the non-rt-decoding case
        // was already handled above).
        if self.try_margin_chunk(d, false) {
            return;
        }

        // (5) Inter-XPU backfill (§6.3): proactive (or starved) prefill
        // fills the iGPU bubble while the NPU is held by reactive
        // prefill; also plain structural slack when the NPU is busy.
        if !self.sched.backfill {
            return;
        }
        if !d.sim.busy(self.prefill_xpu()) {
            return; // structural slack only
        }
        // Candidates come from the driver's incrementally maintained
        // waiting-proactive-prefill index — a full `states` scan per
        // step was the old hot path; the debug assert proves the index
        // always matches it, so schedules are bit-identical.
        let mut cands: Vec<ReqId> = d.waiting_proactive_prefills();
        debug_assert_eq!(
            cands,
            Self::scan_waiting_proactive(d),
            "waiting-proactive-prefill index diverged from a state scan"
        );
        if cands.is_empty() {
            return;
        }
        // Order by the §6.2 resumption strategy (starvation age →
        // continuation → critical path → ETC): the candidates share one
        // kernel shape class on the iGPU, so this is the tiebreak that
        // decides which proactive prefill claims the backfill bubble.
        resume_order(
            &d.states,
            &mut cands,
            &self.ann,
            self.igpu,
            d.now(),
            self.sched.starvation_age_ms * 1e3,
            self.sched.critical_path_priority,
        );
        for id in cands {
            let st = &d.states[&id];
            let chunk = *st.current_chunk().unwrap();
            if chunk.dynamic {
                continue; // handled by try_margin_chunk
            }
            if !self.memory_admit(d, id) {
                continue;
            }
            let annotated = self.ann.prefill_kernel(&chunk);
            let timing = *annotated.timing_on(self.igpu);
            // Backfill constraints (§6.3): duration within the reactive
            // window (chunking bounds this), memory threshold (Alg. 1).
            if dispatch_check(&d.sim, &self.sched, &timing, false)
                == DispatchDecision::Launch
            {
                d.backfills += 1;
                d.launch(self.igpu, timing, false, KernelTag::Prefill { req: id });
                return;
            }
        }
    }

    /// Launch the next *dynamic* (margin) chunk of a reactive/proactive
    /// request on the iGPU.  Returns true if launched.
    fn try_margin_chunk(&mut self, d: &mut Driver, reactive: bool) -> bool {
        let mut cands: Vec<ReqId> = d
            .states
            .values()
            .filter(|s| {
                s.phase == Phase::Prefilling
                    && !s.running
                    && s.is_reactive() == reactive
                    && s.current_chunk().map(|c| c.dynamic).unwrap_or(false)
            })
            .map(|s| s.id())
            .collect();
        cands.sort_by(|a, b| {
            d.states[a]
                .req
                .arrival_us
                .total_cmp(&d.states[b].req.arrival_us)
                .then(a.cmp(b))
        });
        let Some(&id) = cands.first() else { return false };
        if !self.memory_admit(d, id) {
            return false;
        }
        let chunk = *d.states[&id].current_chunk().unwrap();
        let annotated = self.ann.prefill_kernel(&chunk);
        let timing = *annotated.timing_on(self.igpu);
        if dispatch_check(&d.sim, &self.sched, &timing, reactive)
            == DispatchDecision::Defer
        {
            return false;
        }
        if reactive {
            Self::account_preemption(d, id);
        }
        d.launch(self.igpu, timing, reactive, KernelTag::Prefill { req: id });
        true
    }

    /// Deadlock guard: if nothing is running, nothing was launched, and
    /// work remains, force-launch the most urgent kernel (WaitForSlot
    /// has nothing to wait for on an idle SoC — dispatch_check already
    /// allows this, so this only fires for margin-vs-busy-iGPU corner
    /// cases).
    fn force_progress(&mut self, d: &mut Driver) {
        if !d.sim.all_idle() {
            return;
        }
        // any runnable prefill (incl. dynamic margins on the NPU with
        // JIT) — reactive first, then aged proactive
        let mut cands: Vec<ReqId> = d
            .states
            .values()
            .filter(|s| s.phase == Phase::Prefilling && !s.running)
            .map(|s| s.id())
            .collect();
        if cands.is_empty() {
            return;
        }
        cands.sort_by(|a, b| {
            let (sa, sb) = (&d.states[a], &d.states[b]);
            sb.is_reactive()
                .cmp(&sa.is_reactive())
                .then(sa.req.arrival_us.total_cmp(&sb.req.arrival_us))
                .then(a.cmp(b))
        });
        let id = cands[0];
        let st = &d.states[&id];
        let chunk = *st.current_chunk().unwrap();
        let annotated = self.ann.prefill_kernel(&chunk);
        // run on the iGPU if dynamic, NPU otherwise
        let xpu = if chunk.dynamic { self.igpu } else { self.prefill_xpu() };
        let timing = *annotated.timing_on(xpu);
        let reactive = st.is_reactive();
        d.launch(xpu, timing, reactive, KernelTag::Prefill { req: id });
    }

    fn schedule(&mut self, d: &mut Driver) {
        self.schedule_prefill_pipeline(d);
        self.schedule_decode_pipeline(d);
        self.force_progress(d);
    }
}

impl EngineCore for AgentXpuEngine {
    fn name(&self) -> String {
        "agent.xpu".into()
    }

    fn start(&mut self, clock: EngineClock) -> Result<()> {
        self.npu_owner = None;
        let mut d = Driver::open(&self.soc, self.bridge(), clock);
        // Flow-level session retention (DESIGN.md §3): continuation
        // turns prefill only their delta tokens.  Baselines run the
        // same flow traces without this — full-prefix recompute —
        // so the figures quantify the reuse win.
        if self.sched.session_capacity > 0 {
            d.enable_session_reuse(self.sched.session_capacity);
        }
        self.active = Some(d);
        self.stalled = false;
        Ok(())
    }

    fn submit(&mut self, req: Request) -> Result<()> {
        self.active
            .as_mut()
            .context("agent.xpu: submit before start")?
            .submit(req);
        self.stalled = false;
        Ok(())
    }

    fn cancel(&mut self, id: ReqId) -> Result<bool> {
        let hit = self
            .active
            .as_mut()
            .context("agent.xpu: cancel before start")?
            .cancel_request(id);
        if hit {
            // wake a stalled run so the Cancelled event flushes
            self.stalled = false;
        }
        Ok(hit)
    }

    fn step(&mut self) -> Result<Vec<EngineEvent>> {
        let mut d = self.active.take().context("agent.xpu: step before start")?;
        d.admit_ready(self.max_chunk);
        self.schedule(&mut d);
        let progressed = d.step()?;
        self.stalled = !progressed;
        let events = d.take_events();
        self.active = Some(d);
        Ok(events)
    }

    fn has_work(&self) -> bool {
        self.active.is_some() && !self.stalled
    }

    fn finish(&mut self) -> Result<RunReport> {
        let d = self.active.take().context("agent.xpu: finish before start")?;
        self.last_trace = Some(d.trace.clone());
        d.finish(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;
    use crate::workload::Priority;

    fn geo() -> ModelGeometry {
        let mut g = crate::config::llama32_3b();
        g.n_layers = 4; // keep DES unit tests fast
        g
    }

    fn engine() -> AgentXpuEngine {
        AgentXpuEngine::synthetic(geo(), default_soc(), SchedulerConfig::default())
    }

    fn req(id: u64, prio: Priority, arrival: f64, plen: usize, out: usize) -> Request {
        Request {
            id,
            priority: prio,
            arrival_us: arrival,
            prompt: vec![1; plen],
            max_new_tokens: out,
            profile: "test".into(),
            flow: None,
        }
    }

    /// A hand-built multi-turn reactive flow (see driver tests).
    fn flow(flow_id: u64, first_id: u64, arrival: f64, turns: usize, think_us: f64) -> Vec<Request> {
        let (p0, out, delta) = (128usize, 6usize, 48usize);
        let mut out_reqs = vec![];
        let mut prompt = vec![1i32; p0];
        for k in 0..turns {
            if k > 0 {
                let ds = prompt.len() + out;
                prompt = vec![2; ds];
                prompt.extend(vec![1; delta]);
            }
            out_reqs.push(Request {
                id: first_id + k as u64,
                priority: Priority::Reactive,
                arrival_us: arrival,
                prompt: prompt.clone(),
                max_new_tokens: out,
                profile: "flow".into(),
                flow: Some(crate::workload::FlowBinding::linear(
                    flow_id,
                    k,
                    turns,
                    if k == 0 { 0.0 } else { think_us },
                    if k == 0 { 0 } else { prompt.len() - delta },
                )),
            });
        }
        out_reqs
    }

    #[test]
    fn completes_a_single_reactive_request() {
        let rep = engine().run(vec![req(1, Priority::Reactive, 0.0, 300, 10)]).unwrap();
        let m = &rep.reqs[0];
        assert!(m.finished());
        assert_eq!(m.output_tokens, 10);
        assert!(m.ttft_us().unwrap() > 0.0);
    }

    #[test]
    fn completes_mixed_load() {
        let mut trace = vec![];
        for i in 0..6 {
            trace.push(req(i, Priority::Proactive, i as f64 * 50_000.0, 256, 12));
        }
        trace.push(req(100, Priority::Reactive, 120_000.0, 128, 8));
        let rep = engine().run(trace).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 7);
    }

    #[test]
    fn reactive_latency_unaffected_by_proactive_load() {
        // the paper's headline property: reactive TTFT stays ~flat as
        // proactive rate grows (Fig. 7)
        let solo = engine()
            .run(vec![req(1, Priority::Reactive, 0.0, 256, 8)])
            .unwrap();
        let solo_ttft = solo.reqs[0].ttft_us().unwrap();

        let mut trace: Vec<Request> = (0..10)
            .map(|i| req(i, Priority::Proactive, i as f64 * 30_000.0, 400, 20))
            .collect();
        trace.push(req(100, Priority::Reactive, 200_000.0, 256, 8));
        let busy = engine().run(trace).unwrap();
        let busy_ttft = busy
            .reqs
            .iter()
            .find(|m| m.id == 100)
            .unwrap()
            .ttft_us()
            .unwrap();
        assert!(
            busy_ttft < 3.0 * solo_ttft,
            "reactive TTFT under load {busy_ttft} vs solo {solo_ttft}"
        );
    }

    #[test]
    fn preemption_is_counted_under_contention() {
        // Two long proactive prefills (4 chunks x 4 layers each) occupy
        // both pipelines; the reactive arrival must displace one of them
        // at a kernel boundary.
        let mut trace: Vec<Request> = (0..2)
            .map(|i| req(i, Priority::Proactive, 0.0, 2048, 4))
            .collect();
        trace.push(req(100, Priority::Reactive, 100_000.0, 256, 4));
        let rep = engine().run(trace).unwrap();
        assert!(rep.preemptions >= 1, "reactive arrival mid-proactive-prefill must preempt");
    }

    #[test]
    fn backfill_happens_with_mixed_decode() {
        let mut trace: Vec<Request> = (0..4)
            .map(|i| req(i, Priority::Proactive, 0.0, 128, 30))
            .collect();
        trace.push(req(100, Priority::Reactive, 10_000.0, 128, 30));
        let rep = engine().run(trace).unwrap();
        assert!(rep.backfills >= 1, "proactive work should backfill");
    }

    #[test]
    fn ablation_engines_still_complete() {
        for (b, p, dg) in
            [(false, true, true), (true, false, true), (true, true, false), (false, false, false)]
        {
            let mut sched = SchedulerConfig::default();
            sched.backfill = b;
            sched.preemption = p;
            sched.disaggregation = dg;
            let mut e = AgentXpuEngine::synthetic(geo(), default_soc(), sched);
            let mut trace: Vec<Request> = (0..4)
                .map(|i| req(i, Priority::Proactive, i as f64 * 40_000.0, 200, 10))
                .collect();
            trace.push(req(100, Priority::Reactive, 100_000.0, 150, 6));
            let rep = e.run(trace).unwrap();
            assert_eq!(
                rep.reqs.iter().filter(|m| m.finished()).count(),
                5,
                "ablation (backfill={b},preempt={p},disagg={dg}) must finish"
            );
        }
    }

    #[test]
    fn flow_turns_reuse_session_kv() {
        let rep = engine().run(flow(7, 0, 0.0, 3, 30_000.0)).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        for m in rep.reqs.iter().filter(|m| m.turn_idx > 0) {
            assert!(
                m.cached_prefix_len > 0,
                "turn {} must admit from the session pool",
                m.turn_idx
            );
            assert_eq!(m.prefill_tokens, m.input_len - m.cached_prefix_len);
        }
        assert!((rep.prefix_cache_hit_rate() - 1.0).abs() < 1e-9);
        let flows = rep.flows();
        assert_eq!(flows.len(), 1);
        assert!(flows[0].finished);
        assert!(flows[0].e2e_us.unwrap() > 0.0);
    }

    #[test]
    fn workflow_dags_complete_with_tool_nodes_on_the_cpu() {
        use crate::workload::{DagShape, DagSpec, dag_flow_trace, flatten_flows, profile};
        let spec = DagSpec {
            profile: profile("proactivebench").unwrap(),
            flow_rate_per_s: 0.05,
            think_time_s: 4.0,
            shape: DagShape::MapReduce { fanout: 3 },
            duration_s: 60.0,
            seed: 11,
            max_seq: 2048,
        };
        let flows = dag_flow_trace(&spec, Priority::Proactive, 2048, 0, 0);
        let trace = flatten_flows(flows);
        assert!(!trace.is_empty());
        let total = trace.len();
        let rep = engine().run(trace).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), total);
        // tool nodes ran on the CPU, LLM turns on NPU/iGPU
        assert!(rep.reqs.iter().any(|m| m.tool));
        assert!(rep.utilization("cpu") > 0.0);
        // every flow's makespan is bounded below by its critical path
        for f in rep.flows() {
            assert!(f.finished);
            assert!(
                f.e2e_us.unwrap() + 1e-6 >= f.critical_path_us.unwrap(),
                "flow {}: makespan below its critical path",
                f.flow_id
            );
        }
    }

    #[test]
    fn session_capacity_zero_disables_reuse() {
        let mut sched = SchedulerConfig::default();
        sched.session_capacity = 0;
        let mut e = AgentXpuEngine::synthetic(geo(), default_soc(), sched);
        let rep = e.run(flow(7, 0, 0.0, 3, 30_000.0)).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        assert!(rep.reqs.iter().all(|m| m.cached_prefix_len == 0));
        assert!(rep.prefix_cache_hit_rate().abs() < 1e-9);
    }

    /// Satellite: reactive arrival under memory pressure evicts the
    /// least-progressed waiting proactive prefill; the victim's
    /// restart_prefill resets its plan and it still completes.
    #[test]
    fn reactive_arrival_under_pressure_evicts_proactive_prefill() {
        let g = geo();
        let mut soc = default_soc();
        // room for weights + ~2 KV slots only
        let weights_gb = g.n_params() as f64 * g.weight_bytes / 1e9;
        let kv_gb = (2 * g.n_layers * g.cache_elems() * 4) as f64 / 1e9;
        soc.dram_gb = weights_gb + 2.2 * kv_gb;
        let mut e = AgentXpuEngine::synthetic(g, soc, SchedulerConfig::default());
        let mut trace: Vec<Request> = (0..3)
            .map(|i| req(i, Priority::Proactive, 0.0, 1800, 4))
            .collect();
        trace.push(req(100, Priority::Reactive, 120_000.0, 256, 4));
        let rep = e.run(trace).unwrap();
        assert!(
            rep.kv_evictions >= 1,
            "reactive under pressure must evict a proactive prefill"
        );
        // nothing is lost: the victim recomputed and finished
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 4);
        // the victim's restart shows up as extra prefilled tokens
        assert!(
            rep.reqs
                .iter()
                .any(|m| m.priority == Priority::Proactive
                    && m.prefill_tokens > m.input_len),
            "a restarted prefill recomputes chunks it had already run"
        );
    }

    /// Satellite: idle retained sessions are the first thing the
    /// governor sheds — LRU-first, before touching any in-flight work.
    #[test]
    fn idle_sessions_evicted_lru_first_under_pressure() {
        let g = geo();
        let mut soc = default_soc();
        let weights_gb = g.n_params() as f64 * g.weight_bytes / 1e9;
        let kv_gb = (2 * g.n_layers * g.cache_elems() * 4) as f64 / 1e9;
        // weights + ~1.5 KV slots: an idle session + a new start can
        // never coexist
        soc.dram_gb = weights_gb + 1.5 * kv_gb;
        let mut e = AgentXpuEngine::synthetic(g, soc, SchedulerConfig::default());
        // flow turn 0 finishes and parks its session; a big single-shot
        // arrives during the think-time window
        let mut trace = flow(7, 0, 0.0, 2, 3_000_000.0);
        trace.push(req(100, Priority::Reactive, 1_000_000.0, 512, 4));
        let rep = e.run(trace).unwrap();
        assert!(
            rep.session_evictions >= 1,
            "the idle session must be dropped to fit the arrival"
        );
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        // the evicted session forces turn 1 back to full recompute
        let turn1 = rep.reqs.iter().find(|m| m.turn_idx == 1).unwrap();
        assert_eq!(turn1.cached_prefix_len, 0);
        assert_eq!(turn1.prefill_tokens, turn1.input_len);
        // no in-flight prefill was harmed
        assert_eq!(rep.kv_evictions, 0);
    }

    #[test]
    fn deterministic_runs() {
        let mk_trace = || {
            let mut t: Vec<Request> = (0..5)
                .map(|i| req(i, Priority::Proactive, i as f64 * 20_000.0, 200, 8))
                .collect();
            t.push(req(9, Priority::Reactive, 70_000.0, 100, 5));
            t
        };
        let a = engine().run(mk_trace()).unwrap();
        let b = engine().run(mk_trace()).unwrap();
        assert_eq!(a.makespan_us, b.makespan_us);
        for (x, y) in a.reqs.iter().zip(&b.reqs) {
            assert_eq!(x.first_token_us, y.first_token_us);
            assert_eq!(x.done_us, y.done_us);
        }
    }
}
