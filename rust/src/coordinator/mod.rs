//! The Agent.xpu online workload-aware scheduler (paper §6).
//!
//! Architecture (Fig. 5): a dual-queue admission front (real-time
//! reactive vs best-effort proactive), task decomposition onto the HEG,
//! and the central **XPU coordinator** loop that owns:
//!
//! - **hetero-disaggregation** (§5.2): static chunked prefill → NPU,
//!   dynamic margin + attention + decode → iGPU, with elastic rebinding;
//! - **kernel-level preemption** (§6.2): reactive tasks take the prefill
//!   pipeline at the next kernel boundary; proactive contexts checkpoint
//!   for free in unified memory;
//! - **slack-aware backfill** (§6.3): proactive decodes join reactive
//!   decode batches at iteration boundaries (intra-XPU), proactive
//!   prefill fills NPU/iGPU bubbles (inter-XPU), ranked by TFLOPS/W;
//! - **memory-aware dispatch** (§6.4, Algorithm 1): a three-tier policy
//!   over the bandwidth-pressure estimate keeps memory-bound kernels
//!   from destructive co-execution;
//! - **starvation prevention + dynamic load balancing** (§6.5).

mod deadline;
mod dispatch;
mod engine_impl;
mod memory;
mod select;

pub use deadline::{DeadlineEngine, DeadlinePolicy};
pub use dispatch::{DispatchDecision, dispatch_check};
pub use engine_impl::{AgentXpuEngine, AgentXpuPolicy, XpuCoordinator};
pub use memory::MemoryGovernor;
pub use select::{decode_lanes, prefill_etc_us, resume_order};
