//! `deadline` — slack-aware earliest-deadline-first scheduling, the
//! first policy written *against* the `SchedPolicy` API instead of as
//! an engine fork (DESIGN.md §7).  It reuses the whole
//! [`XpuCoordinator`] pipeline (disaggregation, preemption, margin
//! chunks, backfill, memory governor) and overrides exactly two hooks:
//!
//! - **resume ordering** — paused proactive prefills resume in EDF
//!   order by *slack*: `deadline − now − ETC(remaining prefill)`.  A
//!   task about to blow its deadline outranks everything; slack decays
//!   as wall/virtual time advances, so EDF ages waiting work into
//!   priority and starvation prevention falls out of the order itself
//!   (no explicit aging threshold).
//! - **decode-batch formation** — lanes are ranked by deadline, and
//!   proactive lanes may only join a batch carrying reactive lanes
//!   while the tightest reactive deadline still has most of its budget
//!   left.  Joining inflates *every* iteration of the batch (more
//!   lanes, larger average context), so once a reactive request's
//!   slack runs low the batch stays lean and its remaining tokens
//!   stream at the fastest per-iteration rate.
//!
//! Deadlines are derived from the priority class (the paper's workload
//! dichotomy, §1): reactive requests get a tight interactive budget,
//! proactive requests a loose background one.  Before the policy
//! redesign this scheduler would have cost a fifth copy of the engine
//! lifecycle; now it is this file.

use crate::config::{ModelGeometry, SchedulerConfig, SocConfig};
use crate::engine::{
    Action, ExecBridge, Phase, PolicyCtx, PolicyEngine, ReqState, ResumeCtx,
    SchedPolicy, States,
};
use crate::workload::ReqId;

use super::engine_impl::XpuCoordinator;
use super::select::prefill_etc_us;

/// Per-class deadline budgets (µs after arrival).  Reactive: an
/// interactive-latency envelope; proactive: a background-throughput
/// envelope two orders looser.
const REACTIVE_BUDGET_US: f64 = 1_000_000.0;
const PROACTIVE_BUDGET_US: f64 = 30_000_000.0;
/// Proactive lanes may join a reactive decode batch only while the
/// tightest reactive slack exceeds this (i.e. early in the reactive
/// request's budget); after that the batch stays lean.
const JOIN_GUARD_US: f64 = 900_000.0;

/// The EDF engine behind the one generic [`PolicyEngine`].
pub type DeadlineEngine = PolicyEngine<DeadlinePolicy>;

impl PolicyEngine<DeadlinePolicy> {
    /// Timing-only EDF engine at a given geometry.
    pub fn synthetic(geo: ModelGeometry, soc: SocConfig, sched: SchedulerConfig) -> Self {
        let bridge = ExecBridge::synthetic(geo.clone());
        PolicyEngine::with_policy(DeadlinePolicy::new(geo, &soc, sched), soc, bridge)
    }
}

/// Slack-aware EDF over per-request deadlines derived from priority
/// class.
pub struct DeadlinePolicy {
    coord: XpuCoordinator,
}

impl DeadlinePolicy {
    pub fn new(geo: ModelGeometry, soc: &SocConfig, sched: SchedulerConfig) -> Self {
        Self { coord: XpuCoordinator::new(geo, soc, sched) }
    }

    /// The request's absolute deadline: arrival plus its class budget.
    fn deadline_us(st: &ReqState) -> f64 {
        st.req.arrival_us
            + if st.is_reactive() { REACTIVE_BUDGET_US } else { PROACTIVE_BUDGET_US }
    }
}

impl SchedPolicy for DeadlinePolicy {
    fn label(&self) -> String {
        "deadline".into()
    }

    fn max_chunk(&self) -> usize {
        self.coord.max_chunk()
    }

    fn session_capacity(&self) -> usize {
        self.coord.sched.session_capacity
    }

    fn decide(&mut self, mut ctx: PolicyCtx<'_>) -> Vec<Action> {
        let this = &*self;
        this.coord.schedule(&mut ctx, this);
        ctx.take_actions()
    }

    /// EDF resumption: least slack first, where slack is the margin
    /// between the deadline and the earliest possible prefill
    /// completion (`now + ETC`).  Slack keys are precomputed per
    /// candidate — same O(n) ETC discipline as the default order.
    fn resume_order(&self, r: ResumeCtx<'_>, cands: &mut Vec<ReqId>) {
        let mut keyed: Vec<(f64, ReqId)> = cands
            .iter()
            .map(|id| {
                let st = &r.states[id];
                let slack =
                    Self::deadline_us(st) - r.now_us - prefill_etc_us(st, r.ann, r.xpu);
                (slack, *id)
            })
            .collect();
        keyed.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        cands.clear();
        cands.extend(keyed.into_iter().map(|(_, id)| id));
    }

    /// Deadline-ordered lanes with a slack-aware join gate (see module
    /// docs).  Deadline-keyed scratch is thread-local, mirroring the
    /// default `coordinator::select::decode_lanes`.
    fn decode_batch(
        &self,
        states: &States,
        b_max: usize,
        allow_join: bool,
        now_us: f64,
        lanes: &mut Vec<ReqId>,
    ) -> bool {
        use std::cell::RefCell;
        thread_local! {
            static EDF_KEYS: RefCell<(Vec<(f64, ReqId)>, Vec<(f64, ReqId)>)> =
                const { RefCell::new((Vec::new(), Vec::new())) };
        }
        lanes.clear();
        EDF_KEYS.with_borrow_mut(|(reactive, proactive)| {
            reactive.clear();
            proactive.clear();
            // lint:allow(no-unordered-iteration) keys collected then sorted by the (deadline, id) total key below
            for st in states.values() {
                if st.phase != Phase::Decoding || st.running {
                    continue;
                }
                let d = Self::deadline_us(st);
                if st.is_reactive() {
                    reactive.push((d, st.id()));
                } else {
                    proactive.push((d, st.id()));
                }
            }
            reactive.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let any_reactive = !reactive.is_empty();
            // The tightest reactive lane gates proactive joins: once its
            // slack is inside the guard, the batch stays reactive-only.
            let join_ok = reactive
                .first()
                .map(|(d, _)| d - now_us > JOIN_GUARD_US)
                .unwrap_or(true);
            lanes.extend(reactive.iter().map(|(_, id)| *id));
            if (allow_join && join_ok) || lanes.is_empty() {
                proactive.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                for &(_, id) in proactive.iter() {
                    if lanes.len() >= b_max {
                        break;
                    }
                    lanes.push(id);
                }
            }
            lanes.truncate(b_max);
            any_reactive
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{default_soc, llama32_3b};
    use crate::engine::{Engine, ExecBridge};
    use crate::heg::Annotator;
    use crate::soc::XpuModel;
    use crate::workload::{Priority, Request};

    fn geo() -> ModelGeometry {
        let mut g = llama32_3b();
        g.n_layers = 3;
        g
    }

    fn req(id: u64, prio: Priority, arrival: f64, plen: usize, out: usize) -> Request {
        Request {
            id,
            priority: prio,
            arrival_us: arrival,
            prompt: vec![1; plen],
            max_new_tokens: out,
            profile: "edf".into(),
            flow: None,
        }
    }

    fn mk_states(specs: &[(u64, Priority, Phase, f64)]) -> States {
        let bridge = ExecBridge::synthetic(geo());
        specs
            .iter()
            .map(|&(id, prio, phase, arrival)| {
                let mut st = bridge.init_state(req(id, prio, arrival, 300, 8), 512);
                st.phase = phase;
                (id, st)
            })
            .collect()
    }

    fn policy() -> DeadlinePolicy {
        DeadlinePolicy::new(geo(), &default_soc(), SchedulerConfig::default())
    }

    #[test]
    fn resume_order_is_edf_by_slack() {
        let states = mk_states(&[
            (1, Priority::Proactive, Phase::Prefilling, 500_000.0),
            (2, Priority::Proactive, Phase::Prefilling, 0.0),
            (3, Priority::Proactive, Phase::Prefilling, 900_000.0),
        ]);
        let ann = Annotator::new(
            geo(),
            default_soc().xpus.iter().cloned().map(XpuModel::new).collect(),
        );
        let p = policy();
        let mut cands = vec![1, 2, 3];
        // identical prompts → identical ETC, so slack order == arrival
        // (deadline) order: the earliest-arrived is closest to its
        // deadline
        p.resume_order(
            ResumeCtx {
                states: &states,
                ann: &ann,
                xpu: 0,
                now_us: 1_000_000.0,
                starvation_age_us: 1e12,
                critical_path: true,
            },
            &mut cands,
        );
        assert_eq!(cands, vec![2, 1, 3], "least slack resumes first");
    }

    #[test]
    fn decode_join_gate_closes_when_reactive_slack_runs_low() {
        let states = mk_states(&[
            (1, Priority::Reactive, Phase::Decoding, 0.0),
            (2, Priority::Proactive, Phase::Decoding, 0.0),
            (3, Priority::Proactive, Phase::Decoding, 0.0),
        ]);
        let p = policy();
        let mut lanes = vec![];
        // early in the reactive budget: proactive lanes may join
        let any_rt = p.decode_batch(&states, 8, true, 10_000.0, &mut lanes);
        assert!(any_rt);
        assert_eq!(lanes.len(), 3, "joins allowed while slack is ample");
        assert_eq!(lanes[0], 1, "reactive (tightest deadline) leads");
        // late in the budget: the batch stays reactive-only
        let any_rt = p.decode_batch(&states, 8, true, 500_000.0, &mut lanes);
        assert!(any_rt);
        assert_eq!(lanes, vec![1], "join gate closed under low slack");
        // without reactive lanes the gate never applies
        let pro_only = mk_states(&[
            (2, Priority::Proactive, Phase::Decoding, 0.0),
            (3, Priority::Proactive, Phase::Decoding, 0.0),
        ]);
        let any_rt = p.decode_batch(&pro_only, 8, true, 500_000.0, &mut lanes);
        assert!(!any_rt);
        assert_eq!(lanes.len(), 2);
    }

    #[test]
    fn deadline_engine_completes_mixed_loads() {
        let mut e =
            DeadlineEngine::synthetic(geo(), default_soc(), SchedulerConfig::default());
        let mut trace: Vec<Request> = (0..6)
            .map(|i| req(i, Priority::Proactive, i as f64 * 30_000.0, 300, 20))
            .collect();
        trace.push(req(100, Priority::Reactive, 50_000.0, 128, 8));
        trace.push(req(101, Priority::Reactive, 700_000.0, 128, 8));
        let rep = e.run(trace).unwrap();
        assert_eq!(rep.engine, "deadline");
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 8);
        assert!(e.last_trace().is_some());
    }
}
