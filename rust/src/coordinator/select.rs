//! Candidate selection: proactive resumption ordering (§6.2) and decode
//! batch formation / intra-XPU backfill (§6.3).
//!
//! Both helpers run on every engine step, so their working vectors are
//! thread-local scratch reused across calls — the steady-state decision
//! loop allocates nothing here once the buffers are warm.

use std::cell::RefCell;

use crate::engine::{Phase, ReqState, States};
use crate::heg::Annotator;
use crate::workload::ReqId;

/// Exact estimated-time-to-completion of a request's remaining prefill
/// on `xpu` (§6.2): sum each remaining chunk's per-layer kernel time
/// over its remaining layers — the annotations make this a lookup.
pub fn prefill_etc_us(st: &ReqState, ann: &Annotator, xpu: usize) -> f64 {
    let n_layers = ann.geo.n_layers;
    let mut total = 0.0;
    for (i, chunk) in st.plan.pending().iter().enumerate() {
        let per = ann.prefill_kernel(chunk).timings[xpu].nominal_us;
        let layers = if i == 0 { n_layers - st.layer_idx() } else { n_layers };
        total += per * layers as f64;
    }
    total
}

/// Pre-computed sort key for one resumption candidate.
#[derive(Clone, Copy)]
struct ResumeKey {
    starved: bool,
    age: f64,
    cont: bool,
    cp: usize,
    etc: f64,
}

thread_local! {
    /// Keyed-candidate scratch for [`resume_order`].
    static RESUME_KEYS: RefCell<Vec<(ReqId, ResumeKey)>> = const { RefCell::new(Vec::new()) };
    /// (reactive, proactive) enqueue-keyed scratch for [`decode_lanes`].
    static LANE_KEYS: RefCell<(Vec<(f64, ReqId)>, Vec<(f64, ReqId)>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Resumption strategy (§6.2): among paused proactive prefills, pick
/// (1) starved tasks first — pending longer than `starvation_age_ms`,
///     oldest first — to prevent indefinite postponement (§6.5);
/// (2) otherwise continuation turns of in-flight flows first — their
///     session KV is already resident, so finishing them both frees the
///     retained cache soonest and keeps the flow's think-time pipeline
///     moving (DESIGN.md §3);
/// (3) then, when `critical_path` is set, the longest remaining
///     dependency chain first (`FlowBinding::crit_path`): finishing the
///     deepest workflow DAG keeps its serial tail from gating the
///     overall makespan while shallow branches fill the bubbles
///     (DESIGN.md §3 critical-path priority);
/// (4) then the lowest estimated-time-to-completion (ETC), so tasks
///     enter the decode pipeline sooner and feed its throughput.
///
/// All sort keys — ETC included — are computed once per candidate
/// before the sort; evaluating the exact chunk-sum ETC inside the
/// comparator cost O(n log n) chunk walks per call against the §8 5 µs
/// decision budget (tracked by `benches/sched_micro.rs`).
pub fn resume_order(
    states: &States,
    candidates: &mut Vec<ReqId>,
    ann: &Annotator,
    npu: usize,
    now_us: f64,
    starvation_age_us: f64,
    critical_path: bool,
) {
    RESUME_KEYS.with_borrow_mut(|keyed| {
        keyed.clear();
        keyed.extend(candidates.iter().map(|id| {
            let st = &states[id];
            let age = now_us - st.enqueued_at_us;
            let cont =
                st.req.flow.as_ref().map(|f| f.is_continuation()).unwrap_or(false);
            let cp = if critical_path {
                st.req.flow.as_ref().map(|f| f.crit_path_len()).unwrap_or(1)
            } else {
                1 // FIFO/ETC baseline: critical path never discriminates
            };
            let key = ResumeKey {
                starved: age > starvation_age_us,
                age,
                cont,
                cp,
                etc: prefill_etc_us(st, ann, npu),
            };
            (*id, key)
        }));
        keyed.sort_by(|(ia, a), (ib, b)| match (a.starved, b.starved) {
            (true, false) => std::cmp::Ordering::Less,
            (false, true) => std::cmp::Ordering::Greater,
            (true, true) => b.age.total_cmp(&a.age), // older first
            (false, false) => b
                .cont
                .cmp(&a.cont) // flow continuations first
                .then(b.cp.cmp(&a.cp)) // longest remaining chain first
                .then(a.etc.total_cmp(&b.etc))
                .then(ia.cmp(ib)),
        });
        candidates.clear();
        candidates.extend(keyed.iter().map(|(id, _)| *id));
    });
}

/// Decode batch formation (§6.3 intra-XPU backfill / adaptive batching):
/// reactive lanes always join; proactive lanes backfill at the iteration
/// boundary up to `b_max` when allowed.  Fills `lanes` (cleared first)
/// and returns whether any lane is reactive.
pub fn decode_lanes(
    states: &States,
    b_max: usize,
    allow_proactive_join: bool,
    lanes: &mut Vec<ReqId>,
) -> bool {
    lanes.clear();
    LANE_KEYS.with_borrow_mut(|(reactive, proactive)| {
        reactive.clear();
        proactive.clear();
        // lint:allow(no-unordered-iteration) lane keys collected then sorted by the (enqueue time, id) total key below
        for st in states.values() {
            if st.phase != Phase::Decoding || st.running {
                continue;
            }
            if st.is_reactive() {
                reactive.push((st.enqueued_at_us, st.id()));
            } else {
                proactive.push((st.enqueued_at_us, st.id()));
            }
        }
        // longest-waiting reactive lanes lead (enqueue order, not ReqId —
        // ids say nothing about who has been decoding-ready longest)
        reactive.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let any_reactive = !reactive.is_empty();
        lanes.extend(reactive.iter().map(|(_, id)| *id));
        if allow_proactive_join || lanes.is_empty() {
            proactive.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            for &(_, id) in proactive.iter() {
                if lanes.len() >= b_max {
                    break;
                }
                lanes.push(id);
            }
        }
        lanes.truncate(b_max);
        any_reactive
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{default_soc, llama32_3b};
    use crate::engine::ExecBridge;
    use crate::soc::XpuModel;
    use crate::workload::{Priority, Request};

    fn mk_states(specs: &[(u64, Priority, Phase, f64)]) -> States {
        let mut geo = llama32_3b();
        geo.n_layers = 4;
        let bridge = ExecBridge::synthetic(geo);
        specs
            .iter()
            .map(|&(id, prio, phase, enq)| {
                let req = Request {
                    id,
                    priority: prio,
                    arrival_us: 0.0,
                    prompt: vec![1; 300],
                    max_new_tokens: 8,
                    profile: "test".into(),
                    flow: None,
                };
                let mut st = bridge.init_state(req, 512);
                st.phase = phase;
                st.enqueued_at_us = enq;
                (id, st)
            })
            .collect()
    }

    fn lanes_of(states: &States, b_max: usize, join: bool) -> (Vec<ReqId>, bool) {
        let mut lanes = vec![];
        let any_rt = decode_lanes(states, b_max, join, &mut lanes);
        (lanes, any_rt)
    }

    fn ann() -> Annotator {
        let mut geo = llama32_3b();
        geo.n_layers = 4;
        Annotator::new(
            geo,
            default_soc().xpus.iter().cloned().map(XpuModel::new).collect(),
        )
    }

    #[test]
    fn starved_tasks_resume_first_oldest_first() {
        let states = mk_states(&[
            (1, Priority::Proactive, Phase::Prefilling, 0.0),
            (2, Priority::Proactive, Phase::Prefilling, 100.0),
            (3, Priority::Proactive, Phase::Prefilling, 5_000_000.0),
        ]);
        let mut c = vec![3, 2, 1];
        // now=6s, threshold 2s → tasks 1 and 2 are starved, 3 is not
        resume_order(&states, &mut c, &ann(), 0, 6e6, 2e6, true);
        assert_eq!(&c[..2], &[1, 2], "starved oldest-first");
        assert_eq!(c[2], 3);
    }

    #[test]
    fn unstarved_ordered_by_etc() {
        let mut states = mk_states(&[
            (1, Priority::Proactive, Phase::Prefilling, 0.0),
            (2, Priority::Proactive, Phase::Prefilling, 0.0),
        ]);
        // give task 2 more progress → lower ETC
        states.get_mut(&2).unwrap().plan.set_progress(1, 0);
        let mut c = vec![1, 2];
        resume_order(&states, &mut c, &ann(), 0, 1000.0, 1e12, true);
        assert_eq!(c, vec![2, 1], "lower ETC first");
    }

    #[test]
    fn flow_continuations_resume_before_fresh_starts() {
        let mut states = mk_states(&[
            (1, Priority::Proactive, Phase::Prefilling, 0.0),
            (2, Priority::Proactive, Phase::Prefilling, 0.0),
        ]);
        // request 2 is turn 1 of an in-flight monitor flow
        states.get_mut(&2).unwrap().req.flow =
            Some(crate::workload::FlowBinding::linear(9, 1, 3, 0.0, 100));
        // equal ETC and age: the continuation outranks the fresh start
        let mut c = vec![1, 2];
        resume_order(&states, &mut c, &ann(), 0, 1000.0, 1e12, true);
        assert_eq!(c, vec![2, 1], "continuation work first");
        // ... but starvation still dominates: starve request 1
        states.get_mut(&1).unwrap().enqueued_at_us = -1e9;
        let mut c = vec![1, 2];
        resume_order(&states, &mut c, &ann(), 0, 1000.0, 1e6, true);
        assert_eq!(c, vec![1, 2], "starved task outranks continuation");
    }

    #[test]
    fn longest_critical_path_resumes_first_among_continuations() {
        let mut states = mk_states(&[
            (1, Priority::Proactive, Phase::Prefilling, 0.0),
            (2, Priority::Proactive, Phase::Prefilling, 0.0),
        ]);
        // both are continuations; request 1 sits on a 6-node chain,
        // request 2 on a 2-node chain
        states.get_mut(&1).unwrap().req.flow =
            Some(crate::workload::FlowBinding::linear(7, 1, 7, 0.0, 100));
        states.get_mut(&2).unwrap().req.flow =
            Some(crate::workload::FlowBinding::linear(8, 1, 3, 0.0, 100));
        let mut c = vec![2, 1];
        resume_order(&states, &mut c, &ann(), 0, 1000.0, 1e12, true);
        assert_eq!(c, vec![1, 2], "deepest remaining chain first");
        // the FIFO/ETC baseline (ablation) ignores the critical path:
        // equal ETC and age fall back to id order
        let mut c = vec![2, 1];
        resume_order(&states, &mut c, &ann(), 0, 1000.0, 1e12, false);
        assert_eq!(c, vec![1, 2], "ties break by id without cp priority");
        // give request 2 more progress → lower ETC wins when cp is off
        states.get_mut(&2).unwrap().plan.set_progress(1, 0);
        let mut c = vec![1, 2];
        resume_order(&states, &mut c, &ann(), 0, 1000.0, 1e12, false);
        assert_eq!(c, vec![2, 1], "ETC decides without cp priority");
        // ... while cp priority keeps the deep chain ahead regardless
        let mut c = vec![1, 2];
        resume_order(&states, &mut c, &ann(), 0, 1000.0, 1e12, true);
        assert_eq!(c, vec![1, 2], "cp outranks ETC");
    }

    #[test]
    fn decode_lanes_reactive_first_then_backfill() {
        let states = mk_states(&[
            (1, Priority::Proactive, Phase::Decoding, 10.0),
            (2, Priority::Reactive, Phase::Decoding, 50.0),
            (3, Priority::Proactive, Phase::Decoding, 5.0),
            (4, Priority::Proactive, Phase::Prefilling, 0.0),
        ]);
        let (lanes, any_rt) = lanes_of(&states, 8, true);
        assert!(any_rt);
        assert_eq!(lanes[0], 2, "reactive lane leads");
        // proactive join ordered by wait time
        assert_eq!(&lanes[1..], &[3, 1]);
    }

    #[test]
    fn reactive_lanes_ordered_by_enqueue_time_not_id() {
        // request 9 has the higher id but has waited longer than 2 —
        // enqueue order must win (sorting by ReqId starved late-id
        // requests that became decode-ready first)
        let states = mk_states(&[
            (2, Priority::Reactive, Phase::Decoding, 500.0),
            (9, Priority::Reactive, Phase::Decoding, 100.0),
            (5, Priority::Reactive, Phase::Decoding, 300.0),
        ]);
        let (lanes, any_rt) = lanes_of(&states, 8, true);
        assert!(any_rt);
        assert_eq!(lanes, vec![9, 5, 2], "enqueue order, oldest first");
        // b_max truncation drops the *newest* reactive lanes
        let (lanes, _) = lanes_of(&states, 2, true);
        assert_eq!(lanes, vec![9, 5]);
        // ties fall back to id for determinism
        let tied = mk_states(&[
            (4, Priority::Reactive, Phase::Decoding, 7.0),
            (1, Priority::Reactive, Phase::Decoding, 7.0),
        ]);
        let (lanes, _) = lanes_of(&tied, 8, true);
        assert_eq!(lanes, vec![1, 4]);
    }

    #[test]
    fn no_proactive_join_when_disallowed_but_reactive_present() {
        let states = mk_states(&[
            (1, Priority::Proactive, Phase::Decoding, 10.0),
            (2, Priority::Reactive, Phase::Decoding, 50.0),
        ]);
        let (lanes, any_rt) = lanes_of(&states, 8, false);
        assert!(any_rt);
        assert_eq!(lanes, vec![2]);
        // ... but proactive-only batches still form
        let states = mk_states(&[
            (1, Priority::Proactive, Phase::Decoding, 10.0),
            (3, Priority::Proactive, Phase::Decoding, 5.0),
        ]);
        let (lanes, any_rt) = lanes_of(&states, 8, false);
        assert!(!any_rt);
        assert_eq!(lanes.len(), 2);
    }

    #[test]
    fn b_max_caps_the_batch() {
        let specs: Vec<_> = (1..=10)
            .map(|i| (i as u64, Priority::Proactive, Phase::Decoding, i as f64))
            .collect();
        let states = mk_states(&specs);
        let (lanes, _) = lanes_of(&states, 4, true);
        assert_eq!(lanes.len(), 4);
    }

    #[test]
    fn running_lanes_are_excluded() {
        let mut states = mk_states(&[
            (1, Priority::Proactive, Phase::Decoding, 1.0),
            (2, Priority::Proactive, Phase::Decoding, 2.0),
        ]);
        states.get_mut(&1).unwrap().running = true;
        let (lanes, _) = lanes_of(&states, 8, true);
        assert_eq!(lanes, vec![2]);
    }
}
