//! Memory management (paper §6.5): the coordinator tracks the footprint
//! of weights + per-request KV caches against the SoC's physical DRAM
//! budget, defers *starting* proactive prefills that would not fit, and
//! — as graceful degradation — evicts a started proactive task (losing
//! its prefill progress, like scheme (a)) to make room for a reactive
//! arrival under extreme pressure.
//!
//! Flow-level sessions add a third residency class: *idle* retained
//! session caches (a finished turn's KV parked for the next turn).
//! They are charged one KV slot each and evicted LRU-first **before**
//! any in-flight prefill — losing a session only costs a recompute of
//! one conversation prefix, while losing an in-flight prefill wastes
//! work already scheduled.
//!
//! The paper assumes "moderate workload density without exceeding
//! available RAM" and treats flash offloading as orthogonal future work;
//! this governor is the admission-control half that keeps that
//! assumption true.

use crate::config::{ModelGeometry, SocConfig};
use crate::engine::{Phase, ReqState, States};
use crate::workload::ReqId;

/// Tracks model + KV residency against the DRAM budget.
#[derive(Debug, Clone)]
pub struct MemoryGovernor {
    pub budget_bytes: u64,
    pub weights_bytes: u64,
    pub kv_bytes_per_req: u64,
}

impl MemoryGovernor {
    pub fn new(geo: &ModelGeometry, soc: &SocConfig) -> Self {
        // weights stream at `weight_bytes`/param; KV caches are f32 in
        // our runtime (max_seq preallocated per request, both K and V,
        // all layers)
        let weights_bytes = (geo.n_params() as f64 * geo.weight_bytes) as u64;
        let kv_bytes_per_req = (2 * geo.n_layers * geo.cache_elems() * 4) as u64;
        Self {
            budget_bytes: (soc.dram_gb * 1e9) as u64,
            weights_bytes,
            kv_bytes_per_req,
        }
    }

    /// A request holds KV memory once its prefill has started (progress
    /// or a running kernel) until it completes.  A continuation turn
    /// that claimed its session's retained cache holds that KV from
    /// admission — the slot moved out of the pool's books and into the
    /// request's (an eviction resets `cached_prefix_len`, releasing it).
    fn holds_memory(st: &ReqState) -> bool {
        match st.phase {
            Phase::Prefilling => {
                st.running || st.prefill_started() || st.cached_prefix_len > 0
            }
            Phase::Decoding => true,
            Phase::Done => false,
        }
    }

    /// Current resident footprint (bytes): weights + in-flight KV +
    /// `retained_sessions` idle session caches (one KV slot each).
    pub fn footprint_with_sessions(
        &self,
        states: &States,
        retained_sessions: usize,
    ) -> u64 {
        let held = states.values().filter(|s| Self::holds_memory(s)).count() as u64;
        self.weights_bytes + (held + retained_sessions as u64) * self.kv_bytes_per_req
    }

    /// Current resident footprint (bytes), ignoring retained sessions.
    pub fn footprint(&self, states: &States) -> u64 {
        self.footprint_with_sessions(states, 0)
    }

    /// Would starting one more request fit the budget?
    pub fn can_start(&self, states: &States) -> bool {
        self.can_start_with_sessions(states, 0)
    }

    /// Like [`Self::can_start`], also charging `retained_sessions` idle
    /// session caches against the budget.
    pub fn can_start_with_sessions(
        &self,
        states: &States,
        retained_sessions: usize,
    ) -> bool {
        self.footprint_with_sessions(states, retained_sessions) + self.kv_bytes_per_req
            <= self.budget_bytes
    }

    /// Graceful-degradation victim for a reactive admission: the
    /// *least-progressed* started proactive prefill that is not
    /// currently running (its context is recomputable; decode-phase
    /// tasks are never evicted — their work is nearly done).
    pub fn eviction_victim(&self, states: &States) -> Option<ReqId> {
        states
            .values() // lint:allow(no-unordered-iteration) min_by_key over the (cursor, id) total key — order-free
            .filter(|s| {
                !s.is_reactive()
                    && s.phase == Phase::Prefilling
                    && !s.running
                    && Self::holds_memory(s)
            })
            .min_by_key(|s| (s.plan.cursor(), s.id()))
            .map(|s| s.id())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{default_soc, llama32_3b};
    use crate::engine::ExecBridge;
    use crate::workload::{Priority, Request};

    fn mk_state(id: u64, prio: Priority, progress: usize) -> ReqState {
        let mut geo = llama32_3b();
        geo.n_layers = 4;
        let bridge = ExecBridge::synthetic(geo);
        let mut st = bridge.init_state(
            Request {
                id,
                priority: prio,
                arrival_us: 0.0,
                prompt: vec![1; 600],
                max_new_tokens: 4,
                profile: "mem".into(),
                flow: None,
            },
            512,
        );
        st.plan.set_progress(0, progress);
        st
    }

    fn gov() -> MemoryGovernor {
        let mut geo = llama32_3b();
        geo.n_layers = 4;
        MemoryGovernor::new(&geo, &default_soc())
    }

    #[test]
    fn footprint_counts_only_started_requests() {
        let g = gov();
        let mut states = States::default();
        states.insert(1, mk_state(1, Priority::Proactive, 0)); // not started
        assert_eq!(g.footprint(&states), g.weights_bytes);
        states.insert(2, mk_state(2, Priority::Proactive, 2)); // mid-prefill
        assert_eq!(g.footprint(&states), g.weights_bytes + g.kv_bytes_per_req);
        let mut done = mk_state(3, Priority::Proactive, 1);
        done.phase = Phase::Done;
        states.insert(3, done);
        assert_eq!(g.footprint(&states), g.weights_bytes + g.kv_bytes_per_req);
    }

    #[test]
    fn budget_gates_new_starts() {
        let mut g = gov();
        // budget: weights + exactly 2 KV slots
        g.budget_bytes = g.weights_bytes + 2 * g.kv_bytes_per_req;
        let mut states = States::default();
        assert!(g.can_start(&states));
        states.insert(1, mk_state(1, Priority::Proactive, 1));
        assert!(g.can_start(&states));
        states.insert(2, mk_state(2, Priority::Proactive, 1));
        assert!(!g.can_start(&states), "third start must be deferred");
    }

    #[test]
    fn eviction_picks_least_progressed_waiting_proactive() {
        let g = gov();
        let mut states = States::default();
        states.insert(1, mk_state(1, Priority::Proactive, 3));
        states.insert(2, mk_state(2, Priority::Proactive, 1));
        let mut rt = mk_state(9, Priority::Reactive, 2);
        rt.phase = Phase::Prefilling;
        states.insert(9, rt);
        assert_eq!(g.eviction_victim(&states), Some(2));
        // a running victim is untouchable (kernel atomicity)
        states.get_mut(&2).unwrap().running = true;
        assert_eq!(g.eviction_victim(&states), Some(1));
        // decoding tasks are never evicted
        states.get_mut(&1).unwrap().phase = Phase::Decoding;
        states.get_mut(&2).unwrap().running = false;
        assert_eq!(g.eviction_victim(&states), Some(2));
    }

    #[test]
    fn claimed_session_kv_is_charged_from_admission() {
        // a continuation turn that claimed its session cache holds a KV
        // slot before its first kernel runs — the slot left the pool's
        // books at take_match time and must not vanish from the total
        let mut geo = llama32_3b();
        geo.n_layers = 4;
        let bridge = ExecBridge::synthetic(geo);
        let seed = crate::runtime::SessionSeed { cache: None, reuse: 200 };
        let st = bridge.init_state_with_session(
            Request {
                id: 1,
                priority: Priority::Reactive,
                arrival_us: 0.0,
                prompt: vec![1; 300],
                max_new_tokens: 4,
                profile: "mem".into(),
                flow: None,
            },
            512,
            Some(seed),
        );
        assert_eq!(st.cached_prefix_len, 200);
        let g = gov();
        let mut states = States::default();
        states.insert(1, st);
        assert_eq!(g.footprint(&states), g.weights_bytes + g.kv_bytes_per_req);
        // ... and an eviction releases it again
        let geo2 = {
            let mut g2 = llama32_3b();
            g2.n_layers = 4;
            g2
        };
        states.get_mut(&1).unwrap().restart_prefill(&geo2);
        assert_eq!(g.footprint(&states), g.weights_bytes);
    }

    #[test]
    fn retained_sessions_are_charged_one_kv_slot_each() {
        let mut g = gov();
        g.budget_bytes = g.weights_bytes + 3 * g.kv_bytes_per_req;
        let mut states = States::default();
        states.insert(1, mk_state(1, Priority::Proactive, 1)); // one in-flight KV
        assert_eq!(
            g.footprint_with_sessions(&states, 2),
            g.weights_bytes + 3 * g.kv_bytes_per_req
        );
        // in-flight + 1 session + new start = 3 slots → fits exactly
        assert!(g.can_start_with_sessions(&states, 1));
        // a second idle session pushes the new start over budget
        assert!(!g.can_start_with_sessions(&states, 2));
        // ignoring sessions (legacy view) it still fits
        assert!(g.can_start(&states));
    }

    #[test]
    fn paper_scale_budget_holds_dozens_of_requests() {
        let geo = llama32_3b();
        let g = MemoryGovernor::new(&geo, &default_soc());
        // 3.2 GB weights in 32 GB DRAM; KV (f32, 2048 ctx) ≈ 0.47 GB/req
        let slots = (g.budget_bytes - g.weights_bytes) / g.kv_bytes_per_req;
        assert!((30..200).contains(&slots), "slots {slots}");
    }
}
