//! Memory-aware kernel dispatch — the paper's Algorithm 1 (§6.4).
//!
//! ```text
//! procedure DispatchKernel(K, XPU_target)
//!     P  ← GetMemoryPressure()
//!     ΔP ← EstimatePressureIncrease(K)
//!     if P + ΔP > τ_high:            WaitForSlot(XPU_target)
//!     else if K.priority = REACTIVE: LaunchImmediate(K, XPU_target)
//!     else if CanCoSchedule(K, ActiveKernels): Launch(K, XPU_target)
//!     else:                          EnqueueDeferred(K)
//! ```
//!
//! Tiers (§6.4): P<τ_low aggressive co-scheduling; τ_low≤P<τ_high
//! selective pairing by memory intensity; P≥τ_high sequential with
//! reactive priority.

use crate::config::SchedulerConfig;
use crate::soc::{KernelTiming, SocSim};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchDecision {
    Launch,
    /// Leave the XPU idle; retry at the next scheduling point
    /// (WaitForSlot / EnqueueDeferred collapse to this in a DES).
    Defer,
}

/// Algorithm 1.  `reactive` is K.priority == REACTIVE.
pub fn dispatch_check(
    sim: &SocSim,
    cfg: &SchedulerConfig,
    t: &KernelTiming,
    reactive: bool,
) -> DispatchDecision {
    // Nothing is running: deferring would deadlock, and there is no
    // contention to avoid — launch unconditionally.
    if sim.all_idle() {
        return DispatchDecision::Launch;
    }
    let p = sim.memory_pressure();
    // ΔP estimate: the paper's BW_k(t;φ) is *instantaneous* — a
    // compute-bound kernel draws bandwidth only during its (short)
    // memory phase, so its sustained pressure contribution is weighted
    // by the memory duty cycle tm/body.  Memory-bound kernels (duty≈1)
    // are charged in full.
    let body = t.tc_us.max(t.tm_us).max(1e-9);
    let duty = (t.tm_us / body).min(1.0);
    let dp = sim.pressure_increase(t) * duty;
    if p + dp > cfg.pressure_high {
        // High tier: sequential execution... but reactive kernels keep
        // priority — they may still launch when the pressure overshoot
        // is their own demand (i.e. the system was below the tier).
        if reactive && p < cfg.pressure_high {
            return DispatchDecision::Launch;
        }
        return DispatchDecision::Defer;
    }
    if reactive {
        return DispatchDecision::Launch;
    }
    if p + dp < cfg.pressure_low {
        // Low tier: aggressive co-scheduling.
        return DispatchDecision::Launch;
    }
    // Medium tier: selective pairing — never co-run two memory-bound
    // kernels (the Fig. 3 destructive case); compute-bound candidates
    // pair with anything.
    let candidate_memory_bound = t.tm_us > t.tc_us;
    if candidate_memory_bound && sim.any_active_memory_bound() {
        DispatchDecision::Defer
    } else {
        DispatchDecision::Launch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SchedulerConfig, default_soc};
    use crate::model::{gemm_cost, gemv_cost};
    use crate::soc::{KernelClass, LaunchSpec};

    fn setup() -> (SocSim, SchedulerConfig) {
        (SocSim::new(&default_soc()), SchedulerConfig::default())
    }

    #[test]
    fn idle_soc_always_launches() {
        let (sim, cfg) = setup();
        let t = sim.xpus[0].timing(&gemv_cost(8192, 8192));
        // even a bandwidth-saturating kernel launches on an idle SoC
        assert_eq!(dispatch_check(&sim, &cfg, &t, false), DispatchDecision::Launch);
    }

    #[test]
    fn low_pressure_aggressive_coscheduling() {
        let (mut sim, cfg) = setup();
        let npu = sim.xpu_index("npu").unwrap();
        let gemm = sim.xpus[npu].timing(&gemm_cost(4096, 4096, 4096));
        sim.launch(npu, LaunchSpec { timing: gemm, class: KernelClass::Proactive });
        // another compute-bound kernel: P stays tiny → launch
        let igpu = sim.xpu_index("igpu").unwrap();
        let gemm2 = sim.xpus[igpu].timing(&gemm_cost(4096, 4096, 4096));
        assert_eq!(dispatch_check(&sim, &cfg, &gemm2, false), DispatchDecision::Launch);
    }

    #[test]
    fn high_pressure_defers_proactive() {
        let (mut sim, cfg) = setup();
        let igpu = sim.xpu_index("igpu").unwrap();
        let gemv = sim.xpus[igpu].timing(&gemv_cost(8192, 8192));
        sim.launch(igpu, LaunchSpec { timing: gemv, class: KernelClass::Proactive });
        // iGPU GEMV demands ~70/89.6 = 0.78 > τ_high already
        let npu = sim.xpu_index("npu").unwrap();
        let gemv2 = sim.xpus[npu].timing(&gemv_cost(8192, 8192));
        assert_eq!(dispatch_check(&sim, &cfg, &gemv2, false), DispatchDecision::Defer);
        // a reactive kernel still launches: the system itself sits below
        // the high tier (0.61), so the overshoot is the candidate's own
        // demand — reactive priority wins (Algorithm 1 lines 6-7)
        assert_eq!(dispatch_check(&sim, &cfg, &gemv2, true), DispatchDecision::Launch);
        // ... but when the system is *already* at the high tier, even
        // reactive waits for the slot
        let npu_gemv = sim.xpus[npu].timing(&gemv_cost(8192, 8192));
        sim.launch(npu, LaunchSpec { timing: npu_gemv, class: KernelClass::Proactive });
        let cpu = sim.xpu_index("cpu").unwrap();
        let gemv3 = sim.xpus[cpu].timing(&gemv_cost(8192, 8192));
        assert_eq!(dispatch_check(&sim, &cfg, &gemv3, true), DispatchDecision::Defer);
    }

    #[test]
    fn medium_pressure_selective_pairing() {
        let (mut sim, mut cfg) = setup();
        // widen the medium band so the GEMV lands in it
        cfg.pressure_low = 0.2;
        cfg.pressure_high = 2.0;
        let igpu = sim.xpu_index("igpu").unwrap();
        let gemv = sim.xpus[igpu].timing(&gemv_cost(8192, 8192));
        sim.launch(igpu, LaunchSpec { timing: gemv, class: KernelClass::Proactive });
        let npu = sim.xpu_index("npu").unwrap();
        // memory-bound candidate vs memory-bound active → defer
        let gemv2 = sim.xpus[npu].timing(&gemv_cost(8192, 8192));
        assert_eq!(dispatch_check(&sim, &cfg, &gemv2, false), DispatchDecision::Defer);
        // compute-bound candidate pairs fine
        let gemm = sim.xpus[npu].timing(&gemm_cost(4096, 4096, 4096));
        assert_eq!(dispatch_check(&sim, &cfg, &gemm, false), DispatchDecision::Launch);
    }

    #[test]
    fn reactive_priority_in_medium_band() {
        let (mut sim, mut cfg) = setup();
        cfg.pressure_low = 0.2;
        cfg.pressure_high = 2.0;
        let igpu = sim.xpu_index("igpu").unwrap();
        let gemv = sim.xpus[igpu].timing(&gemv_cost(8192, 8192));
        sim.launch(igpu, LaunchSpec { timing: gemv, class: KernelClass::Proactive });
        let npu = sim.xpu_index("npu").unwrap();
        let gemv2 = sim.xpus[npu].timing(&gemv_cost(8192, 8192));
        // reactive launches immediately in the medium band
        assert_eq!(dispatch_check(&sim, &cfg, &gemv2, true), DispatchDecision::Launch);
    }
}
