//! Per-kernel predictive annotation (paper §5.3): every HEG kernel gets
//! standalone execution time, bandwidth utilization, memory footprint,
//! and power for *each* XPU it may elastically bind to, so the online
//! scheduler's decisions are table lookups, not model evaluations.

use crate::config::ModelGeometry;
use crate::model::{KernelCost, decode_iter_cost, prefill_layer_cost};
use crate::soc::{KernelTiming, XpuModel};

use super::plan::ChunkSpec;

/// A kernel with its annotation across all XPUs.
#[derive(Debug, Clone)]
pub struct Annotated {
    pub cost: KernelCost,
    /// Per-XPU standalone timing, indexed like `Annotator::xpus`.
    pub timings: Vec<KernelTiming>,
    /// Fastest XPU index (ties break to lower index).
    pub fastest: usize,
    /// Most energy-efficient XPU index (TFLOPS/W — backfill ranking §6.3).
    pub most_efficient: usize,
}

impl Annotated {
    pub fn timing_on(&self, xpu: usize) -> &KernelTiming {
        &self.timings[xpu]
    }

    /// Predicted duration on `xpu` while co-running against the other
    /// XPU's kernel: the memory phase is stretched by the asymmetric
    /// DDR contention penalty from the mobile-SoC characterization
    /// study (PAPERS.md) — a split is *not* free bandwidth.  Exact for
    /// the simulator's progress model: `max(tc + launch, tm)` becomes
    /// `max(tc + launch, tm × penalty) = max(nominal, tm × penalty)`.
    pub fn co_run_us(&self, xpu: usize, ddr_penalty: f64) -> f64 {
        let t = &self.timings[xpu];
        t.nominal_us.max(t.tm_us * ddr_penalty)
    }
}

/// Annotation factory bound to one geometry + SoC.
pub struct Annotator {
    pub geo: ModelGeometry,
    pub xpus: Vec<XpuModel>,
}

impl Annotator {
    pub fn new(geo: ModelGeometry, xpus: Vec<XpuModel>) -> Self {
        Self { geo, xpus }
    }

    pub fn xpu_index(&self, name: &str) -> Option<usize> {
        self.xpus.iter().position(|x| x.name() == name)
    }

    pub fn annotate(&self, cost: KernelCost) -> Annotated {
        let timings: Vec<KernelTiming> =
            self.xpus.iter().map(|x| x.timing(&cost)).collect();
        let fastest = timings
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.nominal_us.total_cmp(&b.1.nominal_us))
            .map(|(i, _)| i)
            .unwrap_or(0);
        let most_efficient = self
            .xpus
            .iter()
            .enumerate()
            .max_by(|a, b| {
                a.1.tflops_per_watt(&cost).total_cmp(&b.1.tflops_per_watt(&cost))
            })
            .map(|(i, _)| i)
            .unwrap_or(0);
        Annotated { cost, timings, fastest, most_efficient }
    }

    /// One (chunk, layer) prefill kernel.  All layers share the shape,
    /// so the annotation is layer-independent.
    pub fn prefill_kernel(&self, chunk: &ChunkSpec) -> Annotated {
        self.annotate(prefill_layer_cost(
            &self.geo,
            chunk.variant,
            chunk.valid,
            chunk.pos,
            chunk.dynamic,
        ))
    }

    /// One batched decode iteration (head + embed + all layers).
    pub fn decode_iter(&self, lanes: usize, avg_ctx: usize) -> Annotated {
        self.annotate(decode_iter_cost(&self.geo, lanes, avg_ctx.max(1)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;

    fn annot() -> Annotator {
        // Paper-scale geometry: affinity assertions only hold when
        // kernels are big enough that compute dominates launch overhead.
        let geo = crate::config::llama32_3b();
        let xpus = default_soc().xpus.iter().cloned().map(XpuModel::new).collect();
        Annotator::new(geo, xpus)
    }

    #[test]
    fn static_prefill_prefers_npu() {
        // §5.2 hetero-disaggregation: static chunked prefill is NPU-affine.
        let a = annot();
        let npu = a.xpu_index("npu").unwrap();
        let k = a.prefill_kernel(&ChunkSpec {
            variant: 128,
            valid: 128,
            pos: 0,
            dynamic: false,
            co_run: false,
        });
        assert_eq!(k.fastest, npu);
        assert_eq!(k.most_efficient, npu);
    }

    #[test]
    fn dynamic_margin_prefers_igpu() {
        let a = annot();
        let igpu = a.xpu_index("igpu").unwrap();
        let k = a.prefill_kernel(&ChunkSpec {
            variant: 64,
            valid: 44,
            pos: 256,
            dynamic: true,
            co_run: false,
        });
        assert_eq!(k.fastest, igpu, "NPU JIT penalty must push margins to iGPU");
    }

    #[test]
    fn decode_prefers_igpu_over_npu() {
        // decode is attention/GEMV heavy and batch-dynamic: iGPU territory
        let a = annot();
        let npu = a.xpu_index("npu").unwrap();
        let igpu = a.xpu_index("igpu").unwrap();
        let k = a.decode_iter(4, 256);
        assert!(
            k.timings[igpu].nominal_us < k.timings[npu].nominal_us * 1.2,
            "igpu {} npu {}",
            k.timings[igpu].nominal_us,
            k.timings[npu].nominal_us
        );
    }

    #[test]
    fn co_run_timing_pays_the_ddr_penalty() {
        let a = annot();
        let igpu = a.xpu_index("igpu").unwrap();
        // long-context decode is memory-bound: the co-run penalty
        // stretches it by the full factor
        let k = a.decode_iter(1, 2048);
        let t = k.timing_on(igpu).clone();
        assert!(t.tm_us >= t.nominal_us - 1e-9, "expected memory-bound");
        assert!((k.co_run_us(igpu, 1.2) - t.tm_us * 1.2).abs() < 1e-9);
        // a unity factor is the standalone timing
        assert!((k.co_run_us(igpu, 1.0) - t.nominal_us).abs() < 1e-9);
        // a compute-bound kernel hides a small penalty entirely
        let npu = a.xpu_index("npu").unwrap();
        let p = a.prefill_kernel(&ChunkSpec {
            variant: 256,
            valid: 256,
            pos: 0,
            dynamic: false,
            co_run: false,
        });
        let tn = p.timing_on(npu);
        if tn.tc_us > tn.tm_us * 1.3 {
            assert!((p.co_run_us(npu, 1.2) - tn.nominal_us).abs() < 1e-9);
        }
    }

    #[test]
    fn annotations_cover_all_xpus() {
        let a = annot();
        let k = a.decode_iter(1, 10);
        assert_eq!(k.timings.len(), a.xpus.len());
        assert!(k.timings.iter().all(|t| t.nominal_us > 0.0));
    }
}
