//! Elastic chunk planning: split a prompt into precompiled static chunks
//! plus one dynamic margin chunk (paper §5.2 "Elastic Chunked Kernel").
//!
//! The plan greedily uses the largest precompiled chunk whose worst-case
//! per-layer kernel time fits the preemption latency budget (§6.2 keeps
//! prefill kernels under ~100 ms so a reactive arrival never waits long
//! for a kernel boundary).

use crate::config::ModelGeometry;
use crate::model::prefill_layer_cost;
use crate::soc::XpuModel;

/// One prefill chunk of a request's plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChunkSpec {
    /// Precompiled variant size executed (padded if `valid < variant`).
    pub variant: usize,
    /// Real tokens in this chunk.
    pub valid: usize,
    /// Cache position where the chunk starts.
    pub pos: usize,
    /// Margin chunks are dynamic-shape (iGPU-affine, §5.2).
    pub dynamic: bool,
}

/// Pick the largest chunk size whose worst-position per-layer kernel
/// stays within `budget_ms` on the slowest candidate XPU.
pub fn max_chunk_within_budget(
    geo: &ModelGeometry,
    xpus: &[&XpuModel],
    budget_ms: f64,
) -> usize {
    let mut best = *geo.chunk_sizes.iter().min().unwrap_or(&1);
    for &c in &geo.chunk_sizes {
        let worst = prefill_layer_cost(geo, c, c, geo.max_seq.saturating_sub(c), false);
        let fits = xpus
            .iter()
            .all(|x| x.timing(&worst).nominal_us <= budget_ms * 1e3);
        if fits && c > best {
            best = c;
        }
    }
    best
}

/// Split `prompt_len` tokens into a chunk plan.
pub fn plan_chunks(geo: &ModelGeometry, prompt_len: usize, max_chunk: usize) -> Vec<ChunkSpec> {
    plan_chunks_from(geo, prompt_len, max_chunk, 0)
}

/// Split the tokens `[start..prompt_len)` into a chunk plan whose cache
/// positions begin at `start` — the *delta-prefill* path of flow-level
/// session reuse (DESIGN.md §3): positions `[0..start)` are already
/// resident in the session's retained KV cache, so only the fresh turn
/// delta is planned (and each chunk's attention still spans the full
/// prefix via its absolute `pos`).
pub fn plan_chunks_from(
    geo: &ModelGeometry,
    prompt_len: usize,
    max_chunk: usize,
    start: usize,
) -> Vec<ChunkSpec> {
    assert!(prompt_len > 0, "empty prompt");
    assert!(start < prompt_len, "cached prefix {start} swallows prompt {prompt_len}");
    assert!(
        prompt_len <= geo.max_seq,
        "prompt {prompt_len} exceeds max_seq {}",
        geo.max_seq
    );
    let smallest = *geo.chunk_sizes.iter().min().unwrap();
    let mut plan = vec![];
    let mut pos = start;
    // Greedy descending: consume the largest budget-feasible chunk that
    // fits the remainder, so mid-sized prompts still get static
    // (NPU-compilable) chunks instead of one big dynamic margin.
    loop {
        let left = prompt_len - pos;
        if left == 0 {
            break;
        }
        let fit = geo
            .chunk_sizes
            .iter()
            .copied()
            .filter(|&c| c <= max_chunk && c <= left)
            .max();
        match fit {
            Some(c) => {
                plan.push(ChunkSpec { variant: c, valid: c, pos, dynamic: false });
                pos += c;
            }
            None => {
                // margin: smaller than every variant — run it as the
                // smallest one, dynamic-shape (iGPU-affine, §5.2)
                plan.push(ChunkSpec {
                    variant: smallest,
                    valid: left,
                    pos,
                    dynamic: true,
                });
                pos += left;
            }
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;

    fn geo() -> ModelGeometry {
        ModelGeometry {
            name: "small".into(),
            vocab: 2048,
            d_model: 256,
            n_layers: 6,
            n_q_heads: 8,
            n_kv_heads: 2,
            head_dim: 32,
            d_ffn: 704,
            max_seq: 512,
            chunk_sizes: vec![16, 32, 64, 128],
            batch_sizes: vec![1, 2, 4, 8],
            rope_theta: 10000.0,
            weight_bytes: 4.0,
        }
    }

    #[test]
    fn plan_covers_prompt_exactly() {
        let g = geo();
        for len in [1, 15, 16, 17, 100, 128, 129, 300, 512] {
            let plan = plan_chunks(&g, len, 128);
            let total: usize = plan.iter().map(|c| c.valid).sum();
            assert_eq!(total, len, "len {len}");
            // positions are contiguous
            let mut pos = 0;
            for c in &plan {
                assert_eq!(c.pos, pos);
                assert!(c.valid <= c.variant);
                pos += c.valid;
            }
        }
    }

    #[test]
    fn only_last_chunk_is_margin() {
        let g = geo();
        let plan = plan_chunks(&g, 300, 128);
        for c in &plan[..plan.len() - 1] {
            assert!(!c.dynamic);
            assert_eq!(c.valid, c.variant);
        }
        // 300 = 128 + 128 + 32 + margin 12
        assert_eq!(
            plan.iter().map(|c| c.variant).collect::<Vec<_>>(),
            vec![128, 128, 32, 16]
        );
        let last = plan.last().unwrap();
        assert_eq!(last.valid, 12);
        assert!(last.dynamic);
    }

    #[test]
    fn mid_sized_prompts_get_static_chunks() {
        // the bug this guards: a 180-token prompt must NOT become one
        // big dynamic margin — it gets 128 + 32 + 16 static + margin 4
        let g = geo();
        let plan = plan_chunks(&g, 180, 512);
        assert_eq!(
            plan.iter().map(|c| (c.variant, c.dynamic)).collect::<Vec<_>>(),
            vec![(128, false), (32, false), (16, false), (16, true)]
        );
        let static_tokens: usize =
            plan.iter().filter(|c| !c.dynamic).map(|c| c.valid).sum();
        assert!(static_tokens as f64 >= 0.9 * 176.0);
    }

    #[test]
    fn exact_multiple_has_no_margin() {
        let g = geo();
        let plan = plan_chunks(&g, 256, 128);
        assert_eq!(plan.len(), 2);
        assert!(plan.iter().all(|c| !c.dynamic));
    }

    #[test]
    fn small_prompt_single_dynamic_chunk() {
        let g = geo();
        let plan = plan_chunks(&g, 5, 128);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].variant, 16);
        assert!(plan[0].dynamic);
        assert_eq!(plan[0].valid, 5);
    }

    #[test]
    fn offset_plan_covers_only_the_delta() {
        let g = geo();
        // 300-token conversation, 180 already cached → plan 120 tokens
        let plan = plan_chunks_from(&g, 300, 128, 180);
        let total: usize = plan.iter().map(|c| c.valid).sum();
        assert_eq!(total, 120);
        assert_eq!(plan[0].pos, 180, "first chunk starts at the cached prefix");
        let mut pos = 180;
        for c in &plan {
            assert_eq!(c.pos, pos);
            pos += c.valid;
        }
        assert_eq!(pos, 300);
        // zero offset is the plain plan
        assert_eq!(plan_chunks_from(&g, 300, 128, 0), plan_chunks(&g, 300, 128));
    }

    #[test]
    #[should_panic(expected = "swallows prompt")]
    fn offset_must_leave_delta_tokens() {
        let g = geo();
        plan_chunks_from(&g, 100, 128, 100);
    }

    #[test]
    fn max_chunk_cap_respected() {
        let g = geo();
        let plan = plan_chunks(&g, 300, 32);
        assert!(plan.iter().all(|c| c.variant <= 32));
    }

    #[test]
    fn budget_picks_large_chunk_on_fast_xpus() {
        let g = geo();
        let soc = default_soc();
        let npu = XpuModel::new(soc.xpu("npu").unwrap().clone());
        let c = max_chunk_within_budget(&g, &[&npu], 100.0);
        assert_eq!(c, 128, "small model easily fits 128-chunks in 100 ms");
        // an absurdly tight budget falls back to the smallest chunk
        let c = max_chunk_within_budget(&g, &[&npu], 1e-6);
        assert_eq!(c, 16);
    }

    #[test]
    #[should_panic(expected = "exceeds max_seq")]
    fn oversized_prompt_panics() {
        let g = geo();
        plan_chunks(&g, 513, 128);
    }
}
