//! Elastic chunk planning: split a prompt into precompiled static chunks
//! plus one dynamic margin chunk (paper §5.2 "Elastic Chunked Kernel").
//!
//! The plan greedily uses the largest precompiled chunk whose worst-case
//! per-layer kernel time fits the preemption latency budget (§6.2 keeps
//! prefill kernels under ~100 ms so a reactive arrival never waits long
//! for a kernel boundary).

use crate::config::ModelGeometry;
use crate::model::prefill_layer_cost;
use crate::soc::XpuModel;

/// One prefill chunk of a request's plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChunkSpec {
    /// Precompiled variant size executed (padded if `valid < variant`).
    pub variant: usize,
    /// Real tokens in this chunk.
    pub valid: usize,
    /// Cache position where the chunk starts.
    pub pos: usize,
    /// Margin chunks are dynamic-shape (iGPU-affine, §5.2).
    pub dynamic: bool,
    /// Produced by a mid-flight [`ElasticPlan::split`]: this chunk runs
    /// while its sibling occupies the other XPU, so its memory phase
    /// pays the asymmetric co-run DDR penalty (PAPERS.md
    /// characterization study).
    pub co_run: bool,
}

/// Pick the largest chunk size whose worst-position per-layer kernel
/// stays within `budget_ms` on the slowest candidate XPU.
pub fn max_chunk_within_budget(
    geo: &ModelGeometry,
    xpus: &[&XpuModel],
    budget_ms: f64,
) -> usize {
    // `ModelGeometry::validate` guarantees a non-empty, sorted, deduped
    // list at config load, so an empty list here is a programmer error —
    // fail loudly instead of silently degrading to 1-token chunks.
    let mut best = *geo
        .chunk_sizes
        .iter()
        .min()
        .expect("geometry has no chunk_sizes (ModelGeometry::validate not run?)");
    for &c in &geo.chunk_sizes {
        let worst = prefill_layer_cost(geo, c, c, geo.max_seq.saturating_sub(c), false);
        let fits = xpus
            .iter()
            .all(|x| x.timing(&worst).nominal_us <= budget_ms * 1e3);
        if fits && c > best {
            best = c;
        }
    }
    best
}

/// Split `prompt_len` tokens into a chunk plan.
pub fn plan_chunks(geo: &ModelGeometry, prompt_len: usize, max_chunk: usize) -> Vec<ChunkSpec> {
    plan_chunks_from(geo, prompt_len, max_chunk, 0)
}

/// Split the tokens `[start..prompt_len)` into a chunk plan whose cache
/// positions begin at `start` — the *delta-prefill* path of flow-level
/// session reuse (DESIGN.md §3): positions `[0..start)` are already
/// resident in the session's retained KV cache, so only the fresh turn
/// delta is planned (and each chunk's attention still spans the full
/// prefix via its absolute `pos`).
pub fn plan_chunks_from(
    geo: &ModelGeometry,
    prompt_len: usize,
    max_chunk: usize,
    start: usize,
) -> Vec<ChunkSpec> {
    assert!(prompt_len > 0, "empty prompt");
    assert!(start < prompt_len, "cached prefix {start} swallows prompt {prompt_len}");
    assert!(
        prompt_len <= geo.max_seq,
        "prompt {prompt_len} exceeds max_seq {}",
        geo.max_seq
    );
    let smallest = *geo
        .chunk_sizes
        .iter()
        .min()
        .expect("geometry has no chunk_sizes (ModelGeometry::validate not run?)");
    let mut plan = vec![];
    let mut pos = start;
    // Greedy descending: consume the largest budget-feasible chunk that
    // fits the remainder, so mid-sized prompts still get static
    // (NPU-compilable) chunks instead of one big dynamic margin.
    loop {
        let left = prompt_len - pos;
        if left == 0 {
            break;
        }
        let fit = geo
            .chunk_sizes
            .iter()
            .copied()
            .filter(|&c| c <= max_chunk && c <= left)
            .max();
        match fit {
            Some(c) => {
                plan.push(ChunkSpec {
                    variant: c,
                    valid: c,
                    pos,
                    dynamic: false,
                    co_run: false,
                });
                pos += c;
            }
            None => {
                // margin: smaller than every variant — run it as the
                // smallest one, dynamic-shape (iGPU-affine, §5.2)
                plan.push(ChunkSpec {
                    variant: smallest,
                    valid: left,
                    pos,
                    dynamic: true,
                    co_run: false,
                });
                pos += left;
            }
        }
    }
    plan
}

/// A live, re-partitionable prefill plan (the HEG's *elastic* operator
/// binding, paper §4/§5.2).
///
/// Where the old pipeline froze a `Vec<ChunkSpec>` at admission and let
/// the request state carry raw `chunk_idx`/`layer_idx` cursors, an
/// `ElasticPlan` owns both the remaining chunks and the execution
/// cursor, and supports mid-flight *re-binding*:
///
/// - [`replan`](Self::replan) — rebuild the remaining coverage from an
///   arbitrary position with a new chunk budget (restart-after-evict,
///   delta-prefill after session stitch).
/// - [`split`](Self::split) — cut one pending static chunk along the
///   tensor-partition dimension into an iGPU-affine dynamic part and an
///   NPU-affine static remainder, both flagged `co_run` so the SoC
///   model charges the asymmetric DDR co-run penalty.
/// - [`fold_margin`](Self::fold_margin) — re-bind the pending dynamic
///   margin chunk to a padded static variant so it can run on the NPU
///   when the duty governor or graphics contention squeezes the iGPU.
///
/// Every mutation preserves the coverage invariant checked by
/// [`assert_coverage`](Self::assert_coverage): pending chunks tile
/// `[cursor position .. prompt_len)` exactly once, contiguously and in
/// order.
#[derive(Debug, Clone, PartialEq)]
pub struct ElasticPlan {
    chunks: Vec<ChunkSpec>,
    chunk_idx: usize,
    layer_idx: usize,
    prompt_len: usize,
}

impl ElasticPlan {
    /// Wrap an existing chunk vector (must tile `[start..prompt_len)`).
    pub fn new(chunks: Vec<ChunkSpec>, prompt_len: usize) -> Self {
        let p = Self { chunks, chunk_idx: 0, layer_idx: 0, prompt_len };
        p.assert_coverage();
        p
    }

    /// Plan the tokens `[start..prompt_len)` (delta-prefill when
    /// `start > 0`) — the elastic counterpart of [`plan_chunks_from`].
    pub fn plan(geo: &ModelGeometry, prompt_len: usize, max_chunk: usize, start: usize) -> Self {
        Self::new(plan_chunks_from(geo, prompt_len, max_chunk, start), prompt_len)
    }

    /// All chunks, consumed and pending.
    pub fn chunks(&self) -> &[ChunkSpec] {
        &self.chunks
    }

    /// Chunks not yet fully executed (the current one first).
    pub fn pending(&self) -> &[ChunkSpec] {
        &self.chunks[self.chunk_idx.min(self.chunks.len())..]
    }

    pub fn chunk_idx(&self) -> usize {
        self.chunk_idx
    }

    pub fn layer_idx(&self) -> usize {
        self.layer_idx
    }

    /// The execution cursor as an ordered pair (progress comparisons:
    /// eviction victims, preemption accounting).
    pub fn cursor(&self) -> (usize, usize) {
        (self.chunk_idx, self.layer_idx)
    }

    /// The chunk the next prefill kernel executes (None when done).
    pub fn current(&self) -> Option<&ChunkSpec> {
        self.chunks.get(self.chunk_idx)
    }

    pub fn len(&self) -> usize {
        self.chunks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.chunks.is_empty()
    }

    pub fn prompt_len(&self) -> usize {
        self.prompt_len
    }

    /// Has any prefill kernel of this plan completed?
    pub fn started(&self) -> bool {
        self.chunk_idx > 0 || self.layer_idx > 0
    }

    /// All chunks fully executed.
    pub fn done(&self) -> bool {
        self.chunk_idx >= self.chunks.len()
    }

    /// Prefill kernels left ((chunks × layers) remaining).
    pub fn remaining_kernels(&self, n_layers: usize) -> usize {
        if self.done() {
            return 0;
        }
        (self.chunks.len() - self.chunk_idx - 1) * n_layers + (n_layers - self.layer_idx)
    }

    /// Tokens not yet prefilled (Σ valid over pending chunks).
    pub fn pending_tokens(&self) -> usize {
        self.pending().iter().map(|c| c.valid).sum()
    }

    /// Advance the cursor past one completed (chunk, layer) kernel.
    /// Returns true when that finished the current *chunk* (the caller
    /// then commits pos/KV side effects before checking [`done`](Self::done)).
    pub fn advance_layer(&mut self, n_layers: usize) -> bool {
        debug_assert!(!self.done(), "advance_layer beyond plan");
        self.layer_idx += 1;
        if self.layer_idx < n_layers {
            return false;
        }
        self.layer_idx = 0;
        self.chunk_idx += 1;
        true
    }

    /// Place the cursor directly (tests and recovery paths).
    pub fn set_progress(&mut self, chunk_idx: usize, layer_idx: usize) {
        assert!(chunk_idx <= self.chunks.len(), "cursor beyond plan");
        self.chunk_idx = chunk_idx;
        self.layer_idx = layer_idx;
    }

    /// Reset the cursor to the start (restart-after-evict keeps the
    /// same coverage; use [`replan`](Self::replan) to rebuild it).
    pub fn rewind(&mut self) {
        self.chunk_idx = 0;
        self.layer_idx = 0;
    }

    /// Rebuild the remaining coverage: plan `[from_pos..prompt_len)`
    /// afresh under `max_chunk` and reset the cursor.  This is the
    /// restart / delta-prefill transition — any split or folded chunks
    /// are discarded with the old tail.
    pub fn replan(&mut self, geo: &ModelGeometry, from_pos: usize, max_chunk: usize) {
        self.chunks = plan_chunks_from(geo, self.prompt_len, max_chunk, from_pos);
        self.chunk_idx = 0;
        self.layer_idx = 0;
        self.assert_coverage();
    }

    /// Split pending chunk `idx` along the tensor-partition dimension:
    /// the first `ratio` of its tokens become an iGPU-affine dynamic
    /// part, the rest an NPU-affine static remainder (padded to the
    /// smallest compiled variant that fits).  Both are flagged
    /// `co_run`, so their memory phases pay the asymmetric DDR
    /// contention penalty.  The iGPU part is placed *first* in plan
    /// order (it dispatches immediately while the NPU is pinned).
    ///
    /// Returns `(npu_part, igpu_part)`, or None when the chunk is not
    /// splittable: already started, dynamic, or too small to cut.
    pub fn split(
        &mut self,
        geo: &ModelGeometry,
        idx: usize,
        ratio: f64,
    ) -> Option<(ChunkSpec, ChunkSpec)> {
        if idx < self.chunk_idx || idx >= self.chunks.len() {
            return None;
        }
        // the head chunk is only splittable before its first layer ran
        if idx == self.chunk_idx && self.layer_idx > 0 {
            return None;
        }
        let c = self.chunks[idx];
        if c.dynamic || c.valid < 2 {
            return None;
        }
        let k = ((c.valid as f64 * ratio).round() as usize).clamp(1, c.valid - 1);
        let rest = c.valid - k;
        let igpu_part =
            ChunkSpec { variant: k, valid: k, pos: c.pos, dynamic: true, co_run: true };
        let npu_part = ChunkSpec {
            variant: geo.chunk_for(rest).unwrap_or(rest),
            valid: rest,
            pos: c.pos + k,
            dynamic: false,
            co_run: true,
        };
        self.chunks.splice(idx..=idx, [igpu_part, npu_part]);
        self.assert_coverage();
        Some((npu_part, igpu_part))
    }

    /// Re-bind the pending dynamic margin chunk to a padded static
    /// variant so the NPU can run it (duty governor / graphics squeeze
    /// on the iGPU).  Returns the rebound spec, or None when the
    /// current chunk is not an unstarted dynamic margin or no compiled
    /// variant fits it.
    pub fn fold_margin(&mut self, geo: &ModelGeometry) -> Option<ChunkSpec> {
        if self.layer_idx > 0 {
            return None;
        }
        let c = *self.current()?;
        if !c.dynamic {
            return None;
        }
        let variant = geo.chunk_for(c.valid)?;
        let folded = ChunkSpec { variant, dynamic: false, ..c };
        self.chunks[self.chunk_idx] = folded;
        self.assert_coverage();
        Some(folded)
    }

    /// The coverage invariant (debug builds): chunks tile a contiguous
    /// token range ending at `prompt_len`, each valid ≤ variant.
    pub fn assert_coverage(&self) {
        #[cfg(debug_assertions)]
        {
            let mut pos = None;
            for c in &self.chunks {
                assert!(c.valid >= 1 && c.valid <= c.variant, "chunk valid/variant corrupt");
                if let Some(p) = pos {
                    assert_eq!(c.pos, p, "chunk coverage not contiguous");
                }
                pos = Some(c.pos + c.valid);
            }
            if let Some(end) = pos {
                assert_eq!(end, self.prompt_len, "plan does not end at prompt_len");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;

    fn geo() -> ModelGeometry {
        ModelGeometry {
            name: "small".into(),
            vocab: 2048,
            d_model: 256,
            n_layers: 6,
            n_q_heads: 8,
            n_kv_heads: 2,
            head_dim: 32,
            d_ffn: 704,
            max_seq: 512,
            chunk_sizes: vec![16, 32, 64, 128],
            batch_sizes: vec![1, 2, 4, 8],
            rope_theta: 10000.0,
            weight_bytes: 4.0,
        }
    }

    #[test]
    fn plan_covers_prompt_exactly() {
        let g = geo();
        for len in [1, 15, 16, 17, 100, 128, 129, 300, 512] {
            let plan = plan_chunks(&g, len, 128);
            let total: usize = plan.iter().map(|c| c.valid).sum();
            assert_eq!(total, len, "len {len}");
            // positions are contiguous
            let mut pos = 0;
            for c in &plan {
                assert_eq!(c.pos, pos);
                assert!(c.valid <= c.variant);
                pos += c.valid;
            }
        }
    }

    #[test]
    fn only_last_chunk_is_margin() {
        let g = geo();
        let plan = plan_chunks(&g, 300, 128);
        for c in &plan[..plan.len() - 1] {
            assert!(!c.dynamic);
            assert_eq!(c.valid, c.variant);
        }
        // 300 = 128 + 128 + 32 + margin 12
        assert_eq!(
            plan.iter().map(|c| c.variant).collect::<Vec<_>>(),
            vec![128, 128, 32, 16]
        );
        let last = plan.last().unwrap();
        assert_eq!(last.valid, 12);
        assert!(last.dynamic);
    }

    #[test]
    fn mid_sized_prompts_get_static_chunks() {
        // the bug this guards: a 180-token prompt must NOT become one
        // big dynamic margin — it gets 128 + 32 + 16 static + margin 4
        let g = geo();
        let plan = plan_chunks(&g, 180, 512);
        assert_eq!(
            plan.iter().map(|c| (c.variant, c.dynamic)).collect::<Vec<_>>(),
            vec![(128, false), (32, false), (16, false), (16, true)]
        );
        let static_tokens: usize =
            plan.iter().filter(|c| !c.dynamic).map(|c| c.valid).sum();
        assert!(static_tokens as f64 >= 0.9 * 176.0);
    }

    #[test]
    fn exact_multiple_has_no_margin() {
        let g = geo();
        let plan = plan_chunks(&g, 256, 128);
        assert_eq!(plan.len(), 2);
        assert!(plan.iter().all(|c| !c.dynamic));
    }

    #[test]
    fn small_prompt_single_dynamic_chunk() {
        let g = geo();
        let plan = plan_chunks(&g, 5, 128);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].variant, 16);
        assert!(plan[0].dynamic);
        assert_eq!(plan[0].valid, 5);
    }

    #[test]
    fn offset_plan_covers_only_the_delta() {
        let g = geo();
        // 300-token conversation, 180 already cached → plan 120 tokens
        let plan = plan_chunks_from(&g, 300, 128, 180);
        let total: usize = plan.iter().map(|c| c.valid).sum();
        assert_eq!(total, 120);
        assert_eq!(plan[0].pos, 180, "first chunk starts at the cached prefix");
        let mut pos = 180;
        for c in &plan {
            assert_eq!(c.pos, pos);
            pos += c.valid;
        }
        assert_eq!(pos, 300);
        // zero offset is the plain plan
        assert_eq!(plan_chunks_from(&g, 300, 128, 0), plan_chunks(&g, 300, 128));
    }

    #[test]
    #[should_panic(expected = "swallows prompt")]
    fn offset_must_leave_delta_tokens() {
        let g = geo();
        plan_chunks_from(&g, 100, 128, 100);
    }

    #[test]
    fn max_chunk_cap_respected() {
        let g = geo();
        let plan = plan_chunks(&g, 300, 32);
        assert!(plan.iter().all(|c| c.variant <= 32));
    }

    #[test]
    fn budget_picks_large_chunk_on_fast_xpus() {
        let g = geo();
        let soc = default_soc();
        let npu = XpuModel::new(soc.xpu("npu").unwrap().clone());
        let c = max_chunk_within_budget(&g, &[&npu], 100.0);
        assert_eq!(c, 128, "small model easily fits 128-chunks in 100 ms");
        // an absurdly tight budget falls back to the smallest chunk
        let c = max_chunk_within_budget(&g, &[&npu], 1e-6);
        assert_eq!(c, 16);
    }

    #[test]
    #[should_panic(expected = "exceeds max_seq")]
    fn oversized_prompt_panics() {
        let g = geo();
        plan_chunks(&g, 513, 128);
    }

    #[test]
    fn elastic_cursor_walks_chunks_and_layers() {
        let g = geo();
        let mut p = ElasticPlan::plan(&g, 300, 128, 0);
        assert_eq!(p.len(), 4);
        assert!(!p.started() && !p.done());
        assert_eq!(p.pending_tokens(), 300);
        assert_eq!(p.remaining_kernels(g.n_layers), 4 * g.n_layers);
        // one full chunk of layers
        for l in 0..g.n_layers {
            let finished = p.advance_layer(g.n_layers);
            assert_eq!(finished, l == g.n_layers - 1);
        }
        assert_eq!(p.cursor(), (1, 0));
        assert!(p.started());
        assert_eq!(p.pending_tokens(), 300 - 128);
        while !p.done() {
            p.advance_layer(g.n_layers);
        }
        assert_eq!(p.remaining_kernels(g.n_layers), 0);
        assert_eq!(p.pending_tokens(), 0);
        assert!(p.current().is_none());
    }

    #[test]
    fn split_partitions_head_chunk_between_xpus() {
        let g = geo();
        let mut p = ElasticPlan::plan(&g, 300, 128, 0);
        let (npu, igpu) = p.split(&g, 0, 0.25).expect("head chunk splittable");
        // 128 tokens → 32 iGPU-affine + 96 NPU-affine (padded to 128)
        assert_eq!(igpu.valid, 32);
        assert!(igpu.dynamic && igpu.co_run);
        assert_eq!(igpu.pos, 0);
        assert_eq!(npu.valid, 96);
        assert!(!npu.dynamic && npu.co_run);
        assert_eq!(npu.pos, 32);
        assert_eq!(npu.variant, 128, "padded to smallest compiled fit");
        // the iGPU part dispatches first
        assert_eq!(p.current(), Some(&igpu));
        assert_eq!(p.chunks()[1], npu);
        assert_eq!(p.pending_tokens(), 300, "coverage preserved");
        assert_eq!(p.len(), 5);
    }

    #[test]
    fn split_refuses_started_dynamic_or_tiny_chunks() {
        let g = geo();
        let mut p = ElasticPlan::plan(&g, 300, 128, 0);
        p.advance_layer(g.n_layers); // head chunk mid-flight
        assert!(p.split(&g, 0, 0.5).is_none(), "started head chunk");
        assert!(p.split(&g, 3, 0.5).is_none(), "dynamic margin");
        assert!(p.split(&g, 9, 0.5).is_none(), "out of range");
        let mut q = ElasticPlan::new(
            vec![ChunkSpec { variant: 16, valid: 1, pos: 0, dynamic: false, co_run: false }],
            1,
        );
        assert!(q.split(&g, 0, 0.5).is_none(), "single token");
    }

    #[test]
    fn split_ratio_is_clamped_to_a_real_cut() {
        let g = geo();
        for ratio in [0.0, 0.001, 0.999, 1.0] {
            let mut p = ElasticPlan::plan(&g, 128, 128, 0);
            let (npu, igpu) = p.split(&g, 0, ratio).unwrap();
            assert!(igpu.valid >= 1 && npu.valid >= 1, "ratio {ratio}");
            assert_eq!(igpu.valid + npu.valid, 128);
        }
    }

    #[test]
    fn fold_margin_rebinds_to_padded_static() {
        let g = geo();
        let mut p = ElasticPlan::plan(&g, 300, 128, 0);
        assert!(p.fold_margin(&g).is_none(), "head chunk is static");
        while p.current().map(|c| !c.dynamic).unwrap_or(false) {
            for _ in 0..g.n_layers {
                p.advance_layer(g.n_layers);
            }
        }
        let folded = p.fold_margin(&g).expect("margin foldable");
        assert!(!folded.dynamic);
        assert_eq!(folded.valid, 12);
        assert_eq!(folded.variant, 16, "padded to smallest compiled fit");
        assert_eq!(p.pending_tokens(), 12);
        assert!(p.fold_margin(&g).is_none(), "already static now");
    }

    #[test]
    fn replan_rebuilds_remaining_coverage() {
        let g = geo();
        let mut p = ElasticPlan::plan(&g, 300, 128, 0);
        p.split(&g, 0, 0.5).unwrap();
        // restart from scratch discards splits
        p.replan(&g, 0, 128);
        assert_eq!(p.chunks(), &plan_chunks(&g, 300, 128)[..]);
        assert_eq!(p.cursor(), (0, 0));
        // delta replan from a cached prefix with a tighter budget
        p.replan(&g, 180, 32);
        assert_eq!(p.pending_tokens(), 120);
        assert_eq!(p.chunks()[0].pos, 180);
        assert!(p.chunks().iter().all(|c| c.variant <= 32));
    }
}
