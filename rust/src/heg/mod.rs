//! Heterogeneous Execution Graph (paper §5).
//!
//! The HEG is the hetero-centric compute abstraction: the model's op
//! groups become *elastic chunked kernels* whose XPU binding is decided
//! at dispatch time, pruned by affinity constraints (static chunks are
//! NPU-compilable; dynamic margin/attention kernels prefer the iGPU),
//! and annotated with predictive cost/timing/power so the online
//! scheduler can reason about them (§5.3).

mod annotate;
mod plan;

pub use annotate::{Annotated, Annotator};
pub use plan::{ChunkSpec, max_chunk_within_budget, plan_chunks, plan_chunks_from};
