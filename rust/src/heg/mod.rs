//! Heterogeneous Execution Graph (paper §5).
//!
//! The HEG is the hetero-centric compute abstraction: the model's op
//! groups become *elastic chunked kernels* held in a live
//! [`ElasticPlan`] that stays re-partitionable mid-flight — the XPU
//! binding is not frozen at dispatch time.  Affinity constraints prune
//! the choices (static chunks are NPU-compilable; dynamic
//! margin/attention kernels prefer the iGPU), and when contention
//! squeezes one side the scheduler's rebind hook can *fold* a margin
//! chunk back to a padded static NPU variant or *split* a pending
//! static chunk across NPU+iGPU along the tensor-partition dimension,
//! costed with the asymmetric co-run DDR penalty (§5.3 predictive
//! annotation + the PAPERS.md mobile-SoC characterization).

mod annotate;
mod plan;

pub use annotate::{Annotated, Annotator};
pub use plan::{
    ChunkSpec, ElasticPlan, max_chunk_within_budget, plan_chunks, plan_chunks_from,
};
