//! Per-request serving state — the paper's preemption context (§6.2):
//!
//! ```c
//! struct ReqContext {
//!     int layer_id;                      // model progress
//!     float16_t** kv_cache_ptr;          // attention states by layer
//!     std::vector<float16_t*> activation_buffer;
//!     std::vector<Kernel*> remaining_kernels;
//! };
//! ```
//!
//! In unified host memory the checkpoint is just this struct: preempting
//! at a kernel boundary costs nothing, and resumption recalls it with no
//! data movement.
//!
//! Flow turns additionally carry `cached_prefix_len`: the conversation
//! prefix already resident from the session's previous turn, so the
//! chunk plan covers only the delta tokens (DESIGN.md §3).

use crate::heg::{ChunkSpec, ElasticPlan};
use crate::metrics::ReqMetrics;
use crate::runtime::{HostTensor, KvCache};
use crate::workload::{Priority, ReqId, Request};

/// Where a request is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// Prefill kernels remain (`chunk_idx`/`layer_idx` point at the next).
    Prefilling,
    /// Prefill finished; first token emitted; decode iterations remain.
    Decoding,
    Done,
}

/// The serving context of one request.
#[derive(Debug)]
pub struct ReqState {
    pub req: Request,
    /// Live elastic chunk plan (paper §5.2): owns both the remaining
    /// chunks and the (chunk, layer) execution cursor, and stays
    /// re-partitionable mid-flight (split/fold/replan).  Covers only
    /// `[cached_prefix_len..prompt_len)` when a session cache was
    /// reused.
    pub plan: ElasticPlan,
    /// KV cache (None in timing-only mode).  Seeded from the session
    /// pool for continuation turns in real-compute mode.
    pub cache: Option<KvCache>,
    /// Activation buffer: the chunk/lane hidden state flowing between
    /// kernels (None in timing-only mode).
    pub x: Option<HostTensor>,
    /// Last emitted token (input to the next decode iteration).
    pub last_token: Option<i32>,
    /// Tokens generated so far (first token counts).
    pub tokens: Vec<i32>,
    /// Valid cached positions (mirrors cache.pos in real mode).
    pub pos: usize,
    /// Prompt tokens already resident from this flow's previous turn
    /// (0 for single-shot requests and prefix-cache misses).
    pub cached_prefix_len: usize,
    /// Chunk-size cap the plan was built with (needed to replan the
    /// full prompt if an eviction wipes the reused prefix).
    pub max_chunk: usize,
    pub phase: Phase,
    /// A kernel for this request is currently in flight.
    pub running: bool,
    /// When the request entered its current wait (for aging, §6.5).
    pub enqueued_at_us: f64,
    /// When a kernel for this request last completed (admission time
    /// before the first).  The iGPU duty governor's starvation valve
    /// keys off this — a request being served every iteration is not
    /// starved, however old its `enqueued_at_us` grows — while the
    /// §6.2 wait-ordering keeps using `enqueued_at_us` untouched.
    pub last_progress_us: f64,
    /// Times this request was preempted (introspection).
    pub preempted: u64,
    /// Preemption already counted for the current wait episode (cleared
    /// whenever the request launches a kernel).
    pub preempt_counted: bool,
    /// The request was cancelled while a batched decode kernel carrying
    /// it was in flight; it retires at the iteration boundary.
    pub cancelled: bool,
    pub metrics: ReqMetrics,
}

impl ReqState {
    pub fn new(
        req: Request,
        plan: ElasticPlan,
        cache: Option<KvCache>,
        max_chunk: usize,
        cached_prefix_len: usize,
    ) -> Self {
        let metrics = ReqMetrics {
            id: req.id,
            priority: req.priority,
            profile: req.profile.clone(),
            flow_id: req.flow_id(),
            turn_idx: req.turn_idx(),
            deps: req.dep_indices(),
            think_time_us: req.flow.as_ref().map(|f| f.think_time_us).unwrap_or(0.0),
            tool: false, // tool nodes never allocate serving state
            arrival_us: req.arrival_us,
            first_token_us: None,
            done_us: None,
            input_len: req.prompt_len(),
            output_tokens: 0,
            cached_prefix_len,
            prefill_tokens: 0,
            cancelled: false,
        };
        Self {
            enqueued_at_us: req.arrival_us,
            last_progress_us: req.arrival_us,
            req,
            plan,
            cache,
            x: None,
            last_token: None,
            tokens: vec![],
            pos: cached_prefix_len,
            cached_prefix_len,
            max_chunk,
            phase: Phase::Prefilling,
            running: false,
            preempted: 0,
            preempt_counted: false,
            cancelled: false,
            metrics,
        }
    }

    pub fn id(&self) -> ReqId {
        self.req.id
    }

    pub fn priority(&self) -> Priority {
        self.req.priority
    }

    pub fn is_reactive(&self) -> bool {
        self.req.priority.is_reactive()
    }

    pub fn current_chunk(&self) -> Option<&ChunkSpec> {
        self.plan.current()
    }

    /// The plan's (chunk, layer) cursor: next prefill kernel to execute.
    pub fn chunk_idx(&self) -> usize {
        self.plan.chunk_idx()
    }

    pub fn layer_idx(&self) -> usize {
        self.plan.layer_idx()
    }

    /// Any prefill kernel of this request has completed (progress worth
    /// protecting: memory accounting, preemption counting, eviction
    /// victim ordering all key off this).
    pub fn prefill_started(&self) -> bool {
        self.plan.started()
    }

    /// Remaining prefill kernels (the paper's remaining_kernels length).
    pub fn remaining_prefill_kernels(&self, n_layers: usize) -> usize {
        if self.phase != Phase::Prefilling {
            return 0;
        }
        self.plan.remaining_kernels(n_layers)
    }

    /// Reset all prefill progress (scheme-(a) baseline: preemption
    /// without saving context forces recomputation).  Any reused
    /// session prefix is lost with the KV, so the plan is rebuilt over
    /// the full prompt; a split or folded plan is also rebuilt (the
    /// recomputed coverage starts from scratch on the default binding).
    pub fn restart_prefill(&mut self, geo: &crate::config::ModelGeometry) {
        assert_eq!(self.phase, Phase::Prefilling, "can only restart prefill");
        if self.cached_prefix_len > 0 {
            self.cached_prefix_len = 0;
            self.metrics.cached_prefix_len = 0; // the reuse never materialized
        }
        self.plan.replan(geo, 0, self.max_chunk);
        self.pos = 0;
        self.x = None;
        if self.cache.is_some() {
            self.cache = Some(KvCache::new(geo));
        }
    }

    pub fn decode_iterations_left(&self) -> usize {
        self.req.max_new_tokens.saturating_sub(self.tokens.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Priority;

    pub(crate) fn mk(id: u64, prio: Priority, plen: usize) -> ReqState {
        let req = Request {
            id,
            priority: prio,
            arrival_us: 0.0,
            prompt: vec![1; plen],
            max_new_tokens: 4,
            profile: "test".into(),
            flow: None,
        };
        let plan = ElasticPlan::new(
            vec![
                ChunkSpec { variant: 16, valid: 16, pos: 0, dynamic: false, co_run: false },
                ChunkSpec { variant: 16, valid: 5, pos: 16, dynamic: true, co_run: false },
            ],
            // the literal chunks tile 21 tokens; callers with shorter
            // prompts only exercise decode-side accounting
            21,
        );
        ReqState::new(req, plan, None, 64, 0)
    }

    #[test]
    fn remaining_kernels_counts_down() {
        let mut st = mk(1, Priority::Proactive, 21);
        assert_eq!(st.remaining_prefill_kernels(4), 8);
        st.plan.set_progress(0, 3);
        assert_eq!(st.remaining_prefill_kernels(4), 5);
        st.plan.set_progress(1, 0);
        assert_eq!(st.remaining_prefill_kernels(4), 4);
        st.phase = Phase::Decoding;
        assert_eq!(st.remaining_prefill_kernels(4), 0);
    }

    #[test]
    fn restart_prefill_resets_progress() {
        let geo = crate::config::llama32_3b();
        let mut st = mk(1, Priority::Proactive, 21);
        st.plan.set_progress(1, 2);
        st.pos = 16;
        st.restart_prefill(&geo);
        assert_eq!((st.chunk_idx(), st.layer_idx(), st.pos), (0, 0, 0));
    }

    #[test]
    fn restart_prefill_discards_reused_prefix_and_replans() {
        let geo = crate::config::llama32_3b();
        let req = Request {
            id: 1,
            priority: Priority::Proactive,
            arrival_us: 0.0,
            prompt: vec![1; 300],
            max_new_tokens: 4,
            profile: "test".into(),
            flow: None,
        };
        // continuation turn: 200 of 300 tokens already cached
        let plan = ElasticPlan::plan(&geo, 300, 128, 200);
        let mut st = ReqState::new(req, plan, None, 128, 200);
        assert_eq!(st.pos, 200);
        assert_eq!(st.metrics.cached_prefix_len, 200);
        st.restart_prefill(&geo);
        assert_eq!(st.cached_prefix_len, 0);
        assert_eq!(st.metrics.cached_prefix_len, 0);
        assert_eq!(st.pos, 0);
        // the new plan covers the whole prompt from position 0
        assert_eq!(st.plan.chunks().first().unwrap().pos, 0);
        assert_eq!(st.plan.pending_tokens(), 300);
    }

    #[test]
    fn decode_iterations_left() {
        let mut st = mk(1, Priority::Reactive, 8);
        assert_eq!(st.decode_iterations_left(), 4);
        st.tokens = vec![1, 2, 3];
        assert_eq!(st.decode_iterations_left(), 1);
        st.tokens.push(4);
        assert_eq!(st.decode_iterations_left(), 0);
    }
}
