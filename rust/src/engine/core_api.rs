//! The incremental engine API (DESIGN.md §7): one clock-abstracted
//! `submit`/`step`/`cancel`/`drain` surface shared by the DES figure
//! harnesses and the real-time server.
//!
//! An engine is a *streaming* object: requests are submitted one at a
//! time, `step()` advances the engine to its next decision point and
//! returns what happened as [`EngineEvent`]s, and in-flight work can be
//! cancelled.  The run-to-completion `run(trace)` every figure harness
//! and baseline comparison uses is just a default-method loop over this
//! surface, so there is exactly one copy of every scheduling policy —
//! the same `AgentXpuEngine` serves a UDS socket against wall-clock
//! time and regenerates the paper's figures against virtual time.
//!
//! The clock split:
//!
//! - [`EngineClock::Virtual`] — discrete-event time from the SoC
//!   simulator; arrivals are honored at their trace `arrival_us`, and
//!   all lifecycle timestamps are virtual µs.  Simulation mode.
//! - [`EngineClock::Wall`] — wall-clock µs since `start()`; submissions
//!   are stamped on arrival and admitted immediately, kernel *ordering*
//!   still comes from the virtual SoC (so preemption, backfill, and
//!   batching decisions match the DES exactly), but lifecycle
//!   timestamps (TTFT, completion) are measured wall time.  Serving
//!   mode.

use std::time::Instant;

use anyhow::Result;

use crate::metrics::RunReport;
use crate::workload::{FlowId, ReqId, Request};

/// The time base an engine run executes against.
#[derive(Debug, Clone, Copy)]
pub enum EngineClock {
    /// Discrete-event virtual time (simulation / figures).
    Virtual,
    /// Wall-clock time measured from `t0` (real-time serving).
    Wall { t0: Instant },
}

impl EngineClock {
    /// A wall clock whose epoch is now.
    pub fn wall() -> Self {
        // lint:allow(no-wall-clock) the one sanctioned wall-clock epoch — only the server constructs it; DES runs never do
        EngineClock::Wall { t0: Instant::now() }
    }

    pub fn is_wall(&self) -> bool {
        matches!(self, EngineClock::Wall { .. })
    }
}

/// What happened during one `step()` — the streaming face of the run.
/// Timestamps are in the run's clock domain (virtual or wall µs).
#[derive(Debug, Clone, PartialEq)]
pub enum EngineEvent {
    /// The request entered the engine's working set (serving state
    /// allocated, session cache claimed if one matched).
    Admitted { id: ReqId, at_us: f64 },
    /// One generated token (the first marks the TTFT point).
    TokenEmitted { id: ReqId, token: i32, n: usize, at_us: f64 },
    /// The request completed with its full token budget.
    TurnDone {
        id: ReqId,
        at_us: f64,
        arrival_us: f64,
        first_token_us: f64,
        tokens: Vec<i32>,
        /// Prompt tokens served from a retained session cache.
        cached_prefix: usize,
    },
    /// A proactive task waiting at its kernel-boundary checkpoint was
    /// displaced by a reactive launch (§6.2).
    Preempted { id: ReqId, at_us: f64 },
    /// The request's elastic plan was re-bound mid-flight (§5.2): its
    /// margin chunk folded to a padded static variant
    /// (`split_tokens == 0`), or its head chunk split across NPU+iGPU
    /// with `split_tokens` routed to the co-run iGPU part.
    Rebound { id: ReqId, at_us: f64, split_tokens: usize },
    /// The memory governor wiped this in-flight prefill's KV (§6.5);
    /// the request recomputes from scratch.
    KvEvicted { id: ReqId, at_us: f64 },
    /// An idle retained session's KV was dropped (LRU shedding).
    SessionEvicted { flow_id: FlowId, at_us: f64 },
    /// The request was cancelled; its state and KV are freed.
    Cancelled { id: ReqId, at_us: f64 },
}

impl EngineEvent {
    /// The request this event concerns (None for session-level events).
    pub fn req_id(&self) -> Option<ReqId> {
        match self {
            EngineEvent::Admitted { id, .. }
            | EngineEvent::TokenEmitted { id, .. }
            | EngineEvent::TurnDone { id, .. }
            | EngineEvent::Preempted { id, .. }
            | EngineEvent::Rebound { id, .. }
            | EngineEvent::KvEvicted { id, .. }
            | EngineEvent::Cancelled { id, .. } => Some(*id),
            EngineEvent::SessionEvicted { .. } => None,
        }
    }

    /// True for events that end a request's lifecycle.
    pub fn is_terminal(&self) -> bool {
        matches!(self, EngineEvent::TurnDone { .. } | EngineEvent::Cancelled { .. })
    }
}

/// A cheap load snapshot of a running engine — what a fleet router
/// reads before placing a turn (DESIGN.md §9).  All fields are
/// instantaneous: outstanding work, the engine's clock position, the
/// windowed busy fraction of each LLM XPU, and cumulative energy.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EngineLoad {
    /// Submitted requests without a terminal event yet (queued, held
    /// flow turns, and in-flight work).
    pub unfinished: usize,
    /// Engine time (µs in the run's clock domain); 0 before `start`.
    pub now_us: f64,
    /// Windowed NPU duty cycle in [0, 1] (0 without an NPU).
    pub npu_duty: f64,
    /// Windowed iGPU duty cycle in [0, 1] (0 without an iGPU).
    pub igpu_duty: f64,
    /// Cumulative energy drawn this run (J).
    pub energy_j: f64,
}

/// What the overload detector measured at one decision point — the
/// serving loop computes this and asks the engine (and through it the
/// policy) how hard to shed (DESIGN.md §7).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverloadSignal {
    /// Requests queued ahead of the engine (admission queue).
    pub queue_depth: usize,
    /// Configured admission bound (0 = unbounded).
    pub max_queue_depth: usize,
    /// Measured reactive p99 TTFT over the recent window (ms); NaN
    /// before the first reactive completion.
    pub reactive_ttft_p99_ms: f64,
    /// Configured reactive TTFT SLO (ms); 0 disables the TTFT leg.
    pub reactive_ttft_slo_ms: f64,
}

/// How aggressively to degrade proactive work right now, weakest to
/// strongest.  Each level implies the ones below it: parking running
/// proactive decodes also pauses proactive admissions.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ShedLevel {
    /// No overload: admit and run everything.
    None,
    /// Stop admitting *new* proactive requests (reject with
    /// `retry_after`); queued and running proactive work proceeds.
    PauseProactive,
    /// Additionally cancel queued (not yet running) proactive
    /// requests, newest first — least invested work dies first.
    CancelQueuedProactive,
    /// Additionally preempt-and-park running proactive decodes so
    /// every XPU cycle serves reactive work; parked requests resume
    /// when the overload clears.
    ParkRunningProactive,
}

/// The default overload → shed-level mapping every policy inherits:
/// thresholds on queue occupancy and on measured reactive p99 TTFT as
/// a multiple of its SLO (either leg alone can escalate; a disabled
/// leg contributes nothing).
pub fn default_shed_level(s: &OverloadSignal) -> ShedLevel {
    let depth_frac = if s.max_queue_depth == 0 {
        0.0
    } else {
        s.queue_depth as f64 / s.max_queue_depth as f64
    };
    let ttft_frac = if s.reactive_ttft_slo_ms <= 0.0 || !s.reactive_ttft_p99_ms.is_finite()
    {
        0.0
    } else {
        s.reactive_ttft_p99_ms / s.reactive_ttft_slo_ms
    };
    if depth_frac >= 1.0 || ttft_frac >= 4.0 {
        ShedLevel::ParkRunningProactive
    } else if depth_frac >= 0.75 || ttft_frac >= 2.0 {
        ShedLevel::CancelQueuedProactive
    } else if depth_frac >= 0.5 || ttft_frac > 1.0 {
        ShedLevel::PauseProactive
    } else {
        ShedLevel::None
    }
}

/// The streaming engine core: every engine (Agent.xpu and the
/// baselines) is a scheduling policy behind this one surface.
///
/// Lifecycle: `start(clock)` opens a run, `submit` feeds it requests
/// (any time, including mid-run under a wall clock), `step` advances to
/// the next decision point, `cancel` aborts an in-flight request, and
/// `finish` closes the run into a [`RunReport`].  `has_work()` is false
/// when the engine is idle *right now* — under a wall clock new
/// submissions wake it again.
///
/// `run(trace)` — the legacy batch entry point — is a provided method:
/// submit everything, step until idle, report.  Nothing reimplements
/// the loop, so the DES harnesses, property tests, and the real-time
/// server all exercise the same policy code.
pub trait EngineCore {
    fn name(&self) -> String;

    /// Open a fresh run against the given clock, discarding any
    /// previous run's state.
    fn start(&mut self, clock: EngineClock) -> Result<()>;

    /// Feed one request into the run.  Under [`EngineClock::Virtual`]
    /// the request's `arrival_us` is honored; under a wall clock it is
    /// re-stamped to the submission instant.
    fn submit(&mut self, req: Request) -> Result<()>;

    /// Abort a request wherever it is (queued, held flow turn,
    /// prefilling, or decoding), freeing its KV.  Later turns of the
    /// same flow that can no longer be stitched are cancelled with it.
    /// Returns false if the id is unknown or already finished.
    fn cancel(&mut self, id: ReqId) -> Result<bool>;

    /// Advance to the next decision point (admissions, one scheduling
    /// pass, the next kernel completion or arrival) and report what
    /// happened.  An empty result with `has_work() == false` means the
    /// engine is idle.
    fn step(&mut self) -> Result<Vec<EngineEvent>>;

    /// True while the run can still make progress without new input.
    fn has_work(&self) -> bool;

    /// Close the run and produce its report.  Fails if admitted work
    /// never completed (a policy bug, surfaced loudly).
    fn finish(&mut self) -> Result<RunReport>;

    /// Instantaneous load snapshot of the open run (queue depth, XPU
    /// duty, energy) — what a fleet router reads before placing a turn.
    /// Default: an empty snapshot, for engines with nothing to report;
    /// `PolicyEngine` implements it for every registry policy.
    fn load(&self) -> EngineLoad {
        EngineLoad::default()
    }

    /// Kernel-level trace of the last *finished* run (Gantt figures,
    /// serialization checks).  `PolicyEngine` retains one for every
    /// policy — baselines included; engines that don't record traces
    /// may return `None`.
    fn last_trace(&self) -> Option<&crate::trace::Trace> {
        None
    }

    /// How hard should the serving loop degrade proactive work given
    /// what the overload detector measured?  `PolicyEngine` delegates
    /// to [`SchedPolicy::shed_level`](crate::engine::SchedPolicy::shed_level),
    /// so every registry policy inherits (or overrides) the response.
    fn overload_response(&self, s: &OverloadSignal) -> ShedLevel {
        default_shed_level(s)
    }

    /// Attach a synthetic graphics workload to subsequent runs (frames
    /// render on the iGPU with compositor priority; jank lands in
    /// `RunReport::frames_missed`).  Virtual-clock runs only; `None`
    /// detaches.  Default: ignored — `PolicyEngine` implements it for
    /// every policy.
    fn set_graphics(&mut self, _cfg: Option<crate::soc::GraphicsConfig>) {}

    /// Step until idle, collecting every event.
    fn drain(&mut self) -> Result<Vec<EngineEvent>> {
        let mut out = vec![];
        while self.has_work() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    /// Legacy batch entry point: run a whole trace to completion on the
    /// virtual clock.  This is the thin generic loop every figure
    /// harness and property test goes through.
    fn run(&mut self, trace: Vec<Request>) -> Result<RunReport> {
        self.start(EngineClock::Virtual)?;
        for r in trace {
            self.submit(r)?;
        }
        while self.has_work() {
            let _ = self.step()?;
        }
        self.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sig(depth: usize, cap: usize, p99: f64, slo: f64) -> OverloadSignal {
        OverloadSignal {
            queue_depth: depth,
            max_queue_depth: cap,
            reactive_ttft_p99_ms: p99,
            reactive_ttft_slo_ms: slo,
        }
    }

    #[test]
    fn shed_levels_escalate_with_queue_occupancy() {
        assert_eq!(default_shed_level(&sig(0, 100, f64::NAN, 0.0)), ShedLevel::None);
        assert_eq!(
            default_shed_level(&sig(50, 100, f64::NAN, 0.0)),
            ShedLevel::PauseProactive
        );
        assert_eq!(
            default_shed_level(&sig(75, 100, f64::NAN, 0.0)),
            ShedLevel::CancelQueuedProactive
        );
        assert_eq!(
            default_shed_level(&sig(100, 100, f64::NAN, 0.0)),
            ShedLevel::ParkRunningProactive
        );
    }

    #[test]
    fn shed_levels_escalate_with_ttft_slo_violation() {
        assert_eq!(default_shed_level(&sig(0, 0, 99.0, 100.0)), ShedLevel::None);
        assert_eq!(
            default_shed_level(&sig(0, 0, 150.0, 100.0)),
            ShedLevel::PauseProactive
        );
        assert_eq!(
            default_shed_level(&sig(0, 0, 250.0, 100.0)),
            ShedLevel::CancelQueuedProactive
        );
        assert_eq!(
            default_shed_level(&sig(0, 0, 500.0, 100.0)),
            ShedLevel::ParkRunningProactive
        );
    }

    #[test]
    fn disabled_legs_never_shed() {
        // unbounded queue + no SLO: any depth / latency is "fine"
        assert_eq!(default_shed_level(&sig(10_000, 0, 1e9, 0.0)), ShedLevel::None);
        // NaN p99 (no reactive completions yet) contributes nothing
        assert_eq!(default_shed_level(&sig(0, 100, f64::NAN, 10.0)), ShedLevel::None);
        // levels are ordered so detectors can compare strength
        assert!(ShedLevel::ParkRunningProactive > ShedLevel::PauseProactive);
        assert!(ShedLevel::PauseProactive > ShedLevel::None);
    }
}
