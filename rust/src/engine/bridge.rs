//! The numerics bridge: when the DES declares a kernel finished, this
//! executes its *effect* — either for real through the PJRT runtime
//! (`ModelExecutor`) or synthetically (timing-only sweeps).
//!
//! The timing/numerics split is the core of the hardware substitution
//! (DESIGN.md §1): scheduling decisions consume virtual time from the
//! SoC simulator; tokens and KV caches are still bit-exact when
//! `real_compute` is on.

use std::sync::Arc;

use anyhow::Result;

use crate::config::ModelGeometry;
use crate::heg::ElasticPlan;
use crate::runtime::{KvCache, ModelExecutor, SessionSeed};
use crate::workload::Request;

use super::reqstate::{Phase, ReqState};

/// Synthetic next-token function (timing-only mode): deterministic,
/// in-vocab, and distinct per position so traces are inspectable.
fn synth_token(pos: usize, vocab: usize) -> i32 {
    ((pos.wrapping_mul(1_103_515_245).wrapping_add(12_345)) % vocab.max(1)) as i32
}

/// Executes kernel effects for one model.  Cloning is cheap (the real
/// executor is shared behind an `Arc`) — `PolicyEngine` clones its
/// bridge into each fresh run's `Driver`.
#[derive(Clone)]
pub struct ExecBridge {
    exec: Option<Arc<ModelExecutor>>,
    pub geo: ModelGeometry,
}

impl ExecBridge {
    pub fn real(exec: Arc<ModelExecutor>) -> Self {
        let geo = exec.geo().clone();
        Self { exec: Some(exec), geo }
    }

    pub fn synthetic(geo: ModelGeometry) -> Self {
        Self { exec: None, geo }
    }

    pub fn is_real(&self) -> bool {
        self.exec.is_some()
    }

    /// The underlying PJRT executor, when this is a real-compute bridge
    /// (lets the serving layer rebuild an engine around the same
    /// loaded artifacts).
    pub fn executor(&self) -> Option<Arc<ModelExecutor>> {
        self.exec.clone()
    }

    /// Build the initial serving context for an admitted request.
    pub fn init_state(&self, req: Request, max_chunk: usize) -> ReqState {
        self.init_state_with_session(req, max_chunk, None)
    }

    /// Build the serving context for a flow turn, optionally seeded from
    /// the session pool: with a usable seed the chunk plan covers only
    /// the delta tokens `[reuse..prompt_len)` and (in real mode) the
    /// retained KV becomes the turn's cache.  A real-compute turn can
    /// only reuse a seed that actually carries a KV cache.
    pub fn init_state_with_session(
        &self,
        req: Request,
        max_chunk: usize,
        session: Option<SessionSeed>,
    ) -> ReqState {
        let plen = req.prompt_len();
        let cap = plen.saturating_sub(1);
        let (cache, cached) = match (self.exec.is_some(), session) {
            (true, Some(s)) if s.cache.is_some() => {
                let reuse = s.reuse.min(cap);
                let mut kv = s.cache.unwrap();
                kv.pos = reuse; // positions beyond a partial match are stale
                (Some(kv), reuse)
            }
            (true, _) => (Some(KvCache::new(&self.geo)), 0),
            (false, Some(s)) => (None, s.reuse.min(cap)),
            (false, None) => (None, 0),
        };
        let plan = ElasticPlan::plan(&self.geo, plen, max_chunk, cached);
        ReqState::new(req, plan, cache, max_chunk, cached)
    }

    /// Effect of the prefill kernel at the plan's (chunk, layer)
    /// cursor; advances it through the elastic plan and, at the end of
    /// the last chunk, emits the first token (TTFT point).  Returns
    /// `true` when prefill completed.
    pub fn prefill_kernel_done(&self, st: &mut ReqState) -> Result<bool> {
        debug_assert_eq!(st.phase, Phase::Prefilling);
        let chunk = *st.current_chunk().expect("prefill kernel beyond plan");
        let n_layers = self.geo.n_layers;

        if let Some(exec) = &self.exec {
            let cache = st.cache.as_mut().expect("real mode has cache");
            if st.layer_idx() == 0 {
                let toks =
                    &st.req.prompt[chunk.pos..chunk.pos + chunk.valid];
                st.x = Some(exec.embed(toks, chunk.variant)?);
            }
            let x = st.x.take().expect("activation buffer");
            let y = exec.layer_prefill(
                chunk.variant,
                st.layer_idx(),
                &x,
                cache,
                chunk.pos,
            )?;
            st.x = Some(y);
        }

        if !st.plan.advance_layer(n_layers) {
            return Ok(false);
        }
        // chunk finished — commit its KV/position side effects
        st.pos = chunk.pos + chunk.valid;
        st.metrics.prefill_tokens += chunk.valid;
        if let Some(cache) = st.cache.as_mut() {
            cache.pos = st.pos;
        }
        if !st.plan.done() {
            return Ok(false);
        }
        // prefill complete → first token
        let tok = if let Some(exec) = &self.exec {
            let x = st.x.as_ref().expect("activation buffer");
            let last = x.row(chunk.valid - 1);
            st.x = Some(last.clone());
            exec.head(&last)?[0]
        } else {
            synth_token(st.pos, self.geo.vocab)
        };
        st.tokens.push(tok);
        st.last_token = Some(tok);
        st.metrics.output_tokens = st.tokens.len();
        st.phase = if st.decode_iterations_left() == 0 {
            Phase::Done
        } else {
            Phase::Decoding
        };
        Ok(true)
    }

    /// Effect of one batched decode iteration over `lanes` (embed last
    /// token → all layers → head → next token per lane).  Marks lanes
    /// `Done` when they hit their token budget.
    pub fn decode_iter_done(&self, lanes: &mut [&mut ReqState]) -> Result<()> {
        debug_assert!(!lanes.is_empty());
        if let Some(exec) = &self.exec {
            let b = lanes.len();
            let toks: Vec<i32> = lanes
                .iter()
                .map(|s| s.last_token.expect("decode lane without token"))
                .collect();
            let bv = self.geo.batch_for(b).unwrap_or(b);
            let x_pad = exec.embed(&toks, bv)?;
            // drop pad rows
            let d = self.geo.d_model;
            let mut x = crate::runtime::HostTensor::new(
                x_pad.data[..b * d].to_vec(),
                &[b, d],
            );
            {
                let mut caches: Vec<&mut KvCache> = lanes
                    .iter_mut()
                    .map(|s| s.cache.as_mut().expect("real mode has cache"))
                    .collect();
                for layer in 0..self.geo.n_layers {
                    x = exec.layer_decode(layer, &x, &mut caches)?;
                }
            }
            let next = exec.head(&x)?;
            for (i, st) in lanes.iter_mut().enumerate() {
                st.pos += 1;
                if let Some(c) = st.cache.as_mut() {
                    c.pos = st.pos;
                }
                st.x = Some(x.row(i));
                st.tokens.push(next[i]);
                st.last_token = Some(next[i]);
            }
        } else {
            for st in lanes.iter_mut() {
                st.pos += 1;
                let tok = synth_token(st.pos, self.geo.vocab);
                st.tokens.push(tok);
                st.last_token = Some(tok);
            }
        }
        for st in lanes.iter_mut() {
            st.metrics.output_tokens = st.tokens.len();
            if st.decode_iterations_left() == 0 {
                st.phase = Phase::Done;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Priority;

    fn synth_bridge() -> ExecBridge {
        let mut geo = crate::config::llama32_3b();
        geo.n_layers = 2;
        geo.chunk_sizes = vec![16, 32];
        ExecBridge::synthetic(geo)
    }

    fn req(plen: usize, maxnew: usize) -> Request {
        Request {
            id: 1,
            priority: Priority::Reactive,
            arrival_us: 0.0,
            prompt: vec![7; plen],
            max_new_tokens: maxnew,
            profile: "test".into(),
            flow: None,
        }
    }

    #[test]
    fn prefill_walks_chunks_and_layers() {
        let b = synth_bridge();
        let mut st = b.init_state(req(40, 3), 32);
        // plan: 32 + margin 8 → 2 chunks × 2 layers = 4 kernels
        assert_eq!(st.plan.len(), 2);
        assert!(!b.prefill_kernel_done(&mut st).unwrap());
        assert_eq!(st.plan.cursor(), (0, 1));
        assert!(!b.prefill_kernel_done(&mut st).unwrap());
        assert_eq!(st.plan.cursor(), (1, 0));
        assert_eq!(st.pos, 32);
        assert!(!b.prefill_kernel_done(&mut st).unwrap());
        assert!(b.prefill_kernel_done(&mut st).unwrap());
        assert_eq!(st.phase, Phase::Decoding);
        assert_eq!(st.tokens.len(), 1, "first token at prefill completion");
        assert_eq!(st.pos, 40);
    }

    #[test]
    fn session_seed_prefills_only_the_delta() {
        let b = synth_bridge();
        // 40-token conversation, 24 already cached from the last turn
        let seed = crate::runtime::SessionSeed { cache: None, reuse: 24 };
        let mut st = b.init_state_with_session(req(40, 3), 32, Some(seed));
        assert_eq!(st.cached_prefix_len, 24);
        assert_eq!(st.pos, 24);
        assert_eq!(st.plan.pending_tokens(), 16, "only 40 - 24 tokens planned");
        assert_eq!(st.plan.chunks()[0].pos, 24);
        // run the (shorter) prefill to completion
        let kernels = st.remaining_prefill_kernels(b.geo.n_layers);
        for k in 0..kernels {
            let done = b.prefill_kernel_done(&mut st).unwrap();
            assert_eq!(done, k + 1 == kernels);
        }
        assert_eq!(st.pos, 40);
        assert_eq!(st.metrics.prefill_tokens, 16);
        assert_eq!(st.tokens.len(), 1);
    }

    #[test]
    fn session_reuse_never_swallows_the_whole_prompt() {
        let b = synth_bridge();
        // a reuse claim covering the full prompt still leaves the last
        // token to prefill (it must produce the first-token logits)
        let seed = crate::runtime::SessionSeed { cache: None, reuse: 999 };
        let st = b.init_state_with_session(req(16, 2), 32, Some(seed));
        assert_eq!(st.cached_prefix_len, 15);
        assert_eq!(st.plan.pending_tokens(), 1);
    }

    #[test]
    fn full_prefill_counts_every_prompt_token() {
        let b = synth_bridge();
        let mut st = b.init_state(req(40, 3), 32);
        while st.phase == Phase::Prefilling {
            b.prefill_kernel_done(&mut st).unwrap();
        }
        assert_eq!(st.metrics.prefill_tokens, 40);
        assert_eq!(st.metrics.cached_prefix_len, 0);
    }

    #[test]
    fn split_plan_prefills_to_completion() {
        let b = synth_bridge();
        let mut st = b.init_state(req(40, 3), 32);
        let (npu, igpu) = st.plan.split(&b.geo, 0, 0.5).expect("head splittable");
        assert_eq!(igpu.valid + npu.valid, 32);
        assert_eq!(st.plan.len(), 3);
        while st.phase == Phase::Prefilling {
            b.prefill_kernel_done(&mut st).unwrap();
        }
        assert_eq!(st.metrics.prefill_tokens, 40, "every token prefilled once");
        assert_eq!(st.pos, 40);
        assert_eq!(st.tokens.len(), 1);
    }

    #[test]
    fn decode_iterations_finish_request() {
        let b = synth_bridge();
        let mut st = b.init_state(req(16, 3), 32);
        for _ in 0..b.geo.n_layers {
            b.prefill_kernel_done(&mut st).unwrap();
        }
        assert_eq!(st.phase, Phase::Decoding);
        b.decode_iter_done(&mut [&mut st]).unwrap();
        assert_eq!(st.tokens.len(), 2);
        assert_eq!(st.phase, Phase::Decoding);
        b.decode_iter_done(&mut [&mut st]).unwrap();
        assert_eq!(st.tokens.len(), 3);
        assert_eq!(st.phase, Phase::Done);
        assert_eq!(st.metrics.output_tokens, 3);
    }

    #[test]
    fn single_token_request_done_at_prefill() {
        let b = synth_bridge();
        let mut st = b.init_state(req(8, 1), 32);
        for _ in 0..b.geo.n_layers {
            b.prefill_kernel_done(&mut st).unwrap();
        }
        assert_eq!(st.phase, Phase::Done);
        assert_eq!(st.tokens.len(), 1);
    }

    #[test]
    fn batched_decode_advances_all_lanes() {
        let b = synth_bridge();
        let mut s1 = b.init_state(req(16, 5), 32);
        let mut s2 = b.init_state(req(16, 5), 32);
        for st in [&mut s1, &mut s2] {
            for _ in 0..b.geo.n_layers {
                b.prefill_kernel_done(st).unwrap();
            }
        }
        b.decode_iter_done(&mut [&mut s1, &mut s2]).unwrap();
        assert_eq!(s1.tokens.len(), 2);
        assert_eq!(s2.tokens.len(), 2);
        assert_eq!(s1.pos, 17);
    }

    #[test]
    fn synthetic_tokens_in_vocab() {
        for pos in 0..1000 {
            let t = synth_token(pos, 2048);
            assert!((0..2048).contains(&t));
        }
    }
}
