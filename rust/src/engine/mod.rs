//! Engine plumbing shared by Agent.xpu and every baseline:
//!
//! - [`ReqState`] — the paper's `ReqContext` (§6.2): KV cache pointers,
//!   layer/chunk progress, remaining kernels, activation buffer.  Because
//!   it lives in unified host memory, a preemption checkpoint is free.
//! - [`ExecBridge`] — runs kernel *numerics* (real PJRT or synthetic)
//!   when the DES says a kernel finished.
//! - [`Driver`] — the DES event loop: arrivals, kernel completions,
//!   metrics collection.
//! - [`Engine`] — the trait the figure harnesses run.

mod bridge;
mod driver;
mod reqstate;

pub use bridge::ExecBridge;
pub use driver::{Driver, Engine, KernelTag};
pub use reqstate::{Phase, ReqState};
