//! Engine plumbing shared by Agent.xpu and every baseline:
//!
//! - [`ReqState`] — the paper's `ReqContext` (§6.2): KV cache pointers,
//!   layer/chunk progress, remaining kernels, activation buffer.  Because
//!   it lives in unified host memory, a preemption checkpoint is free.
//! - [`ExecBridge`] — runs kernel *numerics* (real PJRT or synthetic)
//!   when the DES says a kernel finished.
//! - [`Driver`] — the clock-abstracted event loop: submission, arrivals,
//!   kernel-completion effects, cancellation, the [`EngineEvent`] stream.
//! - [`EngineCore`] — the streaming `submit`/`step`/`cancel`/`drain`
//!   trait every engine implements; the batch `run(trace)` entry point
//!   the figure harnesses use is a provided method over it.  `Engine`
//!   is the same trait under its historical name.
//! - [`SchedPolicy`] / [`PolicyEngine`] — the pluggable-policy split
//!   (DESIGN.md §7): one generic engine owns the whole lifecycle, and
//!   each comparison point is just a policy's per-step decision.  The
//!   [`registry`] maps policy names to built engines.

mod bridge;
mod core_api;
mod driver;
mod policy;
pub mod registry;
mod reqstate;

pub use bridge::ExecBridge;
pub use core_api::EngineCore as Engine;
pub use core_api::{
    EngineClock, EngineCore, EngineEvent, EngineLoad, OverloadSignal, ShedLevel,
    default_shed_level,
};
pub use driver::{Driver, KernelTag};
pub use policy::{
    Action, IgpuGateCtx, PolicyCtx, PolicyEngine, RebindCtx, RebindDecision, ResumeCtx,
    SchedPolicy, States,
};
pub use reqstate::{Phase, ReqState};
