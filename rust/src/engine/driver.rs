//! The shared DES event loop: every engine (Agent.xpu and the
//! baselines) is a scheduling policy plugged into this driver.
//!
//! Responsibilities: arrival admission, kernel-completion effects (via
//! [`ExecBridge`]), lifecycle metrics (TTFT at prefill completion,
//! completion time at token budget), and the final [`RunReport`].

use std::collections::{HashMap, VecDeque};

use anyhow::{Context, Result, bail};

use crate::config::SocConfig;
use crate::metrics::RunReport;
use crate::soc::{Completion, KernelTiming, LaunchSpec, RunId, SocSim};
use crate::workload::{ReqId, Request};

use super::bridge::ExecBridge;
use super::reqstate::{Phase, ReqState};
use crate::trace::Trace;

/// Semantic meaning of an in-flight kernel.
#[derive(Debug, Clone, PartialEq)]
pub enum KernelTag {
    /// The next prefill kernel (st.chunk_idx, st.layer_idx) of `req`.
    Prefill { req: ReqId },
    /// One batched decode iteration over `lanes`.
    DecodeIter { lanes: Vec<ReqId> },
}

impl KernelTag {
    pub fn reqs(&self) -> Vec<ReqId> {
        match self {
            KernelTag::Prefill { req } => vec![*req],
            KernelTag::DecodeIter { lanes } => lanes.clone(),
        }
    }
}

/// An engine = a scheduling policy over the shared driver.
pub trait Engine {
    fn name(&self) -> String;
    fn run(&mut self, trace: Vec<Request>) -> Result<RunReport>;
}

/// Shared DES driver state.
pub struct Driver {
    pub sim: SocSim,
    pub bridge: ExecBridge,
    pub states: HashMap<ReqId, ReqState>,
    pending: VecDeque<Request>,
    inflight: HashMap<RunId, KernelTag>,
    pub preemptions: u64,
    pub backfills: u64,
    /// Kernel-level execution trace (always recorded; events are tiny).
    pub trace: Trace,
    total_requests: usize,
    finished: usize,
}

impl Driver {
    pub fn new(soc: &SocConfig, bridge: ExecBridge, mut trace: Vec<Request>) -> Self {
        trace.sort_by(|a, b| a.arrival_us.total_cmp(&b.arrival_us));
        Self {
            sim: SocSim::new(soc),
            bridge,
            states: HashMap::new(),
            total_requests: trace.len(),
            pending: trace.into(),
            inflight: HashMap::new(),
            preemptions: 0,
            backfills: 0,
            trace: Trace::default(),
            finished: 0,
        }
    }

    pub fn now(&self) -> f64 {
        self.sim.now_us
    }

    pub fn next_arrival_us(&self) -> Option<f64> {
        self.pending.front().map(|r| r.arrival_us)
    }

    /// Admit every request whose arrival time has passed; returns ids.
    pub fn admit_ready(&mut self, max_chunk: usize) -> Vec<ReqId> {
        let mut out = vec![];
        while self
            .pending
            .front()
            .map(|r| r.arrival_us <= self.now() + 1e-9)
            .unwrap_or(false)
        {
            let req = self.pending.pop_front().unwrap();
            let id = req.id;
            let mut st = self.bridge.init_state(req, max_chunk);
            st.enqueued_at_us = self.now();
            self.states.insert(id, st);
            out.push(id);
        }
        out
    }

    /// Launch a kernel; marks all tagged requests as running.
    pub fn launch(&mut self, xpu: usize, timing: KernelTiming, reactive: bool, tag: KernelTag) {
        for id in tag.reqs() {
            let st = self.states.get_mut(&id).expect("launch for unknown req");
            assert!(!st.running, "request {id} already has a kernel in flight");
            st.running = true;
            st.preempt_counted = false;
        }
        let run = self.sim.launch(xpu, LaunchSpec { timing, reactive });
        self.inflight.insert(run, tag);
    }

    /// Abort the kernel on `xpu` (scheme-(a) instant preemption).  The
    /// tagged requests stop running; the caller decides what progress
    /// they lose.  Returns the aborted tag.
    pub fn cancel(&mut self, xpu: usize) -> Option<KernelTag> {
        let run = self.sim.cancel(xpu)?;
        let tag = self.inflight.remove(&run).expect("cancelled unknown run");
        for id in tag.reqs() {
            if let Some(st) = self.states.get_mut(&id) {
                st.running = false;
            }
        }
        Some(tag)
    }

    /// Advance virtual time to the next completion or arrival, applying
    /// kernel effects.  Returns false when the run is over (no work, no
    /// arrivals).
    pub fn step(&mut self) -> Result<bool> {
        let next_fin = self.sim.next_event_in().map(|dt| self.now() + dt);
        let next_arr = self.next_arrival_us();
        let target = match (next_fin, next_arr) {
            (Some(f), Some(a)) => f.min(a),
            (Some(f), None) => f,
            (None, Some(a)) => a,
            (None, None) => return Ok(false),
        };
        let completions = self.sim.advance_until(target);
        for c in completions {
            self.apply_completion(&c)?;
        }
        Ok(true)
    }

    fn apply_completion(&mut self, c: &Completion) -> Result<()> {
        let tag = self
            .inflight
            .remove(&c.id)
            .context("completion for unknown run")?;
        let (label, reactive) = match &tag {
            KernelTag::Prefill { req } => (
                format!("prefill:{req}"),
                self.states.get(req).map(|s| s.is_reactive()).unwrap_or(false),
            ),
            KernelTag::DecodeIter { lanes } => (
                format!("decode:b{}", lanes.len()),
                lanes
                    .iter()
                    .any(|id| self.states.get(id).map(|s| s.is_reactive()).unwrap_or(false)),
            ),
        };
        self.trace.record(c.xpu, c.started_us, c.finished_us, label, reactive);
        match &tag {
            KernelTag::Prefill { req } => {
                let mut st = self.states.remove(req).context("unknown req")?;
                st.running = false;
                let done = self.bridge.prefill_kernel_done(&mut st)?;
                if done {
                    st.metrics.first_token_us = Some(c.finished_us);
                    st.enqueued_at_us = c.finished_us;
                }
                if st.phase == Phase::Done {
                    st.metrics.done_us = Some(c.finished_us);
                    self.finished += 1;
                }
                self.states.insert(*req, st);
            }
            KernelTag::DecodeIter { lanes } => {
                let mut taken: Vec<ReqState> = lanes
                    .iter()
                    .map(|id| self.states.remove(id).context("unknown lane"))
                    .collect::<Result<_>>()?;
                {
                    let mut refs: Vec<&mut ReqState> = taken.iter_mut().collect();
                    self.bridge.decode_iter_done(&mut refs)?;
                }
                for mut st in taken {
                    st.running = false;
                    if st.phase == Phase::Done {
                        st.metrics.done_us = Some(c.finished_us);
                        self.finished += 1;
                    }
                    self.states.insert(st.id(), st);
                }
            }
        }
        Ok(())
    }

    pub fn all_done(&self) -> bool {
        self.pending.is_empty() && self.finished == self.total_requests
    }

    pub fn unfinished(&self) -> usize {
        self.total_requests - self.finished
    }

    /// Requests in a given phase that do not have a kernel in flight.
    pub fn idle_in_phase(&self, phase: Phase) -> Vec<ReqId> {
        let mut v: Vec<ReqId> = self
            .states
            .values()
            .filter(|s| s.phase == phase && !s.running)
            .map(|s| s.id())
            .collect();
        v.sort_unstable();
        v
    }

    pub fn finish(self, engine: String) -> Result<RunReport> {
        if !self.all_done() {
            bail!(
                "{engine}: run ended with {} unfinished requests",
                self.unfinished()
            );
        }
        let makespan_us = self.sim.now_us;
        Ok(RunReport {
            engine,
            reqs: {
                let mut v: Vec<_> =
                    self.states.into_values().map(|s| s.metrics).collect();
                v.sort_by_key(|m| m.id);
                v
            },
            xpus: self.sim.snapshot(),
            makespan_us,
            total_energy_j: self.sim.total_energy_j(),
            peak_power_w: self.sim.peak_power_w,
            mean_bw_gbps: self.sim.mean_bandwidth_gbps(),
            preemptions: self.preemptions,
            backfills: self.backfills,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;
    use crate::heg::Annotator;
    use crate::soc::XpuModel;
    use crate::workload::Priority;

    fn mk_driver(traces: Vec<Request>) -> (Driver, Annotator) {
        let mut geo = crate::config::llama32_3b();
        geo.n_layers = 2;
        let soc = default_soc();
        let ann = Annotator::new(
            geo.clone(),
            soc.xpus.iter().cloned().map(XpuModel::new).collect(),
        );
        (Driver::new(&soc, ExecBridge::synthetic(geo), traces), ann)
    }

    fn req(id: u64, arrival: f64, plen: usize, maxnew: usize) -> Request {
        Request {
            id,
            priority: Priority::Proactive,
            arrival_us: arrival,
            prompt: vec![3; plen],
            max_new_tokens: maxnew,
            profile: "test",
        }
    }

    /// A trivial FCFS policy good enough to exercise the driver.
    fn run_fcfs(trace: Vec<Request>) -> RunReport {
        let (mut d, ann) = mk_driver(trace);
        let npu = d.sim.xpu_index("npu").unwrap();
        let igpu = d.sim.xpu_index("igpu").unwrap();
        loop {
            d.admit_ready(512);
            // NPU: first prefilling request (by id)
            if !d.sim.busy(npu) {
                if let Some(&id) = d.idle_in_phase(Phase::Prefilling).first() {
                    let chunk = *d.states[&id].current_chunk().unwrap();
                    let a = ann.prefill_kernel(&chunk);
                    let t = *a.timing_on(npu);
                    d.launch(npu, t, false, KernelTag::Prefill { req: id });
                }
            }
            // iGPU: batch every idle decoder
            if !d.sim.busy(igpu) {
                let lanes = d.idle_in_phase(Phase::Decoding);
                if !lanes.is_empty() {
                    let avg = d.states[&lanes[0]].pos;
                    let a = ann.decode_iter(lanes.len(), avg);
                    let t = *a.timing_on(igpu);
                    d.launch(igpu, t, false, KernelTag::DecodeIter { lanes });
                }
            }
            if !d.step().unwrap() {
                break;
            }
        }
        d.finish("fcfs-test".into()).unwrap()
    }

    #[test]
    fn driver_completes_single_request() {
        let rep = run_fcfs(vec![req(1, 0.0, 100, 5)]);
        assert_eq!(rep.reqs.len(), 1);
        let m = &rep.reqs[0];
        assert!(m.finished());
        assert_eq!(m.output_tokens, 5);
        assert!(m.ttft_us().unwrap() > 0.0);
        assert!(m.done_us.unwrap() > m.first_token_us.unwrap());
    }

    #[test]
    fn driver_completes_overlapping_requests() {
        let rep = run_fcfs(vec![
            req(1, 0.0, 300, 8),
            req(2, 1000.0, 200, 4),
            req(3, 2000.0, 64, 2),
        ]);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        // arrivals respected: nothing starts before it arrives
        for m in &rep.reqs {
            assert!(m.first_token_us.unwrap() > m.arrival_us);
        }
        assert!(rep.makespan_us > 0.0);
        assert!(rep.total_energy_j > 0.0);
    }

    #[test]
    fn late_arrivals_wake_the_driver() {
        // second request arrives long after the first finishes — the
        // driver must jump the clock to it
        let rep = run_fcfs(vec![req(1, 0.0, 64, 2), req(2, 5e6, 64, 2)]);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 2);
        let m2 = rep.reqs.iter().find(|m| m.id == 2).unwrap();
        assert!(m2.first_token_us.unwrap() >= 5e6);
    }

    #[test]
    fn finish_fails_with_unfinished_requests() {
        let (d, _) = mk_driver(vec![req(1, 0.0, 64, 2)]);
        // never scheduled anything
        assert!(d.finish("broken".into()).is_err());
    }
}
