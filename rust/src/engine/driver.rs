//! The shared DES event loop: every engine (Agent.xpu and the
//! baselines) is a scheduling policy plugged into this driver.
//!
//! Responsibilities: incremental request submission, arrival admission,
//! kernel-completion effects (via [`ExecBridge`]), cancellation,
//! lifecycle metrics (TTFT at prefill completion, completion time at
//! token budget), the [`EngineEvent`] stream, and the final
//! [`RunReport`].
//!
//! Clock abstraction (DESIGN.md §7): the driver runs against an
//! [`EngineClock`].  Under `Virtual` it is the classic DES — arrivals
//! honored at their trace times, timestamps in virtual µs.  Under
//! `Wall` submissions are stamped on arrival and admitted immediately,
//! kernel *ordering* still comes from the virtual SoC (so the serving
//! path makes exactly the coordinator's decisions), and lifecycle
//! timestamps are measured wall µs.
//!
//! Workflow DAGs (DESIGN.md §3): the driver owns the workload semantics
//! of agentic flows — a node with DAG predecessors is *held* until all
//! of them complete, released one think-time later with the actual
//! generated context stitched over the generator's placeholder prefix
//! (a join merges its first predecessor's conversation with the other
//! branches' contributions, in dependency order).  CPU **tool-call
//! nodes** never allocate serving state: the driver runs each as one
//! kernel on the SoC's CPU roofline, contending for DDR like any
//! accelerator kernel, and passes the conversation through to its
//! dependents.  Every engine gets all of this for free (so baselines
//! see identical workflow traffic); engines that additionally call
//! [`Driver::enable_session_reuse`] get cross-turn KV retention — a
//! continuation turn then prefills only its delta tokens instead of
//! recomputing the whole conversation prefix.  Under a wall clock a
//! node submitted after its predecessors completed is admitted directly
//! (the online-session path the server uses).

use std::collections::{BTreeMap, BTreeSet, HashMap, VecDeque};

use anyhow::{Context, Result, bail};

use crate::util::{FxHashMap, FxHashSet};

use crate::config::SocConfig;
use crate::metrics::{ReqMetrics, RunReport};
use crate::model::KernelCost;
use crate::runtime::{KvCache, SessionCachePool};
use crate::soc::{
    Completion, GraphicsSim, KernelClass, KernelTiming, LaunchSpec, RunId, SocSim,
};
use crate::workload::{FlowBinding, FlowId, NodeKind, ReqId, Request};

use super::bridge::ExecBridge;
use super::core_api::{EngineClock, EngineEvent};
use super::reqstate::{Phase, ReqState};
use crate::trace::Trace;

/// Semantic meaning of an in-flight kernel.
#[derive(Debug, Clone, PartialEq)]
pub enum KernelTag {
    /// The next prefill kernel (the plan's chunk/layer cursor) of `req`.
    Prefill { req: ReqId },
    /// One batched decode iteration over `lanes`.
    DecodeIter { lanes: Vec<ReqId> },
}

impl KernelTag {
    pub fn reqs(&self) -> Vec<ReqId> {
        match self {
            KernelTag::Prefill { req } => vec![*req],
            KernelTag::DecodeIter { lanes } => lanes.clone(),
        }
    }
}

/// Wall-clock runs bound their history so a long-lived server never
/// grows without limit: `retired` keeps the most recent window of
/// request metrics (older ones have already been streamed as events —
/// the shed count surfaces as [`RunReport::dropped_reqs`], and the
/// incremental `metrics::ReportAccumulator` stays exact), and the
/// per-flow DAG progress keeps only the most recent flows (ids are
/// monotonic on the serving path, so the smallest keys are oldest).
const WALL_RETIRED_MAX: usize = 8_192;
const FLOW_DONE_MAX: usize = 65_536;
/// Completed/cancelled node indices remembered *per flow*: a long-lived
/// serving session completes unboundedly many calls, so the per-flow
/// progress sets keep only the most recent indices (far beyond any
/// distance an online `deps` reference can reach — the server remembers
/// at most 64 generation ids per tag).
const NODE_DONE_MAX: usize = 4_096;

/// Conversation state a completed workflow node leaves for stitching.
struct NodeOutput {
    /// The conversation after this node (first-predecessor lineage):
    /// stitched prompt + generated reply for LLM turns, the inherited
    /// context for tool calls.
    context: Vec<i32>,
    /// This node's branch contribution (delta + reply) — what a join
    /// appends for each predecessor beyond its first.
    contrib: Vec<i32>,
}

/// Per-flow DAG progress.  The index sets are ordered so the oldest
/// entries can be shed once `NODE_DONE_MAX` is exceeded, keeping a
/// long-lived serving session's footprint bounded (the pre-DAG
/// watermark was one integer; this is its DAG generalization).
#[derive(Default)]
struct FlowProgress {
    /// Completed (or cancelled) node indices — releases gate on this.
    done: BTreeSet<usize>,
    /// Cancelled node indices: held placeholder dependents can never be
    /// stitched and die transitively.
    dead: BTreeSet<usize>,
    /// Completed nodes' conversation state, retained while held nodes
    /// may still stitch against it (cleared once nothing is held).
    outputs: HashMap<usize, NodeOutput>,
}

impl FlowProgress {
    /// Record a completed node, shedding the oldest indices beyond the
    /// bound (an old shed index can only matter to a dep reaching
    /// further back than anything the server hands out).
    fn mark_done(&mut self, turn: usize) {
        self.done.insert(turn);
        while self.done.len() > NODE_DONE_MAX {
            let _ = self.done.pop_first();
        }
    }

    fn mark_dead(&mut self, turn: usize) {
        self.mark_done(turn);
        self.dead.insert(turn);
        while self.dead.len() > NODE_DONE_MAX {
            let _ = self.dead.pop_first();
        }
    }
}

/// Incremental phase index over the live serving states: every set a
/// scheduler polls per decision pass — waiting prefills, unbatched
/// decoders, dynamic-chunk candidates, live reactive work — split by
/// priority class and kept in sync at every lifecycle transition
/// instead of re-derived by an O(all-requests) scan.  `BTreeSet`s so
/// iteration is id-ordered (deterministic schedules).  Debug builds
/// property-check each consumer against a fresh scan of `states`
/// (see `coordinator::engine_impl`).
#[derive(Default)]
pub(crate) struct PhaseIndex {
    /// Waiting prefills (phase == Prefilling, not running), per class.
    pub wait_prefill_rt: BTreeSet<ReqId>,
    pub wait_prefill_pro: BTreeSet<ReqId>,
    /// Unbatched decoders (phase == Decoding, not running), per class.
    pub idle_decode_rt: BTreeSet<ReqId>,
    pub idle_decode_pro: BTreeSet<ReqId>,
    /// Waiting prefills whose *current* chunk is dynamic-shaped
    /// (margin-backfill candidates), per class.
    pub dyn_chunk_rt: BTreeSet<ReqId>,
    pub dyn_chunk_pro: BTreeSet<ReqId>,
    /// Waiting *proactive* prefills whose current chunk could still be
    /// split across XPUs (static-shaped, ≥ 2 valid tokens, cursor at a
    /// chunk boundary) — the rebind hook's split candidates.
    pub split_pro: BTreeSet<ReqId>,
    /// Reactive requests that are not Done (replaces the
    /// `.values().any(is_reactive)` liveness scan).
    pub live_rt: BTreeSet<ReqId>,
}

impl PhaseIndex {
    fn put(set: &mut BTreeSet<ReqId>, id: ReqId, member: bool) {
        if member {
            set.insert(id);
        } else {
            set.remove(&id);
        }
    }

    /// Re-derive `id`'s membership in every set from its current state
    /// (idempotent; absent state = out of all sets).
    fn update(&mut self, id: ReqId, s: Option<&ReqState>) {
        let (rt, wait_pre, idle_dec, dynamic, splittable, live_rt) = match s {
            Some(s) => {
                let rt = s.is_reactive();
                let wait_pre = s.phase == Phase::Prefilling && !s.running;
                let idle_dec = s.phase == Phase::Decoding && !s.running;
                let dynamic =
                    wait_pre && s.current_chunk().map(|c| c.dynamic).unwrap_or(false);
                let splittable = wait_pre
                    && s.layer_idx() == 0
                    && s.current_chunk()
                        .map(|c| !c.dynamic && c.valid >= 2)
                        .unwrap_or(false);
                (rt, wait_pre, idle_dec, dynamic, splittable, rt && s.phase != Phase::Done)
            }
            None => (false, false, false, false, false, false),
        };
        Self::put(&mut self.wait_prefill_rt, id, wait_pre && rt);
        Self::put(&mut self.wait_prefill_pro, id, wait_pre && !rt);
        Self::put(&mut self.idle_decode_rt, id, idle_dec && rt);
        Self::put(&mut self.idle_decode_pro, id, idle_dec && !rt);
        Self::put(&mut self.dyn_chunk_rt, id, dynamic && rt);
        Self::put(&mut self.dyn_chunk_pro, id, dynamic && !rt);
        Self::put(&mut self.split_pro, id, splittable && !rt);
        Self::put(&mut self.live_rt, id, live_rt);
    }
}

/// Shared DES driver state.
pub struct Driver {
    pub sim: SocSim,
    pub bridge: ExecBridge,
    clock: EngineClock,
    pub states: FxHashMap<ReqId, ReqState>,
    pending: VecDeque<Request>,
    /// Workflow nodes waiting on DAG predecessors, per flow (sorted by
    /// (turn_idx, id) for determinism).
    held: FxHashMap<FlowId, Vec<Request>>,
    /// Per-flow DAG progress — the completed-node set doubles as the
    /// watermark that lets a wall-clock continuation submitted *after*
    /// its predecessors finished skip the hold queue.  Ordered so the
    /// oldest flows can be shed once `FLOW_DONE_MAX` is exceeded.
    flows: BTreeMap<FlowId, FlowProgress>,
    /// Cross-turn KV retention — `None` (full recompute every turn)
    /// unless the engine opted in via [`Driver::enable_session_reuse`].
    pub sessions: Option<SessionCachePool>,
    inflight: FxHashMap<RunId, KernelTag>,
    /// Ready CPU tool-call nodes waiting for the CPU to free.
    tool_wait: VecDeque<Request>,
    /// Tool kernels in flight on the CPU.
    tool_inflight: FxHashMap<RunId, Request>,
    /// The SoC's CPU index (tool nodes run here; `None` = the SoC
    /// models no CPU and tools complete instantly).
    cpu: Option<usize>,
    /// The SoC's iGPU index (graphics frames render here).
    igpu: Option<usize>,
    /// Synthetic display workload (DES runs only) — frames launch with
    /// compositor priority whenever the iGPU is free, before the
    /// scheduling policy's decision pass.
    graphics: Option<GraphicsSim>,
    /// One-shot DES wake-up a time-gated policy decision requested
    /// (duty-governor veto retry): the clock stops here even with no
    /// kernel or arrival event pending, so a vetoed-and-otherwise-idle
    /// run still advances to the veto's expiry instead of ending with
    /// unfinished work.
    wake_at_us: Option<f64>,
    /// The phase index — see [`PhaseIndex`].
    idx: PhaseIndex,
    /// Reusable id buffers loaned to decision passes via
    /// [`Driver::take_id_buf`] so the per-step candidate/lane vectors
    /// stop allocating once the pool is warm.
    scratch_ids: Vec<Vec<ReqId>>,
    /// Streaming events since the last [`Driver::take_events`].
    events: Vec<EngineEvent>,
    /// Metrics of retired requests (cancelled, or completed under a
    /// wall clock) whose live state has been dropped.
    retired: Vec<ReqMetrics>,
    retired_cap: usize,
    /// Bound on the per-flow DAG-progress table (see
    /// [`Driver::shed_flow_state`]).
    flow_cap: usize,
    /// Retired metrics shed from the bounded wall-clock history — the
    /// final RunReport flags this truncation instead of silently
    /// reporting fewer requests than were served.
    pub dropped_reqs: u64,
    pub preemptions: u64,
    pub backfills: u64,
    /// In-flight prefills evicted by the memory governor (KV wiped).
    pub kv_evictions: u64,
    /// Idle retained sessions dropped by the memory governor.
    pub session_evictions: u64,
    /// Requests aborted via [`Driver::cancel_request`].
    pub cancellations: u64,
    /// Elastic rebinds (folds + splits) applied to waiting plans.
    pub rebinds: u64,
    /// Mid-flight chunk splits (a subset of `rebinds`).
    pub splits: u64,
    /// Tokens routed to the co-run iGPU side by those splits.
    pub split_tokens: u64,
    /// Kernel-level execution trace (always recorded; events are tiny).
    pub trace: Trace,
    total_requests: usize,
    finished: usize,
}

impl Driver {
    /// Open an empty driver against a clock; feed it with
    /// [`Driver::submit`].
    pub fn open(soc: &SocConfig, bridge: ExecBridge, clock: EngineClock) -> Self {
        let sim = SocSim::new(soc);
        let cpu = sim.xpu_index("cpu");
        let igpu = sim.xpu_index("igpu");
        Self {
            sim,
            bridge,
            clock,
            states: FxHashMap::default(),
            total_requests: 0,
            pending: VecDeque::new(),
            held: FxHashMap::default(),
            flows: BTreeMap::new(),
            sessions: None,
            inflight: FxHashMap::default(),
            tool_wait: VecDeque::new(),
            tool_inflight: FxHashMap::default(),
            cpu,
            igpu,
            graphics: None,
            wake_at_us: None,
            idx: PhaseIndex::default(),
            scratch_ids: vec![],
            events: vec![],
            retired: vec![],
            retired_cap: WALL_RETIRED_MAX,
            flow_cap: FLOW_DONE_MAX,
            dropped_reqs: 0,
            preemptions: 0,
            backfills: 0,
            kv_evictions: 0,
            session_evictions: 0,
            cancellations: 0,
            rebinds: 0,
            splits: 0,
            split_tokens: 0,
            trace: Trace::default(),
            finished: 0,
        }
    }

    /// Classic batch construction: a virtual-clock driver preloaded
    /// with a whole trace.
    pub fn new(soc: &SocConfig, bridge: ExecBridge, trace: Vec<Request>) -> Self {
        let mut d = Self::open(soc, bridge, EngineClock::Virtual);
        for r in trace {
            d.submit(r);
        }
        d
    }

    /// Feed one request.  A workflow node whose DAG predecessors have
    /// not all completed is held; everything else queues by arrival
    /// time.  Under a wall clock the arrival is re-stamped to *now*.
    pub fn submit(&mut self, mut req: Request) {
        if self.clock.is_wall() {
            req.arrival_us = self.now();
        }
        self.total_requests += 1;
        let held = match &req.flow {
            Some(fb) => {
                let prog = self.flows.get(&fb.flow_id);
                fb.dep_indices()
                    .iter()
                    .any(|d| !prog.map(|p| p.done.contains(d)).unwrap_or(false))
            }
            None => false,
        };
        if held {
            // lint:allow(panic-free-hot-path) held is only true when req.flow is Some
            let fid = req.flow_id().expect("held node has a flow");
            let key = (req.turn_idx(), req.id);
            let chain = self.held.entry(fid).or_default();
            let at = chain.partition_point(|r| (r.turn_idx(), r.id) <= key);
            chain.insert(at, req);
        } else {
            self.insert_pending(req);
        }
    }

    /// Opt in to cross-turn KV retention: finished flow turns park
    /// their cache (real or logical) in a [`SessionCachePool`] keyed by
    /// flow id, and continuation turns admit with a delta-only plan.
    pub fn enable_session_reuse(&mut self, capacity: usize) {
        self.sessions = Some(SessionCachePool::new(capacity));
    }

    /// Attach a synthetic display workload (DES runs only; ignored
    /// without an iGPU in the SoC).  Frames launch with compositor
    /// priority before every policy pass; their jank accounting lands
    /// in `RunReport::{frames_scheduled, frames_missed}`.
    pub fn set_graphics(&mut self, g: GraphicsSim) {
        if self.igpu.is_some() {
            self.graphics = Some(g);
        }
    }

    /// Would a kernel of `nominal_us` launched now run past the next
    /// graphics frame's due instant?  False without a display workload.
    /// (Frame timing lives on the virtual SoC clock.)
    pub fn would_delay_next_frame(&self, nominal_us: f64) -> bool {
        self.graphics
            .as_ref()
            .map(|g| g.would_delay_next_frame(self.sim.now_us, nominal_us))
            .unwrap_or(false)
    }

    /// Launch the due graphics frame if the iGPU is free (compositor
    /// priority: called before the policy's decision pass and at every
    /// step).  A finished run launches nothing: the frame would never
    /// render (the run ends at the last agentic completion), and a
    /// phantom launch would pad `frames_scheduled` and the kernel
    /// counts.
    fn launch_graphics(&mut self) {
        if self.all_done() {
            return;
        }
        if let (Some(g), Some(igpu)) = (&mut self.graphics, self.igpu) {
            g.try_launch(&mut self.sim, igpu);
        }
    }

    /// Ask the next [`Driver::step`] to advance the clock to `at_us`
    /// (earliest wins) even if no kernel completion or arrival falls
    /// before it — how a time-gated veto (the iGPU duty governor)
    /// schedules its own retry.  One-shot: consumed by the step that
    /// reaches it; a persisting veto re-requests on its next pass.
    pub fn request_wakeup(&mut self, at_us: f64) {
        let at = at_us.max(self.sim.now_us);
        self.wake_at_us = Some(self.wake_at_us.map_or(at, |w| w.min(at)));
    }

    /// Retained idle sessions (for the memory governor's accounting).
    pub fn retained_sessions(&self) -> usize {
        self.sessions.as_ref().map(|p| p.len()).unwrap_or(0)
    }

    /// Current time in the run's clock domain (virtual or wall µs).
    pub fn now(&self) -> f64 {
        match &self.clock {
            EngineClock::Virtual => self.sim.now_us,
            EngineClock::Wall { t0 } => t0.elapsed().as_secs_f64() * 1e6,
        }
    }

    /// Map a virtual completion instant into the run's clock domain.
    fn stamp(&self, virtual_us: f64) -> f64 {
        match &self.clock {
            EngineClock::Virtual => virtual_us,
            EngineClock::Wall { t0 } => t0.elapsed().as_secs_f64() * 1e6,
        }
    }

    pub fn next_arrival_us(&self) -> Option<f64> {
        self.pending.front().map(|r| r.arrival_us)
    }

    /// Drain the events accumulated since the last call.
    pub fn take_events(&mut self) -> Vec<EngineEvent> {
        std::mem::take(&mut self.events)
    }

    /// Bound the retired-metrics window (wall-clock runs only; shed
    /// entries are counted in [`RunReport::dropped_reqs`]).
    pub fn limit_retained_history(&mut self, cap: usize) {
        self.retired_cap = cap.max(2);
    }

    /// Waiting proactive prefills (phase == Prefilling, not running),
    /// in id order — maintained incrementally, identical to a fresh
    /// scan of `states` (property-checked in debug builds by the
    /// coordinator's backfill path).
    pub fn waiting_proactive_prefills(&self) -> Vec<ReqId> {
        self.idx.wait_prefill_pro.iter().copied().collect()
    }

    /// Fill `out` with the waiting proactive prefills, in id order,
    /// without allocating (clears `out` first).
    pub fn waiting_proactive_prefills_into(&self, out: &mut Vec<ReqId>) {
        out.clear();
        out.extend(self.idx.wait_prefill_pro.iter().copied());
    }

    /// Fill `out` with the waiting *reactive* prefills, in id order.
    pub fn waiting_reactive_prefills_into(&self, out: &mut Vec<ReqId>) {
        out.clear();
        out.extend(self.idx.wait_prefill_rt.iter().copied());
    }

    /// Fill `out` with every waiting prefill of both classes, in id
    /// order.
    pub fn waiting_prefills_into(&self, out: &mut Vec<ReqId>) {
        out.clear();
        out.extend(self.idx.wait_prefill_rt.iter().copied());
        out.extend(self.idx.wait_prefill_pro.iter().copied());
        out.sort_unstable();
    }

    /// Fill `out` with the waiting prefills of `reactive` class whose
    /// current chunk is dynamic-shaped (margin-backfill candidates),
    /// in id order.
    pub fn dynamic_chunk_candidates_into(&self, reactive: bool, out: &mut Vec<ReqId>) {
        out.clear();
        let set = if reactive {
            &self.idx.dyn_chunk_rt
        } else {
            &self.idx.dyn_chunk_pro
        };
        out.extend(set.iter().copied());
    }

    /// Fill `out` with the waiting proactive prefills whose current
    /// chunk could still be split across XPUs (static-shaped, ≥ 2 valid
    /// tokens, cursor at a chunk boundary), in id order.
    pub fn split_candidates_into(&self, out: &mut Vec<ReqId>) {
        out.clear();
        out.extend(self.idx.split_pro.iter().copied());
    }

    /// Any reactive request not yet Done?  (Index-backed replacement
    /// for `states.values().any(is_reactive)`.)
    pub fn reactive_live(&self) -> bool {
        !self.idx.live_rt.is_empty()
    }

    /// Any reactive decoder waiting at a kernel boundary?
    pub fn has_idle_reactive_decoder(&self) -> bool {
        !self.idx.idle_decode_rt.is_empty()
    }

    /// Any decoder of either class waiting at a kernel boundary?
    pub fn has_idle_decoder(&self) -> bool {
        !self.idx.idle_decode_rt.is_empty() || !self.idx.idle_decode_pro.is_empty()
    }

    /// Borrow a cleared id buffer from the scratch pool (return it
    /// with [`Driver::put_id_buf`] so its capacity is reused).
    pub(crate) fn take_id_buf(&mut self) -> Vec<ReqId> {
        self.scratch_ids
            .pop()
            .map(|mut v| {
                v.clear();
                v
            })
            .unwrap_or_default()
    }

    /// Return a loaned id buffer to the scratch pool.
    pub(crate) fn put_id_buf(&mut self, buf: Vec<ReqId>) {
        if self.scratch_ids.len() < 8 {
            self.scratch_ids.push(buf);
        }
    }

    /// Re-derive `id`'s membership in the phase index from its current
    /// state (idempotent; absent state = out of every set).  Must be
    /// called after any transition of phase / running / current chunk.
    pub(crate) fn reindex(&mut self, id: ReqId) {
        self.idx.update(id, self.states.get(&id));
    }

    fn insert_pending(&mut self, req: Request) {
        let at = self
            .pending
            .partition_point(|r| (r.arrival_us, r.id) <= (req.arrival_us, req.id));
        self.pending.insert(at, req);
    }

    /// Admit every request whose arrival time has passed; returns the
    /// ids of newly allocated LLM serving states (tool nodes queue for
    /// the CPU instead — the driver runs them itself).
    pub fn admit_ready(&mut self, max_chunk: usize) -> Vec<ReqId> {
        let mut out = vec![];
        while self
            .pending
            .front()
            .map(|r| r.arrival_us <= self.now() + 1e-9)
            .unwrap_or(false)
        {
            // lint:allow(panic-free-hot-path) the while condition proves front() is Some
            let req = self.pending.pop_front().unwrap();
            let id = req.id;
            if req.is_tool() {
                // Tool nodes never allocate serving state: they run as
                // one CPU kernel (launch_tools) and pass the flow's
                // conversation through.
                self.events.push(EngineEvent::Admitted { id, at_us: self.now() });
                self.tool_wait.push_back(req);
                continue;
            }
            // Continuation turns try the session pool first: a hit
            // seeds the state with the retained KV + prefix length.
            let seed = match (&mut self.sessions, &req.flow) {
                (Some(pool), Some(fb)) if fb.is_continuation() => {
                    pool.take_match(fb.flow_id, &req.prompt)
                }
                _ => None,
            };
            let mut st = self.bridge.init_state_with_session(req, max_chunk, seed);
            st.enqueued_at_us = self.now();
            self.states.insert(id, st);
            self.reindex(id);
            self.events.push(EngineEvent::Admitted { id, at_us: self.now() });
            out.push(id);
        }
        self.launch_tools();
        self.launch_graphics();
        out
    }

    /// Launch ready tool nodes on the SoC's CPU — one roofline kernel
    /// each, drawing DDR bandwidth like any accelerator kernel.  A SoC
    /// without a CPU model completes tools instantly.
    fn launch_tools(&mut self) {
        if self.tool_wait.is_empty() {
            return;
        }
        let Some(cpu) = self.cpu else {
            while let Some(req) = self.tool_wait.pop_front() {
                let t = self.now();
                self.finish_tool(req, t);
            }
            return;
        };
        while !self.sim.busy(cpu) {
            let Some(req) = self.tool_wait.pop_front() else { break };
            let (flops, bytes) = match req.flow.as_ref().map(|f| f.node) {
                Some(NodeKind::Tool { flops, bytes }) => (flops, bytes),
                _ => (0.0, 0.0),
            };
            let cost = KernelCost {
                gemm_flops: flops,
                attn_flops: 0.0,
                bytes,
                footprint_bytes: 0.0,
                is_dynamic: false,
            };
            let timing: KernelTiming = self.sim.xpus[cpu].timing(&cost);
            let class = KernelClass::from_reactive(req.priority.is_reactive());
            let run = self.sim.launch(cpu, LaunchSpec { timing, class });
            self.tool_inflight.insert(run, req);
        }
    }

    fn mark_running(&mut self, id: ReqId) {
        // lint:allow(panic-free-hot-path) launches come from the phase index, which only holds admitted ids
        let st = self.states.get_mut(&id).expect("launch for unknown req");
        assert!(!st.running, "request {id} already has a kernel in flight");
        st.running = true;
        st.preempt_counted = false;
        self.reindex(id);
    }

    /// Launch a kernel; marks all tagged requests as running.
    pub fn launch(&mut self, xpu: usize, timing: KernelTiming, reactive: bool, tag: KernelTag) {
        self.launch_with_factor(xpu, timing, reactive, tag, 1.0);
    }

    /// [`Driver::launch`] with a co-run DDR-penalty factor on the
    /// kernel's memory phase (§5.3 asymmetric slowdown).  Factor 1.0 is
    /// bit-identical to a plain launch; split chunks pass the per-XPU
    /// `CO_RUN_DDR_PENALTY_*` constant instead.
    pub fn launch_with_factor(
        &mut self,
        xpu: usize,
        timing: KernelTiming,
        reactive: bool,
        tag: KernelTag,
        co_run_mem_factor: f64,
    ) {
        match &tag {
            KernelTag::Prefill { req } => self.mark_running(*req),
            KernelTag::DecodeIter { lanes } => {
                for i in 0..lanes.len() {
                    self.mark_running(lanes[i]);
                }
            }
        }
        let run = self.sim.launch_with_factor(
            xpu,
            LaunchSpec { timing, class: KernelClass::from_reactive(reactive) },
            co_run_mem_factor,
        );
        self.inflight.insert(run, tag);
    }

    /// Abort the kernel on `xpu` (scheme-(a) instant preemption).  The
    /// tagged requests stop running; the caller decides what progress
    /// they lose.  Returns the aborted tag (`None` when the slot held a
    /// driver-managed tool kernel — it is re-queued, not lost).
    pub fn cancel(&mut self, xpu: usize) -> Option<KernelTag> {
        let run = self.sim.cancel(xpu)?;
        if let Some(g) = &mut self.graphics {
            // an aborted frame never reaches the display: one miss, and
            // the next frame schedules as usual
            if g.on_abort(run) {
                return None;
            }
        }
        if let Some(req) = self.tool_inflight.remove(&run) {
            self.tool_wait.push_front(req);
            return None;
        }
        // lint:allow(panic-free-hot-path) every launched run is in exactly one inflight table; tool_inflight was checked above
        let tag = self.inflight.remove(&run).expect("cancelled unknown run");
        match &tag {
            KernelTag::Prefill { req } => self.mark_stopped(*req),
            KernelTag::DecodeIter { lanes } => {
                for i in 0..lanes.len() {
                    self.mark_stopped(lanes[i]);
                }
            }
        }
        Some(tag)
    }

    fn mark_stopped(&mut self, id: ReqId) {
        if let Some(st) = self.states.get_mut(&id) {
            st.running = false;
        }
        self.reindex(id);
    }

    /// Preemption accounting hook: bump the counter and stream the
    /// event (the caller decides *who* was preempted and why).
    pub fn note_preemption(&mut self, id: ReqId) {
        self.preemptions += 1;
        self.events.push(EngineEvent::Preempted { id, at_us: self.now() });
    }

    /// Memory-governor accounting: an in-flight prefill lost its KV.
    pub fn note_kv_eviction(&mut self, id: ReqId) {
        self.kv_evictions += 1;
        self.events.push(EngineEvent::KvEvicted { id, at_us: self.now() });
    }

    /// Memory-governor accounting: an idle retained session was shed.
    pub fn note_session_eviction(&mut self, flow_id: FlowId) {
        self.session_evictions += 1;
        self.events
            .push(EngineEvent::SessionEvicted { flow_id, at_us: self.now() });
    }

    /// Elastic-binding accounting: a waiting plan was re-bound (its
    /// dynamic margin chunk folded to a padded static variant so the
    /// NPU can take it).
    pub fn note_rebind(&mut self, id: ReqId) {
        self.rebinds += 1;
        self.events
            .push(EngineEvent::Rebound { id, at_us: self.now(), split_tokens: 0 });
    }

    /// Elastic-binding accounting: a head chunk was split across XPUs;
    /// `tokens` of it moved to the co-run iGPU part.
    pub fn note_split(&mut self, id: ReqId, tokens: usize) {
        self.rebinds += 1;
        self.splits += 1;
        self.split_tokens += tokens as u64;
        self.events
            .push(EngineEvent::Rebound { id, at_us: self.now(), split_tokens: tokens });
    }

    /// Abort a request wherever it is: still queued, held behind DAG
    /// predecessors, queued or running as a CPU tool kernel, waiting at
    /// a kernel boundary, or mid-kernel.  A lone prefill kernel is
    /// aborted immediately; a lane of a batched decode retires at the
    /// iteration boundary (the other lanes keep their tokens).  The
    /// request's KV is freed and dependent nodes that can no longer be
    /// stitched are cancelled with it.  Returns false when the id is
    /// unknown or already finished.
    pub fn cancel_request(&mut self, id: ReqId) -> bool {
        // not yet admitted
        if let Some(i) = self.pending.iter().position(|r| r.id == id) {
            // lint:allow(panic-free-hot-path) i came from position() on this deque
            let req = self.pending.remove(i).unwrap();
            let fid = req.flow_id();
            self.retire_cancelled_request(req);
            if let Some(fid) = fid {
                self.propagate_flow_cancel(fid);
            }
            return true;
        }
        // ready tool node waiting for the CPU
        if let Some(i) = self.tool_wait.iter().position(|r| r.id == id) {
            // lint:allow(panic-free-hot-path) i came from position() on this deque
            let req = self.tool_wait.remove(i).unwrap();
            let fid = req.flow_id();
            self.retire_cancelled_request(req);
            if let Some(fid) = fid {
                self.propagate_flow_cancel(fid);
            }
            return true;
        }
        // tool kernel in flight on the CPU: abort it
        if let Some(run) = self
            .tool_inflight
            .iter() // lint:allow(no-unordered-iteration) req ids are unique — at most one entry matches
            .find(|(_, r)| r.id == id)
            .map(|(run, _)| *run)
        {
            if let Some(xpu) = self.sim.xpu_of(run) {
                self.sim.cancel(xpu);
            }
            // lint:allow(panic-free-hot-path) run was just found in this map
            let req = self.tool_inflight.remove(&run).unwrap();
            let fid = req.flow_id();
            self.retire_cancelled_request(req);
            if let Some(fid) = fid {
                self.propagate_flow_cancel(fid);
            }
            return true;
        }
        // held behind DAG predecessors
        if let Some(fid) = self
            .held
            .iter() // lint:allow(no-unordered-iteration) req ids are unique — at most one chain matches
            .find(|(_, c)| c.iter().any(|r| r.id == id))
            .map(|(fid, _)| *fid)
        {
            // lint:allow(panic-free-hot-path) fid and the id were just found in held
            let chain = self.held.get_mut(&fid).unwrap();
            let i = chain.iter().position(|r| r.id == id).unwrap(); // lint:allow(panic-free-hot-path) the find above proves membership
            let node = chain.remove(i);
            if chain.is_empty() {
                self.held.remove(&fid);
            }
            self.retire_cancelled_request(node);
            self.propagate_flow_cancel(fid);
            return true;
        }
        // live serving state
        let (running, done, already, fid) = match self.states.get(&id) {
            Some(st) => (
                st.running,
                st.phase == Phase::Done,
                st.cancelled,
                st.req.flow_id(),
            ),
            None => return false,
        };
        if done || already {
            return false;
        }
        if running {
            // lint:allow(no-unordered-iteration) a request has at most one prefill kernel in flight
            let prefill_run = self.inflight.iter().find_map(|(run, tag)| match tag {
                KernelTag::Prefill { req } if *req == id => Some(*run),
                _ => None,
            });
            match prefill_run {
                Some(run) => {
                    // lone prefill kernel: abort it at once
                    if let Some(xpu) = self.sim.xpu_of(run) {
                        self.cancel(xpu);
                    }
                }
                None => {
                    // mid decode batch: the iteration finishes, the
                    // lane retires at the boundary
                    let turn = self.states[&id].req.turn_idx();
                    // lint:allow(panic-free-hot-path) id was found in states at the top of this fn
                    self.states.get_mut(&id).unwrap().cancelled = true;
                    if let Some(fid) = fid {
                        self.mark_node_dead(fid, turn);
                        self.propagate_flow_cancel(fid);
                    }
                    return true;
                }
            }
        }
        // lint:allow(panic-free-hot-path) id was found in states at the top of this fn
        let st = self.states.remove(&id).unwrap();
        self.reindex(id);
        self.retire_cancelled_state(st);
        if let Some(fid) = fid {
            self.propagate_flow_cancel(fid);
        }
        true
    }

    /// Record a node as cancelled in its flow's DAG progress: done (so
    /// surviving dependents can still release) *and* dead (so held
    /// placeholder dependents die transitively).
    fn mark_node_dead(&mut self, fid: FlowId, turn: usize) {
        self.flows.entry(fid).or_default().mark_dead(turn);
        self.shed_flow_state();
    }

    /// A workflow node died: held nodes whose prompts are generator
    /// placeholders (`delta_start > 0`) and depend — directly or
    /// transitively — on a dead node can never be stitched; they die
    /// with it and the retained session is dropped.  Self-contained
    /// dependents (`delta_start == 0`, the serving path) release as
    /// soon as their remaining predecessors complete; they merely miss
    /// the prefix cache.
    fn propagate_flow_cancel(&mut self, fid: FlowId) {
        let mut any_killed = false;
        loop {
            let victim = {
                let Some(prog) = self.flows.get(&fid) else { break };
                let Some(chain) = self.held.get(&fid) else { break };
                chain.iter().position(|r| {
                    r.flow
                        .as_ref()
                        .map(|fb| {
                            fb.delta_start > 0
                                && fb.dep_indices().iter().any(|d| prog.dead.contains(d))
                        })
                        .unwrap_or(false)
                })
            };
            let Some(i) = victim else { break };
            // lint:allow(panic-free-hot-path) victim was found inside held[fid] just above
            let chain = self.held.get_mut(&fid).unwrap();
            let node = chain.remove(i);
            if chain.is_empty() {
                self.held.remove(&fid);
            }
            any_killed = true;
            self.retire_cancelled_request(node); // marks it dead in turn
        }
        if any_killed {
            if let Some(pool) = &mut self.sessions {
                pool.drop_session(fid);
            }
        }
        self.release_ready(fid);
        self.cleanup_flow(fid);
    }

    fn retire_cancelled_state(&mut self, mut st: ReqState) {
        st.metrics.cancelled = true;
        let flow = st.req.flow.as_ref().map(|f| (f.flow_id, f.turn_idx));
        let m = st.metrics.clone();
        self.push_cancelled(m, flow);
        // st — and its KV, if any — drops here
    }

    fn retire_cancelled_request(&mut self, req: Request) {
        let m = ReqMetrics {
            id: req.id,
            priority: req.priority,
            profile: req.profile.clone(),
            flow_id: req.flow_id(),
            turn_idx: req.turn_idx(),
            deps: req.dep_indices(),
            think_time_us: req.flow.as_ref().map(|f| f.think_time_us).unwrap_or(0.0),
            tool: req.is_tool(),
            arrival_us: req.arrival_us,
            first_token_us: None,
            done_us: None,
            input_len: req.prompt_len(),
            output_tokens: 0,
            cached_prefix_len: 0,
            prefill_tokens: 0,
            cancelled: true,
        };
        let flow = req.flow.as_ref().map(|f| (f.flow_id, f.turn_idx));
        self.push_cancelled(m, flow);
    }

    fn push_cancelled(&mut self, m: ReqMetrics, flow: Option<(FlowId, usize)>) {
        if let Some((fid, turn)) = flow {
            self.mark_node_dead(fid, turn);
        }
        self.events
            .push(EngineEvent::Cancelled { id: m.id, at_us: self.now() });
        self.cancellations += 1;
        self.finished += 1;
        self.retire_metrics(m);
    }

    /// Record retired metrics.  Wall-clock runs keep only the most
    /// recent window (older ones were already streamed as events), so a
    /// long-lived server's history stays bounded; the shed count is
    /// reported as `RunReport::dropped_reqs` so `finish()` never
    /// *silently* under-reports what `ReportAccumulator` counted.
    fn retire_metrics(&mut self, m: ReqMetrics) {
        self.retired.push(m);
        if self.clock.is_wall() && self.retired.len() > self.retired_cap {
            // amortized: shed the older half of the window at once
            let shed = self.retired_cap / 2;
            let _ = self.retired.drain(..shed);
            self.dropped_reqs += shed as u64;
        }
    }

    /// Bound the per-flow DAG-progress table (defaults to
    /// `FLOW_DONE_MAX`; mainly for tests and memory-tight servers).
    pub fn limit_flow_state(&mut self, cap: usize) {
        self.flow_cap = cap.max(1);
    }

    /// Drop old flows' DAG progress beyond the cap — but never a flow
    /// that still has live nodes anywhere in the driver (held behind
    /// predecessors, pending, in the tool queues, or serving), since
    /// shedding its done-set would strand those nodes forever.  Dead
    /// flows shed oldest-first down to half the cap (amortized O(1);
    /// serving-path flow ids are monotonic, and a shed flow's next
    /// call merely starts cold).  The table may stay above the cap
    /// while everything in it is live.
    fn shed_flow_state(&mut self) {
        if self.flows.len() <= self.flow_cap {
            return;
        }
        let mut live: FxHashSet<FlowId> = FxHashSet::default();
        live.extend(self.held.keys().copied()); // lint:allow(no-unordered-iteration) feeds a membership-only set
        live.extend(self.pending.iter().filter_map(|r| r.flow_id()));
        live.extend(self.tool_wait.iter().filter_map(|r| r.flow_id()));
        // lint:allow(no-unordered-iteration) feeds a membership-only set
        live.extend(self.tool_inflight.values().filter_map(|r| r.flow_id()));
        // lint:allow(no-unordered-iteration) feeds a membership-only set
        live.extend(self.states.values().filter_map(|s| s.req.flow_id()));
        let target = (self.flow_cap / 2).max(1);
        let excess = self.flows.len().saturating_sub(target);
        let victims: Vec<FlowId> = self
            .flows
            .keys()
            .filter(|f| !live.contains(*f))
            .take(excess)
            .copied()
            .collect();
        for f in victims {
            self.flows.remove(&f);
        }
    }

    /// Advance to the next completion or arrival, applying kernel
    /// effects.  Returns false when the run is idle: under a virtual
    /// clock that means the run is over (no work, no arrivals); under a
    /// wall clock new submissions make it runnable again.
    pub fn step(&mut self) -> Result<bool> {
        self.launch_tools();
        self.launch_graphics();
        // A display renders frames forever, and a stale veto-retry
        // wake-up points past the last completion — neither must keep a
        // finished run alive or stretch its makespan.
        if self.all_done() && (self.graphics.is_some() || self.wake_at_us.is_some()) {
            return Ok(false);
        }
        if self.clock.is_wall() {
            // Wall mode: virtual durations only *order* the in-flight
            // kernels; their effects execute now, stamped in wall time.
            if let Some(dt) = self.sim.next_event_in() {
                let target = self.sim.now_us + dt;
                let completions = self.sim.advance_until(target);
                for c in completions {
                    self.apply_completion(&c)?;
                }
                return Ok(true);
            }
            // A veto-retry wake-up under a wall clock: nap until the
            // requested instant (bounded like the arrival nap below)
            // and hand control back to the policy (wall time advances
            // on its own; the §6.5 starvation valve bounds retries).
            if let Some(w) = self.wake_at_us.take() {
                let now = self.now();
                if w > now + 1e-9 {
                    let us = (w - now).clamp(1.0, 1_000.0);
                    std::thread::sleep(std::time::Duration::from_micros(us as u64));
                }
                return Ok(true);
            }
            // Nothing in flight: runnable iff an arrival is pending.  A
            // flow node released with think-time arrives in the *future*
            // in wall µs (the release stamp is wall time, never virtual
            // SoC time) — nap briefly instead of stalling the run, so
            // the held turn still admits without an external wake-up.
            return Ok(match self.pending.front().map(|r| r.arrival_us) {
                None => false,
                Some(a) => {
                    let now = self.now();
                    if a > now + 1e-9 {
                        let us = (a - now).clamp(1.0, 1_000.0);
                        std::thread::sleep(std::time::Duration::from_micros(us as u64));
                    }
                    true
                }
            });
        }
        let next_fin = self.sim.next_event_in().map(|dt| self.now() + dt);
        let next_arr = self.next_arrival_us();
        // A due-but-blocked frame is not an event (it launches after the
        // blocking completion); only a *future* frame due stops the clock.
        let next_frame = self
            .graphics
            .as_ref()
            .and_then(|g| g.next_launch_due())
            .filter(|&t| t > self.sim.now_us + 1e-9);
        let wake = self.wake_at_us.filter(|&t| t > self.sim.now_us + 1e-9);
        let target = [next_fin, next_arr, next_frame, wake]
            .into_iter()
            .flatten()
            .min_by(|a, b| a.total_cmp(b));
        let Some(target) = target else { return Ok(false) };
        // consume a wake-up the clock is about to reach (or has passed)
        if self.wake_at_us.map_or(false, |w| w <= target + 1e-9) {
            self.wake_at_us = None;
        }
        let completions = self.sim.advance_until(target);
        for c in completions {
            self.apply_completion(&c)?;
        }
        Ok(true)
    }

    fn apply_completion(&mut self, c: &Completion) -> Result<()> {
        // Graphics frames are driver-managed: fold the jank accounting
        // and record the render on the kernel trace.
        if let Some(g) = &mut self.graphics {
            if g.on_completion(c) {
                if !self.clock.is_wall() {
                    self.trace
                        .record(c.xpu, c.started_us, c.finished_us, "frame".into(), false);
                }
                return Ok(());
            }
        }
        // Driver-managed tool kernels complete outside the engine's
        // prefill/decode lifecycle.
        if let Some(req) = self.tool_inflight.remove(&c.id) {
            if !self.clock.is_wall() {
                self.trace.record(
                    c.xpu,
                    c.started_us,
                    c.finished_us,
                    format!("tool:{}", req.id),
                    req.priority.is_reactive(),
                );
            }
            let t = self.stamp(c.finished_us);
            self.finish_tool(req, t);
            return Ok(());
        }
        let tag = self
            .inflight
            .remove(&c.id)
            .context("completion for unknown run")?;
        // The kernel trace is a simulation artifact (Gantt figures,
        // invariant checks); a long-lived wall-clock server must not
        // accumulate one event per kernel forever.
        if !self.clock.is_wall() {
            let (label, reactive) = match &tag {
                KernelTag::Prefill { req } => (
                    format!("prefill:{req}"),
                    self.states.get(req).map(|s| s.is_reactive()).unwrap_or(false),
                ),
                KernelTag::DecodeIter { lanes } => (
                    format!("decode:b{}", lanes.len()),
                    lanes.iter().any(|id| {
                        self.states.get(id).map(|s| s.is_reactive()).unwrap_or(false)
                    }),
                ),
            };
            self.trace.record(c.xpu, c.started_us, c.finished_us, label, reactive);
        }
        // lifecycle timestamps in the run's clock domain
        let t = self.stamp(c.finished_us);
        match &tag {
            KernelTag::Prefill { req } => {
                let mut st = self.states.remove(req).context("unknown req")?;
                st.running = false;
                st.last_progress_us = t;
                let done = self.bridge.prefill_kernel_done(&mut st)?;
                if done {
                    st.metrics.first_token_us = Some(t);
                    st.enqueued_at_us = t;
                    if let Some(&tok) = st.tokens.last() {
                        self.events.push(EngineEvent::TokenEmitted {
                            id: *req,
                            token: tok,
                            n: st.tokens.len(),
                            at_us: t,
                        });
                    }
                }
                if st.phase == Phase::Done {
                    self.complete(st, t);
                } else {
                    self.states.insert(*req, st);
                }
                self.reindex(*req);
            }
            KernelTag::DecodeIter { lanes } => {
                let mut taken: Vec<ReqState> = lanes
                    .iter()
                    .map(|id| self.states.remove(id).context("unknown lane"))
                    .collect::<Result<_>>()?;
                {
                    let mut refs: Vec<&mut ReqState> = taken.iter_mut().collect();
                    self.bridge.decode_iter_done(&mut refs)?;
                }
                for mut st in taken {
                    let id = st.id();
                    st.running = false;
                    st.last_progress_us = t;
                    if st.cancelled {
                        // deferred lane cancellation: the iteration is
                        // over, the KV can go
                        self.retire_cancelled_state(st);
                        self.reindex(id);
                        continue;
                    }
                    if let Some(&tok) = st.tokens.last() {
                        self.events.push(EngineEvent::TokenEmitted {
                            id,
                            token: tok,
                            n: st.tokens.len(),
                            at_us: t,
                        });
                    }
                    if st.phase == Phase::Done {
                        self.complete(st, t);
                    } else {
                        self.states.insert(id, st);
                        self.reindex(id);
                    }
                }
            }
        }
        Ok(())
    }

    /// Request completion: stamp metrics, run flow bookkeeping, stream
    /// `TurnDone`, and retire the state — its metrics move to the
    /// retired list (bounded under a wall clock, exact under a virtual
    /// one) and the `ReqState` with its KV drops here, so the hot
    /// `states` map holds only live work in both clock domains.
    fn complete(&mut self, mut st: ReqState, t: f64) {
        let id = st.id();
        st.metrics.done_us = Some(t);
        self.finished += 1;
        self.on_request_done(&mut st, t);
        self.events.push(EngineEvent::TurnDone {
            id,
            at_us: t,
            arrival_us: st.metrics.arrival_us,
            first_token_us: st.metrics.first_token_us.unwrap_or(t),
            tokens: st.tokens.clone(),
            cached_prefix: st.cached_prefix_len,
        });
        self.retire_metrics(st.metrics);
        self.reindex(id);
    }

    /// Tool-node completion: stamp metrics (the TTFT point *is* the
    /// completion — a tool emits no tokens), stream `TurnDone`, and run
    /// the shared DAG bookkeeping (tools pass the conversation through
    /// to their dependents).
    fn finish_tool(&mut self, req: Request, t: f64) {
        self.finished += 1;
        let m = ReqMetrics {
            id: req.id,
            priority: req.priority,
            profile: req.profile.clone(),
            flow_id: req.flow_id(),
            turn_idx: req.turn_idx(),
            deps: req.dep_indices(),
            think_time_us: req.flow.as_ref().map(|f| f.think_time_us).unwrap_or(0.0),
            tool: true,
            arrival_us: req.arrival_us,
            first_token_us: Some(t),
            done_us: Some(t),
            input_len: req.prompt_len(),
            output_tokens: 0,
            cached_prefix_len: 0,
            prefill_tokens: 0,
            cancelled: false,
        };
        self.events.push(EngineEvent::TurnDone {
            id: req.id,
            at_us: t,
            arrival_us: req.arrival_us,
            first_token_us: t,
            tokens: vec![],
            cached_prefix: 0,
        });
        self.on_tool_done(&req, t);
        self.retire_metrics(m);
    }

    /// Flow bookkeeping at LLM-turn completion: record the actual
    /// conversation and branch contribution, retain the session KV, and
    /// release whatever the DAG unblocked.
    fn on_request_done(&mut self, st: &mut ReqState, now_us: f64) {
        let Some(fb) = st.req.flow.clone() else { return };
        let mut context = st.req.prompt.clone();
        context.extend(&st.tokens);
        let ds = fb.delta_start.min(st.req.prompt.len());
        let mut contrib = st.req.prompt[ds..].to_vec();
        contrib.extend(&st.tokens);
        let cache = st.cache.take();
        let pos = st.pos;
        self.flow_node_done(&fb, context, contrib, Some((cache, pos)), now_us);
    }

    /// Flow bookkeeping at tool completion: the conversation passes
    /// through from the tool's first predecessor (its result is part of
    /// the *next* LLM turn's delta), so the retained LLM cache stays
    /// valid across the hop.
    fn on_tool_done(&mut self, req: &Request, now_us: f64) {
        let Some(fb) = req.flow.clone() else { return };
        let context = fb
            .dep_indices()
            .first()
            .and_then(|d| {
                self.flows
                    .get(&fb.flow_id)
                    .and_then(|p| p.outputs.get(d))
                    .map(|o| o.context.clone())
            })
            .unwrap_or_default();
        self.flow_node_done(&fb, context, vec![], None, now_us);
    }

    /// Shared DAG bookkeeping at node completion: mark the node done,
    /// retain conversation state for joins and (LLM nodes) the session
    /// KV, then release every held node whose predecessors are all
    /// complete — each one think-time later, with the actual context
    /// stitched over its placeholder prefix.
    fn flow_node_done(
        &mut self,
        fb: &FlowBinding,
        context: Vec<i32>,
        contrib: Vec<i32>,
        session: Option<(Option<KvCache>, usize)>,
        now_us: f64,
    ) {
        let fid = fb.flow_id;
        self.flows.entry(fid).or_default().mark_done(fb.turn_idx);
        let held_more = self.held.get(&fid).map(|c| !c.is_empty()).unwrap_or(false);
        // Wall clock: a later call of this session may still arrive
        // online — retain while the binding expects more nodes.
        // Virtual clock: the observed DAG *is* the flow.
        let expects_more = self.clock.is_wall() && fb.turn_idx + 1 < fb.total_turns;
        match session {
            Some((cache, pos)) => {
                if let Some(pool) = &mut self.sessions {
                    if held_more || expects_more {
                        pool.retain(fid, cache, context.clone(), pos, now_us);
                    } else {
                        pool.drop_session(fid);
                    }
                }
            }
            // Tool nodes leave the retained LLM cache untouched.
            None => {
                if !held_more && !expects_more {
                    if let Some(pool) = &mut self.sessions {
                        pool.drop_session(fid);
                    }
                }
            }
        }
        if held_more {
            self.flows
                .entry(fid)
                .or_default()
                .outputs
                .insert(fb.turn_idx, NodeOutput { context, contrib });
        }
        self.release_ready(fid);
        self.cleanup_flow(fid);
        self.shed_flow_state();
    }

    /// Release every held node of `fid` whose DAG predecessors are all
    /// done: stitch the actual merged context over placeholder
    /// prefixes, stamp the arrival one think-time after the *last*
    /// predecessor's completion (i.e. now), and queue it.
    fn release_ready(&mut self, fid: FlowId) {
        let ready: Vec<Request> = {
            let Some(prog) = self.flows.get(&fid) else { return };
            let Some(chain) = self.held.get_mut(&fid) else { return };
            let mut out = vec![];
            let mut i = 0;
            while i < chain.len() {
                let ok = chain[i]
                    .flow
                    .as_ref()
                    .map(|fb| fb.dep_indices().iter().all(|d| prog.done.contains(d)))
                    .unwrap_or(true);
                if ok {
                    out.push(chain.remove(i));
                } else {
                    i += 1;
                }
            }
            out
        };
        if self.held.get(&fid).map(|c| c.is_empty()).unwrap_or(false) {
            self.held.remove(&fid);
        }
        if ready.is_empty() {
            return;
        }
        let now = self.now();
        for mut nxt in ready {
            // lint:allow(panic-free-hot-path) only flow-bound nodes are ever held
            let fb = nxt.flow.clone().expect("held node has a binding");
            if fb.delta_start > 0 {
                self.stitch(&mut nxt, &fb);
            }
            // the node "arrives" one think-time after its predecessors
            nxt.arrival_us = now + fb.think_time_us.max(0.0);
            self.insert_pending(nxt);
        }
    }

    /// Replace a placeholder context estimate with the actual one: the
    /// first predecessor's conversation plus every other predecessor's
    /// branch contribution, in dependency order.  Same length by
    /// construction (reply budgets are always generated in full); if
    /// the outputs were shed, the placeholder stays — a deterministic,
    /// mild degradation.
    fn stitch(&self, nxt: &mut Request, fb: &FlowBinding) {
        let Some(prog) = self.flows.get(&fb.flow_id) else { return };
        let deps = fb.dep_indices();
        let Some(first) = deps.first() else { return };
        let Some(base) = prog.outputs.get(first) else { return };
        let mut merged = base.context.clone();
        for d in &deps[1..] {
            if let Some(o) = prog.outputs.get(d) {
                merged.extend_from_slice(&o.contrib);
            }
        }
        let ds = fb.delta_start.min(nxt.prompt.len());
        let delta = nxt.prompt.split_off(ds);
        nxt.prompt = merged;
        nxt.prompt.extend(delta);
    }

    /// Once a flow has no held nodes left, nothing will stitch against
    /// its outputs — drop them.  The done/dead sets stay as the online
    /// continuation watermark (bounded by `FLOW_DONE_MAX`).
    fn cleanup_flow(&mut self, fid: FlowId) {
        if !self.held.contains_key(&fid) {
            if let Some(p) = self.flows.get_mut(&fid) {
                p.outputs.clear();
            }
        }
    }

    pub fn all_done(&self) -> bool {
        self.pending.is_empty()
            && self.tool_wait.is_empty()
            && self.finished == self.total_requests
    }

    pub fn unfinished(&self) -> usize {
        self.total_requests - self.finished
    }

    /// Requests in a given phase that do not have a kernel in flight.
    pub fn idle_in_phase(&self, phase: Phase) -> Vec<ReqId> {
        let mut v: Vec<ReqId> = self
            .states
            .values() // lint:allow(no-unordered-iteration) collected then sorted by id below
            .filter(|s| s.phase == phase && !s.running)
            .map(|s| s.id())
            .collect();
        v.sort_unstable();
        v
    }

    pub fn finish(self, engine: String) -> Result<RunReport> {
        if !self.all_done() {
            bail!(
                "{engine}: run ended with {} unfinished requests",
                self.unfinished()
            );
        }
        let makespan_us = self.now();
        Ok(RunReport {
            engine,
            reqs: {
                let mut v: Vec<_> =
                    self.states.into_values().map(|s| s.metrics).collect(); // lint:allow(no-unordered-iteration) sorted by id below
                v.extend(self.retired);
                v.sort_by_key(|m| m.id);
                v
            },
            xpus: self.sim.snapshot(),
            makespan_us,
            total_energy_j: self.sim.total_energy_j(),
            energy_by_class: self.sim.energy_by_class(),
            busy_by_class: self.sim.busy_by_class(),
            frames_scheduled: self.graphics.as_ref().map(|g| g.frames_scheduled).unwrap_or(0),
            frames_missed: self.graphics.as_ref().map(|g| g.frames_missed).unwrap_or(0),
            peak_power_w: self.sim.peak_power_w,
            mean_bw_gbps: self.sim.mean_bandwidth_gbps(),
            preemptions: self.preemptions,
            backfills: self.backfills,
            kv_evictions: self.kv_evictions,
            session_evictions: self.session_evictions,
            cancellations: self.cancellations,
            rebinds: self.rebinds,
            splits: self.splits,
            split_tokens: self.split_tokens,
            dropped_reqs: self.dropped_reqs,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;
    use crate::heg::Annotator;
    use crate::soc::XpuModel;
    use crate::workload::Priority;

    fn mk_driver(traces: Vec<Request>) -> (Driver, Annotator) {
        let mut geo = crate::config::llama32_3b();
        geo.n_layers = 2;
        let soc = default_soc();
        let ann = Annotator::new(
            geo.clone(),
            soc.xpus.iter().cloned().map(XpuModel::new).collect(),
        );
        (Driver::new(&soc, ExecBridge::synthetic(geo), traces), ann)
    }

    fn req(id: u64, arrival: f64, plen: usize, maxnew: usize) -> Request {
        Request {
            id,
            priority: Priority::Proactive,
            arrival_us: arrival,
            prompt: vec![3; plen],
            max_new_tokens: maxnew,
            profile: "test".into(),
            flow: None,
        }
    }

    /// A hand-built 3-turn flow whose conversation grows by `delta`
    /// tokens + the full reply budget each turn.
    fn flow_turns(flow_id: u64, first_id: u64, think_us: f64) -> Vec<Request> {
        let (p0, out, delta) = (60usize, 4usize, 30usize);
        let mut turns = vec![];
        let mut prompt = vec![3i32; p0];
        for k in 0..3usize {
            if k > 0 {
                let ds = prompt.len() + out;
                prompt = vec![9; ds]; // placeholder convo (driver re-stitches)
                prompt.extend(vec![3; delta]);
            }
            turns.push(Request {
                id: first_id + k as u64,
                priority: Priority::Reactive,
                arrival_us: 0.0,
                prompt: prompt.clone(),
                max_new_tokens: out,
                profile: "flow".into(),
                flow: Some(crate::workload::FlowBinding::linear(
                    flow_id,
                    k,
                    3,
                    if k == 0 { 0.0 } else { think_us },
                    if k == 0 { 0 } else { prompt.len() - delta },
                )),
            });
        }
        turns
    }

    /// A fan-out/join DAG: 0 → {1, 2} → 3 (all LLM nodes).
    ///
    /// node 0: 40-token opener, 4-token reply → context 44;
    /// nodes 1/2: deltas 10/12 over the context → prompts 54/56;
    /// node 3: join of both branches — merged context
    /// 44 + (10+4) + (12+4) = 74, delta 8 → prompt 82.
    fn diamond_flow(flow_id: u64, first_id: u64) -> Vec<Request> {
        let mk = |idx: usize, plen: usize, ds: usize, deps: Vec<usize>, think: f64| {
            let mut prompt = vec![9i32; ds];
            prompt.extend(vec![(3 + idx) as i32; plen - ds]);
            Request {
                id: first_id + idx as u64,
                priority: Priority::Reactive,
                arrival_us: 0.0,
                prompt,
                max_new_tokens: 4,
                profile: "dag".into(),
                flow: Some(crate::workload::FlowBinding {
                    flow_id,
                    turn_idx: idx,
                    total_turns: 4,
                    think_time_us: think,
                    delta_start: ds,
                    deps,
                    node: crate::workload::NodeKind::Llm,
                    crit_path: 1,
                }),
            }
        };
        vec![
            mk(0, 40, 0, vec![], 0.0),
            mk(1, 54, 44, vec![0], 1_000.0),
            mk(2, 56, 44, vec![0], 2_000.0),
            mk(3, 82, 74, vec![1, 2], 500.0),
        ]
    }

    /// LLM turn → CPU tool call → LLM digest.
    fn tool_chain_flow(flow_id: u64, first_id: u64) -> Vec<Request> {
        let fb = |idx: usize, ds: usize, deps: Vec<usize>, node| {
            crate::workload::FlowBinding {
                flow_id,
                turn_idx: idx,
                total_turns: 3,
                think_time_us: 0.0,
                delta_start: ds,
                deps,
                node,
                crit_path: 1,
            }
        };
        use crate::workload::NodeKind;
        let mut digest = vec![9i32; 44];
        digest.extend(vec![5; 16]);
        vec![
            Request {
                id: first_id,
                priority: Priority::Reactive,
                arrival_us: 0.0,
                prompt: vec![3; 40],
                max_new_tokens: 4,
                profile: "agent".into(),
                flow: Some(fb(0, 0, vec![], NodeKind::Llm)),
            },
            Request {
                id: first_id + 1,
                priority: Priority::Reactive,
                arrival_us: 0.0,
                prompt: vec![7; 8],
                max_new_tokens: 0,
                profile: "agent".into(),
                flow: Some(fb(1, 0, vec![0], NodeKind::Tool { flops: 7e9, bytes: 2e8 })),
            },
            Request {
                id: first_id + 2,
                priority: Priority::Reactive,
                arrival_us: 0.0,
                prompt: digest,
                max_new_tokens: 4,
                profile: "agent".into(),
                flow: Some(fb(2, 44, vec![1], NodeKind::Llm)),
            },
        ]
    }

    /// A trivial FCFS policy good enough to exercise the driver.
    fn run_fcfs(trace: Vec<Request>) -> RunReport {
        run_fcfs_opts(trace, false)
    }

    fn drive_fcfs(d: &mut Driver, ann: &Annotator) {
        let npu = d.sim.xpu_index("npu").unwrap();
        let igpu = d.sim.xpu_index("igpu").unwrap();
        loop {
            d.admit_ready(512);
            // NPU: first prefilling request (by id)
            if !d.sim.busy(npu) {
                if let Some(&id) = d.idle_in_phase(Phase::Prefilling).first() {
                    let chunk = *d.states[&id].current_chunk().unwrap();
                    let a = ann.prefill_kernel(&chunk);
                    let t = *a.timing_on(npu);
                    d.launch(npu, t, false, KernelTag::Prefill { req: id });
                }
            }
            // iGPU: batch every idle decoder
            if !d.sim.busy(igpu) {
                let lanes = d.idle_in_phase(Phase::Decoding);
                if !lanes.is_empty() {
                    let avg = d.states[&lanes[0]].pos;
                    let a = ann.decode_iter(lanes.len(), avg);
                    let t = *a.timing_on(igpu);
                    d.launch(igpu, t, false, KernelTag::DecodeIter { lanes });
                }
            }
            if !d.step().unwrap() {
                break;
            }
        }
    }

    fn run_fcfs_opts(trace: Vec<Request>, session_reuse: bool) -> RunReport {
        let (mut d, ann) = mk_driver(trace);
        if session_reuse {
            d.enable_session_reuse(8);
        }
        drive_fcfs(&mut d, &ann);
        d.finish("fcfs-test".into()).unwrap()
    }

    #[test]
    fn driver_completes_single_request() {
        let rep = run_fcfs(vec![req(1, 0.0, 100, 5)]);
        assert_eq!(rep.reqs.len(), 1);
        let m = &rep.reqs[0];
        assert!(m.finished());
        assert_eq!(m.output_tokens, 5);
        assert!(m.ttft_us().unwrap() > 0.0);
        assert!(m.done_us.unwrap() > m.first_token_us.unwrap());
    }

    #[test]
    fn driver_completes_overlapping_requests() {
        let rep = run_fcfs(vec![
            req(1, 0.0, 300, 8),
            req(2, 1000.0, 200, 4),
            req(3, 2000.0, 64, 2),
        ]);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        // arrivals respected: nothing starts before it arrives
        for m in &rep.reqs {
            assert!(m.first_token_us.unwrap() > m.arrival_us);
        }
        assert!(rep.makespan_us > 0.0);
        assert!(rep.total_energy_j > 0.0);
    }

    #[test]
    fn late_arrivals_wake_the_driver() {
        // second request arrives long after the first finishes — the
        // driver must jump the clock to it
        let rep = run_fcfs(vec![req(1, 0.0, 64, 2), req(2, 5e6, 64, 2)]);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 2);
        let m2 = rep.reqs.iter().find(|m| m.id == 2).unwrap();
        assert!(m2.first_token_us.unwrap() >= 5e6);
    }

    #[test]
    fn finish_fails_with_unfinished_requests() {
        let (d, _) = mk_driver(vec![req(1, 0.0, 64, 2)]);
        // never scheduled anything
        assert!(d.finish("broken".into()).is_err());
    }

    #[test]
    fn flow_turns_run_in_order_with_think_time() {
        let think = 50_000.0;
        let rep = run_fcfs(flow_turns(1, 10, think));
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        for w in rep.reqs.windows(2) {
            let (prev, next) = (&w[0], &w[1]);
            // turn k+1 arrives exactly one think-time after turn k ends
            assert!(
                (next.arrival_us - (prev.done_us.unwrap() + think)).abs() < 1e-6,
                "turn {} release", next.id
            );
            assert!(next.first_token_us.unwrap() >= prev.done_us.unwrap() + think);
        }
        // flow identity lands in the metrics
        assert!(rep.reqs.iter().all(|m| m.flow_id == Some(1)));
        assert_eq!(rep.reqs.iter().map(|m| m.turn_idx).collect::<Vec<_>>(), vec![0, 1, 2]);
        // linear chains resolve their implicit DAG edges
        assert_eq!(rep.reqs[1].deps, vec![0]);
        assert_eq!(rep.reqs[2].deps, vec![1]);
    }

    #[test]
    fn flow_reuse_prefills_only_deltas() {
        let rep = run_fcfs_opts(flow_turns(1, 10, 10_000.0), true);
        let m: Vec<_> = rep.reqs.iter().collect();
        assert_eq!(m[0].cached_prefix_len, 0);
        assert_eq!(m[0].prefill_tokens, 60);
        // turn 0 ends with pos = 60 + (4 - 1) generated = 63 cached;
        // the stitched turn-1 prompt (94 tokens) extends it exactly
        assert_eq!(m[1].cached_prefix_len, 63, "turn 1 reuses the session KV");
        assert_eq!(m[1].prefill_tokens, 94 - 63);
        assert_eq!(m[2].cached_prefix_len, 94 + 3);
        assert_eq!(m[2].prefill_tokens, 128 - 97);
        assert!(
            rep.recomputed_prefill_tokens()
                < rep.reqs.iter().map(|m| m.input_len).sum::<usize>(),
            "delta prefill must beat full recompute"
        );
    }

    #[test]
    fn flows_without_session_reuse_recompute_everything() {
        let rep = run_fcfs(flow_turns(1, 10, 10_000.0));
        for m in &rep.reqs {
            assert_eq!(m.cached_prefix_len, 0);
            assert_eq!(m.prefill_tokens, m.input_len, "full recompute per turn");
        }
        // head-to-head: the reuse run does strictly less prefill work
        let reuse = run_fcfs_opts(flow_turns(1, 10, 10_000.0), true);
        assert!(reuse.recomputed_prefill_tokens() < rep.recomputed_prefill_tokens());
        assert_eq!(reuse.reused_prefix_tokens(), 63 + 97);
    }

    #[test]
    fn mixed_flow_and_single_shot_traffic_completes() {
        let mut trace = flow_turns(5, 100, 20_000.0);
        trace.push(req(1, 0.0, 80, 3));
        trace.push(req(2, 30_000.0, 50, 2));
        let rep = run_fcfs_opts(trace, true);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 5);
        // single-shot requests never touch the session pool
        for m in rep.reqs.iter().filter(|m| m.flow_id.is_none()) {
            assert_eq!(m.cached_prefix_len, 0);
        }
    }

    #[test]
    fn fan_out_join_releases_after_all_predecessors() {
        let rep = run_fcfs_opts(diamond_flow(1, 10), true);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 4);
        let m = |i: u64| rep.reqs.iter().find(|m| m.id == 10 + i).unwrap();
        let (m0, m1, m2, m3) = (m(0), m(1), m(2), m(3));
        // both branches release one think-time after the root completes
        assert!((m1.arrival_us - (m0.done_us.unwrap() + 1_000.0)).abs() < 1e-6);
        assert!((m2.arrival_us - (m0.done_us.unwrap() + 2_000.0)).abs() < 1e-6);
        // the join waits for *both* branches, then its own think-time
        let last = m1.done_us.unwrap().max(m2.done_us.unwrap());
        assert!(
            (m3.arrival_us - (last + 500.0)).abs() < 1e-6,
            "join released at {} want {}", m3.arrival_us, last + 500.0
        );
        assert!(m3.first_token_us.unwrap() > last + 500.0);
        // join stitching preserves the generator's length estimate
        assert_eq!(m3.input_len, 82);
        // the first branch claimed the root's session cache (43 of the
        // 44 trunk tokens; the last prompt token always recomputes)
        assert_eq!(m1.cached_prefix_len, 43);
        // the join reuses the shared 44-token trunk of whichever branch
        // was retained last — both agree on the trunk
        assert_eq!(m3.cached_prefix_len, 44);
        // DAG identity lands in the metrics
        assert_eq!(m3.deps, vec![1, 2]);
        assert_eq!(m1.deps, vec![0]);
    }

    #[test]
    fn tool_nodes_run_on_the_cpu_and_pass_the_context_through() {
        let rep = run_fcfs_opts(tool_chain_flow(1, 20), true);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        let m = |i: u64| rep.reqs.iter().find(|m| m.id == 20 + i).unwrap();
        let (m0, mt, m2) = (m(0), m(1), m(2));
        // the tool runs right after its predecessor, for a real CPU
        // roofline duration, and generates no tokens
        assert!(mt.tool);
        assert!((mt.arrival_us - m0.done_us.unwrap()).abs() < 1e-6);
        assert!(mt.done_us.unwrap() > mt.arrival_us + 1_000.0, "CPU roofline time");
        assert_eq!(mt.output_tokens, 0);
        assert!(rep.utilization("cpu") > 0.0, "the tool kernel ran on the CPU");
        // the digest waits for the tool, sees the stitched conversation,
        // and still reuses the LLM turn's KV across the tool hop
        assert!((m2.arrival_us - mt.done_us.unwrap()).abs() < 1e-6);
        assert_eq!(m2.input_len, 60);
        assert_eq!(m2.cached_prefix_len, 43, "KV survives the tool hop");
        assert_eq!(m2.prefill_tokens, 60 - 43);
    }

    #[test]
    fn events_stream_tokens_and_completions() {
        let (mut d, ann) = mk_driver(vec![req(1, 0.0, 100, 5), req(2, 500.0, 60, 3)]);
        drive_fcfs(&mut d, &ann);
        let evs = d.take_events();
        use crate::engine::EngineEvent::{Admitted, TokenEmitted, TurnDone};
        let admitted = evs.iter().filter(|e| matches!(e, Admitted { .. })).count();
        let tokens = evs.iter().filter(|e| matches!(e, TokenEmitted { .. })).count();
        let done = evs.iter().filter(|e| matches!(e, TurnDone { .. })).count();
        assert_eq!(admitted, 2);
        assert_eq!(tokens, 5 + 3, "one event per generated token");
        assert_eq!(done, 2);
        // the TurnDone carries the full token vector and timestamps
        let td = evs
            .iter()
            .find_map(|e| match e {
                TurnDone { id: 1, tokens, first_token_us, at_us, arrival_us, .. } => {
                    Some((tokens.clone(), *first_token_us, *at_us, *arrival_us))
                }
                _ => None,
            })
            .unwrap();
        assert_eq!(td.0.len(), 5);
        assert!(td.3 <= td.1 && td.1 <= td.2);
        let rep = d.finish("fcfs-test".into()).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 2);
    }

    #[test]
    fn cancel_pending_request_never_admits() {
        let (mut d, ann) = mk_driver(vec![req(1, 0.0, 80, 3), req(2, 50_000.0, 80, 3)]);
        assert!(d.cancel_request(2), "queued request is cancellable");
        assert!(!d.cancel_request(2), "double cancel is a no-op");
        drive_fcfs(&mut d, &ann);
        let evs = d.take_events();
        assert!(evs.iter().any(|e| matches!(e, EngineEvent::Cancelled { id: 2, .. })));
        let rep = d.finish("fcfs-test".into()).unwrap();
        assert_eq!(rep.cancellations, 1);
        let m2 = rep.reqs.iter().find(|m| m.id == 2).unwrap();
        assert!(m2.cancelled && !m2.finished());
        assert!(rep.reqs.iter().find(|m| m.id == 1).unwrap().finished());
    }

    #[test]
    fn cancel_mid_prefill_aborts_the_kernel() {
        let (mut d, ann) = mk_driver(vec![req(1, 0.0, 400, 3)]);
        let npu = d.sim.xpu_index("npu").unwrap();
        d.admit_ready(512);
        let chunk = *d.states[&1].current_chunk().unwrap();
        let t = *ann.prefill_kernel(&chunk).timing_on(npu);
        d.launch(npu, t, false, KernelTag::Prefill { req: 1 });
        assert!(d.sim.busy(npu));
        assert!(d.cancel_request(1));
        assert!(!d.sim.busy(npu), "the in-flight prefill kernel is aborted");
        assert!(d.states.is_empty(), "state and KV freed");
        assert!(d.all_done());
        let rep = d.finish("fcfs-test".into()).unwrap();
        assert_eq!(rep.cancellations, 1);
    }

    #[test]
    fn cancel_decode_lane_retires_at_iteration_boundary() {
        let (mut d, ann) = mk_driver(vec![req(1, 0.0, 60, 8), req(2, 0.0, 60, 8)]);
        let npu = d.sim.xpu_index("npu").unwrap();
        let igpu = d.sim.xpu_index("igpu").unwrap();
        // prefill both to decode phase
        loop {
            d.admit_ready(512);
            if !d.sim.busy(npu) {
                if let Some(&id) = d.idle_in_phase(Phase::Prefilling).first() {
                    let chunk = *d.states[&id].current_chunk().unwrap();
                    let t = *ann.prefill_kernel(&chunk).timing_on(npu);
                    d.launch(npu, t, false, KernelTag::Prefill { req: id });
                }
            }
            if d.idle_in_phase(Phase::Decoding).len() == 2 {
                break;
            }
            assert!(d.step().unwrap());
        }
        // launch a 2-lane decode, then cancel lane 2 mid-kernel
        let lanes = d.idle_in_phase(Phase::Decoding);
        let t = *ann.decode_iter(2, 64).timing_on(igpu);
        d.launch(igpu, t, false, KernelTag::DecodeIter { lanes });
        assert!(d.cancel_request(2));
        assert!(d.sim.busy(igpu), "a batched decode is never aborted mid-kernel");
        drive_fcfs(&mut d, &ann);
        let rep = d.finish("fcfs-test".into()).unwrap();
        let m1 = rep.reqs.iter().find(|m| m.id == 1).unwrap();
        let m2 = rep.reqs.iter().find(|m| m.id == 2).unwrap();
        assert!(m1.finished() && m1.output_tokens == 8, "surviving lane unaffected");
        assert!(m2.cancelled && !m2.finished());
    }

    #[test]
    fn cancel_flow_turn_kills_placeholder_successors() {
        let (mut d, ann) = mk_driver(flow_turns(1, 10, 1_000.0));
        // cancel the middle turn while it is still held
        assert!(d.cancel_request(11));
        drive_fcfs(&mut d, &ann);
        let rep = d.finish("fcfs-test".into()).unwrap();
        // turn 0 completes; turns 1 and 2 are cancelled together (turn
        // 2's placeholder prompt can never be stitched without turn 1)
        assert!(rep.reqs.iter().find(|m| m.id == 10).unwrap().finished());
        assert!(rep.reqs.iter().find(|m| m.id == 11).unwrap().cancelled);
        assert!(rep.reqs.iter().find(|m| m.id == 12).unwrap().cancelled);
        assert_eq!(rep.cancellations, 2);
    }

    #[test]
    fn cancelling_a_tool_node_kills_placeholder_dependents() {
        let (mut d, ann) = mk_driver(tool_chain_flow(1, 20));
        // the tool is still held behind the opening turn
        assert!(d.cancel_request(21));
        drive_fcfs(&mut d, &ann);
        let rep = d.finish("fcfs-test".into()).unwrap();
        assert!(rep.reqs.iter().find(|m| m.id == 20).unwrap().finished());
        assert!(rep.reqs.iter().find(|m| m.id == 21).unwrap().cancelled);
        assert!(
            rep.reqs.iter().find(|m| m.id == 22).unwrap().cancelled,
            "the digest's placeholder prompt cannot exist without the tool"
        );
        assert_eq!(rep.cancellations, 2);
    }

    #[test]
    fn waiting_proactive_prefill_index_tracks_the_lifecycle() {
        let (mut d, ann) = mk_driver(vec![req(1, 0.0, 100, 2), req(2, 0.0, 100, 2)]);
        d.admit_ready(512);
        assert_eq!(d.waiting_proactive_prefills(), vec![1, 2]);
        let npu = d.sim.xpu_index("npu").unwrap();
        let chunk = *d.states[&1].current_chunk().unwrap();
        let t = *ann.prefill_kernel(&chunk).timing_on(npu);
        d.launch(npu, t, false, KernelTag::Prefill { req: 1 });
        assert_eq!(
            d.waiting_proactive_prefills(),
            vec![2],
            "a running prefill leaves the index"
        );
        drive_fcfs(&mut d, &ann);
        assert!(d.waiting_proactive_prefills().is_empty(), "drained at completion");
        d.finish("fcfs-test".into()).unwrap();
    }

    #[test]
    fn wall_bounded_history_flags_truncation_and_stream_stays_exact() {
        let mut geo = crate::config::llama32_3b();
        geo.n_layers = 2;
        let soc = default_soc();
        let ann = Annotator::new(
            geo.clone(),
            soc.xpus.iter().cloned().map(XpuModel::new).collect(),
        );
        let mut d = Driver::open(&soc, ExecBridge::synthetic(geo), EngineClock::wall());
        d.limit_retained_history(4);
        for i in 0..8u64 {
            d.submit(req(i, 0.0, 40, 2));
        }
        drive_fcfs(&mut d, &ann);
        let evs = d.take_events();
        let mut acc = crate::metrics::ReportAccumulator::new();
        for e in &evs {
            acc.absorb(e);
        }
        let rep = d.finish("fcfs-test".into()).unwrap();
        // the bounded window shed old entries — flagged, never silent
        assert!(rep.dropped_reqs > 0);
        assert_eq!(rep.reqs.len() + rep.dropped_reqs as usize, 8);
        // the incremental accumulator still saw every completion
        assert_eq!(acc.served, 8);
    }

    #[test]
    fn wall_wakeup_nap_is_proportional_to_the_requested_instant() {
        let mut geo = crate::config::llama32_3b();
        geo.n_layers = 2;
        let soc = default_soc();
        let mut d = Driver::open(&soc, ExecBridge::synthetic(geo), EngineClock::wall());
        d.submit(req(1, 0.0, 8, 1)); // keeps the run alive (all_done is false)
        d.admit_ready(512); // drain pending so step() reaches the wake branch
        d.request_wakeup(d.now() + 5.0);
        let t0 = std::time::Instant::now();
        assert!(d.step().unwrap());
        let waited = t0.elapsed();
        assert!(
            waited < std::time::Duration::from_micros(450),
            "a 5 µs wake-up must not nap a fixed 500 µs (waited {waited:?})"
        );
    }

    #[test]
    fn flow_shedding_spares_flows_with_live_nodes() {
        // A held multi-turn flow with the lowest flow id (the first
        // victim under oldest-first shedding) must survive a flood of
        // completed one-shot flows that pushes the progress table far
        // over its cap — shedding its done-set would strand the held
        // turns forever.
        let mut trace = flow_turns(1, 10, 5_000.0);
        for k in 0..40u64 {
            trace.push(Request {
                id: 100 + k,
                priority: Priority::Proactive,
                arrival_us: 0.0,
                prompt: vec![3; 20],
                max_new_tokens: 1,
                profile: "flood".into(),
                flow: Some(crate::workload::FlowBinding::linear(100 + k, 0, 1, 0.0, 0)),
            });
        }
        let (mut d, ann) = mk_driver(trace);
        d.limit_flow_state(2);
        drive_fcfs(&mut d, &ann);
        let rep = d.finish("fcfs-test".into()).unwrap();
        for t in 0..3u64 {
            assert!(
                rep.reqs.iter().find(|m| m.id == 10 + t).unwrap().finished(),
                "held turn {t} of the live flow completed"
            );
        }
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 43);
    }

    #[test]
    fn wall_shedding_accounts_for_every_request_at_scale() {
        // 100k requests through a wall-clock driver with a tight
        // retained-history window: the final report plus the dropped
        // count must account for every request exactly, and the
        // streaming accumulator must have seen every completion.
        const N: u64 = 100_000;
        let mut geo = crate::config::llama32_3b();
        geo.n_layers = 2;
        let soc = default_soc();
        let ann = Annotator::new(
            geo.clone(),
            soc.xpus.iter().cloned().map(XpuModel::new).collect(),
        );
        let mut d = Driver::open(&soc, ExecBridge::synthetic(geo), EngineClock::wall());
        d.limit_retained_history(64);
        let mut acc = crate::metrics::ReportAccumulator::new();
        let mut next = 0u64;
        while next < N {
            let hi = (next + 256).min(N);
            for i in next..hi {
                d.submit(req(i, 0.0, 8, 1));
            }
            next = hi;
            drive_fcfs(&mut d, &ann);
            for e in &d.take_events() {
                acc.absorb(e);
            }
        }
        let rep = d.finish("fcfs-test".into()).unwrap();
        assert!(rep.dropped_reqs > 0);
        assert_eq!(rep.reqs.len() + rep.dropped_reqs as usize, N as usize);
        assert_eq!(acc.served, N as usize);
    }
}
