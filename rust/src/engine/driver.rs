//! The shared DES event loop: every engine (Agent.xpu and the
//! baselines) is a scheduling policy plugged into this driver.
//!
//! Responsibilities: arrival admission, kernel-completion effects (via
//! [`ExecBridge`]), lifecycle metrics (TTFT at prefill completion,
//! completion time at token budget), and the final [`RunReport`].
//!
//! Flow-level sessions (DESIGN.md §3): the driver owns the workload
//! semantics of multi-turn flows — a turn after the first is *held*
//! until its predecessor completes, released one think-time later with
//! the actual generated conversation stitched into its prompt.  Every
//! engine gets this for free (so baselines see identical flow traffic);
//! engines that additionally call [`Driver::enable_session_reuse`] get
//! cross-turn KV retention — turn *k+1* then prefills only its delta
//! tokens instead of recomputing the whole conversation prefix.

use std::collections::{HashMap, VecDeque};

use anyhow::{Context, Result, bail};

use crate::config::SocConfig;
use crate::metrics::RunReport;
use crate::runtime::SessionCachePool;
use crate::soc::{Completion, KernelTiming, LaunchSpec, RunId, SocSim};
use crate::workload::{FlowId, ReqId, Request};

use super::bridge::ExecBridge;
use super::reqstate::{Phase, ReqState};
use crate::trace::Trace;

/// Semantic meaning of an in-flight kernel.
#[derive(Debug, Clone, PartialEq)]
pub enum KernelTag {
    /// The next prefill kernel (st.chunk_idx, st.layer_idx) of `req`.
    Prefill { req: ReqId },
    /// One batched decode iteration over `lanes`.
    DecodeIter { lanes: Vec<ReqId> },
}

impl KernelTag {
    pub fn reqs(&self) -> Vec<ReqId> {
        match self {
            KernelTag::Prefill { req } => vec![*req],
            KernelTag::DecodeIter { lanes } => lanes.clone(),
        }
    }
}

/// An engine = a scheduling policy over the shared driver.
pub trait Engine {
    fn name(&self) -> String;
    fn run(&mut self, trace: Vec<Request>) -> Result<RunReport>;
}

/// Shared DES driver state.
pub struct Driver {
    pub sim: SocSim,
    pub bridge: ExecBridge,
    pub states: HashMap<ReqId, ReqState>,
    pending: VecDeque<Request>,
    /// Later turns of multi-turn flows, waiting on their predecessor
    /// (front = next turn to release per flow).
    chains: HashMap<FlowId, VecDeque<Request>>,
    /// Cross-turn KV retention — `None` (full recompute every turn)
    /// unless the engine opted in via [`Driver::enable_session_reuse`].
    pub sessions: Option<SessionCachePool>,
    inflight: HashMap<RunId, KernelTag>,
    pub preemptions: u64,
    pub backfills: u64,
    /// In-flight prefills evicted by the memory governor (KV wiped).
    pub kv_evictions: u64,
    /// Idle retained sessions dropped by the memory governor.
    pub session_evictions: u64,
    /// Kernel-level execution trace (always recorded; events are tiny).
    pub trace: Trace,
    total_requests: usize,
    finished: usize,
}

impl Driver {
    pub fn new(soc: &SocConfig, bridge: ExecBridge, trace: Vec<Request>) -> Self {
        let total_requests = trace.len();
        // Split flows into their opening turn (arrives like any other
        // request) and the held successor chain, ordered by turn index.
        let mut chains: HashMap<FlowId, VecDeque<Request>> = HashMap::new();
        let mut groups: HashMap<FlowId, Vec<Request>> = HashMap::new();
        let mut pending: Vec<Request> = vec![];
        for r in trace {
            match r.flow_id() {
                Some(fid) => groups.entry(fid).or_default().push(r),
                None => pending.push(r),
            }
        }
        for (fid, mut turns) in groups {
            turns.sort_by_key(|r| (r.turn_idx(), r.id));
            let mut dq: VecDeque<Request> = turns.into();
            pending.push(dq.pop_front().unwrap());
            if !dq.is_empty() {
                chains.insert(fid, dq);
            }
        }
        pending.sort_by(|a, b| a.arrival_us.total_cmp(&b.arrival_us).then(a.id.cmp(&b.id)));
        Self {
            sim: SocSim::new(soc),
            bridge,
            states: HashMap::new(),
            total_requests,
            pending: pending.into(),
            chains,
            sessions: None,
            inflight: HashMap::new(),
            preemptions: 0,
            backfills: 0,
            kv_evictions: 0,
            session_evictions: 0,
            trace: Trace::default(),
            finished: 0,
        }
    }

    /// Opt in to cross-turn KV retention: finished flow turns park
    /// their cache (real or logical) in a [`SessionCachePool`] keyed by
    /// flow id, and continuation turns admit with a delta-only plan.
    pub fn enable_session_reuse(&mut self, capacity: usize) {
        self.sessions = Some(SessionCachePool::new(capacity));
    }

    /// Retained idle sessions (for the memory governor's accounting).
    pub fn retained_sessions(&self) -> usize {
        self.sessions.as_ref().map(|p| p.len()).unwrap_or(0)
    }

    pub fn now(&self) -> f64 {
        self.sim.now_us
    }

    pub fn next_arrival_us(&self) -> Option<f64> {
        self.pending.front().map(|r| r.arrival_us)
    }

    fn insert_pending(&mut self, req: Request) {
        let at = self
            .pending
            .partition_point(|r| {
                (r.arrival_us, r.id) <= (req.arrival_us, req.id)
            });
        self.pending.insert(at, req);
    }

    /// Admit every request whose arrival time has passed; returns ids.
    pub fn admit_ready(&mut self, max_chunk: usize) -> Vec<ReqId> {
        let mut out = vec![];
        while self
            .pending
            .front()
            .map(|r| r.arrival_us <= self.now() + 1e-9)
            .unwrap_or(false)
        {
            let req = self.pending.pop_front().unwrap();
            let id = req.id;
            // Continuation turns try the session pool first: a hit
            // seeds the state with the retained KV + prefix length.
            let seed = match (&mut self.sessions, &req.flow) {
                (Some(pool), Some(fb)) if fb.is_continuation() => {
                    pool.take_match(fb.flow_id, &req.prompt)
                }
                _ => None,
            };
            let mut st = self.bridge.init_state_with_session(req, max_chunk, seed);
            st.enqueued_at_us = self.now();
            self.states.insert(id, st);
            out.push(id);
        }
        out
    }

    /// Launch a kernel; marks all tagged requests as running.
    pub fn launch(&mut self, xpu: usize, timing: KernelTiming, reactive: bool, tag: KernelTag) {
        for id in tag.reqs() {
            let st = self.states.get_mut(&id).expect("launch for unknown req");
            assert!(!st.running, "request {id} already has a kernel in flight");
            st.running = true;
            st.preempt_counted = false;
        }
        let run = self.sim.launch(xpu, LaunchSpec { timing, reactive });
        self.inflight.insert(run, tag);
    }

    /// Abort the kernel on `xpu` (scheme-(a) instant preemption).  The
    /// tagged requests stop running; the caller decides what progress
    /// they lose.  Returns the aborted tag.
    pub fn cancel(&mut self, xpu: usize) -> Option<KernelTag> {
        let run = self.sim.cancel(xpu)?;
        let tag = self.inflight.remove(&run).expect("cancelled unknown run");
        for id in tag.reqs() {
            if let Some(st) = self.states.get_mut(&id) {
                st.running = false;
            }
        }
        Some(tag)
    }

    /// Advance virtual time to the next completion or arrival, applying
    /// kernel effects.  Returns false when the run is over (no work, no
    /// arrivals).
    pub fn step(&mut self) -> Result<bool> {
        let next_fin = self.sim.next_event_in().map(|dt| self.now() + dt);
        let next_arr = self.next_arrival_us();
        let target = match (next_fin, next_arr) {
            (Some(f), Some(a)) => f.min(a),
            (Some(f), None) => f,
            (None, Some(a)) => a,
            (None, None) => return Ok(false),
        };
        let completions = self.sim.advance_until(target);
        for c in completions {
            self.apply_completion(&c)?;
        }
        Ok(true)
    }

    fn apply_completion(&mut self, c: &Completion) -> Result<()> {
        let tag = self
            .inflight
            .remove(&c.id)
            .context("completion for unknown run")?;
        let (label, reactive) = match &tag {
            KernelTag::Prefill { req } => (
                format!("prefill:{req}"),
                self.states.get(req).map(|s| s.is_reactive()).unwrap_or(false),
            ),
            KernelTag::DecodeIter { lanes } => (
                format!("decode:b{}", lanes.len()),
                lanes
                    .iter()
                    .any(|id| self.states.get(id).map(|s| s.is_reactive()).unwrap_or(false)),
            ),
        };
        self.trace.record(c.xpu, c.started_us, c.finished_us, label, reactive);
        match &tag {
            KernelTag::Prefill { req } => {
                let mut st = self.states.remove(req).context("unknown req")?;
                st.running = false;
                let done = self.bridge.prefill_kernel_done(&mut st)?;
                if done {
                    st.metrics.first_token_us = Some(c.finished_us);
                    st.enqueued_at_us = c.finished_us;
                }
                if st.phase == Phase::Done {
                    st.metrics.done_us = Some(c.finished_us);
                    self.finished += 1;
                    self.on_request_done(&mut st, c.finished_us);
                }
                self.states.insert(*req, st);
            }
            KernelTag::DecodeIter { lanes } => {
                let mut taken: Vec<ReqState> = lanes
                    .iter()
                    .map(|id| self.states.remove(id).context("unknown lane"))
                    .collect::<Result<_>>()?;
                {
                    let mut refs: Vec<&mut ReqState> = taken.iter_mut().collect();
                    self.bridge.decode_iter_done(&mut refs)?;
                }
                for mut st in taken {
                    st.running = false;
                    if st.phase == Phase::Done {
                        st.metrics.done_us = Some(c.finished_us);
                        self.finished += 1;
                        self.on_request_done(&mut st, c.finished_us);
                    }
                    self.states.insert(st.id(), st);
                }
            }
        }
        Ok(())
    }

    /// Flow bookkeeping at turn completion: retain the session KV for
    /// the successor turn, record the actual conversation, and release
    /// the successor one think-time later with that conversation
    /// stitched over the generator's placeholder prefix.
    fn on_request_done(&mut self, st: &mut ReqState, now_us: f64) {
        let Some(fb) = st.req.flow.clone() else { return };
        let successor = self.chains.get_mut(&fb.flow_id).and_then(|c| c.pop_front());
        if self.chains.get(&fb.flow_id).map(|c| c.is_empty()).unwrap_or(false) {
            self.chains.remove(&fb.flow_id);
        }
        let Some(mut nxt) = successor else {
            // flow over: nothing will reuse this session
            if let Some(pool) = &mut self.sessions {
                pool.drop_session(fb.flow_id);
            }
            return;
        };
        // actual conversation = this turn's prompt + everything generated
        let mut convo = st.req.prompt.clone();
        convo.extend(&st.tokens);
        if let Some(pool) = &mut self.sessions {
            pool.retain(fb.flow_id, st.cache.take(), convo.clone(), st.pos, now_us);
        }
        // stitch: replace the placeholder conversation estimate with
        // the real one (same length by construction: the reply budget
        // is always generated in full)
        let nfb = nxt.flow.as_ref().expect("chained turn has a binding");
        let think = nfb.think_time_us.max(0.0);
        let ds = nfb.delta_start.min(nxt.prompt.len());
        let delta = nxt.prompt.split_off(ds);
        nxt.prompt = convo;
        nxt.prompt.extend(delta);
        // the turn "arrives" when the user finishes thinking
        nxt.arrival_us = now_us + think;
        self.insert_pending(nxt);
    }

    pub fn all_done(&self) -> bool {
        self.pending.is_empty() && self.finished == self.total_requests
    }

    pub fn unfinished(&self) -> usize {
        self.total_requests - self.finished
    }

    /// Requests in a given phase that do not have a kernel in flight.
    pub fn idle_in_phase(&self, phase: Phase) -> Vec<ReqId> {
        let mut v: Vec<ReqId> = self
            .states
            .values()
            .filter(|s| s.phase == phase && !s.running)
            .map(|s| s.id())
            .collect();
        v.sort_unstable();
        v
    }

    pub fn finish(self, engine: String) -> Result<RunReport> {
        if !self.all_done() {
            bail!(
                "{engine}: run ended with {} unfinished requests",
                self.unfinished()
            );
        }
        let makespan_us = self.sim.now_us;
        Ok(RunReport {
            engine,
            reqs: {
                let mut v: Vec<_> =
                    self.states.into_values().map(|s| s.metrics).collect();
                v.sort_by_key(|m| m.id);
                v
            },
            xpus: self.sim.snapshot(),
            makespan_us,
            total_energy_j: self.sim.total_energy_j(),
            peak_power_w: self.sim.peak_power_w,
            mean_bw_gbps: self.sim.mean_bandwidth_gbps(),
            preemptions: self.preemptions,
            backfills: self.backfills,
            kv_evictions: self.kv_evictions,
            session_evictions: self.session_evictions,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;
    use crate::heg::Annotator;
    use crate::soc::XpuModel;
    use crate::workload::Priority;

    fn mk_driver(traces: Vec<Request>) -> (Driver, Annotator) {
        let mut geo = crate::config::llama32_3b();
        geo.n_layers = 2;
        let soc = default_soc();
        let ann = Annotator::new(
            geo.clone(),
            soc.xpus.iter().cloned().map(XpuModel::new).collect(),
        );
        (Driver::new(&soc, ExecBridge::synthetic(geo), traces), ann)
    }

    fn req(id: u64, arrival: f64, plen: usize, maxnew: usize) -> Request {
        Request {
            id,
            priority: Priority::Proactive,
            arrival_us: arrival,
            prompt: vec![3; plen],
            max_new_tokens: maxnew,
            profile: "test".into(),
            flow: None,
        }
    }

    /// A hand-built 3-turn flow whose conversation grows by `delta`
    /// tokens + the full reply budget each turn.
    fn flow_turns(flow_id: u64, first_id: u64, think_us: f64) -> Vec<Request> {
        let (p0, out, delta) = (60usize, 4usize, 30usize);
        let mut turns = vec![];
        let mut prompt = vec![3i32; p0];
        for k in 0..3usize {
            if k > 0 {
                let ds = prompt.len() + out;
                prompt = vec![9; ds]; // placeholder convo (driver re-stitches)
                prompt.extend(vec![3; delta]);
            }
            turns.push(Request {
                id: first_id + k as u64,
                priority: Priority::Reactive,
                arrival_us: 0.0,
                prompt: prompt.clone(),
                max_new_tokens: out,
                profile: "flow".into(),
                flow: Some(crate::workload::FlowBinding {
                    flow_id,
                    turn_idx: k,
                    total_turns: 3,
                    think_time_us: if k == 0 { 0.0 } else { think_us },
                    delta_start: if k == 0 { 0 } else { prompt.len() - delta },
                }),
            });
        }
        turns
    }

    /// A trivial FCFS policy good enough to exercise the driver.
    fn run_fcfs(trace: Vec<Request>) -> RunReport {
        run_fcfs_opts(trace, false)
    }

    fn run_fcfs_opts(trace: Vec<Request>, session_reuse: bool) -> RunReport {
        let (mut d, ann) = mk_driver(trace);
        if session_reuse {
            d.enable_session_reuse(8);
        }
        let npu = d.sim.xpu_index("npu").unwrap();
        let igpu = d.sim.xpu_index("igpu").unwrap();
        loop {
            d.admit_ready(512);
            // NPU: first prefilling request (by id)
            if !d.sim.busy(npu) {
                if let Some(&id) = d.idle_in_phase(Phase::Prefilling).first() {
                    let chunk = *d.states[&id].current_chunk().unwrap();
                    let a = ann.prefill_kernel(&chunk);
                    let t = *a.timing_on(npu);
                    d.launch(npu, t, false, KernelTag::Prefill { req: id });
                }
            }
            // iGPU: batch every idle decoder
            if !d.sim.busy(igpu) {
                let lanes = d.idle_in_phase(Phase::Decoding);
                if !lanes.is_empty() {
                    let avg = d.states[&lanes[0]].pos;
                    let a = ann.decode_iter(lanes.len(), avg);
                    let t = *a.timing_on(igpu);
                    d.launch(igpu, t, false, KernelTag::DecodeIter { lanes });
                }
            }
            if !d.step().unwrap() {
                break;
            }
        }
        d.finish("fcfs-test".into()).unwrap()
    }

    #[test]
    fn driver_completes_single_request() {
        let rep = run_fcfs(vec![req(1, 0.0, 100, 5)]);
        assert_eq!(rep.reqs.len(), 1);
        let m = &rep.reqs[0];
        assert!(m.finished());
        assert_eq!(m.output_tokens, 5);
        assert!(m.ttft_us().unwrap() > 0.0);
        assert!(m.done_us.unwrap() > m.first_token_us.unwrap());
    }

    #[test]
    fn driver_completes_overlapping_requests() {
        let rep = run_fcfs(vec![
            req(1, 0.0, 300, 8),
            req(2, 1000.0, 200, 4),
            req(3, 2000.0, 64, 2),
        ]);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        // arrivals respected: nothing starts before it arrives
        for m in &rep.reqs {
            assert!(m.first_token_us.unwrap() > m.arrival_us);
        }
        assert!(rep.makespan_us > 0.0);
        assert!(rep.total_energy_j > 0.0);
    }

    #[test]
    fn late_arrivals_wake_the_driver() {
        // second request arrives long after the first finishes — the
        // driver must jump the clock to it
        let rep = run_fcfs(vec![req(1, 0.0, 64, 2), req(2, 5e6, 64, 2)]);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 2);
        let m2 = rep.reqs.iter().find(|m| m.id == 2).unwrap();
        assert!(m2.first_token_us.unwrap() >= 5e6);
    }

    #[test]
    fn finish_fails_with_unfinished_requests() {
        let (d, _) = mk_driver(vec![req(1, 0.0, 64, 2)]);
        // never scheduled anything
        assert!(d.finish("broken".into()).is_err());
    }

    #[test]
    fn flow_turns_run_in_order_with_think_time() {
        let think = 50_000.0;
        let rep = run_fcfs(flow_turns(1, 10, think));
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        for w in rep.reqs.windows(2) {
            let (prev, next) = (&w[0], &w[1]);
            // turn k+1 arrives exactly one think-time after turn k ends
            assert!(
                (next.arrival_us - (prev.done_us.unwrap() + think)).abs() < 1e-6,
                "turn {} release", next.id
            );
            assert!(next.first_token_us.unwrap() >= prev.done_us.unwrap() + think);
        }
        // flow identity lands in the metrics
        assert!(rep.reqs.iter().all(|m| m.flow_id == Some(1)));
        assert_eq!(rep.reqs.iter().map(|m| m.turn_idx).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn flow_reuse_prefills_only_deltas() {
        let rep = run_fcfs_opts(flow_turns(1, 10, 10_000.0), true);
        let m: Vec<_> = rep.reqs.iter().collect();
        assert_eq!(m[0].cached_prefix_len, 0);
        assert_eq!(m[0].prefill_tokens, 60);
        // turn 0 ends with pos = 60 + (4 - 1) generated = 63 cached;
        // the stitched turn-1 prompt (94 tokens) extends it exactly
        assert_eq!(m[1].cached_prefix_len, 63, "turn 1 reuses the session KV");
        assert_eq!(m[1].prefill_tokens, 94 - 63);
        assert_eq!(m[2].cached_prefix_len, 94 + 3);
        assert_eq!(m[2].prefill_tokens, 128 - 97);
        assert!(
            rep.recomputed_prefill_tokens()
                < rep.reqs.iter().map(|m| m.input_len).sum::<usize>(),
            "delta prefill must beat full recompute"
        );
    }

    #[test]
    fn flows_without_session_reuse_recompute_everything() {
        let rep = run_fcfs(flow_turns(1, 10, 10_000.0));
        for m in &rep.reqs {
            assert_eq!(m.cached_prefix_len, 0);
            assert_eq!(m.prefill_tokens, m.input_len, "full recompute per turn");
        }
        // head-to-head: the reuse run does strictly less prefill work
        let reuse = run_fcfs_opts(flow_turns(1, 10, 10_000.0), true);
        assert!(reuse.recomputed_prefill_tokens() < rep.recomputed_prefill_tokens());
        assert_eq!(reuse.reused_prefix_tokens(), 63 + 97);
    }

    #[test]
    fn mixed_flow_and_single_shot_traffic_completes() {
        let mut trace = flow_turns(5, 100, 20_000.0);
        trace.push(req(1, 0.0, 80, 3));
        trace.push(req(2, 30_000.0, 50, 2));
        let rep = run_fcfs_opts(trace, true);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 5);
        // single-shot requests never touch the session pool
        for m in rep.reqs.iter().filter(|m| m.flow_id.is_none()) {
            assert_eq!(m.cached_prefix_len, 0);
        }
    }
}
