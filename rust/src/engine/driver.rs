//! The shared DES event loop: every engine (Agent.xpu and the
//! baselines) is a scheduling policy plugged into this driver.
//!
//! Responsibilities: incremental request submission, arrival admission,
//! kernel-completion effects (via [`ExecBridge`]), cancellation,
//! lifecycle metrics (TTFT at prefill completion, completion time at
//! token budget), the [`EngineEvent`] stream, and the final
//! [`RunReport`].
//!
//! Clock abstraction (DESIGN.md §7): the driver runs against an
//! [`EngineClock`].  Under `Virtual` it is the classic DES — arrivals
//! honored at their trace times, timestamps in virtual µs.  Under
//! `Wall` submissions are stamped on arrival and admitted immediately,
//! kernel *ordering* still comes from the virtual SoC (so the serving
//! path makes exactly the coordinator's decisions), and lifecycle
//! timestamps are measured wall µs.
//!
//! Flow-level sessions (DESIGN.md §3): the driver owns the workload
//! semantics of multi-turn flows — a turn after the first is *held*
//! until its predecessor completes, released one think-time later with
//! the actual generated conversation stitched into its prompt.  Every
//! engine gets this for free (so baselines see identical flow traffic);
//! engines that additionally call [`Driver::enable_session_reuse`] get
//! cross-turn KV retention — turn *k+1* then prefills only its delta
//! tokens instead of recomputing the whole conversation prefix.  A
//! flow's opening turn must carry `turn_idx == 0`; under a wall clock a
//! continuation turn submitted after its predecessor completed is
//! admitted directly (the online-session path the server uses).

use std::collections::{BTreeMap, HashMap, VecDeque};

use anyhow::{Context, Result, bail};

use crate::config::SocConfig;
use crate::metrics::{ReqMetrics, RunReport};
use crate::runtime::SessionCachePool;
use crate::soc::{Completion, KernelTiming, LaunchSpec, RunId, SocSim};
use crate::workload::{FlowId, ReqId, Request};

use super::bridge::ExecBridge;
use super::core_api::{EngineClock, EngineEvent};
use super::reqstate::{Phase, ReqState};
use crate::trace::Trace;

/// Semantic meaning of an in-flight kernel.
#[derive(Debug, Clone, PartialEq)]
pub enum KernelTag {
    /// The next prefill kernel (st.chunk_idx, st.layer_idx) of `req`.
    Prefill { req: ReqId },
    /// One batched decode iteration over `lanes`.
    DecodeIter { lanes: Vec<ReqId> },
}

impl KernelTag {
    pub fn reqs(&self) -> Vec<ReqId> {
        match self {
            KernelTag::Prefill { req } => vec![*req],
            KernelTag::DecodeIter { lanes } => lanes.clone(),
        }
    }
}

/// Wall-clock runs bound their history so a long-lived server never
/// grows without limit: `retired` keeps the most recent window of
/// request metrics (older ones have already been streamed as events),
/// and `flow_done` keeps watermarks for the most recent flows (ids are
/// monotonic on the serving path, so the smallest keys are oldest).
const WALL_RETIRED_MAX: usize = 8_192;
const FLOW_DONE_MAX: usize = 65_536;

/// Shared DES driver state.
pub struct Driver {
    pub sim: SocSim,
    pub bridge: ExecBridge,
    clock: EngineClock,
    pub states: HashMap<ReqId, ReqState>,
    pending: VecDeque<Request>,
    /// Later turns of multi-turn flows, waiting on their predecessor
    /// (front = next turn to release per flow).
    chains: HashMap<FlowId, VecDeque<Request>>,
    /// Completed turns per flow (the next turn index that may admit
    /// directly) — lets a wall-clock continuation submitted *after* its
    /// predecessor finished skip the hold queue.  Ordered so the oldest
    /// flows can be shed once `FLOW_DONE_MAX` is exceeded.
    flow_done: BTreeMap<FlowId, usize>,
    /// Cross-turn KV retention — `None` (full recompute every turn)
    /// unless the engine opted in via [`Driver::enable_session_reuse`].
    pub sessions: Option<SessionCachePool>,
    inflight: HashMap<RunId, KernelTag>,
    /// Streaming events since the last [`Driver::take_events`].
    events: Vec<EngineEvent>,
    /// Metrics of retired requests (cancelled, or completed under a
    /// wall clock) whose live state has been dropped.
    retired: Vec<ReqMetrics>,
    pub preemptions: u64,
    pub backfills: u64,
    /// In-flight prefills evicted by the memory governor (KV wiped).
    pub kv_evictions: u64,
    /// Idle retained sessions dropped by the memory governor.
    pub session_evictions: u64,
    /// Requests aborted via [`Driver::cancel_request`].
    pub cancellations: u64,
    /// Kernel-level execution trace (always recorded; events are tiny).
    pub trace: Trace,
    total_requests: usize,
    finished: usize,
}

impl Driver {
    /// Open an empty driver against a clock; feed it with
    /// [`Driver::submit`].
    pub fn open(soc: &SocConfig, bridge: ExecBridge, clock: EngineClock) -> Self {
        Self {
            sim: SocSim::new(soc),
            bridge,
            clock,
            states: HashMap::new(),
            total_requests: 0,
            pending: VecDeque::new(),
            chains: HashMap::new(),
            flow_done: BTreeMap::new(),
            sessions: None,
            inflight: HashMap::new(),
            events: vec![],
            retired: vec![],
            preemptions: 0,
            backfills: 0,
            kv_evictions: 0,
            session_evictions: 0,
            cancellations: 0,
            trace: Trace::default(),
            finished: 0,
        }
    }

    /// Classic batch construction: a virtual-clock driver preloaded
    /// with a whole trace.
    pub fn new(soc: &SocConfig, bridge: ExecBridge, trace: Vec<Request>) -> Self {
        let mut d = Self::open(soc, bridge, EngineClock::Virtual);
        for r in trace {
            d.submit(r);
        }
        d
    }

    /// Feed one request.  Flow turns after the first are held behind
    /// their predecessor; everything else queues by arrival time.
    /// Under a wall clock the arrival is re-stamped to *now*.
    pub fn submit(&mut self, mut req: Request) {
        if self.clock.is_wall() {
            req.arrival_us = self.now();
        }
        self.total_requests += 1;
        let held = match &req.flow {
            Some(fb) if fb.turn_idx > 0 => {
                fb.turn_idx > self.flow_done.get(&fb.flow_id).copied().unwrap_or(0)
            }
            _ => false,
        };
        if held {
            let fid = req.flow_id().expect("held turn has a flow");
            let key = (req.turn_idx(), req.id);
            let chain = self.chains.entry(fid).or_default();
            let at = chain.partition_point(|r| (r.turn_idx(), r.id) <= key);
            chain.insert(at, req);
        } else {
            self.insert_pending(req);
        }
    }

    /// Opt in to cross-turn KV retention: finished flow turns park
    /// their cache (real or logical) in a [`SessionCachePool`] keyed by
    /// flow id, and continuation turns admit with a delta-only plan.
    pub fn enable_session_reuse(&mut self, capacity: usize) {
        self.sessions = Some(SessionCachePool::new(capacity));
    }

    /// Retained idle sessions (for the memory governor's accounting).
    pub fn retained_sessions(&self) -> usize {
        self.sessions.as_ref().map(|p| p.len()).unwrap_or(0)
    }

    /// Current time in the run's clock domain (virtual or wall µs).
    pub fn now(&self) -> f64 {
        match &self.clock {
            EngineClock::Virtual => self.sim.now_us,
            EngineClock::Wall { t0 } => t0.elapsed().as_secs_f64() * 1e6,
        }
    }

    /// Map a virtual completion instant into the run's clock domain.
    fn stamp(&self, virtual_us: f64) -> f64 {
        match &self.clock {
            EngineClock::Virtual => virtual_us,
            EngineClock::Wall { t0 } => t0.elapsed().as_secs_f64() * 1e6,
        }
    }

    pub fn next_arrival_us(&self) -> Option<f64> {
        self.pending.front().map(|r| r.arrival_us)
    }

    /// Drain the events accumulated since the last call.
    pub fn take_events(&mut self) -> Vec<EngineEvent> {
        std::mem::take(&mut self.events)
    }

    fn insert_pending(&mut self, req: Request) {
        let at = self
            .pending
            .partition_point(|r| {
                (r.arrival_us, r.id) <= (req.arrival_us, req.id)
            });
        self.pending.insert(at, req);
    }

    /// Admit every request whose arrival time has passed; returns ids.
    pub fn admit_ready(&mut self, max_chunk: usize) -> Vec<ReqId> {
        let mut out = vec![];
        while self
            .pending
            .front()
            .map(|r| r.arrival_us <= self.now() + 1e-9)
            .unwrap_or(false)
        {
            let req = self.pending.pop_front().unwrap();
            let id = req.id;
            // Continuation turns try the session pool first: a hit
            // seeds the state with the retained KV + prefix length.
            let seed = match (&mut self.sessions, &req.flow) {
                (Some(pool), Some(fb)) if fb.is_continuation() => {
                    pool.take_match(fb.flow_id, &req.prompt)
                }
                _ => None,
            };
            let mut st = self.bridge.init_state_with_session(req, max_chunk, seed);
            st.enqueued_at_us = self.now();
            self.states.insert(id, st);
            self.events.push(EngineEvent::Admitted { id, at_us: self.now() });
            out.push(id);
        }
        out
    }

    /// Launch a kernel; marks all tagged requests as running.
    pub fn launch(&mut self, xpu: usize, timing: KernelTiming, reactive: bool, tag: KernelTag) {
        for id in tag.reqs() {
            let st = self.states.get_mut(&id).expect("launch for unknown req");
            assert!(!st.running, "request {id} already has a kernel in flight");
            st.running = true;
            st.preempt_counted = false;
        }
        let run = self.sim.launch(xpu, LaunchSpec { timing, reactive });
        self.inflight.insert(run, tag);
    }

    /// Abort the kernel on `xpu` (scheme-(a) instant preemption).  The
    /// tagged requests stop running; the caller decides what progress
    /// they lose.  Returns the aborted tag.
    pub fn cancel(&mut self, xpu: usize) -> Option<KernelTag> {
        let run = self.sim.cancel(xpu)?;
        let tag = self.inflight.remove(&run).expect("cancelled unknown run");
        for id in tag.reqs() {
            if let Some(st) = self.states.get_mut(&id) {
                st.running = false;
            }
        }
        Some(tag)
    }

    /// Preemption accounting hook: bump the counter and stream the
    /// event (the caller decides *who* was preempted and why).
    pub fn note_preemption(&mut self, id: ReqId) {
        self.preemptions += 1;
        self.events.push(EngineEvent::Preempted { id, at_us: self.now() });
    }

    /// Memory-governor accounting: an in-flight prefill lost its KV.
    pub fn note_kv_eviction(&mut self, id: ReqId) {
        self.kv_evictions += 1;
        self.events.push(EngineEvent::KvEvicted { id, at_us: self.now() });
    }

    /// Memory-governor accounting: an idle retained session was shed.
    pub fn note_session_eviction(&mut self, flow_id: FlowId) {
        self.session_evictions += 1;
        self.events
            .push(EngineEvent::SessionEvicted { flow_id, at_us: self.now() });
    }

    /// Abort a request wherever it is: still queued, held behind a flow
    /// predecessor, waiting at a kernel boundary, or mid-kernel.  A
    /// lone prefill kernel is aborted immediately; a lane of a batched
    /// decode retires at the iteration boundary (the other lanes keep
    /// their tokens).  The request's KV is freed and chained successor
    /// turns that can no longer be stitched are cancelled with it.
    /// Returns false when the id is unknown or already finished.
    pub fn cancel_request(&mut self, id: ReqId) -> bool {
        // not yet admitted
        if let Some(i) = self.pending.iter().position(|r| r.id == id) {
            let req = self.pending.remove(i).unwrap();
            let fid = req.flow_id();
            self.retire_cancelled_request(req);
            if let Some(fid) = fid {
                self.cancel_flow_successors(fid);
            }
            return true;
        }
        // held behind a flow predecessor
        if let Some(fid) = self
            .chains
            .iter()
            .find(|(_, c)| c.iter().any(|r| r.id == id))
            .map(|(fid, _)| *fid)
        {
            let mut chain = self.chains.remove(&fid).unwrap();
            let i = chain.iter().position(|r| r.id == id).unwrap();
            let mut rest = chain.split_off(i);
            let turn = rest.pop_front().unwrap();
            self.retire_cancelled_request(turn);
            // Placeholder successors (delta_start > 0) can never be
            // stitched without this turn — they die with it.  Self-
            // contained successors (the serving path) stay held and
            // release in order as the surviving turns complete; they
            // merely miss the prefix cache.  Earlier turns are
            // untouched (their predecessors are still alive).
            let placeholder = rest
                .front()
                .and_then(|r| r.flow.as_ref())
                .map(|f| f.delta_start > 0)
                .unwrap_or(false);
            if placeholder {
                for req in rest {
                    self.retire_cancelled_request(req);
                }
            } else {
                chain.append(&mut rest);
            }
            if !chain.is_empty() {
                self.chains.insert(fid, chain);
            }
            return true;
        }
        // live serving state
        let (running, done, already, fid) = match self.states.get(&id) {
            Some(st) => (
                st.running,
                st.phase == Phase::Done,
                st.cancelled,
                st.req.flow_id(),
            ),
            None => return false,
        };
        if done || already {
            return false;
        }
        if running {
            let prefill_run = self.inflight.iter().find_map(|(run, tag)| match tag {
                KernelTag::Prefill { req } if *req == id => Some(*run),
                _ => None,
            });
            match prefill_run {
                Some(run) => {
                    // lone prefill kernel: abort it at once
                    if let Some(xpu) = self.sim.xpu_of(run) {
                        self.cancel(xpu);
                    }
                }
                None => {
                    // mid decode batch: the iteration finishes, the
                    // lane retires at the boundary
                    self.states.get_mut(&id).unwrap().cancelled = true;
                    if let Some(fid) = fid {
                        self.cancel_flow_successors(fid);
                    }
                    return true;
                }
            }
        }
        let st = self.states.remove(&id).unwrap();
        self.retire_cancelled_state(st);
        if let Some(fid) = fid {
            self.cancel_flow_successors(fid);
        }
        true
    }

    /// A flow turn died: successor turns whose prompts are generator
    /// placeholders (`delta_start > 0`) can never be stitched without
    /// it — they die too, and the retained session is dropped.
    /// Self-contained successors (`delta_start == 0`, the serving path)
    /// are released instead: their session prefix match simply fails
    /// and they recompute.
    fn cancel_flow_successors(&mut self, fid: FlowId) {
        let Some(mut chain) = self.chains.remove(&fid) else { return };
        let placeholder = chain
            .front()
            .and_then(|r| r.flow.as_ref())
            .map(|f| f.delta_start > 0)
            .unwrap_or(false);
        if placeholder {
            for req in chain {
                self.retire_cancelled_request(req);
            }
            if let Some(pool) = &mut self.sessions {
                pool.drop_session(fid);
            }
            return;
        }
        let now = self.now();
        if let Some(mut nxt) = chain.pop_front() {
            let think = nxt
                .flow
                .as_ref()
                .map(|f| f.think_time_us.max(0.0))
                .unwrap_or(0.0);
            nxt.arrival_us = now + think;
            self.insert_pending(nxt);
        }
        if !chain.is_empty() {
            self.chains.insert(fid, chain);
        }
    }

    fn retire_cancelled_state(&mut self, mut st: ReqState) {
        st.metrics.cancelled = true;
        let flow = st.req.flow.as_ref().map(|f| (f.flow_id, f.turn_idx));
        let m = st.metrics.clone();
        self.push_cancelled(m, flow);
        // st — and its KV, if any — drops here
    }

    fn retire_cancelled_request(&mut self, req: Request) {
        let m = ReqMetrics {
            id: req.id,
            priority: req.priority,
            profile: req.profile.clone(),
            flow_id: req.flow_id(),
            turn_idx: req.turn_idx(),
            arrival_us: req.arrival_us,
            first_token_us: None,
            done_us: None,
            input_len: req.prompt_len(),
            output_tokens: 0,
            cached_prefix_len: 0,
            prefill_tokens: 0,
            cancelled: true,
        };
        let flow = req.flow.as_ref().map(|f| (f.flow_id, f.turn_idx));
        self.push_cancelled(m, flow);
    }

    fn push_cancelled(&mut self, m: ReqMetrics, flow: Option<(FlowId, usize)>) {
        if let Some((fid, turn)) = flow {
            self.advance_flow_done(fid, turn + 1);
        }
        self.events
            .push(EngineEvent::Cancelled { id: m.id, at_us: self.now() });
        self.cancellations += 1;
        self.finished += 1;
        self.retire_metrics(m);
    }

    /// Record retired metrics.  Wall-clock runs keep only the most
    /// recent `WALL_RETIRED_MAX` (older ones were already streamed as
    /// events), so a long-lived server's history stays bounded.
    fn retire_metrics(&mut self, m: ReqMetrics) {
        self.retired.push(m);
        if self.clock.is_wall() && self.retired.len() > WALL_RETIRED_MAX {
            // amortized: shed the older half of the window at once
            let _ = self.retired.drain(..WALL_RETIRED_MAX / 2);
        }
    }

    /// Bump a flow's completed-turn watermark, shedding the oldest
    /// watermarks beyond `FLOW_DONE_MAX` (serving-path flow ids are
    /// monotonic; a shed flow's next call merely starts cold).
    fn advance_flow_done(&mut self, fid: FlowId, next_turn: usize) {
        let e = self.flow_done.entry(fid).or_insert(0);
        *e = (*e).max(next_turn);
        while self.flow_done.len() > FLOW_DONE_MAX {
            let _ = self.flow_done.pop_first();
        }
    }

    /// Advance to the next completion or arrival, applying kernel
    /// effects.  Returns false when the run is idle: under a virtual
    /// clock that means the run is over (no work, no arrivals); under a
    /// wall clock new submissions make it runnable again.
    pub fn step(&mut self) -> Result<bool> {
        if self.clock.is_wall() {
            // Wall mode: virtual durations only *order* the in-flight
            // kernels; their effects execute now, stamped in wall time.
            if let Some(dt) = self.sim.next_event_in() {
                let target = self.sim.now_us + dt;
                let completions = self.sim.advance_until(target);
                for c in completions {
                    self.apply_completion(&c)?;
                }
                return Ok(true);
            }
            // nothing in flight: runnable iff an arrival is already due
            let due = self
                .pending
                .front()
                .map(|r| r.arrival_us <= self.now() + 1e-9)
                .unwrap_or(false);
            return Ok(due);
        }
        let next_fin = self.sim.next_event_in().map(|dt| self.now() + dt);
        let next_arr = self.next_arrival_us();
        let target = match (next_fin, next_arr) {
            (Some(f), Some(a)) => f.min(a),
            (Some(f), None) => f,
            (None, Some(a)) => a,
            (None, None) => return Ok(false),
        };
        let completions = self.sim.advance_until(target);
        for c in completions {
            self.apply_completion(&c)?;
        }
        Ok(true)
    }

    fn apply_completion(&mut self, c: &Completion) -> Result<()> {
        let tag = self
            .inflight
            .remove(&c.id)
            .context("completion for unknown run")?;
        // The kernel trace is a simulation artifact (Gantt figures,
        // invariant checks); a long-lived wall-clock server must not
        // accumulate one event per kernel forever.
        if !self.clock.is_wall() {
            let (label, reactive) = match &tag {
                KernelTag::Prefill { req } => (
                    format!("prefill:{req}"),
                    self.states.get(req).map(|s| s.is_reactive()).unwrap_or(false),
                ),
                KernelTag::DecodeIter { lanes } => (
                    format!("decode:b{}", lanes.len()),
                    lanes.iter().any(|id| {
                        self.states.get(id).map(|s| s.is_reactive()).unwrap_or(false)
                    }),
                ),
            };
            self.trace.record(c.xpu, c.started_us, c.finished_us, label, reactive);
        }
        // lifecycle timestamps in the run's clock domain
        let t = self.stamp(c.finished_us);
        match &tag {
            KernelTag::Prefill { req } => {
                let mut st = self.states.remove(req).context("unknown req")?;
                st.running = false;
                let done = self.bridge.prefill_kernel_done(&mut st)?;
                if done {
                    st.metrics.first_token_us = Some(t);
                    st.enqueued_at_us = t;
                    if let Some(&tok) = st.tokens.last() {
                        self.events.push(EngineEvent::TokenEmitted {
                            id: *req,
                            token: tok,
                            n: st.tokens.len(),
                            at_us: t,
                        });
                    }
                }
                if st.phase == Phase::Done {
                    self.complete(st, t);
                } else {
                    self.states.insert(*req, st);
                }
            }
            KernelTag::DecodeIter { lanes } => {
                let mut taken: Vec<ReqState> = lanes
                    .iter()
                    .map(|id| self.states.remove(id).context("unknown lane"))
                    .collect::<Result<_>>()?;
                {
                    let mut refs: Vec<&mut ReqState> = taken.iter_mut().collect();
                    self.bridge.decode_iter_done(&mut refs)?;
                }
                for mut st in taken {
                    st.running = false;
                    if st.cancelled {
                        // deferred lane cancellation: the iteration is
                        // over, the KV can go
                        self.retire_cancelled_state(st);
                        continue;
                    }
                    if let Some(&tok) = st.tokens.last() {
                        self.events.push(EngineEvent::TokenEmitted {
                            id: st.id(),
                            token: tok,
                            n: st.tokens.len(),
                            at_us: t,
                        });
                    }
                    if st.phase == Phase::Done {
                        self.complete(st, t);
                    } else {
                        self.states.insert(st.id(), st);
                    }
                }
            }
        }
        Ok(())
    }

    /// Request completion: stamp metrics, run flow bookkeeping, stream
    /// `TurnDone`, and either keep the state for the final report
    /// (virtual clock) or retire it so a long-lived server's working
    /// set stays bounded (wall clock).
    fn complete(&mut self, mut st: ReqState, t: f64) {
        st.metrics.done_us = Some(t);
        self.finished += 1;
        self.on_request_done(&mut st, t);
        self.events.push(EngineEvent::TurnDone {
            id: st.id(),
            at_us: t,
            arrival_us: st.metrics.arrival_us,
            first_token_us: st.metrics.first_token_us.unwrap_or(t),
            tokens: st.tokens.clone(),
            cached_prefix: st.cached_prefix_len,
        });
        if self.clock.is_wall() {
            self.retire_metrics(st.metrics.clone());
        } else {
            self.states.insert(st.id(), st);
        }
    }

    /// Flow bookkeeping at turn completion: retain the session KV for
    /// the successor turn, record the actual conversation, and release
    /// the successor one think-time later with that conversation
    /// stitched over the generator's placeholder prefix.
    fn on_request_done(&mut self, st: &mut ReqState, now_us: f64) {
        let Some(fb) = st.req.flow.clone() else { return };
        self.advance_flow_done(fb.flow_id, fb.turn_idx + 1);
        let successor = self.chains.get_mut(&fb.flow_id).and_then(|c| c.pop_front());
        if self.chains.get(&fb.flow_id).map(|c| c.is_empty()).unwrap_or(false) {
            self.chains.remove(&fb.flow_id);
        }
        let Some(mut nxt) = successor else {
            // Wall clock: a later call of this session may still arrive
            // online — retain while the binding expects more turns.
            // Virtual clock: the observed chain *is* the flow; nothing
            // will reuse this session.
            let expects_more =
                self.clock.is_wall() && fb.turn_idx + 1 < fb.total_turns;
            if expects_more {
                let mut convo = st.req.prompt.clone();
                convo.extend(&st.tokens);
                if let Some(pool) = &mut self.sessions {
                    pool.retain(fb.flow_id, st.cache.take(), convo, st.pos, now_us);
                }
            } else if let Some(pool) = &mut self.sessions {
                pool.drop_session(fb.flow_id);
            }
            return;
        };
        // actual conversation = this turn's prompt + everything generated
        let mut convo = st.req.prompt.clone();
        convo.extend(&st.tokens);
        if let Some(pool) = &mut self.sessions {
            pool.retain(fb.flow_id, st.cache.take(), convo.clone(), st.pos, now_us);
        }
        let nfb = nxt.flow.as_ref().expect("chained turn has a binding");
        let think = nfb.think_time_us.max(0.0);
        // stitch: replace the placeholder conversation estimate with
        // the real one (same length by construction: the reply budget
        // is always generated in full).  A self-contained successor
        // (delta_start == 0 — the online-session path) already carries
        // its real prompt and is released as-is.
        if nfb.delta_start > 0 {
            let ds = nfb.delta_start.min(nxt.prompt.len());
            let delta = nxt.prompt.split_off(ds);
            nxt.prompt = convo;
            nxt.prompt.extend(delta);
        }
        // the turn "arrives" when the user finishes thinking
        nxt.arrival_us = now_us + think;
        self.insert_pending(nxt);
    }

    pub fn all_done(&self) -> bool {
        self.pending.is_empty() && self.finished == self.total_requests
    }

    pub fn unfinished(&self) -> usize {
        self.total_requests - self.finished
    }

    /// Requests in a given phase that do not have a kernel in flight.
    pub fn idle_in_phase(&self, phase: Phase) -> Vec<ReqId> {
        let mut v: Vec<ReqId> = self
            .states
            .values()
            .filter(|s| s.phase == phase && !s.running)
            .map(|s| s.id())
            .collect();
        v.sort_unstable();
        v
    }

    pub fn finish(self, engine: String) -> Result<RunReport> {
        if !self.all_done() {
            bail!(
                "{engine}: run ended with {} unfinished requests",
                self.unfinished()
            );
        }
        let makespan_us = self.now();
        Ok(RunReport {
            engine,
            reqs: {
                let mut v: Vec<_> =
                    self.states.into_values().map(|s| s.metrics).collect();
                v.extend(self.retired);
                v.sort_by_key(|m| m.id);
                v
            },
            xpus: self.sim.snapshot(),
            makespan_us,
            total_energy_j: self.sim.total_energy_j(),
            peak_power_w: self.sim.peak_power_w,
            mean_bw_gbps: self.sim.mean_bandwidth_gbps(),
            preemptions: self.preemptions,
            backfills: self.backfills,
            kv_evictions: self.kv_evictions,
            session_evictions: self.session_evictions,
            cancellations: self.cancellations,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;
    use crate::heg::Annotator;
    use crate::soc::XpuModel;
    use crate::workload::Priority;

    fn mk_driver(traces: Vec<Request>) -> (Driver, Annotator) {
        let mut geo = crate::config::llama32_3b();
        geo.n_layers = 2;
        let soc = default_soc();
        let ann = Annotator::new(
            geo.clone(),
            soc.xpus.iter().cloned().map(XpuModel::new).collect(),
        );
        (Driver::new(&soc, ExecBridge::synthetic(geo), traces), ann)
    }

    fn req(id: u64, arrival: f64, plen: usize, maxnew: usize) -> Request {
        Request {
            id,
            priority: Priority::Proactive,
            arrival_us: arrival,
            prompt: vec![3; plen],
            max_new_tokens: maxnew,
            profile: "test".into(),
            flow: None,
        }
    }

    /// A hand-built 3-turn flow whose conversation grows by `delta`
    /// tokens + the full reply budget each turn.
    fn flow_turns(flow_id: u64, first_id: u64, think_us: f64) -> Vec<Request> {
        let (p0, out, delta) = (60usize, 4usize, 30usize);
        let mut turns = vec![];
        let mut prompt = vec![3i32; p0];
        for k in 0..3usize {
            if k > 0 {
                let ds = prompt.len() + out;
                prompt = vec![9; ds]; // placeholder convo (driver re-stitches)
                prompt.extend(vec![3; delta]);
            }
            turns.push(Request {
                id: first_id + k as u64,
                priority: Priority::Reactive,
                arrival_us: 0.0,
                prompt: prompt.clone(),
                max_new_tokens: out,
                profile: "flow".into(),
                flow: Some(crate::workload::FlowBinding {
                    flow_id,
                    turn_idx: k,
                    total_turns: 3,
                    think_time_us: if k == 0 { 0.0 } else { think_us },
                    delta_start: if k == 0 { 0 } else { prompt.len() - delta },
                }),
            });
        }
        turns
    }

    /// A trivial FCFS policy good enough to exercise the driver.
    fn run_fcfs(trace: Vec<Request>) -> RunReport {
        run_fcfs_opts(trace, false)
    }

    fn drive_fcfs(d: &mut Driver, ann: &Annotator) {
        let npu = d.sim.xpu_index("npu").unwrap();
        let igpu = d.sim.xpu_index("igpu").unwrap();
        loop {
            d.admit_ready(512);
            // NPU: first prefilling request (by id)
            if !d.sim.busy(npu) {
                if let Some(&id) = d.idle_in_phase(Phase::Prefilling).first() {
                    let chunk = *d.states[&id].current_chunk().unwrap();
                    let a = ann.prefill_kernel(&chunk);
                    let t = *a.timing_on(npu);
                    d.launch(npu, t, false, KernelTag::Prefill { req: id });
                }
            }
            // iGPU: batch every idle decoder
            if !d.sim.busy(igpu) {
                let lanes = d.idle_in_phase(Phase::Decoding);
                if !lanes.is_empty() {
                    let avg = d.states[&lanes[0]].pos;
                    let a = ann.decode_iter(lanes.len(), avg);
                    let t = *a.timing_on(igpu);
                    d.launch(igpu, t, false, KernelTag::DecodeIter { lanes });
                }
            }
            if !d.step().unwrap() {
                break;
            }
        }
    }

    fn run_fcfs_opts(trace: Vec<Request>, session_reuse: bool) -> RunReport {
        let (mut d, ann) = mk_driver(trace);
        if session_reuse {
            d.enable_session_reuse(8);
        }
        drive_fcfs(&mut d, &ann);
        d.finish("fcfs-test".into()).unwrap()
    }

    #[test]
    fn driver_completes_single_request() {
        let rep = run_fcfs(vec![req(1, 0.0, 100, 5)]);
        assert_eq!(rep.reqs.len(), 1);
        let m = &rep.reqs[0];
        assert!(m.finished());
        assert_eq!(m.output_tokens, 5);
        assert!(m.ttft_us().unwrap() > 0.0);
        assert!(m.done_us.unwrap() > m.first_token_us.unwrap());
    }

    #[test]
    fn driver_completes_overlapping_requests() {
        let rep = run_fcfs(vec![
            req(1, 0.0, 300, 8),
            req(2, 1000.0, 200, 4),
            req(3, 2000.0, 64, 2),
        ]);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        // arrivals respected: nothing starts before it arrives
        for m in &rep.reqs {
            assert!(m.first_token_us.unwrap() > m.arrival_us);
        }
        assert!(rep.makespan_us > 0.0);
        assert!(rep.total_energy_j > 0.0);
    }

    #[test]
    fn late_arrivals_wake_the_driver() {
        // second request arrives long after the first finishes — the
        // driver must jump the clock to it
        let rep = run_fcfs(vec![req(1, 0.0, 64, 2), req(2, 5e6, 64, 2)]);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 2);
        let m2 = rep.reqs.iter().find(|m| m.id == 2).unwrap();
        assert!(m2.first_token_us.unwrap() >= 5e6);
    }

    #[test]
    fn finish_fails_with_unfinished_requests() {
        let (d, _) = mk_driver(vec![req(1, 0.0, 64, 2)]);
        // never scheduled anything
        assert!(d.finish("broken".into()).is_err());
    }

    #[test]
    fn flow_turns_run_in_order_with_think_time() {
        let think = 50_000.0;
        let rep = run_fcfs(flow_turns(1, 10, think));
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 3);
        for w in rep.reqs.windows(2) {
            let (prev, next) = (&w[0], &w[1]);
            // turn k+1 arrives exactly one think-time after turn k ends
            assert!(
                (next.arrival_us - (prev.done_us.unwrap() + think)).abs() < 1e-6,
                "turn {} release", next.id
            );
            assert!(next.first_token_us.unwrap() >= prev.done_us.unwrap() + think);
        }
        // flow identity lands in the metrics
        assert!(rep.reqs.iter().all(|m| m.flow_id == Some(1)));
        assert_eq!(rep.reqs.iter().map(|m| m.turn_idx).collect::<Vec<_>>(), vec![0, 1, 2]);
    }

    #[test]
    fn flow_reuse_prefills_only_deltas() {
        let rep = run_fcfs_opts(flow_turns(1, 10, 10_000.0), true);
        let m: Vec<_> = rep.reqs.iter().collect();
        assert_eq!(m[0].cached_prefix_len, 0);
        assert_eq!(m[0].prefill_tokens, 60);
        // turn 0 ends with pos = 60 + (4 - 1) generated = 63 cached;
        // the stitched turn-1 prompt (94 tokens) extends it exactly
        assert_eq!(m[1].cached_prefix_len, 63, "turn 1 reuses the session KV");
        assert_eq!(m[1].prefill_tokens, 94 - 63);
        assert_eq!(m[2].cached_prefix_len, 94 + 3);
        assert_eq!(m[2].prefill_tokens, 128 - 97);
        assert!(
            rep.recomputed_prefill_tokens()
                < rep.reqs.iter().map(|m| m.input_len).sum::<usize>(),
            "delta prefill must beat full recompute"
        );
    }

    #[test]
    fn flows_without_session_reuse_recompute_everything() {
        let rep = run_fcfs(flow_turns(1, 10, 10_000.0));
        for m in &rep.reqs {
            assert_eq!(m.cached_prefix_len, 0);
            assert_eq!(m.prefill_tokens, m.input_len, "full recompute per turn");
        }
        // head-to-head: the reuse run does strictly less prefill work
        let reuse = run_fcfs_opts(flow_turns(1, 10, 10_000.0), true);
        assert!(reuse.recomputed_prefill_tokens() < rep.recomputed_prefill_tokens());
        assert_eq!(reuse.reused_prefix_tokens(), 63 + 97);
    }

    #[test]
    fn mixed_flow_and_single_shot_traffic_completes() {
        let mut trace = flow_turns(5, 100, 20_000.0);
        trace.push(req(1, 0.0, 80, 3));
        trace.push(req(2, 30_000.0, 50, 2));
        let rep = run_fcfs_opts(trace, true);
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 5);
        // single-shot requests never touch the session pool
        for m in rep.reqs.iter().filter(|m| m.flow_id.is_none()) {
            assert_eq!(m.cached_prefix_len, 0);
        }
    }

    #[test]
    fn events_stream_tokens_and_completions() {
        let (mut d, ann) = mk_driver(vec![req(1, 0.0, 100, 5), req(2, 500.0, 60, 3)]);
        drive_fcfs(&mut d, &ann);
        let evs = d.take_events();
        use crate::engine::EngineEvent::{Admitted, TokenEmitted, TurnDone};
        let admitted = evs.iter().filter(|e| matches!(e, Admitted { .. })).count();
        let tokens = evs.iter().filter(|e| matches!(e, TokenEmitted { .. })).count();
        let done = evs.iter().filter(|e| matches!(e, TurnDone { .. })).count();
        assert_eq!(admitted, 2);
        assert_eq!(tokens, 5 + 3, "one event per generated token");
        assert_eq!(done, 2);
        // the TurnDone carries the full token vector and timestamps
        let td = evs
            .iter()
            .find_map(|e| match e {
                TurnDone { id: 1, tokens, first_token_us, at_us, arrival_us, .. } => {
                    Some((tokens.clone(), *first_token_us, *at_us, *arrival_us))
                }
                _ => None,
            })
            .unwrap();
        assert_eq!(td.0.len(), 5);
        assert!(td.3 <= td.1 && td.1 <= td.2);
        let rep = d.finish("fcfs-test".into()).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 2);
    }

    #[test]
    fn cancel_pending_request_never_admits() {
        let (mut d, ann) = mk_driver(vec![req(1, 0.0, 80, 3), req(2, 50_000.0, 80, 3)]);
        assert!(d.cancel_request(2), "queued request is cancellable");
        assert!(!d.cancel_request(2), "double cancel is a no-op");
        drive_fcfs(&mut d, &ann);
        let evs = d.take_events();
        assert!(evs.iter().any(|e| matches!(e, EngineEvent::Cancelled { id: 2, .. })));
        let rep = d.finish("fcfs-test".into()).unwrap();
        assert_eq!(rep.cancellations, 1);
        let m2 = rep.reqs.iter().find(|m| m.id == 2).unwrap();
        assert!(m2.cancelled && !m2.finished());
        assert!(rep.reqs.iter().find(|m| m.id == 1).unwrap().finished());
    }

    #[test]
    fn cancel_mid_prefill_aborts_the_kernel() {
        let (mut d, ann) = mk_driver(vec![req(1, 0.0, 400, 3)]);
        let npu = d.sim.xpu_index("npu").unwrap();
        d.admit_ready(512);
        let chunk = *d.states[&1].current_chunk().unwrap();
        let t = *ann.prefill_kernel(&chunk).timing_on(npu);
        d.launch(npu, t, false, KernelTag::Prefill { req: 1 });
        assert!(d.sim.busy(npu));
        assert!(d.cancel_request(1));
        assert!(!d.sim.busy(npu), "the in-flight prefill kernel is aborted");
        assert!(d.states.is_empty(), "state and KV freed");
        assert!(d.all_done());
        let rep = d.finish("fcfs-test".into()).unwrap();
        assert_eq!(rep.cancellations, 1);
    }

    #[test]
    fn cancel_decode_lane_retires_at_iteration_boundary() {
        let (mut d, ann) = mk_driver(vec![req(1, 0.0, 60, 8), req(2, 0.0, 60, 8)]);
        let npu = d.sim.xpu_index("npu").unwrap();
        let igpu = d.sim.xpu_index("igpu").unwrap();
        // prefill both to decode phase
        loop {
            d.admit_ready(512);
            if !d.sim.busy(npu) {
                if let Some(&id) = d.idle_in_phase(Phase::Prefilling).first() {
                    let chunk = *d.states[&id].current_chunk().unwrap();
                    let t = *ann.prefill_kernel(&chunk).timing_on(npu);
                    d.launch(npu, t, false, KernelTag::Prefill { req: id });
                }
            }
            if d.idle_in_phase(Phase::Decoding).len() == 2 {
                break;
            }
            assert!(d.step().unwrap());
        }
        // launch a 2-lane decode, then cancel lane 2 mid-kernel
        let lanes = d.idle_in_phase(Phase::Decoding);
        let t = *ann.decode_iter(2, 64).timing_on(igpu);
        d.launch(igpu, t, false, KernelTag::DecodeIter { lanes });
        assert!(d.cancel_request(2));
        assert!(d.sim.busy(igpu), "a batched decode is never aborted mid-kernel");
        drive_fcfs(&mut d, &ann);
        let rep = d.finish("fcfs-test".into()).unwrap();
        let m1 = rep.reqs.iter().find(|m| m.id == 1).unwrap();
        let m2 = rep.reqs.iter().find(|m| m.id == 2).unwrap();
        assert!(m1.finished() && m1.output_tokens == 8, "surviving lane unaffected");
        assert!(m2.cancelled && !m2.finished());
    }

    #[test]
    fn cancel_flow_turn_kills_placeholder_successors() {
        let (mut d, ann) = mk_driver(flow_turns(1, 10, 1_000.0));
        // cancel the middle turn while it is still held
        assert!(d.cancel_request(11));
        drive_fcfs(&mut d, &ann);
        let rep = d.finish("fcfs-test".into()).unwrap();
        // turn 0 completes; turns 1 and 2 are cancelled together (turn
        // 2's placeholder prompt can never be stitched without turn 1)
        assert!(rep.reqs.iter().find(|m| m.id == 10).unwrap().finished());
        assert!(rep.reqs.iter().find(|m| m.id == 11).unwrap().cancelled);
        assert!(rep.reqs.iter().find(|m| m.id == 12).unwrap().cancelled);
        assert_eq!(rep.cancellations, 2);
    }
}
