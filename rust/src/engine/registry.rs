//! Named policy registry: every scheduling policy in the tree is
//! registered under a stable string key, so figure harnesses, the
//! `agent-xpu` CLI (`run --engine`, `serve --policy`), and the
//! property-test suites select engines by name instead of hardcoded
//! constructor lists — a new policy registered here is automatically
//! covered by the §6 invariant suite and `fig schemes`.
//!
//! Canonical names (aliases in parentheses):
//!
//! | name | policy |
//! |---|---|
//! | `agent-xpu` (`agent.xpu`) | the paper's XPU coordinator (§6) |
//! | `cpu-fcfs` (`llamacpp`, `llama.cpp`) | llama.cpp-like CPU baseline |
//! | `scheme-a` (`preempt-restart`) | Fig. 4(a) instant preemption |
//! | `scheme-b` (`time-share`) | Fig. 4(b) kernel time-sharing |
//! | `scheme-c` (`continuous-batching`) | Fig. 4(c) continuous batching |
//! | `deadline` (`edf`) | slack-aware EDF over per-class deadlines |

use std::sync::Arc;

use anyhow::{Result, bail};

use crate::baselines::{CpuFcfsPolicy, Scheme, SingleXpuPolicy};
use crate::config::{ModelGeometry, SchedulerConfig, SocConfig};
use crate::coordinator::{AgentXpuPolicy, DeadlinePolicy};
use crate::runtime::ModelExecutor;

use super::bridge::ExecBridge;
use super::core_api::EngineCore;
use super::policy::PolicyEngine;

/// The llama.cpp-like baseline's fixed concurrency bound (the value
/// every figure harness has always used).
pub const CPU_FCFS_CONCURRENCY: usize = 4;

/// Canonical names of every registered policy, in comparison order
/// (Agent.xpu first, then the paper's baselines, then extensions).
pub fn names() -> &'static [&'static str] {
    &["agent-xpu", "cpu-fcfs", "scheme-a", "scheme-b", "scheme-c", "deadline"]
}

/// Resolve a user-facing name or alias to its canonical key.
pub fn canonical(name: &str) -> Result<&'static str> {
    Ok(match name {
        "agent-xpu" | "agent.xpu" | "agentxpu" => "agent-xpu",
        "cpu-fcfs" | "llamacpp" | "llama.cpp" | "llama.cpp-like" => "cpu-fcfs",
        "scheme-a" | "preempt-restart" => "scheme-a",
        "scheme-b" | "time-share" => "scheme-b",
        "scheme-c" | "continuous-batching" => "scheme-c",
        "deadline" | "edf" => "deadline",
        other => bail!(
            "unknown policy {other:?} (registered: {})",
            names().join(", ")
        ),
    })
}

/// Build a timing-only (synthetic-bridge) engine by policy name.
pub fn build(
    name: &str,
    geo: ModelGeometry,
    soc: SocConfig,
    sched: SchedulerConfig,
) -> Result<Box<dyn EngineCore + Send>> {
    let bridge = ExecBridge::synthetic(geo.clone());
    build_with_bridge(name, geo, soc, sched, bridge)
}

/// Build a real-compute engine by policy name: kernels execute through
/// the loaded PJRT artifacts.  Every policy accepts the real bridge —
/// the numerics are policy-independent.
pub fn build_real(
    name: &str,
    exec: Arc<ModelExecutor>,
    soc: SocConfig,
    sched: SchedulerConfig,
) -> Result<Box<dyn EngineCore + Send>> {
    let geo = exec.geo().clone();
    let bridge = ExecBridge::real(exec);
    build_with_bridge(name, geo, soc, sched, bridge)
}

fn build_with_bridge(
    name: &str,
    geo: ModelGeometry,
    soc: SocConfig,
    sched: SchedulerConfig,
    bridge: ExecBridge,
) -> Result<Box<dyn EngineCore + Send>> {
    Ok(match canonical(name)? {
        "agent-xpu" => Box::new(PolicyEngine::with_policy(
            AgentXpuPolicy::new(geo, &soc, sched),
            soc,
            bridge,
        )),
        "cpu-fcfs" => Box::new(PolicyEngine::with_policy(
            CpuFcfsPolicy::new(geo, &soc, CPU_FCFS_CONCURRENCY),
            soc,
            bridge,
        )),
        "scheme-a" => Box::new(PolicyEngine::with_policy(
            SingleXpuPolicy::new(geo, &soc, Scheme::PreemptRestart),
            soc,
            bridge,
        )),
        "scheme-b" => Box::new(PolicyEngine::with_policy(
            SingleXpuPolicy::new(geo, &soc, Scheme::TimeShare),
            soc,
            bridge,
        )),
        "scheme-c" => Box::new(PolicyEngine::with_policy(
            SingleXpuPolicy::new(geo, &soc, Scheme::ContinuousBatching),
            soc,
            bridge,
        )),
        "deadline" => Box::new(PolicyEngine::with_policy(
            DeadlinePolicy::new(geo, &soc, sched),
            soc,
            bridge,
        )),
        _ => unreachable!("canonical() covers every registered name"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{default_soc, llama32_3b};
    use crate::workload::{Priority, Request};

    #[test]
    fn every_registered_name_builds_and_runs() {
        let mut geo = llama32_3b();
        geo.n_layers = 2;
        for name in names() {
            let mut e = build(
                name,
                geo.clone(),
                default_soc(),
                SchedulerConfig::default(),
            )
            .unwrap();
            let rep = e
                .run(vec![Request {
                    id: 1,
                    priority: Priority::Reactive,
                    arrival_us: 0.0,
                    prompt: vec![1; 64],
                    max_new_tokens: 2,
                    profile: "reg".into(),
                    flow: None,
                }])
                .unwrap();
            assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 1, "{name}");
            assert!(
                e.last_trace().is_some(),
                "{name}: every policy retains its kernel trace"
            );
        }
    }

    #[test]
    fn aliases_resolve_and_unknown_names_fail() {
        assert_eq!(canonical("agent.xpu").unwrap(), "agent-xpu");
        assert_eq!(canonical("llamacpp").unwrap(), "cpu-fcfs");
        assert_eq!(canonical("edf").unwrap(), "deadline");
        assert!(canonical("no-such-policy").is_err());
    }
}
