//! One engine, pluggable policies (DESIGN.md §7).
//!
//! Every comparison point in this repo — Agent.xpu itself, the
//! llama.cpp-like CPU baseline, the single-XPU schemes (a)/(b)/(c),
//! and any future scheduler — differs *only* in its per-step
//! scheduling decision.  This module owns everything else:
//!
//! - [`SchedPolicy`] — the scheduling decision surface.  A policy is
//!   built from `(ModelGeometry, SocConfig, SchedulerConfig)`, makes
//!   one [`SchedPolicy::decide`] pass per engine step over a
//!   read-mostly view of the [`Driver`] ([`PolicyCtx`]), and may
//!   override narrower hooks — admission ordering, proactive resume
//!   ordering, decode-batch formation, eviction preference — whose
//!   defaults are the shared `coordinator::select` / `memory` helpers.
//! - [`PolicyEngine<P>`] — the one generic engine.  It owns the
//!   [`Driver`], the whole [`EngineCore`] lifecycle
//!   (`start`/`submit`/`step`/`cancel`/`finish`), session-reuse
//!   opt-in, kernel-trace retention, and event emission.  No policy
//!   reimplements any of that.
//!
//! The registry (`engine::registry`) maps policy names to boxed
//! `PolicyEngine`s so harnesses, servers, and tests select engines by
//! string instead of hardcoded constructor lists.
//!
//! ### Decision protocol
//!
//! `decide` receives a [`PolicyCtx`] and returns the [`Action`]s it
//! took.  Mutations go through the ctx's sanctioned surface
//! ([`PolicyCtx::launch`], [`PolicyCtx::abort`], the eviction and
//! preemption-accounting helpers) and are applied *at call time*, so
//! later decisions within the same pass observe earlier ones (e.g. a
//! colocated prefill launch makes the iGPU busy for the decode
//! branch).  The returned `Vec<Action>` is the decision record —
//! [`PolicyCtx::take_actions`] at the end of `decide` yields it.

use anyhow::{Context, Result};

use crate::config::{ModelGeometry, SocConfig};
use crate::coordinator::MemoryGovernor;
use crate::heg::{Annotator, ChunkSpec};
use crate::metrics::RunReport;
use crate::soc::{GraphicsConfig, GraphicsSim, KernelTiming, SocSim};
use crate::trace::Trace;
use crate::workload::{FlowId, ReqId, Request};

use super::bridge::ExecBridge;
use super::core_api::{
    EngineClock, EngineCore, EngineEvent, EngineLoad, OverloadSignal, ShedLevel,
    default_shed_level,
};
use super::driver::{Driver, KernelTag};
use super::reqstate::{Phase, ReqState};

/// The per-request state table every selection helper reads.  Backed
/// by the deterministic fx hasher (`util::fxhash`): keys are small
/// sequential ids and the table is probed on every decision pass, so
/// SipHash is pure overhead here.  No schedule depends on iteration
/// order (every selection point sorts by a total key — pinned by the
/// registry fingerprint gates).
pub type States = crate::util::FxHashMap<ReqId, ReqState>;

/// One scheduling decision taken during a [`SchedPolicy::decide`] pass.
/// The list a pass returns is its decision record; effects were already
/// applied through the [`PolicyCtx`] when each action was taken.
#[derive(Debug, Clone, PartialEq)]
pub enum Action {
    /// A kernel was launched on `xpu`.
    Launch { xpu: usize, reactive: bool, tag: KernelTag },
    /// The in-flight kernel on `xpu` was aborted (scheme-(a) style
    /// instant preemption).
    Abort { xpu: usize },
}

/// Read-mostly view of the open [`Driver`] handed to
/// [`SchedPolicy::decide`]: state table, XPU busy/idle, clock, governor
/// bookkeeping — plus the sanctioned mutation surface (launch/abort,
/// session/prefill eviction, preemption accounting).  Every mutation is
/// applied immediately and the kernel-level ones are recorded as
/// [`Action`]s.
pub struct PolicyCtx<'a> {
    d: &'a mut Driver,
    actions: Vec<Action>,
}

impl<'a> PolicyCtx<'a> {
    pub fn new(d: &'a mut Driver) -> Self {
        Self { d, actions: vec![] }
    }

    /// Build a ctx around a recycled action buffer (cleared here) so
    /// the steady-state decision loop stops allocating a fresh record
    /// per step — `PolicyEngine::step` threads the buffer through.
    pub fn with_scratch(d: &'a mut Driver, mut scratch: Vec<Action>) -> Self {
        scratch.clear();
        Self { d, actions: scratch }
    }

    // -- read view ------------------------------------------------------

    /// Every live serving state.
    pub fn states(&self) -> &States {
        &self.d.states
    }

    /// One request's serving state (panics on unknown ids — policies
    /// only hold ids they just read from the state table).
    pub fn state(&self, id: ReqId) -> &ReqState {
        &self.d.states[&id]
    }

    /// The virtual SoC (busy/idle, pressure — for `dispatch_check`).
    pub fn sim(&self) -> &SocSim {
        &self.d.sim
    }

    /// Is a kernel in flight on `xpu`?
    pub fn busy(&self, xpu: usize) -> bool {
        self.d.sim.busy(xpu)
    }

    /// Is every XPU idle?
    pub fn all_idle(&self) -> bool {
        self.d.sim.all_idle()
    }

    /// Current time in the run's clock domain (virtual or wall µs).
    pub fn now(&self) -> f64 {
        self.d.now()
    }

    /// Waiting proactive prefills, in id order (the driver's
    /// incrementally maintained index).
    pub fn waiting_proactive_prefills(&self) -> Vec<ReqId> {
        self.d.waiting_proactive_prefills()
    }

    /// Fill `out` with the waiting proactive prefills, in id order,
    /// without allocating.
    pub fn waiting_proactive_prefills_into(&self, out: &mut Vec<ReqId>) {
        self.d.waiting_proactive_prefills_into(out);
    }

    /// Fill `out` with the waiting *reactive* prefills, in id order.
    pub fn waiting_reactive_prefills_into(&self, out: &mut Vec<ReqId>) {
        self.d.waiting_reactive_prefills_into(out);
    }

    /// Fill `out` with every waiting prefill of both classes, in id
    /// order.
    pub fn waiting_prefills_into(&self, out: &mut Vec<ReqId>) {
        self.d.waiting_prefills_into(out);
    }

    /// Fill `out` with the waiting prefills of `reactive` class whose
    /// current chunk is dynamic-shaped (margin-backfill candidates).
    pub fn dynamic_chunk_candidates_into(&self, reactive: bool, out: &mut Vec<ReqId>) {
        self.d.dynamic_chunk_candidates_into(reactive, out);
    }

    /// Fill `out` with the waiting proactive prefills whose current
    /// chunk could still be split across XPUs (static-shaped, ≥ 2
    /// valid tokens, cursor at a chunk boundary), in id order.
    pub fn split_candidates_into(&self, out: &mut Vec<ReqId>) {
        self.d.split_candidates_into(out);
    }

    /// Any reactive request not yet Done?  (Index-backed.)
    pub fn reactive_live(&self) -> bool {
        self.d.reactive_live()
    }

    /// Any reactive decoder waiting at a kernel boundary?
    pub fn has_idle_reactive_decoder(&self) -> bool {
        self.d.has_idle_reactive_decoder()
    }

    /// Any decoder of either class waiting at a kernel boundary?
    pub fn has_idle_decoder(&self) -> bool {
        self.d.has_idle_decoder()
    }

    /// Borrow a cleared id buffer from the driver's scratch pool
    /// (return it with [`PolicyCtx::put_id_buf`]).
    pub fn take_id_buf(&mut self) -> Vec<ReqId> {
        self.d.take_id_buf()
    }

    /// Return a loaned id buffer to the scratch pool.
    pub fn put_id_buf(&mut self, buf: Vec<ReqId>) {
        self.d.put_id_buf(buf);
    }

    /// Idle retained session caches (memory-governor accounting).
    pub fn retained_sessions(&self) -> usize {
        self.d.retained_sessions()
    }

    /// Windowed *agentic* busy fraction of `xpu` (graphics frames
    /// excluded) — the duty the iGPU governor caps.
    pub fn windowed_duty(&self, xpu: usize) -> f64 {
        self.d.sim.windowed_duty(xpu)
    }

    /// Would a kernel of `nominal_us` launched now run past the next
    /// graphics frame's due instant?  Always false without a display
    /// workload.
    pub fn would_delay_next_frame(&self, nominal_us: f64) -> bool {
        self.d.would_delay_next_frame(nominal_us)
    }

    /// Schedule a DES wake-up at `at_us` (earliest wins): a policy
    /// whose decision is time-gated — a duty-governor veto waiting on
    /// window decay or starvation aging — must request one, or an
    /// otherwise-idle run would end before the gate reopens.
    pub fn request_wakeup(&mut self, at_us: f64) {
        self.d.request_wakeup(at_us);
    }

    // -- sanctioned mutations -------------------------------------------

    /// Launch a kernel; recorded as [`Action::Launch`].
    pub fn launch(&mut self, xpu: usize, timing: KernelTiming, reactive: bool, tag: KernelTag) {
        self.launch_with_factor(xpu, timing, reactive, tag, 1.0);
    }

    /// [`PolicyCtx::launch`] with a co-run DDR-penalty factor on the
    /// kernel's memory phase (split chunks pay the §5.3 asymmetric
    /// slowdown); factor 1.0 is bit-identical to a plain launch.
    pub fn launch_with_factor(
        &mut self,
        xpu: usize,
        timing: KernelTiming,
        reactive: bool,
        tag: KernelTag,
        co_run_mem_factor: f64,
    ) {
        self.actions.push(Action::Launch { xpu, reactive, tag: tag.clone() });
        self.d.launch_with_factor(xpu, timing, reactive, tag, co_run_mem_factor);
    }

    /// Abort the kernel in flight on `xpu` (scheme-(a) instant
    /// preemption); recorded as [`Action::Abort`].  Returns the aborted
    /// tag (`None` when the slot held a driver-managed tool kernel —
    /// re-queued, not lost).
    pub fn abort(&mut self, xpu: usize) -> Option<KernelTag> {
        let tag = self.d.cancel(xpu);
        if tag.is_some() {
            self.actions.push(Action::Abort { xpu });
        }
        tag
    }

    /// Preemption accounting: bump the run counter and stream the
    /// event (the policy decides *who* was preempted and why).
    pub fn note_preemption(&mut self, id: ReqId) {
        self.d.note_preemption(id);
    }

    /// Backfill accounting (`RunReport::backfills`).
    pub fn note_backfill(&mut self) {
        self.d.backfills += 1;
    }

    /// §6.2 preemption accounting for one waiting victim: bump its
    /// per-request counters, restart its aging clock, and stream the
    /// event.
    pub fn mark_preempted(&mut self, id: ReqId) {
        let now = self.d.now();
        let vs = self.d.states.get_mut(&id).expect("mark_preempted: unknown req");
        vs.preempted += 1;
        vs.preempt_counted = true;
        vs.enqueued_at_us = now;
        self.d.note_preemption(id);
    }

    /// Drop the least-recently-used idle retained session (memory
    /// shedding, cheapest residency first).  Returns the evicted flow.
    pub fn evict_lru_session(&mut self) -> Option<FlowId> {
        let fid = self.d.sessions.as_mut().and_then(|p| p.evict_lru())?;
        self.d.note_session_eviction(fid);
        Some(fid)
    }

    /// Memory-governor graceful degradation: wipe a waiting prefill's
    /// KV and progress (it recomputes from scratch) and surface the
    /// eviction in the report.
    pub fn evict_prefill(&mut self, victim: ReqId, geo: &ModelGeometry) {
        let now = self.d.now();
        let vs = self.d.states.get_mut(&victim).expect("evict_prefill: unknown req");
        vs.restart_prefill(geo);
        vs.enqueued_at_us = now;
        // the rebuilt plan can change the current chunk's shape
        self.d.reindex(victim);
        self.d.note_kv_eviction(victim);
    }

    /// Scheme-(a) context wipe: an aborted mid-prefill victim loses all
    /// prefill progress (no governor eviction — this is the *policy*
    /// discarding context, not memory pressure).
    pub fn restart_prefill(&mut self, id: ReqId, geo: &ModelGeometry) {
        if let Some(st) = self.d.states.get_mut(&id) {
            if st.phase == Phase::Prefilling {
                st.restart_prefill(geo);
                self.d.reindex(id);
            }
        }
    }

    /// Elastic rebind (§5.2): fold `id`'s *current* dynamic margin
    /// chunk to its next compiled static variant so the NPU can take it
    /// immediately.  Returns the folded chunk, or `None` when the plan
    /// is not at an unstarted dynamic chunk.  Counted in
    /// `RunReport::rebinds` and streamed as [`EngineEvent::Rebound`]
    /// with `split_tokens == 0`.
    pub fn fold_margin(&mut self, id: ReqId, geo: &ModelGeometry) -> Option<ChunkSpec> {
        let st = self.d.states.get_mut(&id)?;
        let folded = st.plan.fold_margin(geo)?;
        self.d.reindex(id); // the current chunk changed shape
        self.d.note_rebind(id);
        Some(folded)
    }

    /// Elastic rebind (§5.2): split `id`'s current head chunk in two —
    /// a dynamic co-run iGPU part (ratio of the valid tokens, first in
    /// plan order) and a padded static co-run NPU part.  Returns
    /// `(npu_part, igpu_part)`, or `None` when the chunk is ineligible
    /// (started, dynamic, or < 2 valid tokens).  Counted in
    /// `RunReport::{rebinds, splits, split_tokens}` and streamed as
    /// [`EngineEvent::Rebound`].
    pub fn split_head(
        &mut self,
        id: ReqId,
        geo: &ModelGeometry,
        ratio: f64,
    ) -> Option<(ChunkSpec, ChunkSpec)> {
        let st = self.d.states.get_mut(&id)?;
        let at = st.plan.chunk_idx();
        let parts = st.plan.split(geo, at, ratio)?;
        self.d.reindex(id); // the current chunk is now the iGPU part
        self.d.note_split(id, parts.1.valid);
        Some(parts)
    }

    /// Close the pass, yielding the decision record.
    pub fn take_actions(self) -> Vec<Action> {
        self.actions
    }
}

/// Arguments to the [`SchedPolicy::igpu_proactive_grant`] hook — the
/// iGPU duty governor's question: may a *proactive* kernel of this
/// shape occupy the iGPU right now?
pub struct IgpuGateCtx {
    /// `SchedulerConfig::igpu_duty_cap` (≥ 1.0 = uncapped).
    pub duty_cap: f64,
    /// `SchedulerConfig::yield_to_graphics`.
    pub yield_to_graphics: bool,
    /// Windowed agentic busy fraction of the iGPU (graphics excluded).
    pub duty: f64,
    /// The candidate kernel would run past the next graphics frame's
    /// due instant (always false without a display workload).
    pub frame_pending: bool,
    pub now_us: f64,
}

/// Arguments to the [`SchedPolicy::rebind`] hook — the runtime-elastic
/// operator-binding question (§5.2): may this waiting chunk plan be
/// re-partitioned right now?  The coordinator asks it at two points,
/// distinguished by `margin`:
///
/// - `margin == true` (*fold* question): a proactive dynamic margin
///   chunk is waiting for the iGPU while the NPU prefill pipeline sits
///   idle — should it fold to its padded static variant and run on the
///   NPU instead?
/// - `margin == false` (*split* question): a proactive static head
///   chunk is eyeing an iGPU backfill bubble while the NPU is busy —
///   should it split, co-running part of itself on the iGPU now and
///   leaving the rest as a static NPU chunk?
///
/// All timings are the annotator's co-run-aware predictions; the hook
/// is pure (mutations happen through [`PolicyCtx::fold_margin`] /
/// [`PolicyCtx::split_head`] after the decision).
pub struct RebindCtx {
    /// Fold question (dynamic margin chunk) vs split question (static
    /// head chunk).
    pub margin: bool,
    /// The iGPU duty governor would veto this candidate right now.
    pub igpu_squeezed: bool,
    /// The NPU's in-flight kernel is *reactive* (the split scenario:
    /// reactive prefill pins the prefill pipeline).
    pub npu_pinned_reactive: bool,
    /// Fold: predicted duration of the folded static chunk on the NPU.
    pub npu_margin_us: f64,
    /// Fold: predicted duration of the dynamic margin on the iGPU.
    pub igpu_margin_us: f64,
    /// Split: predicted duration of the *whole* chunk on the iGPU.
    pub whole_igpu_us: f64,
    /// Split: remaining wall time of the NPU's in-flight kernel.
    pub npu_wait_us: f64,
    /// Split: the ratio [`PolicyCtx::split_head`] would be called with.
    pub split_ratio: f64,
    /// Split: predicted co-run duration of the iGPU part at that ratio
    /// (DDR-penalty-aware, via `Annotated::co_run_us`).
    pub split_us: f64,
    pub now_us: f64,
}

/// What the [`SchedPolicy::rebind`] hook decided.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RebindDecision {
    /// Leave the plan exactly as planned at admission (every baseline's
    /// answer — keeps their schedules bit-for-bit unchanged).
    Never,
    /// Fold the dynamic margin to its padded static variant and launch
    /// it on the NPU now.
    FoldToNpu,
    /// Split the head chunk: co-run `ratio` of its valid tokens on the
    /// iGPU now, leave the rest as a static NPU chunk.
    Split { ratio: f64 },
}

/// Arguments to the [`SchedPolicy::resume_order`] hook: everything the
/// §6.2 resumption strategy (and any replacement) needs to rank paused
/// proactive prefills.
pub struct ResumeCtx<'a> {
    pub states: &'a States,
    pub ann: &'a Annotator,
    /// The XPU the resumed kernel would run on (ETC is computed there).
    pub xpu: usize,
    pub now_us: f64,
    pub starvation_age_us: f64,
    pub critical_path: bool,
}

/// The scheduling decision surface.  A policy is constructed from
/// `(ModelGeometry, SocConfig, SchedulerConfig)` by its own `new` (the
/// registry does this), owns whatever per-run state it needs (cursors,
/// annotators, governors), and plugs into [`PolicyEngine`] which owns
/// everything else.
///
/// Policy-author guide (see DESIGN.md §7): implement `label`,
/// `max_chunk`, and `decide`; override `session_capacity` to opt into
/// cross-turn KV retention; reset per-run state in `on_start`.  The
/// narrower hooks below default to the shared §6 helpers — a policy
/// that only wants a different *ordering* (like `deadline`) overrides
/// one hook and reuses the whole `XpuCoordinator` pipeline for its
/// `decide`.
pub trait SchedPolicy: Send {
    /// Engine name as it appears in `RunReport::engine`.
    fn label(&self) -> String;

    /// Chunk-size cap handed to `Driver::admit_ready` (elastic chunk
    /// planning; baselines use the geometry's largest variant).
    fn max_chunk(&self) -> usize;

    /// Max idle flow sessions whose KV stays resident between turns.
    /// 0 (the default) disables cross-turn reuse — every turn
    /// recomputes its full conversation prefix, which is exactly what
    /// the baselines model.
    fn session_capacity(&self) -> usize {
        0
    }

    /// Reset per-run policy state (round-robin cursors, …).  Called by
    /// `PolicyEngine::start` before the first step of a fresh run.
    fn on_start(&mut self) {}

    /// One scheduling pass at the current decision point: inspect the
    /// ctx, launch/abort kernels through it, return the decision
    /// record (`ctx.take_actions()`).
    fn decide(&mut self, ctx: PolicyCtx<'_>) -> Vec<Action>;

    // -- narrower hooks (defaults = the shared §6 helpers) --------------

    /// Order same-class prefill candidates for admission to a pipeline
    /// (first element launches).  Default: FCFS by arrival time, id
    /// tiebreak.
    fn admission_order(&self, states: &States, cands: &mut Vec<ReqId>) {
        cands.sort_by(|a, b| {
            states[a]
                .req
                .arrival_us
                .total_cmp(&states[b].req.arrival_us)
                .then(a.cmp(b))
        });
    }

    /// Order paused proactive prefills for resumption.  Default: the
    /// §6.2 strategy (starvation age → flow continuation →
    /// critical path → ETC) from `coordinator::select`.
    fn resume_order(&self, r: ResumeCtx<'_>, cands: &mut Vec<ReqId>) {
        crate::coordinator::resume_order(
            r.states,
            cands,
            r.ann,
            r.xpu,
            r.now_us,
            r.starvation_age_us,
            r.critical_path,
        );
    }

    /// Form the next decode batch into `lanes` (cleared first; an
    /// out-param so the per-step lane vector comes from the scratch
    /// pool instead of a fresh allocation).  Returns whether any lane
    /// is reactive.  Default: §6.3 adaptive batching (reactive lanes
    /// lead by wait time; proactive lanes backfill at the boundary
    /// when allowed) from `coordinator::select`.  `now_us` is provided
    /// for deadline/slack-aware variants.
    fn decode_batch(
        &self,
        states: &States,
        b_max: usize,
        allow_join: bool,
        _now_us: f64,
        lanes: &mut Vec<ReqId>,
    ) -> bool {
        crate::coordinator::decode_lanes(states, b_max, allow_join, lanes)
    }

    /// Under memory pressure, which waiting prefill loses its KV?
    /// Default: the governor's least-progressed waiting proactive
    /// prefill (§6.5 graceful degradation).
    fn eviction_victim(&self, gov: &MemoryGovernor, states: &States) -> Option<ReqId> {
        gov.eviction_victim(states)
    }

    /// iGPU duty governor (the paper's "controlled iGPU usage"): may a
    /// *proactive* kernel of the given shape occupy the iGPU right
    /// now?  The `XpuCoordinator` pipeline consults this before
    /// proactive decode batches/joins, proactive margin chunks, and
    /// inter-XPU backfill.  Reactive work and the force-progress
    /// deadlock guard are never gated, and proactive candidates that
    /// made no progress for a full starvation age (§6.5 aging, keyed
    /// off the last kernel completion) bypass the governor before it
    /// is even consulted — a veto defers, it cannot starve.
    ///
    /// Default: the `igpu_duty_cap` / `yield_to_graphics` knobs — veto
    /// when the iGPU's windowed agentic duty sits at/above the cap, or
    /// when the kernel would run past the next graphics frame's vsync
    /// due instant.  Both knobs at their defaults (cap 1.0, yield off)
    /// always grant, which keeps every registry policy's schedule
    /// bit-for-bit unchanged.
    fn igpu_proactive_grant(&self, g: &IgpuGateCtx) -> bool {
        let duty_ok = g.duty_cap >= 1.0 || g.duty < g.duty_cap;
        let frame_ok = !g.yield_to_graphics || !g.frame_pending;
        duty_ok && frame_ok
    }

    /// Runtime-elastic operator re-binding (§5.2): may the coordinator
    /// re-partition a waiting chunk plan mid-flight — fold a dynamic
    /// margin to the NPU, or split a static head chunk across NPU+iGPU
    /// with the co-run DDR penalty priced in?  Consulted at the two
    /// points described on [`RebindCtx`].
    ///
    /// Default: [`RebindDecision::Never`] — plans stay exactly as
    /// planned at admission, which keeps every baseline policy's
    /// schedule (and the registry fingerprint gates) bit-for-bit
    /// unchanged.  Only `agent-xpu` overrides this.
    fn rebind(&self, _r: &RebindCtx) -> RebindDecision {
        RebindDecision::Never
    }

    /// Overload → shed-level mapping (priority-aware load shedding,
    /// DESIGN.md §7): given what the serving loop's overload detector
    /// measured, how hard should *proactive* work degrade right now?
    /// The default is the shared threshold ladder
    /// ([`default_shed_level`]) — every registry policy inherits it,
    /// and a policy with its own notion of overload (e.g. a
    /// deadline-driven one) overrides just this hook.
    fn shed_level(&self, s: &OverloadSignal) -> ShedLevel {
        default_shed_level(s)
    }
}

/// The one generic engine: a [`Driver`] + the full [`EngineCore`]
/// lifecycle around any [`SchedPolicy`].  All five pre-policy engine
/// families (and every future policy) are `PolicyEngine<P>` instances —
/// there is exactly one copy of the submit/step/cancel/drain/finish
/// plumbing, and every policy (baselines included) gets identical
/// kernel-trace retention for Gantt figures.
pub struct PolicyEngine<P: SchedPolicy> {
    policy: P,
    soc: SocConfig,
    bridge: ExecBridge,
    /// Kernel trace of the last finished run (Fig. 4 Gantt, invariant
    /// checks) — retained here for *every* policy.
    last_trace: Option<Trace>,
    /// Synthetic display workload attached to future runs (DES only).
    graphics: Option<GraphicsConfig>,
    /// The open run, if `start` has been called.
    active: Option<Driver>,
    /// The last `step` made no progress (run idle).
    stalled: bool,
    /// Recycled decision-record buffer threaded through each step's
    /// [`PolicyCtx`] so steady-state passes allocate nothing.
    actions_scratch: Vec<Action>,
}

impl<P: SchedPolicy> PolicyEngine<P> {
    /// Wrap a policy around a numerics bridge (synthetic for DES
    /// sweeps, real for PJRT serving — any policy accepts either).
    /// Named `with_policy` so per-policy aliases keep their historical
    /// inherent constructors (`CpuFcfsEngine::new`, …).
    pub fn with_policy(policy: P, soc: SocConfig, bridge: ExecBridge) -> Self {
        Self {
            policy,
            soc,
            bridge,
            last_trace: None,
            graphics: None,
            active: None,
            stalled: false,
            actions_scratch: vec![],
        }
    }

    /// The wrapped policy (tests, introspection).
    pub fn policy(&self) -> &P {
        &self.policy
    }
}

impl<P: SchedPolicy> EngineCore for PolicyEngine<P> {
    fn name(&self) -> String {
        self.policy.label()
    }

    fn start(&mut self, clock: EngineClock) -> Result<()> {
        let mut d = Driver::open(&self.soc, self.bridge.clone(), clock);
        // Flow-level session retention (DESIGN.md §3): continuation
        // turns prefill only their delta tokens.  Policies that leave
        // `session_capacity` at 0 run full-prefix recompute — the
        // baselines — so the figures quantify the reuse win.
        let cap = self.policy.session_capacity();
        if cap > 0 {
            d.enable_session_reuse(cap);
        }
        // Synthetic display workload (DES only: frame timing lives on
        // the virtual SoC clock) — every policy contends with it the
        // same way, so figure comparisons are apples-to-apples.
        if !clock.is_wall() {
            if let (Some(cfg), Some(igpu)) = (&self.graphics, self.soc.xpu("igpu")) {
                d.set_graphics(GraphicsSim::new(cfg, igpu));
            }
        }
        self.policy.on_start();
        self.active = Some(d);
        self.stalled = false;
        Ok(())
    }

    fn submit(&mut self, req: Request) -> Result<()> {
        self.active
            .as_mut()
            .with_context(|| format!("{}: submit before start", self.policy.label()))?
            .submit(req);
        self.stalled = false;
        Ok(())
    }

    fn cancel(&mut self, id: ReqId) -> Result<bool> {
        let hit = self
            .active
            .as_mut()
            .with_context(|| format!("{}: cancel before start", self.policy.label()))?
            .cancel_request(id);
        if hit {
            // wake a stalled run so the Cancelled event flushes
            self.stalled = false;
        }
        Ok(hit)
    }

    fn step(&mut self) -> Result<Vec<EngineEvent>> {
        let mut d = self
            .active
            .take()
            .with_context(|| format!("{}: step before start", self.policy.label()))?;
        d.admit_ready(self.policy.max_chunk());
        let scratch = std::mem::take(&mut self.actions_scratch);
        let mut decisions = self.policy.decide(PolicyCtx::with_scratch(&mut d, scratch));
        decisions.clear();
        self.actions_scratch = decisions;
        let progressed = d.step()?;
        self.stalled = !progressed;
        let events = d.take_events();
        self.active = Some(d);
        Ok(events)
    }

    fn has_work(&self) -> bool {
        self.active.is_some() && !self.stalled
    }

    fn finish(&mut self) -> Result<RunReport> {
        let d = self
            .active
            .take()
            .with_context(|| format!("{}: finish before start", self.policy.label()))?;
        self.last_trace = Some(d.trace.clone());
        d.finish(self.name())
    }

    fn last_trace(&self) -> Option<&Trace> {
        self.last_trace.as_ref()
    }

    fn overload_response(&self, s: &OverloadSignal) -> ShedLevel {
        self.policy.shed_level(s)
    }

    fn load(&self) -> EngineLoad {
        match &self.active {
            Some(d) => EngineLoad {
                unfinished: d.unfinished(),
                now_us: d.now(),
                npu_duty: d
                    .sim
                    .xpu_index("npu")
                    .map(|i| d.sim.windowed_duty(i))
                    .unwrap_or(0.0),
                igpu_duty: d
                    .sim
                    .xpu_index("igpu")
                    .map(|i| d.sim.windowed_duty(i))
                    .unwrap_or(0.0),
                energy_j: d.sim.total_energy_j(),
            },
            None => EngineLoad::default(),
        }
    }

    fn set_graphics(&mut self, cfg: Option<GraphicsConfig>) {
        self.graphics = cfg;
    }
}
