//! Dependency-free fast hasher for the scheduler's hot maps.
//!
//! The driver's per-request state table and in-flight kernel map are
//! hit on every decision pass; std's SipHash dominates those lookups
//! once traces reach serving scale.  Keys here are small integers
//! (`ReqId`/`RunId`/`FlowId`, all `u64`) with no adversarial source —
//! the multiply-rotate mix used by rustc's own hash maps is enough, and
//! it is deterministic across runs (the per-instance random seed of
//! `RandomState` goes away).  Schedule determinism never rested on map
//! iteration order — every selection point sorts by a total key, which
//! the registry fingerprint gates in `tests/sched_props.rs` pin — so
//! swapping the hasher is observable only as speed.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-rotate hasher (the firefox/rustc "fx" mix).
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashMap<K, V> = HashMap<K, V, FxBuildHasher>;
pub type FxHashSet<T> = HashSet<T, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let h = |n: u64| {
            let mut h = FxHasher::default();
            h.write_u64(n);
            h.finish()
        };
        assert_eq!(h(42), h(42));
        assert_ne!(h(42), h(43));
    }

    #[test]
    fn map_works_with_u64_keys() {
        let mut m: FxHashMap<u64, &str> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert(i, "x");
        }
        assert_eq!(m.len(), 1000);
        assert!(m.contains_key(&999));
        assert!(!m.contains_key(&1000));
    }

    #[test]
    fn byte_slices_hash_consistently() {
        let h = |b: &[u8]| {
            let mut h = FxHasher::default();
            h.write(b);
            h.finish()
        };
        assert_eq!(h(b"hello"), h(b"hello"));
        assert_ne!(h(b"hello"), h(b"hellp"));
    }
}
