//! Minimal, complete JSON implementation: parse + serialize + typed
//! accessors.  Used for the AOT manifest, runtime configs, golden files,
//! the UDS wire protocol, and figure-harness output.
//!
//! Supports the full JSON grammar (RFC 8259) minus exotic number forms
//! beyond f64 precision.  Not performance-critical: every use is on the
//! control path (config load, request admission), never per-kernel.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{Context, Result, anyhow, bail};

/// A JSON value.  Objects use `BTreeMap` for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- constructors ---------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(m) = &mut self {
            m.insert(key.to_string(), val.into());
        }
        self
    }

    /// A finite number, or `null` for an undefined (NaN/infinite)
    /// aggregate — the canonical way figure harnesses serialize means
    /// that may not exist (RFC 8259 has no NaN/Infinity tokens).
    pub fn num_or_null(v: f64) -> Json {
        if v.is_finite() { Json::Num(v) } else { Json::Null }
    }

    // -- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a usize: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("not an integer: {n}");
        }
        Ok(n as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// `[1,2,3]` -> `vec![1,2,3]` for any int-like element type.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_i32_vec(&self) -> Result<Vec<i32>> {
        self.as_arr()?
            .iter()
            .map(|v| Ok(v.as_i64()? as i32))
            .collect()
    }

    // -- parsing ----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of JSON"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at offset {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected {:?} at offset {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i..self.i + 4)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            self.i += 4;
                            let mut cp = u32::from_str_radix(hex, 16)?;
                            // surrogate pair
                            if (0xD800..0xDC00).contains(&cp)
                                && self.b.get(self.i) == Some(&b'\\')
                                && self.b.get(self.i + 1) == Some(&b'u')
                            {
                                let hex2 = std::str::from_utf8(
                                    self.b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| anyhow!("bad surrogate"))?,
                                )?;
                                let lo = u32::from_str_radix(hex2, 16)?;
                                if (0xDC00..0xE000).contains(&lo) {
                                    self.i += 6;
                                    cp = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                }
                            }
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("invalid codepoint {cp:#x}"))?,
                            );
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // UTF-8 passthrough: find the full char
                    let start = self.i - 1;
                    let width = utf8_width(c);
                    self.i = start + width;
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .context("invalid UTF-8 in string")?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().context("bad number")?))
    }
}

fn utf8_width(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// -- serialization ---------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // RFC 8259 has no NaN/Infinity tokens; `{NaN}` used
                    // to serialize as the invalid literal `NaN` and
                    // poison figure output.  An undefined number
                    // degrades to null (round-trips as `Json::Null`).
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize().unwrap(), 2);
        assert!(!arr[2].get("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#""a\nb\té😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\té😀");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = Json::parse("\"héllo — 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — 世界");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let text = r#"{"arr":[1,2.5,null],"nested":{"k":"v\n"},"t":true}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.to_string(), text);
        // parse(serialize(x)) == x
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn builder_api() {
        let v = Json::obj()
            .set("name", "npu")
            .set("tflops", 11.5)
            .set("ids", vec![1usize, 2, 3]);
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "npu");
        assert_eq!(
            v.get("ids").unwrap().as_usize_vec().unwrap(),
            vec![1, 2, 3]
        );
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    /// Satellite regression: `Num(NaN)`/`Num(±inf)` used to emit the
    /// invalid tokens `NaN`/`inf` — unparseable by any JSON consumer
    /// (including this parser).  Non-finite serializes as null and
    /// round-trips to `Json::Null`.
    #[test]
    fn non_finite_numbers_serialize_as_null_and_round_trip() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let text = Json::Num(v).to_string();
            assert_eq!(text, "null", "{v} must not leak into JSON");
            assert_eq!(Json::parse(&text).unwrap(), Json::Null);
        }
        // nested: an object carrying an undefined aggregate stays valid
        let obj = Json::obj().set("mean", f64::NAN).set("ok", 1.5);
        let back = Json::parse(&obj.to_string()).unwrap();
        assert_eq!(*back.get("mean").unwrap(), Json::Null);
        assert_eq!(back.get("ok").unwrap().as_f64().unwrap(), 1.5);
        // the explicit constructor for harnesses
        assert_eq!(Json::num_or_null(f64::NAN), Json::Null);
        assert_eq!(Json::num_or_null(2.0), Json::Num(2.0));
    }

    #[test]
    fn typed_accessor_errors() {
        let v = Json::parse(r#"{"a": 1.5}"#).unwrap();
        assert!(v.get("a").unwrap().as_usize().is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
        assert!(v.get("missing").is_err());
        assert!(Json::Num(1.0).get("x").is_err());
    }
}
