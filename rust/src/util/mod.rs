//! Dependency-free utility substrates.
//!
//! The build environment is fully offline and the paper's reference
//! implementation is deliberately dependency-free (§7: "without any
//! third-party dependencies"), so the pieces a serving framework would
//! normally pull from crates.io are implemented here:
//!
//! - [`json`] — JSON parser/serializer (manifest, configs, UDS protocol).
//! - [`rng`] — deterministic PRNG + exponential/Poisson/normal samplers
//!   for the workload generators.
//! - [`cli`] — minimal flag parser for the `agent-xpu` binary.
//! - [`bench`] — the measurement harness used by `cargo bench`
//!   (`harness = false`) targets: warmup, iterations, mean/p50/p99.
//! - [`fxhash`] — deterministic multiply-rotate hasher for the hot
//!   scheduler maps (integer keys, no adversarial input).

pub mod bench;
pub mod cli;
pub mod fxhash;
pub mod json;
pub mod rng;

pub use fxhash::{FxHashMap, FxHashSet};
