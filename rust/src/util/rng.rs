//! Deterministic PRNG + distribution samplers for the workload
//! generators (Poisson proactive arrivals, exponential reactive
//! think-time — paper §8.1) and the property-test kit.
//!
//! splitmix64 core: tiny, seedable, and plenty for simulation.

/// Seedable splitmix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [lo, hi).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    /// Pick a random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len())]
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda` (Knuth for small
    /// lambda, normal approximation beyond 64).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let n = lambda + lambda.sqrt() * self.normal();
            return n.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given underlying mu/sigma — used for the
    /// dataset-analog prompt/output length distributions.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut c = Rng::new(8);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(2);
        let lambda = 0.5;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 2.0).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(3);
        for &lambda in &[0.5, 4.0, 100.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() / lambda < 0.05,
                "lambda {lambda}: mean {mean}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.usize(3, 9);
            assert!((3..9).contains(&x));
        }
    }
}
