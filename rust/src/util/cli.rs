//! Minimal command-line flag parser for the `agent-xpu` binary and the
//! bench harnesses: `--key value`, `--flag`, positional args.

use std::collections::BTreeMap;

use anyhow::{Context, Result, bail};

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse(args: impl IntoIterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare `--` not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.flags.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}: not a number")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v:?}: not an integer")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            None => default,
            Some(v) => v != "false" && v != "0",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_mixed_styles() {
        let a = parse("fig affinity --rate 3.5 --verbose --out=x.json tail");
        assert_eq!(a.positional, vec!["fig", "affinity", "tail"]);
        assert_eq!(a.f64_or("rate", 1.0).unwrap(), 3.5);
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.str_or("out", ""), "x.json");
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse("--x --y 2");
        assert!(a.bool_or("x", false));
        assert_eq!(a.usize_or("y", 0).unwrap(), 2);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("");
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert!(!a.bool_or("v", false));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("--n abc");
        assert!(a.usize_or("n", 0).is_err());
    }
}
