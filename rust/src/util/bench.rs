//! Tiny measurement harness for the `harness = false` bench targets
//! (criterion is unavailable offline): warmup + timed iterations with
//! mean / p50 / p99 reporting, plus a table printer the figure benches
//! share.

use std::time::Instant;

use crate::util::json::Json;

/// Summary statistics over per-iteration wall-clock samples.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    /// Strict-JSON row (non-finite values become `null` via
    /// [`Json::num_or_null`]) so micro and macro benches share one
    /// `BENCH_*.json` shape.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("iters", self.iters)
            .set("mean_ns", Json::num_or_null(self.mean_ns))
            .set("p50_ns", Json::num_or_null(self.p50_ns))
            .set("p99_ns", Json::num_or_null(self.p99_ns))
            .set("min_ns", Json::num_or_null(self.min_ns))
    }

    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p99_ns),
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Nearest-rank percentile over an ascending-sorted sample slice.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    BenchStats {
        name: name.to_string(),
        iters,
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        p50_ns: percentile(&sorted, 0.50),
        p99_ns: percentile(&sorted, 0.99),
        min_ns: sorted[0],
    }
}

/// Keep the optimizer from deleting a computed value (std-only
/// `black_box` stand-in that works on stable).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width table printer used by the figure harnesses so bench
/// output reads like the paper's tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let mut x = 0u64;
        let s = bench("noop", 2, 50, || {
            x = black_box(x.wrapping_add(1));
        });
        assert_eq!(s.iters, 50);
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.p99_ns);
        assert!(s.mean_ns > 0.0);
    }

    #[test]
    fn to_json_round_trips_and_nan_becomes_null() {
        let s = BenchStats {
            name: "case".into(),
            iters: 10,
            mean_ns: 1.5,
            p50_ns: 1.0,
            p99_ns: f64::NAN,
            min_ns: 0.5,
        };
        let txt = s.to_json().to_string();
        let j = Json::parse(&txt).unwrap();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "case");
        assert_eq!(j.get("iters").unwrap().as_usize().unwrap(), 10);
        assert_eq!(j.get("p99_ns").unwrap(), &Json::Null);
    }

    #[test]
    fn percentile_nearest_rank() {
        let s = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&s, 0.0), 1.0);
        assert_eq!(percentile(&s, 1.0), 4.0);
        assert!(percentile(&[], 0.5).is_nan());
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2e9).ends_with("s"));
    }

    #[test]
    fn table_rows_must_match_headers() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }
}
