//! Discrete-event SoC simulator with a shared-DDR bandwidth arbiter.
//!
//! Model: each XPU runs at most one kernel; a running kernel has a
//! compute phase of `tc + launch` µs (advances at wall rate, private to
//! the XPU) and a memory phase of `tm` µs (advances at the *contended*
//! rate).  When the sum of active kernels' bandwidth demands exceeds the
//! DDR peak, every active memory phase is scaled by
//! `s = peak / Σ demand` — the proportional-share contention that
//! reproduces the paper's Fig. 3: co-executed memory-bound GEMVs stretch
//! while compute-bound GEMMs are barely affected.
//!
//! The scale factor only changes at launch/finish events, so piecewise
//! integration between events is exact and the simulation is fully
//! deterministic.
//!
//! On top of proportional sharing, a kernel may carry a *co-run
//! interference factor* ≥ 1 ([`SocSim::launch_with_factor`]): its
//! memory phase progresses at `s / factor`.  This models the
//! asymmetric DDR inefficiency measured when NPU and iGPU execute
//! tensor-partitioned halves of the *same* operator (the PAPERS.md
//! mobile-SoC characterization study): the partitioned halves fight
//! over the same pages and arbitration slots, so a mid-flight split is
//! *not* free bandwidth even when the link is unsaturated.

use super::xpu::{KernelTiming, XpuModel};
use crate::config::SocConfig;

pub type RunId = u64;

const EPS: f64 = 1e-6;

/// Sliding window over which per-XPU agentic duty is measured (two
/// half-overlapping buckets; see [`SocSim::windowed_duty`]).  100 ms —
/// several frame periods of a 60 Hz display, a few decode iterations.
/// Public so the duty governor can pace its veto-retry wake-ups
/// against the decay rate.
pub const DUTY_WINDOW_US: f64 = 100_000.0;

/// Accounting class of a kernel: who the energy and busy time belong
/// to.  The per-class totals (plus idle) are the paper's §8.1 energy
/// attribution; index order is the layout of
/// [`SocSim::energy_by_class`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelClass {
    /// Real-time agentic work (user-facing chat turns).
    Reactive = 0,
    /// Best-effort agentic work (background tasks).
    Proactive = 1,
    /// Display frames of the synthetic graphics workload.
    Graphics = 2,
}

/// Index of the idle row in [`SocSim::energy_by_class`].
pub const CLASS_IDLE: usize = 3;

/// Memory-phase stretch the *NPU* half of a tensor-partitioned co-run
/// pays (PAPERS.md characterization: the NPU's DMA engine loses more
/// to page conflicts than the iGPU's cache-backed accesses — the
/// penalty is asymmetric, and worse on the NPU side).
pub const CO_RUN_DDR_PENALTY_NPU: f64 = 1.16;

/// Memory-phase stretch the *iGPU* half of a co-run pays.
pub const CO_RUN_DDR_PENALTY_IGPU: f64 = 1.07;

impl KernelClass {
    pub fn from_reactive(reactive: bool) -> Self {
        if reactive { KernelClass::Reactive } else { KernelClass::Proactive }
    }

    pub fn idx(self) -> usize {
        self as usize
    }
}

/// What the engine hands the simulator at kernel launch.
#[derive(Debug, Clone)]
pub struct LaunchSpec {
    pub timing: KernelTiming,
    /// Accounting class (reactive / proactive / graphics) — drives the
    /// per-class energy/busy attribution and the duty window, and is
    /// recorded for traces and pressure policies.
    pub class: KernelClass,
}

/// A finished kernel execution.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub id: RunId,
    pub xpu: usize,
    pub started_us: f64,
    pub finished_us: f64,
}

#[derive(Debug, Clone)]
struct Run {
    id: RunId,
    tc_left: f64,
    tm_left: f64,
    bw_gbps: f64,
    power_w: f64,
    started_us: f64,
    /// Accounting class — consumed by `integrate` for the per-class
    /// energy/busy attribution and the agentic duty window.
    class: KernelClass,
    /// tm > tc at launch (for selective pairing, §6.4).
    memory_bound: bool,
    /// Co-run DDR interference: the memory phase progresses at
    /// `s / factor`.  1.0 (the plain [`SocSim::launch`] path) is
    /// arithmetically exact — non-co-run schedules are bit-for-bit
    /// unchanged.
    co_run_mem_factor: f64,
}

impl Run {
    fn finished(&self) -> bool {
        self.tc_left <= EPS && self.tm_left <= EPS
    }

    /// Remaining wall time under memory scale `s`.
    fn remaining(&self, s: f64) -> f64 {
        let tm = if s > 0.0 {
            self.tm_left * self.co_run_mem_factor / s
        } else {
            f64::INFINITY
        };
        self.tc_left.max(tm)
    }
}

/// Per-XPU utilization/energy snapshot.
#[derive(Debug, Clone, Default)]
pub struct XpuSnapshot {
    pub name: String,
    pub busy_us: f64,
    pub energy_j: f64,
    /// Kernels that launched and were *not* aborted (completed, or
    /// still in flight at snapshot time).
    pub kernels: u64,
    /// Kernels aborted via [`SocSim::cancel`] — counted separately so
    /// abort-heavy runs (scheme-(a) preemption) never over-report
    /// completed work.
    pub aborted: u64,
}

/// The simulated SoC.
pub struct SocSim {
    pub xpus: Vec<XpuModel>,
    slots: Vec<Option<Run>>,
    pub now_us: f64,
    ddr_bw_gbps: f64,
    next_id: RunId,
    busy_us: Vec<f64>,
    energy_j: Vec<f64>,
    kernels: Vec<u64>,
    aborted: Vec<u64>,
    /// Energy by accounting class: [reactive, proactive, graphics,
    /// idle] (J).  Sums to `total_energy_j` at all times.
    class_energy_j: [f64; 4],
    /// Busy time by kernel class: [reactive, proactive, graphics] (µs),
    /// summed over XPUs.
    class_busy_us: [f64; 3],
    /// Agentic (non-graphics) busy µs per XPU in the previous full duty
    /// window / the current partial one — the two-bucket sliding window
    /// behind [`SocSim::windowed_duty`].
    duty_prev: Vec<f64>,
    duty_cur: Vec<f64>,
    duty_cur_start: f64,
    /// Σ over time of (achieved DDR bandwidth · dt) for mean-BW reporting.
    bw_integral_gb: f64,
    pub peak_power_w: f64,
}

impl SocSim {
    pub fn new(cfg: &SocConfig) -> Self {
        let xpus: Vec<XpuModel> =
            cfg.xpus.iter().cloned().map(XpuModel::new).collect();
        let n = xpus.len();
        Self {
            xpus,
            slots: vec![None; n],
            now_us: 0.0,
            ddr_bw_gbps: cfg.ddr_bw_gbps,
            next_id: 1,
            busy_us: vec![0.0; n],
            energy_j: vec![0.0; n],
            kernels: vec![0; n],
            aborted: vec![0; n],
            class_energy_j: [0.0; 4],
            class_busy_us: [0.0; 3],
            duty_prev: vec![0.0; n],
            duty_cur: vec![0.0; n],
            duty_cur_start: 0.0,
            bw_integral_gb: 0.0,
            peak_power_w: 0.0,
        }
    }

    pub fn xpu_index(&self, name: &str) -> Option<usize> {
        self.xpus.iter().position(|x| x.name() == name)
    }

    pub fn busy(&self, xpu: usize) -> bool {
        self.slots[xpu].is_some()
    }

    pub fn idle_xpus(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&i| !self.busy(i)).collect()
    }

    /// Current memory pressure P_mem(t) = Σ BW_k / BW_peak (§6.4).
    /// May exceed 1.0 when oversubscribed.
    pub fn memory_pressure(&self) -> f64 {
        self.demand_sum() / self.ddr_bw_gbps
    }

    /// Pressure increase ΔP that launching `t` would cause.
    pub fn pressure_increase(&self, t: &KernelTiming) -> f64 {
        t.bw_gbps / self.ddr_bw_gbps
    }

    fn demand_sum(&self) -> f64 {
        self.slots
            .iter()
            .flatten()
            .filter(|r| r.tm_left > EPS)
            .map(|r| r.bw_gbps)
            .sum()
    }

    /// True when no XPU is executing anything.
    pub fn all_idle(&self) -> bool {
        self.slots.iter().all(|s| s.is_none())
    }

    /// Is any active kernel memory-bound (tm-dominated)?  Feeds the
    /// medium-pressure "selective pairing" tier of Algorithm 1.
    pub fn any_active_memory_bound(&self) -> bool {
        self.slots.iter().flatten().any(|r| r.memory_bound)
    }

    /// Proportional-share memory scale: 1 when unsaturated.
    fn scale(&self) -> f64 {
        let d = self.demand_sum();
        if d <= self.ddr_bw_gbps { 1.0 } else { self.ddr_bw_gbps / d }
    }

    /// Launch a kernel on `xpu` (panics if busy — the scheduler owns the
    /// invariant; see coordinator::dispatch).
    pub fn launch(&mut self, xpu: usize, spec: LaunchSpec) -> RunId {
        self.launch_with_factor(xpu, spec, 1.0)
    }

    /// Launch with a co-run DDR interference factor ≥ 1: the kernel's
    /// memory phase progresses at `scale / factor`.  Used for the
    /// halves of a tensor-partitioned split; `launch` is the
    /// factor-1.0 case (bit-identical arithmetic).
    pub fn launch_with_factor(&mut self, xpu: usize, spec: LaunchSpec, factor: f64) -> RunId {
        assert!(!self.busy(xpu), "XPU {xpu} already busy");
        assert!(factor >= 1.0, "co-run factor {factor} < 1");
        let id = self.next_id;
        self.next_id += 1;
        let launch_us = self.xpus[xpu].cfg.launch_overhead_us;
        self.slots[xpu] = Some(Run {
            id,
            tc_left: spec.timing.tc_us + launch_us,
            tm_left: spec.timing.tm_us,
            bw_gbps: spec.timing.bw_gbps,
            power_w: spec.timing.power_w,
            started_us: self.now_us,
            class: spec.class,
            memory_bound: spec.timing.tm_us > spec.timing.tc_us,
            co_run_mem_factor: factor,
        });
        self.kernels[xpu] += 1;
        id
    }

    /// Abort the kernel on `xpu` (scheme-(a) baseline: instant preemption
    /// discards in-flight work).  Returns the aborted run id.  The
    /// launch-time `kernels` count is rolled back and the abort counted
    /// separately, so `XpuSnapshot::kernels` never over-reports
    /// completed work on abort-heavy runs.
    pub fn cancel(&mut self, xpu: usize) -> Option<RunId> {
        self.slots[xpu].take().map(|r| {
            self.kernels[xpu] -= 1;
            self.aborted[xpu] += 1;
            r.id
        })
    }

    /// Accounting class of the kernel in flight on `xpu`, if any (the
    /// rebind hook asks whether the NPU is pinned by *reactive* work).
    pub fn running_class(&self, xpu: usize) -> Option<KernelClass> {
        self.slots[xpu].as_ref().map(|r| r.class)
    }

    /// Remaining wall time (µs) of the kernel in flight on `xpu` under
    /// the current contention scale.
    pub fn remaining_on(&self, xpu: usize) -> Option<f64> {
        self.slots[xpu].as_ref().map(|r| r.remaining(self.scale()))
    }

    /// Which XPU `run` is executing on, if it is still in flight.
    pub fn xpu_of(&self, run: RunId) -> Option<usize> {
        self.slots
            .iter()
            .position(|s| s.as_ref().map(|r| r.id == run).unwrap_or(false))
    }

    /// Earliest time any running kernel could finish (µs from now).
    pub fn next_event_in(&self) -> Option<f64> {
        let s = self.scale();
        self.slots
            .iter()
            .flatten()
            .map(|r| r.remaining(s))
            .min_by(|a, b| a.total_cmp(b))
    }

    /// Advance virtual time to `t_target` at the latest, stopping at the
    /// first completion instant.  Returns the kernels that finished
    /// (possibly several, if they tie).
    pub fn advance_until(&mut self, t_target: f64) -> Vec<Completion> {
        assert!(t_target >= self.now_us - EPS, "time went backwards");
        loop {
            let s = self.scale();
            let next_fin = self
                .slots
                .iter()
                .flatten()
                .map(|r| r.remaining(s))
                .min_by(|a, b| a.total_cmp(b));
            let dt_target = t_target - self.now_us;
            match next_fin {
                None => {
                    self.integrate(dt_target.max(0.0), s);
                    self.now_us = t_target;
                    return vec![];
                }
                Some(rem) if rem > dt_target + EPS => {
                    self.integrate(dt_target.max(0.0), s);
                    self.now_us = t_target;
                    return vec![];
                }
                Some(rem) => {
                    self.integrate(rem, s);
                    self.now_us += rem;
                    let mut done = vec![];
                    for (xpu, slot) in self.slots.iter_mut().enumerate() {
                        if slot.as_ref().map(|r| r.finished()).unwrap_or(false) {
                            let r = slot.take().unwrap();
                            done.push(Completion {
                                id: r.id,
                                xpu,
                                started_us: r.started_us,
                                finished_us: self.now_us,
                            });
                        }
                    }
                    if !done.is_empty() {
                        return done;
                    }
                    // numerical corner: nothing crossed the threshold;
                    // keep integrating
                }
            }
        }
    }

    /// Piecewise-exact progress + accounting over `dt` at scale `s`.
    fn integrate(&mut self, dt: f64, s: f64) {
        if dt <= 0.0 {
            return;
        }
        self.roll_duty_window();
        let mut power_now = 0.0;
        let mut achieved_bw = 0.0;
        for (i, slot) in self.slots.iter_mut().enumerate() {
            match slot {
                Some(r) => {
                    r.tc_left = (r.tc_left - dt).max(0.0);
                    if r.tm_left > EPS {
                        achieved_bw += r.bw_gbps * s;
                    }
                    r.tm_left = (r.tm_left - dt * s / r.co_run_mem_factor).max(0.0);
                    self.busy_us[i] += dt;
                    self.energy_j[i] += r.power_w * dt * 1e-6;
                    self.class_energy_j[r.class.idx()] += r.power_w * dt * 1e-6;
                    self.class_busy_us[r.class.idx()] += dt;
                    if r.class != KernelClass::Graphics {
                        self.duty_cur[i] += dt;
                    }
                    power_now += r.power_w;
                }
                None => {
                    let idle = self.xpus[i].cfg.idle_power_w;
                    self.energy_j[i] += idle * dt * 1e-6;
                    self.class_energy_j[CLASS_IDLE] += idle * dt * 1e-6;
                    power_now += idle;
                }
            }
        }
        self.bw_integral_gb += achieved_bw * dt * 1e-6;
        self.peak_power_w = self.peak_power_w.max(power_now);
    }

    /// Advance the two-bucket duty window to cover `now_us`.
    fn roll_duty_window(&mut self) {
        while self.now_us - self.duty_cur_start >= DUTY_WINDOW_US {
            std::mem::swap(&mut self.duty_prev, &mut self.duty_cur);
            for v in self.duty_cur.iter_mut() {
                *v = 0.0;
            }
            self.duty_cur_start += DUTY_WINDOW_US;
        }
    }

    /// Windowed *agentic* duty of `xpu`: the fraction of the trailing
    /// ~[`DUTY_WINDOW_US`] this XPU spent on reactive/proactive kernels
    /// (graphics frames excluded — the duty cap exists to protect
    /// them).  Two-bucket sliding-window estimate: the previous full
    /// window decays linearly as the current one fills.
    pub fn windowed_duty(&self, xpu: usize) -> f64 {
        let elapsed = (self.now_us - self.duty_cur_start).clamp(0.0, DUTY_WINDOW_US);
        let prev_weight = (DUTY_WINDOW_US - elapsed) / DUTY_WINDOW_US;
        ((self.duty_prev[xpu] * prev_weight + self.duty_cur[xpu]) / DUTY_WINDOW_US)
            .min(1.0)
    }

    /// Energy by accounting class: [reactive, proactive, graphics,
    /// idle] (J).  Invariant: sums to [`SocSim::total_energy_j`].
    pub fn energy_by_class(&self) -> [f64; 4] {
        self.class_energy_j
    }

    /// Busy time by kernel class [reactive, proactive, graphics] (µs),
    /// summed over XPUs.
    pub fn busy_by_class(&self) -> [f64; 3] {
        self.class_busy_us
    }

    /// Mean achieved DDR bandwidth since t=0 (GB/s).
    pub fn mean_bandwidth_gbps(&self) -> f64 {
        if self.now_us <= 0.0 { 0.0 } else { self.bw_integral_gb / (self.now_us * 1e-6) }
    }

    /// Instantaneous achieved DDR bandwidth (GB/s).
    pub fn current_bandwidth_gbps(&self) -> f64 {
        let s = self.scale();
        self.slots
            .iter()
            .flatten()
            .filter(|r| r.tm_left > EPS)
            .map(|r| r.bw_gbps * s)
            .sum()
    }

    pub fn snapshot(&self) -> Vec<XpuSnapshot> {
        (0..self.xpus.len())
            .map(|i| XpuSnapshot {
                name: self.xpus[i].name().to_string(),
                busy_us: self.busy_us[i],
                energy_j: self.energy_j[i],
                kernels: self.kernels[i],
                aborted: self.aborted[i],
            })
            .collect()
    }

    pub fn total_energy_j(&self) -> f64 {
        self.energy_j.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;
    use crate::model::{gemm_cost, gemv_cost};

    fn sim() -> SocSim {
        SocSim::new(&default_soc())
    }

    fn run_to_completion(sim: &mut SocSim) -> Vec<Completion> {
        let mut all = vec![];
        while sim.next_event_in().is_some() {
            all.extend(sim.advance_until(sim.now_us + 1e12));
        }
        all
    }

    #[test]
    fn standalone_kernel_matches_nominal() {
        let mut s = sim();
        let npu = s.xpu_index("npu").unwrap();
        let t = s.xpus[npu].timing(&gemm_cost(1024, 1024, 1024));
        s.launch(npu, LaunchSpec { timing: t, class: KernelClass::Proactive });
        let done = run_to_completion(&mut s);
        assert_eq!(done.len(), 1);
        assert!(
            (done[0].finished_us - t.nominal_us).abs() < 1.0,
            "got {} want {}",
            done[0].finished_us,
            t.nominal_us
        );
    }

    #[test]
    fn coexec_gemv_stretches_gemm_does_not() {
        // Fig. 3: memory-bound co-execution stretches; compute-bound
        // co-execution is latency-friendly.
        let soc = default_soc();

        // GEMM on NPU + GEMM on iGPU
        let mut s = SocSim::new(&soc);
        let (npu, igpu) = (s.xpu_index("npu").unwrap(), s.xpu_index("igpu").unwrap());
        let g = gemm_cost(2048, 2048, 2048);
        let tn = s.xpus[npu].timing(&g);
        let ti = s.xpus[igpu].timing(&g);
        s.launch(npu, LaunchSpec { timing: tn, class: KernelClass::Proactive });
        s.launch(igpu, LaunchSpec { timing: ti, class: KernelClass::Proactive });
        let done = run_to_completion(&mut s);
        for c in &done {
            let nominal = if c.xpu == npu { tn.nominal_us } else { ti.nominal_us };
            let stretch = (c.finished_us - c.started_us) / nominal;
            assert!(stretch < 1.05, "GEMM stretched {stretch}");
        }

        // GEMV on NPU + GEMV on iGPU: 60+70 GB/s demanded > 89.6 peak
        let mut s = SocSim::new(&soc);
        let v = gemv_cost(8192, 8192);
        let tn = s.xpus[npu].timing(&v);
        let ti = s.xpus[igpu].timing(&v);
        s.launch(npu, LaunchSpec { timing: tn, class: KernelClass::Proactive });
        s.launch(igpu, LaunchSpec { timing: ti, class: KernelClass::Proactive });
        let done = run_to_completion(&mut s);
        let mut stretched = 0;
        for c in &done {
            let nominal = if c.xpu == npu { tn.nominal_us } else { ti.nominal_us };
            let stretch = (c.finished_us - c.started_us) / nominal;
            if stretch > 1.2 {
                stretched += 1;
            }
        }
        assert!(stretched >= 1, "GEMV co-execution should stretch");
    }

    #[test]
    fn pressure_reflects_active_demands() {
        let mut s = sim();
        assert_eq!(s.memory_pressure(), 0.0);
        let igpu = s.xpu_index("igpu").unwrap();
        let t = s.xpus[igpu].timing(&gemv_cost(8192, 8192));
        s.launch(igpu, LaunchSpec { timing: t, class: KernelClass::Proactive });
        let p = s.memory_pressure();
        assert!(p > 0.5, "GEMV pressure {p}");
        run_to_completion(&mut s);
        assert_eq!(s.memory_pressure(), 0.0);
    }

    #[test]
    fn cancel_frees_the_slot() {
        let mut s = sim();
        let npu = s.xpu_index("npu").unwrap();
        let t = s.xpus[npu].timing(&gemm_cost(2048, 2048, 2048));
        let id = s.launch(npu, LaunchSpec { timing: t, class: KernelClass::Proactive });
        assert!(s.busy(npu));
        assert_eq!(s.cancel(npu), Some(id));
        assert!(!s.busy(npu));
        assert!(run_to_completion(&mut s).is_empty());
    }

    /// Regression (accounting bugfix): an aborted kernel must not be
    /// reported as completed — abort-heavy scheme-(a) runs used to
    /// over-report `XpuSnapshot::kernels`.
    #[test]
    fn cancel_mid_flight_counts_aborted_not_completed() {
        let mut s = sim();
        let npu = s.xpu_index("npu").unwrap();
        let t = s.xpus[npu].timing(&gemm_cost(2048, 2048, 2048));
        s.launch(npu, LaunchSpec { timing: t, class: KernelClass::Reactive });
        // run part of the kernel, then abort it mid-flight
        s.advance_until(t.nominal_us * 0.25);
        assert!(s.cancel(npu).is_some());
        // relaunch and complete a second kernel
        let id2 = s.launch(npu, LaunchSpec { timing: t, class: KernelClass::Reactive });
        let done = run_to_completion(&mut s);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].id, id2);
        let n = &s.snapshot()[npu];
        assert_eq!(n.kernels, 1, "only the completed kernel counts");
        assert_eq!(n.aborted, 1, "the abort is counted separately");
    }

    /// Satellite: energy spent by a kernel before `cancel` stays on the
    /// books (partial work drew real power), attributed to its class.
    #[test]
    fn partial_kernel_energy_survives_cancel() {
        let mut s = sim();
        let npu = s.xpu_index("npu").unwrap();
        let t = s.xpus[npu].timing(&gemm_cost(2048, 2048, 2048));
        s.launch(npu, LaunchSpec { timing: t, class: KernelClass::Proactive });
        let dt = t.nominal_us * 0.5;
        s.advance_until(dt);
        s.cancel(npu);
        let active = s.xpus[npu].cfg.active_power_w;
        let expect_j = active * dt * 1e-6;
        let got = s.energy_by_class()[KernelClass::Proactive.idx()];
        assert!(
            (got - expect_j).abs() / expect_j < 0.01,
            "partial proactive energy {got} want ~{expect_j}"
        );
        assert!((s.busy_by_class()[KernelClass::Proactive.idx()] - dt).abs() < 1.0);
    }

    /// Satellite: per-class energy attribution (reactive / proactive /
    /// graphics / idle) sums to `total_energy_j` even while co-executed
    /// memory phases stretch under DDR contention.
    #[test]
    fn class_attribution_sums_to_total_under_contention() {
        let mut s = sim();
        let npu = s.xpu_index("npu").unwrap();
        let igpu = s.xpu_index("igpu").unwrap();
        // two GEMVs oversubscribe the DDR link (60 + 70 > 89.6 GB/s)
        let tn = s.xpus[npu].timing(&gemv_cost(8192, 8192));
        let ti = s.xpus[igpu].timing(&gemv_cost(8192, 8192));
        s.launch(npu, LaunchSpec { timing: tn, class: KernelClass::Reactive });
        s.launch(igpu, LaunchSpec { timing: ti, class: KernelClass::Graphics });
        run_to_completion(&mut s);
        // idle tail so every class row is non-zero
        s.advance_until(s.now_us + 50_000.0);
        let by_class = s.energy_by_class();
        assert!(by_class[KernelClass::Reactive.idx()] > 0.0);
        assert!(by_class[KernelClass::Graphics.idx()] > 0.0);
        assert!(by_class[CLASS_IDLE] > 0.0);
        let sum: f64 = by_class.iter().sum();
        let total = s.total_energy_j();
        assert!(
            (sum - total).abs() < 1e-9 * total.max(1.0),
            "class sum {sum} != total {total}"
        );
        let busy = s.busy_by_class();
        assert!(busy[KernelClass::Reactive.idx()] > 0.0);
        assert!(busy[KernelClass::Graphics.idx()] > 0.0);
        assert_eq!(busy[KernelClass::Proactive.idx()], 0.0);
    }

    /// Satellite: with nothing running, all accrued energy is idle-class.
    #[test]
    fn idle_power_accrues_to_the_idle_class() {
        let mut s = sim();
        s.advance_until(100_000.0);
        let by_class = s.energy_by_class();
        let total = s.total_energy_j();
        assert!(total > 0.0);
        assert!((by_class[CLASS_IDLE] - total).abs() < 1e-12);
        assert_eq!(by_class[KernelClass::Reactive.idx()], 0.0);
        assert_eq!(s.busy_by_class(), [0.0; 3]);
    }

    /// The duty window tracks agentic occupancy and excludes graphics.
    #[test]
    fn windowed_duty_tracks_agentic_busy_only() {
        let mut s = sim();
        let igpu = s.xpu_index("igpu").unwrap();
        let t = s.xpus[igpu].timing(&gemv_cost(8192, 8192));
        s.launch(igpu, LaunchSpec { timing: t, class: KernelClass::Proactive });
        s.advance_until(20_000.0_f64.min(t.nominal_us * 0.9));
        assert!(s.windowed_duty(igpu) > 0.0, "agentic kernel fills the window");
        run_to_completion(&mut s);

        let mut g = sim();
        let t = g.xpus[igpu].timing(&gemv_cost(8192, 8192));
        g.launch(igpu, LaunchSpec { timing: t, class: KernelClass::Graphics });
        g.advance_until(20_000.0_f64.min(t.nominal_us * 0.9));
        assert_eq!(g.windowed_duty(igpu), 0.0, "graphics never charges the duty cap");
    }

    #[test]
    fn advance_without_work_jumps_clock() {
        let mut s = sim();
        let done = s.advance_until(5_000.0);
        assert!(done.is_empty());
        assert_eq!(s.now_us, 5_000.0);
        // idle power accrues
        assert!(s.total_energy_j() > 0.0);
    }

    #[test]
    fn determinism() {
        let mk = || {
            let mut s = sim();
            let npu = s.xpu_index("npu").unwrap();
            let igpu = s.xpu_index("igpu").unwrap();
            let t1 = s.xpus[npu].timing(&gemm_cost(1024, 1024, 1024));
            let t2 = s.xpus[igpu].timing(&gemv_cost(8192, 8192));
            s.launch(npu, LaunchSpec { timing: t1, class: KernelClass::Reactive });
            s.launch(igpu, LaunchSpec { timing: t2, class: KernelClass::Proactive });
            run_to_completion(&mut s)
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn energy_and_busy_accounting() {
        let mut s = sim();
        let npu = s.xpu_index("npu").unwrap();
        let t = s.xpus[npu].timing(&gemm_cost(1024, 1024, 1024));
        s.launch(npu, LaunchSpec { timing: t, class: KernelClass::Proactive });
        run_to_completion(&mut s);
        let snap = s.snapshot();
        let n = &snap[npu];
        assert_eq!(n.kernels, 1);
        assert!((n.busy_us - t.nominal_us).abs() < 1.0);
        // E ≈ P·t
        let expect_j = s.xpus[npu].cfg.active_power_w * t.nominal_us * 1e-6;
        assert!((n.energy_j - expect_j).abs() / expect_j < 0.01);
        assert!(s.peak_power_w >= s.xpus[npu].cfg.active_power_w);
    }

    #[test]
    fn mean_bandwidth_positive_under_load() {
        let mut s = sim();
        let igpu = s.xpu_index("igpu").unwrap();
        let t = s.xpus[igpu].timing(&gemv_cost(8192, 8192));
        s.launch(igpu, LaunchSpec { timing: t, class: KernelClass::Proactive });
        run_to_completion(&mut s);
        assert!(s.mean_bandwidth_gbps() > 10.0);
        assert!(s.current_bandwidth_gbps() == 0.0);
    }

    /// The co-run interference factor stretches a memory-bound kernel's
    /// memory phase by exactly the factor, even with the link
    /// unsaturated — a split is not free bandwidth.
    #[test]
    fn co_run_factor_stretches_memory_phase() {
        let mut s = sim();
        let igpu = s.xpu_index("igpu").unwrap();
        let t = s.xpus[igpu].timing(&gemv_cost(8192, 8192));
        assert!(t.tm_us > t.tc_us, "want a memory-bound kernel");
        s.launch_with_factor(
            igpu,
            LaunchSpec { timing: t, class: KernelClass::Proactive },
            CO_RUN_DDR_PENALTY_IGPU,
        );
        let done = run_to_completion(&mut s);
        assert_eq!(done.len(), 1);
        let want = t.tm_us * CO_RUN_DDR_PENALTY_IGPU;
        assert!(
            (done[0].finished_us - want).abs() < 1.0,
            "got {} want {want}",
            done[0].finished_us
        );
    }

    /// `launch` is `launch_with_factor(.., 1.0)` — bit-for-bit, so
    /// non-co-run schedules are provably unchanged by the factor path.
    #[test]
    fn unit_co_run_factor_is_bit_identical_to_plain_launch() {
        let run = |unit_factor: bool| {
            let mut s = sim();
            let npu = s.xpu_index("npu").unwrap();
            let igpu = s.xpu_index("igpu").unwrap();
            let tn = s.xpus[npu].timing(&gemv_cost(8192, 8192));
            let ti = s.xpus[igpu].timing(&gemv_cost(8192, 8192));
            if unit_factor {
                s.launch_with_factor(
                    npu,
                    LaunchSpec { timing: tn, class: KernelClass::Reactive },
                    1.0,
                );
            } else {
                s.launch(npu, LaunchSpec { timing: tn, class: KernelClass::Reactive });
            }
            s.launch(igpu, LaunchSpec { timing: ti, class: KernelClass::Proactive });
            run_to_completion(&mut s)
        };
        assert_eq!(run(true), run(false));
    }

    /// The asymmetric penalties: NPU side worse than iGPU side, both > 1.
    #[test]
    fn co_run_penalties_are_asymmetric() {
        assert!(CO_RUN_DDR_PENALTY_NPU > CO_RUN_DDR_PENALTY_IGPU);
        assert!(CO_RUN_DDR_PENALTY_IGPU > 1.0);
    }

    #[test]
    #[should_panic(expected = "already busy")]
    fn double_launch_panics() {
        let mut s = sim();
        let t = s.xpus[0].timing(&gemm_cost(64, 64, 64));
        s.launch(0, LaunchSpec { timing: t, class: KernelClass::Proactive });
        s.launch(0, LaunchSpec { timing: t, class: KernelClass::Proactive });
    }
}
