//! The hetero-SoC substrate: virtual accelerators with roofline timing,
//! a shared-DDR bandwidth arbiter with proportional contention, a power
//! model with per-class energy attribution (reactive / proactive /
//! graphics / idle), a synthetic display workload with frame-deadline
//! (jank) accounting, and the discrete-event simulator the engines
//! schedule against.
//!
//! DESIGN.md §1 explains the substitution: the paper's Intel Core Ultra
//! NPU/iGPU are unavailable, so *timing* comes from these calibrated
//! models while kernel *numerics* still execute for real on PJRT CPU.
//! All experiment figures are reported in this virtual time, which makes
//! the reproduction deterministic.

mod graphics;
mod sim;
mod xpu;

pub use graphics::{GraphicsConfig, GraphicsSim};
pub use sim::{
    CLASS_IDLE, CO_RUN_DDR_PENALTY_IGPU, CO_RUN_DDR_PENALTY_NPU, Completion, DUTY_WINDOW_US,
    KernelClass, LaunchSpec, RunId, SocSim, XpuSnapshot,
};
pub use xpu::{KernelTiming, XpuModel};
