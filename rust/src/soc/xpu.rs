//! Virtual accelerator timing: KernelCost → roofline execution profile
//! on a given XPU.  This is the paper's *standalone execution time* and
//! *memory bandwidth utilization* annotation (§5.3), parameterized by
//! the op-XPU affinities measured in §3.1.

use crate::config::XpuConfig;
use crate::model::KernelCost;

/// How a kernel runs on one XPU, before memory contention.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelTiming {
    /// Pure-compute time at this XPU's effective throughput (µs).
    pub tc_us: f64,
    /// Pure-memory time at this XPU's standalone bandwidth (µs).
    pub tm_us: f64,
    /// Standalone (uncontended) duration: launch + max(tc, tm) (µs).
    pub nominal_us: f64,
    /// Bandwidth this kernel draws while its memory phase runs (GB/s).
    pub bw_gbps: f64,
    /// Dynamic power while the kernel runs (W).
    pub power_w: f64,
}

/// A virtual accelerator (thin wrapper adding behaviour to the config).
#[derive(Debug, Clone)]
pub struct XpuModel {
    pub cfg: XpuConfig,
}

impl XpuModel {
    pub fn new(cfg: XpuConfig) -> Self {
        Self { cfg }
    }

    pub fn name(&self) -> &str {
        &self.cfg.name
    }

    /// Can this XPU execute the kernel at all?  (Dynamic kernels are
    /// *possible* on a static-only NPU, but pay the JIT cost.)
    pub fn runs_natively(&self, cost: &KernelCost) -> bool {
        self.cfg.supports_dynamic || !cost.is_dynamic
    }

    /// Roofline timing of `cost` on this XPU (standalone).
    pub fn timing(&self, cost: &KernelCost) -> KernelTiming {
        let c = &self.cfg;
        let gemm_rate = c.peak_tflops * 1e12 * c.gemm_efficiency * c.util_cap;
        let attn_rate = c.peak_tflops * 1e12 * c.attn_efficiency * c.util_cap;
        let mut tc_us =
            (cost.gemm_flops / gemm_rate + cost.attn_flops / attn_rate) * 1e6;
        if cost.is_dynamic && !c.supports_dynamic {
            // amortized JIT compilation of a dynamic-shape kernel (§3.1)
            tc_us += c.jit_compile_ms * 1e3;
        }
        let tm_us = cost.bytes / (c.max_bw_gbps * 1e9) * 1e6;
        // Launch overhead serializes with compute (it is host-side work);
        // the memory phase can overlap it.  This matches the simulator's
        // progress model exactly: duration = max(tc + launch, tm).
        let body = (tc_us + c.launch_overhead_us).max(tm_us);
        let nominal_us = body;
        // Bandwidth demand: traffic spread over the body duration,
        // capped at the XPU's link width.
        let bw_gbps = if body > 0.0 {
            (cost.bytes / (body * 1e-6) / 1e9).min(c.max_bw_gbps)
        } else {
            0.0
        };
        KernelTiming {
            tc_us,
            tm_us,
            nominal_us,
            bw_gbps,
            power_w: c.active_power_w,
        }
    }

    /// Achieved FLOP/s of `cost` on this XPU (standalone) — the roofline
    /// y-axis of the paper's op-XPU affinity study.
    pub fn achieved_tflops(&self, cost: &KernelCost) -> f64 {
        let t = self.timing(cost);
        cost.total_flops() / (t.nominal_us * 1e-6) / 1e12
    }

    /// Energy efficiency (TFLOPS/W) — the backfill candidate ranking
    /// metric (§6.3) and the second roofline axis.
    pub fn tflops_per_watt(&self, cost: &KernelCost) -> f64 {
        self.achieved_tflops(cost) / self.cfg.active_power_w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;
    use crate::model::{decode_iter_cost, gemm_cost, gemv_cost, mha_cost, prefill_layer_cost};
    use crate::config::ModelGeometry;

    fn geo() -> ModelGeometry {
        ModelGeometry {
            name: "small".into(),
            vocab: 2048,
            d_model: 256,
            n_layers: 6,
            n_q_heads: 8,
            n_kv_heads: 2,
            head_dim: 32,
            d_ffn: 704,
            max_seq: 512,
            chunk_sizes: vec![16, 32, 64, 128],
            batch_sizes: vec![1, 2, 4, 8],
            rope_theta: 10000.0,
            weight_bytes: 4.0,
        }
    }

    fn npu() -> XpuModel {
        XpuModel::new(default_soc().xpu("npu").unwrap().clone())
    }
    fn igpu() -> XpuModel {
        XpuModel::new(default_soc().xpu("igpu").unwrap().clone())
    }

    #[test]
    fn npu_beats_igpu_on_static_gemm() {
        // §3.1: "For GEMM, NPU manifests superior efficiency"
        let c = gemm_cost(4096, 4096, 4096);
        assert!(npu().achieved_tflops(&c) > igpu().achieved_tflops(&c));
        assert!(npu().tflops_per_watt(&c) > 3.0 * igpu().tflops_per_watt(&c));
    }

    #[test]
    fn igpu_beats_npu_on_dynamic_mha() {
        // §3.1: "MHA poses a significant performance bottleneck for the NPU"
        let c = mha_cost(&geo(), 256, 256);
        assert!(igpu().achieved_tflops(&c) > 2.0 * npu().achieved_tflops(&c));
    }

    #[test]
    fn npu_pays_jit_on_dynamic_kernels() {
        let g = geo();
        let static_k = prefill_layer_cost(&g, 64, 64, 0, false);
        let dynamic_k = prefill_layer_cost(&g, 64, 64, 0, true);
        let n = npu();
        let dt = n.timing(&dynamic_k).nominal_us - n.timing(&static_k).nominal_us;
        assert!(dt >= n.cfg.jit_compile_ms * 1e3 * 0.99, "JIT not charged: {dt}");
        // iGPU charges nothing extra
        let i = igpu();
        assert!(
            (i.timing(&dynamic_k).nominal_us - i.timing(&static_k).nominal_us).abs() < 1e-6
        );
    }

    #[test]
    fn gemv_saturates_bandwidth_gemm_does_not() {
        // Fig. 3 premise: memory-bound GEMV demands ~max link bandwidth.
        let i = igpu();
        let gemv = i.timing(&gemv_cost(4096, 4096));
        assert!(gemv.bw_gbps > 0.9 * i.cfg.max_bw_gbps, "{}", gemv.bw_gbps);
        let gemm = i.timing(&gemm_cost(4096, 4096, 4096));
        assert!(gemm.bw_gbps < 0.3 * i.cfg.max_bw_gbps, "{}", gemm.bw_gbps);
    }

    #[test]
    fn decode_iter_on_igpu_is_memory_bound() {
        let g = geo();
        let t = igpu().timing(&decode_iter_cost(&g, 1, 256));
        assert!(t.tm_us > t.tc_us);
    }

    #[test]
    fn prefill_chunk_meets_latency_budget() {
        // §6.2: chunking keeps each prefill kernel under ~100 ms.
        let g = geo();
        let worst = prefill_layer_cost(&g, 128, 128, g.max_seq - 128, false);
        let t = npu().timing(&worst);
        assert!(t.nominal_us < 100_000.0, "{} µs", t.nominal_us);
    }

    #[test]
    fn timing_monotone_in_flops() {
        let g = geo();
        let small = prefill_layer_cost(&g, 16, 16, 0, false);
        let big = prefill_layer_cost(&g, 128, 128, 0, false);
        let n = npu();
        assert!(n.timing(&big).nominal_us > n.timing(&small).nominal_us);
    }
}
