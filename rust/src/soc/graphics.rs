//! Synthetic graphics workload: the display frames the paper's
//! "controlled iGPU usage" claim protects (§1, §8.1).
//!
//! A [`GraphicsSim`] renders one frame per vsync period on the iGPU.
//! Each frame is an ordinary SoC kernel — it occupies the iGPU slot and
//! draws DDR bandwidth through the shared arbiter, so agentic kernels
//! and frames stretch each other exactly like any co-executing pair.
//! Interference shows up as *jank*: a frame misses when it finishes
//! after its vsync deadline (the next frame's due instant plus one
//! period), is dropped because its deadline passed before it could even
//! launch (the iGPU was held by an agentic kernel), or is aborted
//! mid-render by a preempting policy.
//!
//! The driver services frames with compositor priority: a due frame
//! launches the moment the iGPU is free, *before* the scheduling policy
//! gets its decision pass.  What the scheduler controls is how often
//! the iGPU is free — the `igpu_duty_cap` / `yield_to_graphics` knobs
//! (see `SchedPolicy::igpu_proactive_grant`).
//!
//! Virtual-clock (DES) runs only: frame timing lives on the simulated
//! SoC clock.

use crate::config::XpuConfig;

use super::sim::{Completion, KernelClass, LaunchSpec, RunId, SocSim};
use super::xpu::KernelTiming;

const EPS: f64 = 1e-6;

/// Shape of the synthetic display workload.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphicsConfig {
    /// Refresh rate (frames per second).
    pub fps: f64,
    /// Compute per frame (FLOPs on the iGPU's GEMM roofline).
    pub frame_flops: f64,
    /// DDR traffic per frame (bytes) — contends like any kernel.
    pub frame_bytes: f64,
    /// Power draw while a frame renders (W).
    pub render_power_w: f64,
}

impl Default for GraphicsConfig {
    /// A light desktop compositor at 60 Hz: ~2-3 ms standalone per
    /// 16.7 ms period (≈ 16 % iGPU duty, ~150 MB of DDR traffic per
    /// frame) — plenty of headroom alone, janky the moment agentic
    /// kernels squat on the iGPU across vsync.
    fn default() -> Self {
        Self {
            fps: 60.0,
            frame_flops: 2.0e10,
            frame_bytes: 1.5e8,
            render_power_w: 12.0,
        }
    }
}

impl GraphicsConfig {
    pub fn period_us(&self) -> f64 {
        1e6 / self.fps
    }

    /// Standalone roofline timing of one frame on the iGPU.  Unlike
    /// agentic kernels this is *not* derated by `util_cap` — the cap
    /// exists to preserve graphics throughput, not to tax it.
    pub fn frame_timing(&self, igpu: &XpuConfig) -> KernelTiming {
        let tc_us =
            self.frame_flops / (igpu.peak_tflops * 1e12 * igpu.gemm_efficiency) * 1e6;
        let tm_us = self.frame_bytes / (igpu.max_bw_gbps * 1e9) * 1e6;
        let body = (tc_us + igpu.launch_overhead_us).max(tm_us);
        let bw_gbps = if body > 0.0 {
            (self.frame_bytes / (body * 1e-6) / 1e9).min(igpu.max_bw_gbps)
        } else {
            0.0
        };
        KernelTiming { tc_us, tm_us, nominal_us: body, bw_gbps, power_w: self.render_power_w }
    }
}

/// Frame scheduler + jank accounting over one run.
#[derive(Debug, Clone)]
pub struct GraphicsSim {
    timing: KernelTiming,
    period_us: f64,
    /// Due instant of the next frame not yet launched.
    next_due_us: f64,
    /// In-flight frame: (sim run id, vsync deadline).
    inflight: Option<(RunId, f64)>,
    /// Frames scheduled so far: launched + dropped.
    pub frames_scheduled: u64,
    /// Frames that missed their deadline (late, dropped, or aborted).
    pub frames_missed: u64,
}

impl GraphicsSim {
    pub fn new(cfg: &GraphicsConfig, igpu: &XpuConfig) -> Self {
        Self {
            timing: cfg.frame_timing(igpu),
            period_us: cfg.period_us(),
            next_due_us: 0.0,
            inflight: None,
            frames_scheduled: 0,
            frames_missed: 0,
        }
    }

    pub fn period_us(&self) -> f64 {
        self.period_us
    }

    /// The next instant the DES must stop at to launch a frame
    /// (`None` while one is in flight — the next event is then its
    /// completion, which the SoC already tracks).
    pub fn next_launch_due(&self) -> Option<f64> {
        match self.inflight {
            Some(_) => None,
            None => Some(self.next_due_us),
        }
    }

    /// Would a kernel of `nominal_us` launched at `now_us` run past the
    /// next frame's due instant?  The `yield_to_graphics` gate's
    /// question.
    pub fn would_delay_next_frame(&self, now_us: f64, nominal_us: f64) -> bool {
        now_us + nominal_us > self.next_due_us + EPS
    }

    /// Launch the due frame if the iGPU is free, dropping (and counting
    /// as missed) any backlog whose deadline already passed unlaunched.
    pub fn try_launch(&mut self, sim: &mut SocSim, igpu: usize) {
        if self.inflight.is_some() {
            return;
        }
        let now = sim.now_us;
        // Frame k (due t_k) is hopeless once t_k + period passes without
        // a launch: the compositor drops it — one miss, no render cost.
        while self.next_due_us + self.period_us <= now + EPS {
            self.frames_scheduled += 1;
            self.frames_missed += 1;
            self.next_due_us += self.period_us;
        }
        if now + EPS < self.next_due_us || sim.busy(igpu) {
            return;
        }
        let run = sim.launch(
            igpu,
            LaunchSpec { timing: self.timing, class: KernelClass::Graphics },
        );
        self.frames_scheduled += 1;
        self.inflight = Some((run, self.next_due_us + self.period_us));
        self.next_due_us += self.period_us;
    }

    /// Fold a kernel completion; returns true when it was the in-flight
    /// frame (and accounts the deadline miss if it finished late).
    pub fn on_completion(&mut self, c: &Completion) -> bool {
        match self.inflight {
            Some((run, deadline)) if run == c.id => {
                if c.finished_us > deadline + EPS {
                    self.frames_missed += 1;
                }
                self.inflight = None;
                true
            }
            _ => false,
        }
    }

    /// A policy aborted the in-flight frame (scheme-(a) style instant
    /// preemption): it never reaches the display — a miss.  Returns
    /// true when `run` was the frame.
    pub fn on_abort(&mut self, run: RunId) -> bool {
        match self.inflight {
            Some((r, _)) if r == run => {
                self.frames_missed += 1;
                self.inflight = None;
                true
            }
            _ => false,
        }
    }

    /// Jank rate so far: missed / scheduled (0 before the first frame).
    pub fn frame_miss_rate(&self) -> f64 {
        if self.frames_scheduled == 0 {
            0.0
        } else {
            self.frames_missed as f64 / self.frames_scheduled as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::default_soc;
    use crate::model::gemv_cost;

    fn setup() -> (SocSim, GraphicsSim, usize) {
        let soc = default_soc();
        let sim = SocSim::new(&soc);
        let igpu = sim.xpu_index("igpu").unwrap();
        let g = GraphicsSim::new(&GraphicsConfig::default(), soc.xpu("igpu").unwrap());
        (sim, g, igpu)
    }

    /// Drive sim + graphics together until `t_end` (the driver's loop
    /// in miniature).
    fn drive_until(sim: &mut SocSim, g: &mut GraphicsSim, igpu: usize, t_end: f64) {
        loop {
            g.try_launch(sim, igpu);
            let next_frame = g.next_launch_due().filter(|&t| t > sim.now_us + EPS);
            let next_fin = sim.next_event_in().map(|dt| sim.now_us + dt);
            let target = match (next_fin, next_frame) {
                (Some(f), Some(fr)) => f.min(fr),
                (Some(f), None) => f,
                (None, Some(fr)) => fr,
                (None, None) => t_end,
            };
            if target >= t_end {
                sim.advance_until(t_end);
                return;
            }
            sim.advance_until(target);
            // fold any frame completion
            while let Some((run, _)) = g.inflight {
                if sim.xpu_of(run).is_some() {
                    break; // still running
                }
                // completed exactly at now
                g.on_completion(&Completion {
                    id: run,
                    xpu: igpu,
                    started_us: 0.0,
                    finished_us: sim.now_us,
                });
            }
        }
    }

    #[test]
    fn frames_render_on_time_on_an_idle_soc() {
        let (mut sim, mut g, igpu) = setup();
        drive_until(&mut sim, &mut g, igpu, 500_000.0);
        // ~30 frames at 60 Hz over 0.5 s, none missed
        assert!(g.frames_scheduled >= 29, "{} frames", g.frames_scheduled);
        assert_eq!(g.frames_missed, 0);
        assert_eq!(g.frame_miss_rate(), 0.0);
        // frames carry real energy, attributed to the graphics class
        assert!(sim.energy_by_class()[KernelClass::Graphics.idx()] > 0.0);
    }

    #[test]
    fn igpu_squatter_drops_frames() {
        let (mut sim, mut g, igpu) = setup();
        // a long agentic kernel holds the iGPU across several vsyncs
        let mut t = sim.xpus[igpu].timing(&gemv_cost(8192, 8192));
        t.tc_us = 80_000.0; // stretch it to ~5 frame periods
        t.nominal_us = 80_000.0;
        sim.launch(igpu, LaunchSpec { timing: t, class: KernelClass::Proactive });
        drive_until(&mut sim, &mut g, igpu, 100_000.0);
        assert!(
            g.frames_missed >= 3,
            "frames due under the squatter must miss ({} missed)",
            g.frames_missed
        );
        assert!(g.frame_miss_rate() > 0.0);
    }

    #[test]
    fn aborted_frame_counts_as_missed() {
        let (mut sim, mut g, igpu) = setup();
        g.try_launch(&mut sim, igpu);
        let (run, _) = g.inflight.expect("frame launched at t=0");
        sim.cancel(igpu);
        assert!(g.on_abort(run));
        assert_eq!(g.frames_missed, 1);
        assert!(g.inflight.is_none());
        assert!(g.next_launch_due().is_some(), "the next frame still schedules");
    }

    #[test]
    fn frame_timing_ignores_util_cap() {
        let soc = default_soc();
        let igpu = soc.xpu("igpu").unwrap();
        let cfg = GraphicsConfig::default();
        let t = cfg.frame_timing(igpu);
        assert!(t.nominal_us < cfg.period_us() * 0.5, "a lone frame fits easily");
        // derate-free: compute time uses the full GEMM roofline
        let full_rate = igpu.peak_tflops * 1e12 * igpu.gemm_efficiency;
        let expect_tc = cfg.frame_flops / full_rate * 1e6;
        assert!((t.tc_us - expect_tc).abs() < 1e-6);
    }

    #[test]
    fn would_delay_detects_vsync_overlap() {
        let (_sim, g, _igpu) = setup();
        // next frame due at t=0: anything launched now overlaps
        assert!(g.would_delay_next_frame(0.0, 1_000.0));
        let mut g2 = g.clone();
        g2.next_due_us = 16_667.0;
        assert!(!g2.would_delay_next_frame(0.0, 10_000.0));
        assert!(g2.would_delay_next_frame(0.0, 20_000.0));
    }
}
