//! Per-request and per-run measurement containers shared by every
//! engine, plus the aggregates the figure harnesses print.

use crate::soc::XpuSnapshot;
use crate::util::json::Json;
use crate::workload::{Priority, ReqId};

/// Lifecycle timestamps of one served request (virtual µs).
#[derive(Debug, Clone)]
pub struct ReqMetrics {
    pub id: ReqId,
    pub priority: Priority,
    pub profile: &'static str,
    pub arrival_us: f64,
    /// TTFT reference point: prefill completion / first token.
    pub first_token_us: Option<f64>,
    pub done_us: Option<f64>,
    pub input_len: usize,
    pub output_tokens: usize,
}

impl ReqMetrics {
    pub fn ttft_us(&self) -> Option<f64> {
        self.first_token_us.map(|t| t - self.arrival_us)
    }

    /// The paper's normalized latency: TTFT / input length (ms/token).
    pub fn normalized_latency_ms(&self) -> Option<f64> {
        self.ttft_us().map(|t| t / 1e3 / self.input_len as f64)
    }

    /// Mean time per output token after the first (ms).
    pub fn tpot_ms(&self) -> Option<f64> {
        match (self.first_token_us, self.done_us) {
            (Some(f), Some(d)) if self.output_tokens > 1 => {
                Some((d - f) / 1e3 / (self.output_tokens - 1) as f64)
            }
            _ => None,
        }
    }

    pub fn e2e_us(&self) -> Option<f64> {
        self.done_us.map(|d| d - self.arrival_us)
    }

    pub fn finished(&self) -> bool {
        self.done_us.is_some()
    }
}

/// Everything one engine run produces.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub engine: String,
    pub reqs: Vec<ReqMetrics>,
    pub xpus: Vec<XpuSnapshot>,
    pub makespan_us: f64,
    pub total_energy_j: f64,
    pub peak_power_w: f64,
    pub mean_bw_gbps: f64,
    /// Proactive-task preemption count (scheduler introspection).
    pub preemptions: u64,
    /// Kernels launched via slack-aware backfill.
    pub backfills: u64,
}

pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// Aggregate statistics over a priority class.
#[derive(Debug, Clone)]
pub struct Aggregate {
    pub count: usize,
    pub finished: usize,
    pub mean_norm_latency_ms: f64,
    pub p95_norm_latency_ms: f64,
    pub mean_ttft_ms: f64,
    pub mean_tpot_ms: f64,
    pub tokens_per_s: f64,
    pub reqs_per_s: f64,
}

impl RunReport {
    pub fn class(&self, p: Priority) -> Aggregate {
        let sel: Vec<&ReqMetrics> =
            self.reqs.iter().filter(|r| r.priority == p).collect();
        let fin: Vec<&ReqMetrics> = sel.iter().copied().filter(|r| r.finished()).collect();
        let mut norms: Vec<f64> =
            fin.iter().filter_map(|r| r.normalized_latency_ms()).collect();
        norms.sort_by(|a, b| a.total_cmp(b));
        let mean = |xs: &[f64]| {
            if xs.is_empty() { f64::NAN } else { xs.iter().sum::<f64>() / xs.len() as f64 }
        };
        let ttfts: Vec<f64> =
            fin.iter().filter_map(|r| r.ttft_us().map(|t| t / 1e3)).collect();
        let tpots: Vec<f64> = fin.iter().filter_map(|r| r.tpot_ms()).collect();
        let span_s = (self.makespan_us / 1e6).max(1e-9);
        let tokens: usize = fin.iter().map(|r| r.output_tokens).sum();
        Aggregate {
            count: sel.len(),
            finished: fin.len(),
            mean_norm_latency_ms: mean(&norms),
            p95_norm_latency_ms: percentile(&norms, 0.95),
            mean_ttft_ms: mean(&ttfts),
            mean_tpot_ms: mean(&tpots),
            tokens_per_s: tokens as f64 / span_s,
            reqs_per_s: fin.len() as f64 / span_s,
        }
    }

    /// Total generated tokens (all classes).
    pub fn total_tokens(&self) -> usize {
        self.reqs.iter().filter(|r| r.finished()).map(|r| r.output_tokens).sum()
    }

    /// Energy per generated token (J/token) — the paper's efficiency
    /// metric (§8.1).
    pub fn joules_per_token(&self) -> f64 {
        let t = self.total_tokens();
        if t == 0 { f64::NAN } else { self.total_energy_j / t as f64 }
    }

    /// Fraction of the makespan each XPU was busy.
    pub fn utilization(&self, name: &str) -> f64 {
        self.xpus
            .iter()
            .find(|x| x.name == name)
            .map(|x| x.busy_us / self.makespan_us.max(1e-9))
            .unwrap_or(0.0)
    }

    pub fn to_json(&self) -> Json {
        let cls = |p: Priority| {
            let a = self.class(p);
            Json::obj()
                .set("count", a.count)
                .set("finished", a.finished)
                .set("mean_norm_latency_ms", a.mean_norm_latency_ms)
                .set("p95_norm_latency_ms", a.p95_norm_latency_ms)
                .set("mean_ttft_ms", a.mean_ttft_ms)
                .set("mean_tpot_ms", a.mean_tpot_ms)
                .set("tokens_per_s", a.tokens_per_s)
                .set("reqs_per_s", a.reqs_per_s)
        };
        Json::obj()
            .set("engine", self.engine.as_str())
            .set("makespan_s", self.makespan_us / 1e6)
            .set("reactive", cls(Priority::Reactive))
            .set("proactive", cls(Priority::Proactive))
            .set("total_energy_j", self.total_energy_j)
            .set("peak_power_w", self.peak_power_w)
            .set("joules_per_token", self.joules_per_token())
            .set("mean_bw_gbps", self.mean_bw_gbps)
            .set("preemptions", self.preemptions as usize)
            .set("backfills", self.backfills as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, p: Priority, arr: f64, ttft: f64, done: f64, il: usize, ot: usize) -> ReqMetrics {
        ReqMetrics {
            id,
            priority: p,
            profile: "test",
            arrival_us: arr,
            first_token_us: Some(arr + ttft),
            done_us: Some(arr + done),
            input_len: il,
            output_tokens: ot,
        }
    }

    fn report(reqs: Vec<ReqMetrics>) -> RunReport {
        RunReport {
            engine: "test".into(),
            reqs,
            xpus: vec![],
            makespan_us: 2e6,
            total_energy_j: 10.0,
            peak_power_w: 20.0,
            mean_bw_gbps: 30.0,
            preemptions: 0,
            backfills: 0,
        }
    }

    #[test]
    fn normalized_latency_is_ttft_over_len() {
        let r = req(1, Priority::Reactive, 1000.0, 50_000.0, 100_000.0, 100, 10);
        assert!((r.normalized_latency_ms().unwrap() - 0.5).abs() < 1e-9);
        assert!((r.ttft_us().unwrap() - 50_000.0).abs() < 1e-9);
    }

    #[test]
    fn tpot_excludes_first_token() {
        let r = req(1, Priority::Reactive, 0.0, 10_000.0, 100_000.0, 10, 10);
        // 90 ms over 9 tokens
        assert!((r.tpot_ms().unwrap() - 10.0).abs() < 1e-9);
        let single = req(2, Priority::Reactive, 0.0, 10_000.0, 10_000.0, 10, 1);
        assert!(single.tpot_ms().is_none());
    }

    #[test]
    fn class_aggregates_split_priorities() {
        let rep = report(vec![
            req(1, Priority::Reactive, 0.0, 20_000.0, 50_000.0, 20, 5),
            req(2, Priority::Proactive, 0.0, 200_000.0, 500_000.0, 100, 50),
            req(3, Priority::Proactive, 0.0, 400_000.0, 900_000.0, 100, 45),
        ]);
        let r = rep.class(Priority::Reactive);
        let p = rep.class(Priority::Proactive);
        assert_eq!(r.count, 1);
        assert_eq!(p.count, 2);
        assert!((r.mean_norm_latency_ms - 1.0).abs() < 1e-9);
        assert!((p.mean_norm_latency_ms - 3.0).abs() < 1e-9);
        // 95 tokens over 2 s
        assert!((p.tokens_per_s - 47.5).abs() < 1e-9);
    }

    #[test]
    fn joules_per_token() {
        let rep = report(vec![req(1, Priority::Proactive, 0.0, 1.0, 2.0, 10, 5)]);
        assert!((rep.joules_per_token() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn unfinished_requests_excluded_from_aggregates() {
        let mut m = req(1, Priority::Reactive, 0.0, 1.0, 2.0, 10, 5);
        m.first_token_us = None;
        m.done_us = None;
        let rep = report(vec![m]);
        let a = rep.class(Priority::Reactive);
        assert_eq!(a.count, 1);
        assert_eq!(a.finished, 0);
        assert!(a.mean_norm_latency_ms.is_nan());
    }

    #[test]
    fn report_serializes() {
        let rep = report(vec![req(1, Priority::Reactive, 0.0, 1000.0, 2000.0, 10, 5)]);
        let j = rep.to_json();
        assert_eq!(j.get("engine").unwrap().as_str().unwrap(), "test");
        assert!(j.get("reactive").unwrap().get("mean_ttft_ms").is_ok());
    }
}
