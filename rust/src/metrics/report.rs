//! Per-request and per-run measurement containers shared by every
//! engine, plus the aggregates the figure harnesses print: per-class
//! latency/throughput, and flow-level rollups (per-flow end-to-end
//! latency, per-turn TTFT, prefix-cache hit-rate, reused vs recomputed
//! prefill tokens — DESIGN.md §3).

use crate::soc::{CLASS_IDLE, KernelClass, XpuSnapshot};
use crate::util::json::Json;
use crate::workload::{FlowId, Priority, ProfileTag, ReqId};

/// Lifecycle timestamps of one served request (virtual µs).
#[derive(Debug, Clone)]
pub struct ReqMetrics {
    pub id: ReqId,
    pub priority: Priority,
    pub profile: ProfileTag,
    /// Flow/session membership (None for single-shot requests).
    pub flow_id: Option<FlowId>,
    /// Node index within the flow DAG (0 for single-shot requests).
    pub turn_idx: usize,
    /// Resolved DAG predecessors within the flow (empty for roots and
    /// single-shot requests) — feeds the per-flow critical-path rollup.
    pub deps: Vec<usize>,
    /// Think-time on the edge into this node (µs; 0 for roots).
    pub think_time_us: f64,
    /// CPU tool-call node: no prefill/decode, TTFT point = completion.
    /// Excluded from the per-class LLM latency aggregates.
    pub tool: bool,
    pub arrival_us: f64,
    /// TTFT reference point: prefill completion / first token.
    pub first_token_us: Option<f64>,
    pub done_us: Option<f64>,
    pub input_len: usize,
    pub output_tokens: usize,
    /// Prompt tokens served from the session cache (0 = no reuse).
    pub cached_prefix_len: usize,
    /// Prompt tokens actually pushed through prefill kernels — equals
    /// `input_len` under full recompute, `input_len - cached_prefix_len`
    /// under session reuse, and *more* than `input_len` if an eviction
    /// forced a restart.
    pub prefill_tokens: usize,
    /// The request was aborted via `cancel` (never counts as finished).
    pub cancelled: bool,
}

impl ReqMetrics {
    pub fn ttft_us(&self) -> Option<f64> {
        self.first_token_us.map(|t| t - self.arrival_us)
    }

    /// The paper's normalized latency: TTFT / input length (ms/token).
    pub fn normalized_latency_ms(&self) -> Option<f64> {
        self.ttft_us().map(|t| t / 1e3 / self.input_len as f64)
    }

    /// Mean time per output token after the first (ms).
    pub fn tpot_ms(&self) -> Option<f64> {
        match (self.first_token_us, self.done_us) {
            (Some(f), Some(d)) if self.output_tokens > 1 => {
                Some((d - f) / 1e3 / (self.output_tokens - 1) as f64)
            }
            _ => None,
        }
    }

    pub fn e2e_us(&self) -> Option<f64> {
        self.done_us.map(|d| d - self.arrival_us)
    }

    pub fn finished(&self) -> bool {
        self.done_us.is_some()
    }
}

/// Everything one engine run produces.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub engine: String,
    pub reqs: Vec<ReqMetrics>,
    pub xpus: Vec<XpuSnapshot>,
    pub makespan_us: f64,
    pub total_energy_j: f64,
    /// Energy attribution by accounting class: [reactive, proactive,
    /// graphics, idle] (J) — sums to `total_energy_j`.  Attribution is
    /// kernel-granular: a decode batch carrying any reactive lane is
    /// reactive-class.
    pub energy_by_class: [f64; 4],
    /// Busy time by kernel class [reactive, proactive, graphics] (µs),
    /// summed over XPUs.
    pub busy_by_class: [f64; 3],
    /// Graphics frames scheduled during the run (rendered + dropped);
    /// 0 without a display workload.
    pub frames_scheduled: u64,
    /// Frames that missed their vsync deadline (finished late, were
    /// dropped unlaunched, or were aborted mid-render) — the jank the
    /// paper's "controlled iGPU usage" minimizes.
    pub frames_missed: u64,
    pub peak_power_w: f64,
    pub mean_bw_gbps: f64,
    /// Proactive-task preemption count (scheduler introspection).
    pub preemptions: u64,
    /// Kernels launched via slack-aware backfill.
    pub backfills: u64,
    /// In-flight prefills whose KV the memory governor evicted.
    pub kv_evictions: u64,
    /// Idle retained sessions the memory governor dropped.
    pub session_evictions: u64,
    /// Requests aborted via `cancel`.
    pub cancellations: u64,
    /// Elastic re-binding events (§5.2): folds + splits — every time a
    /// planned chunk changed shape or XPU binding mid-flight.
    pub rebinds: u64,
    /// Head chunks split across NPU+iGPU (subset of `rebinds`).
    pub splits: u64,
    /// Prompt tokens moved to co-run iGPU slices by those splits.
    pub split_tokens: u64,
    /// Retired request metrics shed from the bounded wall-clock history
    /// before `finish()` — `reqs` is truncated by exactly this many
    /// (the incremental `ReportAccumulator` remains exact).  Always 0
    /// for virtual-clock runs.
    pub dropped_reqs: u64,
}

/// Rollup of one workflow DAG (a multi-turn flow is the linear case).
#[derive(Debug, Clone)]
pub struct FlowStats {
    pub flow_id: FlowId,
    /// All nodes observed for the flow (LLM turns + tool calls).
    pub turns: usize,
    /// CPU tool-call nodes among them.
    pub tool_turns: usize,
    pub finished: bool,
    /// DAG makespan: first node arrival → last node completion
    /// (includes think-time).
    pub e2e_us: Option<f64>,
    /// Critical-path lower bound on the makespan: the longest
    /// dependency chain of observed per-node latencies + think-times.
    /// `e2e / critical_path ≥ 1`; the gap is scheduling-induced
    /// serialization of parallelizable branches.
    pub critical_path_us: Option<f64>,
    /// Mean per-turn TTFT (ms) over finished LLM turns.
    pub mean_turn_ttft_ms: f64,
    pub reused_tokens: usize,
    pub recomputed_tokens: usize,
}

/// Interpolated percentile over an ascending-sorted slice.
///
/// Linear interpolation between closest ranks (the R-7/NumPy default):
/// `p` is clamped to [0, 1]; `p = 0` is the minimum, `p = 1` exactly
/// the maximum (no out-of-bounds upper index), a single element is
/// every percentile of itself, and an empty slice has none (NaN).
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let p = p.clamp(0.0, 1.0);
    let rank = (sorted.len() - 1) as f64 * p;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize; // ≤ len-1 because p ≤ 1
    let frac = rank - lo as f64;
    sorted[lo] + (sorted[hi] - sorted[lo]) * frac
}

/// Aggregate statistics over a priority class.
#[derive(Debug, Clone)]
pub struct Aggregate {
    pub count: usize,
    pub finished: usize,
    pub mean_norm_latency_ms: f64,
    pub p95_norm_latency_ms: f64,
    pub mean_ttft_ms: f64,
    pub mean_tpot_ms: f64,
    pub tokens_per_s: f64,
    pub reqs_per_s: f64,
}

impl RunReport {
    /// Per-class aggregates over LLM requests (CPU tool-call nodes are
    /// not LLM work — their latency shows up in the flow rollups).
    pub fn class(&self, p: Priority) -> Aggregate {
        let sel: Vec<&ReqMetrics> =
            self.reqs.iter().filter(|r| r.priority == p && !r.tool).collect();
        let fin: Vec<&ReqMetrics> = sel.iter().copied().filter(|r| r.finished()).collect();
        let mut norms: Vec<f64> =
            fin.iter().filter_map(|r| r.normalized_latency_ms()).collect();
        norms.sort_by(|a, b| a.total_cmp(b));
        let mean = |xs: &[f64]| {
            if xs.is_empty() { f64::NAN } else { xs.iter().sum::<f64>() / xs.len() as f64 }
        };
        let ttfts: Vec<f64> =
            fin.iter().filter_map(|r| r.ttft_us().map(|t| t / 1e3)).collect();
        let tpots: Vec<f64> = fin.iter().filter_map(|r| r.tpot_ms()).collect();
        let span_s = (self.makespan_us / 1e6).max(1e-9);
        let tokens: usize = fin.iter().map(|r| r.output_tokens).sum();
        Aggregate {
            count: sel.len(),
            finished: fin.len(),
            mean_norm_latency_ms: mean(&norms),
            p95_norm_latency_ms: percentile(&norms, 0.95),
            mean_ttft_ms: mean(&ttfts),
            mean_tpot_ms: mean(&tpots),
            tokens_per_s: tokens as f64 / span_s,
            reqs_per_s: fin.len() as f64 / span_s,
        }
    }

    /// Per-flow rollups, ordered by flow id.
    pub fn flows(&self) -> Vec<FlowStats> {
        let mut by_flow: std::collections::BTreeMap<FlowId, Vec<&ReqMetrics>> =
            std::collections::BTreeMap::new();
        for m in self.reqs.iter().filter(|m| m.flow_id.is_some()) {
            by_flow.entry(m.flow_id.unwrap()).or_default().push(m);
        }
        by_flow
            .into_iter()
            .map(|(flow_id, mut turns)| {
                turns.sort_by_key(|m| m.turn_idx);
                let finished = turns.iter().all(|m| m.finished());
                let first_arrival = turns.first().map(|m| m.arrival_us).unwrap_or(0.0);
                let last_done =
                    turns.iter().filter_map(|m| m.done_us).fold(f64::NAN, f64::max);
                let ttfts: Vec<f64> = turns
                    .iter()
                    .filter(|m| !m.tool)
                    .filter_map(|m| m.ttft_us().map(|t| t / 1e3))
                    .collect();
                // Critical-path lower bound over the observed DAG:
                // lb(node) = max over deps lb(dep) + think + latency.
                // Nodes are in topological order (deps point at lower
                // indices), so one forward sweep suffices.
                let critical_path_us = finished.then(|| {
                    let mut lb: std::collections::HashMap<usize, f64> =
                        std::collections::HashMap::new();
                    let mut longest = 0.0f64;
                    for m in &turns {
                        let dur = m.done_us.unwrap_or(m.arrival_us) - m.arrival_us;
                        let base = m
                            .deps
                            .iter()
                            .filter_map(|d| lb.get(d).copied())
                            .fold(0.0f64, f64::max);
                        let v = base + m.think_time_us.max(0.0) + dur.max(0.0);
                        lb.insert(m.turn_idx, v);
                        longest = longest.max(v);
                    }
                    longest
                });
                FlowStats {
                    flow_id,
                    turns: turns.len(),
                    tool_turns: turns.iter().filter(|m| m.tool).count(),
                    finished,
                    e2e_us: finished.then_some(last_done - first_arrival),
                    critical_path_us,
                    mean_turn_ttft_ms: if ttfts.is_empty() {
                        f64::NAN
                    } else {
                        ttfts.iter().sum::<f64>() / ttfts.len() as f64
                    },
                    reused_tokens: turns.iter().map(|m| m.cached_prefix_len).sum(),
                    recomputed_tokens: turns.iter().map(|m| m.prefill_tokens).sum(),
                }
            })
            .collect()
    }

    /// Mean DAG makespan (ms) over finished flows — `NaN` without any.
    pub fn mean_flow_makespan_ms(&self) -> f64 {
        self.mean_flow_e2e_ms()
    }

    /// Mean critical-path lower bound (ms) over finished flows.
    pub fn mean_flow_critical_path_ms(&self) -> f64 {
        Self::mean_cp_ms(&self.flows())
    }

    /// Shared by the helper above and `to_json` (which already holds a
    /// rollup) so the figure output can never diverge from the API.
    fn mean_cp_ms(flows: &[FlowStats]) -> f64 {
        let cps: Vec<f64> = flows
            .iter()
            .filter_map(|f| f.critical_path_us.map(|t| t / 1e3))
            .collect();
        if cps.is_empty() {
            f64::NAN
        } else {
            cps.iter().sum::<f64>() / cps.len() as f64
        }
    }

    /// Mean flow end-to-end latency (ms) over finished flows.
    pub fn mean_flow_e2e_ms(&self) -> f64 {
        let e2es: Vec<f64> =
            self.flows().iter().filter_map(|f| f.e2e_us.map(|t| t / 1e3)).collect();
        if e2es.is_empty() {
            f64::NAN
        } else {
            e2es.iter().sum::<f64>() / e2es.len() as f64
        }
    }

    /// Fraction of continuation LLM turns (turn_idx > 0) that admitted
    /// with a usable session cache.  NaN when no continuation turns ran
    /// (tool nodes never prefill, so they are not eligible).
    pub fn prefix_cache_hit_rate(&self) -> f64 {
        let eligible: Vec<&ReqMetrics> = self
            .reqs
            .iter()
            .filter(|m| m.flow_id.is_some() && m.turn_idx > 0 && !m.tool)
            .collect();
        if eligible.is_empty() {
            return f64::NAN;
        }
        eligible.iter().filter(|m| m.cached_prefix_len > 0).count() as f64
            / eligible.len() as f64
    }

    /// Prompt tokens served from session caches instead of recomputed.
    pub fn reused_prefix_tokens(&self) -> usize {
        self.reqs.iter().map(|m| m.cached_prefix_len).sum()
    }

    /// Prompt tokens pushed through prefill kernels across the run.
    pub fn recomputed_prefill_tokens(&self) -> usize {
        self.reqs.iter().map(|m| m.prefill_tokens).sum()
    }

    /// Total generated tokens (all classes).
    pub fn total_tokens(&self) -> usize {
        self.reqs.iter().filter(|r| r.finished()).map(|r| r.output_tokens).sum()
    }

    /// Energy per generated token (J/token) — the paper's efficiency
    /// metric (§8.1).  0.0 when the run generated no tokens (a
    /// tool-only or fully-cancelled run has no defined J/token; the
    /// NaN this used to return leaked into figure JSON as an invalid
    /// `NaN` token).
    pub fn joules_per_token(&self) -> f64 {
        let t = self.total_tokens();
        if t == 0 { 0.0 } else { self.total_energy_j / t as f64 }
    }

    /// Per-class energy efficiency: the class's attributed kernel
    /// energy over the tokens its finished LLM requests generated.
    /// 0.0 when the class generated nothing.
    pub fn joules_per_token_class(&self, p: Priority) -> f64 {
        let class = KernelClass::from_reactive(p == Priority::Reactive);
        let tokens: usize = self
            .reqs
            .iter()
            .filter(|r| r.priority == p && r.finished())
            .map(|r| r.output_tokens)
            .sum();
        if tokens == 0 {
            0.0
        } else {
            self.energy_by_class[class.idx()] / tokens as f64
        }
    }

    /// Fraction of scheduled graphics frames that missed their vsync
    /// deadline (0.0 without a display workload).
    pub fn frame_miss_rate(&self) -> f64 {
        if self.frames_scheduled == 0 {
            0.0
        } else {
            self.frames_missed as f64 / self.frames_scheduled as f64
        }
    }

    /// Fraction of the makespan each XPU was busy.
    pub fn utilization(&self, name: &str) -> f64 {
        self.xpus
            .iter()
            .find(|x| x.name == name)
            .map(|x| x.busy_us / self.makespan_us.max(1e-9))
            .unwrap_or(0.0)
    }

    pub fn to_json(&self) -> Json {
        // Undefined aggregates (no flows ran, no finished requests in a
        // class, …) serialize as null — a bare NaN is not valid JSON.
        let num_or_null = Json::num_or_null;
        let cls = |p: Priority| {
            let a = self.class(p);
            Json::obj()
                .set("count", a.count)
                .set("finished", a.finished)
                .set("mean_norm_latency_ms", num_or_null(a.mean_norm_latency_ms))
                .set("p95_norm_latency_ms", num_or_null(a.p95_norm_latency_ms))
                .set("mean_ttft_ms", num_or_null(a.mean_ttft_ms))
                .set("mean_tpot_ms", num_or_null(a.mean_tpot_ms))
                .set("tokens_per_s", a.tokens_per_s)
                .set("reqs_per_s", a.reqs_per_s)
        };
        // one rollup pass shared by every flow-level field below
        let flows = self.flows();
        let mean_e2e = {
            let e2es: Vec<f64> =
                flows.iter().filter_map(|f| f.e2e_us.map(|t| t / 1e3)).collect();
            if e2es.is_empty() {
                f64::NAN
            } else {
                e2es.iter().sum::<f64>() / e2es.len() as f64
            }
        };
        let mean_cp = Self::mean_cp_ms(&flows);
        let flows_json = Json::obj()
            .set("count", flows.len())
            .set("finished", flows.iter().filter(|f| f.finished).count())
            .set("tool_turns", flows.iter().map(|f| f.tool_turns).sum::<usize>())
            .set("mean_e2e_ms", num_or_null(mean_e2e))
            .set("mean_critical_path_ms", num_or_null(mean_cp))
            .set(
                "mean_turn_ttft_ms",
                num_or_null(if flows.is_empty() {
                    f64::NAN
                } else {
                    flows.iter().map(|f| f.mean_turn_ttft_ms).sum::<f64>()
                        / flows.len() as f64
                }),
            )
            .set("prefix_cache_hit_rate", num_or_null(self.prefix_cache_hit_rate()))
            .set("reused_prefix_tokens", self.reused_prefix_tokens())
            .set("recomputed_prefill_tokens", self.recomputed_prefill_tokens());
        let energy_json = Json::obj()
            .set("reactive_j", self.energy_by_class[KernelClass::Reactive.idx()])
            .set("proactive_j", self.energy_by_class[KernelClass::Proactive.idx()])
            .set("graphics_j", self.energy_by_class[KernelClass::Graphics.idx()])
            .set("idle_j", self.energy_by_class[CLASS_IDLE])
            .set(
                "reactive_j_per_token",
                self.joules_per_token_class(Priority::Reactive),
            )
            .set(
                "proactive_j_per_token",
                self.joules_per_token_class(Priority::Proactive),
            )
            .set(
                "reactive_busy_us",
                self.busy_by_class[KernelClass::Reactive.idx()],
            )
            .set(
                "proactive_busy_us",
                self.busy_by_class[KernelClass::Proactive.idx()],
            )
            .set(
                "graphics_busy_us",
                self.busy_by_class[KernelClass::Graphics.idx()],
            );
        let graphics_json = Json::obj()
            .set("frames_scheduled", self.frames_scheduled as usize)
            .set("frames_missed", self.frames_missed as usize)
            .set("frame_miss_rate", self.frame_miss_rate());
        Json::obj()
            .set("engine", self.engine.as_str())
            .set("makespan_s", self.makespan_us / 1e6)
            .set("reactive", cls(Priority::Reactive))
            .set("proactive", cls(Priority::Proactive))
            .set("flows", flows_json)
            .set("total_energy_j", self.total_energy_j)
            .set("energy_by_class", energy_json)
            .set("graphics", graphics_json)
            .set("peak_power_w", self.peak_power_w)
            .set("joules_per_token", num_or_null(self.joules_per_token()))
            .set("mean_bw_gbps", self.mean_bw_gbps)
            .set("preemptions", self.preemptions as usize)
            .set("backfills", self.backfills as usize)
            .set("kv_evictions", self.kv_evictions as usize)
            .set("session_evictions", self.session_evictions as usize)
            .set("cancellations", self.cancellations as usize)
            .set("rebinds", self.rebinds as usize)
            .set("splits", self.splits as usize)
            .set("split_tokens", self.split_tokens as usize)
            .set("dropped_reqs", self.dropped_reqs as usize)
    }
}

/// Incremental event → report accumulation: folds the
/// [`EngineEvent`](crate::engine::EngineEvent) stream of a live engine
/// into running serving statistics, without holding per-request state.
/// This is what a long-lived server reports from (`stats` verb) — the
/// batch [`RunReport`] requires the whole run to have ended, an
/// accumulator never does.
#[derive(Debug, Clone, Default)]
pub struct ReportAccumulator {
    /// Requests completed with their full token budget.
    pub served: usize,
    /// Requests aborted via cancel.
    pub cancelled: usize,
    /// Generated tokens across all requests.
    pub tokens: usize,
    /// Prompt tokens served from retained session caches.
    pub reused_prefix_tokens: usize,
    /// Proactive prefills preempted at kernel boundaries.
    pub preemptions: usize,
    /// Arrivals refused at admission (`retry_after` frames): queue
    /// full, live-flow cap hit, or proactive intake paused.
    pub rejected: usize,
    /// Queued proactive requests displaced by a reactive arrival at a
    /// full admission queue.
    pub displaced: usize,
    /// Queued proactive requests cancelled by the load shedder
    /// (terminal `done.shed` frames, displacements included).
    pub shed: usize,
    /// Running proactive requests preempted-and-parked by the load
    /// shedder (they resume when the overload clears).
    pub parked: usize,
    /// Parked requests resumed after the overload cleared.
    pub resumed: usize,
    /// Requests resubmitted from the write-ahead journal at startup
    /// (crash recovery).
    pub recovered: usize,
    /// Elastic re-binding events (folds + splits, §5.2).
    pub rebinds: usize,
    /// Head chunks split across NPU+iGPU (subset of `rebinds`).
    pub splits: usize,
    /// Prompt tokens moved to co-run iGPU slices by those splits.
    pub split_tokens: usize,
    ttft_sum_ms: f64,
    ttft_n: usize,
}

impl ReportAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one engine event into the running totals.
    pub fn absorb(&mut self, ev: &crate::engine::EngineEvent) {
        use crate::engine::EngineEvent::*;
        match ev {
            TokenEmitted { .. } => self.tokens += 1,
            TurnDone { arrival_us, first_token_us, cached_prefix, .. } => {
                self.served += 1;
                self.reused_prefix_tokens += cached_prefix;
                self.ttft_sum_ms += (first_token_us - arrival_us) / 1e3;
                self.ttft_n += 1;
            }
            Cancelled { .. } => self.cancelled += 1,
            Preempted { .. } => self.preemptions += 1,
            Rebound { split_tokens, .. } => {
                self.rebinds += 1;
                if *split_tokens > 0 {
                    self.splits += 1;
                    self.split_tokens += split_tokens;
                }
            }
            Admitted { .. } | KvEvicted { .. } | SessionEvicted { .. } => {}
        }
    }

    /// Mean TTFT (ms) over served requests; NaN before the first.
    pub fn mean_ttft_ms(&self) -> f64 {
        if self.ttft_n == 0 {
            f64::NAN
        } else {
            self.ttft_sum_ms / self.ttft_n as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let ttft = self.mean_ttft_ms();
        Json::obj()
            .set("served", self.served)
            .set("cancelled", self.cancelled)
            .set("tokens", self.tokens)
            .set("reused_prefix_tokens", self.reused_prefix_tokens)
            .set("preemptions", self.preemptions)
            .set("rejected", self.rejected)
            .set("displaced", self.displaced)
            .set("shed", self.shed)
            .set("parked", self.parked)
            .set("resumed", self.resumed)
            .set("recovered", self.recovered)
            .set("rebinds", self.rebinds)
            .set("splits", self.splits)
            .set("split_tokens", self.split_tokens)
            .set("mean_ttft_ms", Json::num_or_null(ttft))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, p: Priority, arr: f64, ttft: f64, done: f64, il: usize, ot: usize) -> ReqMetrics {
        ReqMetrics {
            id,
            priority: p,
            profile: "test".into(),
            flow_id: None,
            turn_idx: 0,
            deps: vec![],
            think_time_us: 0.0,
            tool: false,
            arrival_us: arr,
            first_token_us: Some(arr + ttft),
            done_us: Some(arr + done),
            input_len: il,
            output_tokens: ot,
            cached_prefix_len: 0,
            prefill_tokens: il,
            cancelled: false,
        }
    }

    fn flow_req(
        id: u64,
        flow: u64,
        turn: usize,
        arr: f64,
        done: f64,
        il: usize,
        cached: usize,
    ) -> ReqMetrics {
        let mut m = req(id, Priority::Reactive, arr, 10_000.0, done - arr, il, 4);
        m.flow_id = Some(flow);
        m.turn_idx = turn;
        if turn > 0 {
            m.deps = vec![turn - 1];
        }
        m.cached_prefix_len = cached;
        m.prefill_tokens = il - cached;
        m
    }

    fn report(reqs: Vec<ReqMetrics>) -> RunReport {
        RunReport {
            engine: "test".into(),
            reqs,
            xpus: vec![],
            makespan_us: 2e6,
            total_energy_j: 10.0,
            energy_by_class: [4.0, 3.0, 2.0, 1.0],
            busy_by_class: [1e6, 5e5, 2e5],
            frames_scheduled: 0,
            frames_missed: 0,
            peak_power_w: 20.0,
            mean_bw_gbps: 30.0,
            preemptions: 0,
            backfills: 0,
            kv_evictions: 0,
            session_evictions: 0,
            cancellations: 0,
            rebinds: 0,
            splits: 0,
            split_tokens: 0,
            dropped_reqs: 0,
        }
    }

    #[test]
    fn percentile_interpolates_between_ranks() {
        let xs = vec![10.0, 20.0, 30.0, 40.0];
        assert!((percentile(&xs, 0.0) - 10.0).abs() < 1e-9, "p0 = min");
        assert!((percentile(&xs, 1.0) - 40.0).abs() < 1e-9, "p1 = max, in bounds");
        assert!((percentile(&xs, 0.5) - 25.0).abs() < 1e-9, "median interpolates");
        // p95 of 4 elements: rank 2.85 → 30 + 0.85 * 10
        assert!((percentile(&xs, 0.95) - 38.5).abs() < 1e-9);
        // out-of-range p is clamped, not an index panic
        assert!((percentile(&xs, 1.5) - 40.0).abs() < 1e-9);
        assert!((percentile(&xs, -0.5) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_edge_cases() {
        assert!(percentile(&[], 0.5).is_nan(), "empty slice has no percentiles");
        let one = vec![7.0];
        for p in [0.0, 0.5, 0.99, 1.0] {
            assert!((percentile(&one, p) - 7.0).abs() < 1e-9);
        }
        let two = vec![0.0, 100.0];
        assert!((percentile(&two, 0.99) - 99.0).abs() < 1e-9);
        assert!((percentile(&two, 1.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn normalized_latency_is_ttft_over_len() {
        let r = req(1, Priority::Reactive, 1000.0, 50_000.0, 100_000.0, 100, 10);
        assert!((r.normalized_latency_ms().unwrap() - 0.5).abs() < 1e-9);
        assert!((r.ttft_us().unwrap() - 50_000.0).abs() < 1e-9);
    }

    #[test]
    fn tpot_excludes_first_token() {
        let r = req(1, Priority::Reactive, 0.0, 10_000.0, 100_000.0, 10, 10);
        // 90 ms over 9 tokens
        assert!((r.tpot_ms().unwrap() - 10.0).abs() < 1e-9);
        let single = req(2, Priority::Reactive, 0.0, 10_000.0, 10_000.0, 10, 1);
        assert!(single.tpot_ms().is_none());
    }

    #[test]
    fn class_aggregates_split_priorities() {
        let rep = report(vec![
            req(1, Priority::Reactive, 0.0, 20_000.0, 50_000.0, 20, 5),
            req(2, Priority::Proactive, 0.0, 200_000.0, 500_000.0, 100, 50),
            req(3, Priority::Proactive, 0.0, 400_000.0, 900_000.0, 100, 45),
        ]);
        let r = rep.class(Priority::Reactive);
        let p = rep.class(Priority::Proactive);
        assert_eq!(r.count, 1);
        assert_eq!(p.count, 2);
        assert!((r.mean_norm_latency_ms - 1.0).abs() < 1e-9);
        assert!((p.mean_norm_latency_ms - 3.0).abs() < 1e-9);
        // 95 tokens over 2 s
        assert!((p.tokens_per_s - 47.5).abs() < 1e-9);
    }

    #[test]
    fn joules_per_token() {
        let rep = report(vec![req(1, Priority::Proactive, 0.0, 1.0, 2.0, 10, 5)]);
        assert!((rep.joules_per_token() - 2.0).abs() < 1e-9);
        // 3.0 J of proactive-class energy over the same 5 tokens
        assert!((rep.joules_per_token_class(Priority::Proactive) - 0.6).abs() < 1e-9);
        // the reactive class generated nothing: guarded, not NaN
        assert_eq!(rep.joules_per_token_class(Priority::Reactive), 0.0);
    }

    /// Satellite regression: a zero-token run (tool-only flow, or
    /// everything cancelled) used to put `NaN` into figure JSON via
    /// `joules_per_token`.
    #[test]
    fn zero_token_and_tool_only_runs_have_guarded_energy_metrics() {
        // tool-only flow: one finished tool node, zero generated tokens
        let mut tool = flow_req(1, 1, 0, 0.0, 5_000.0, 8, 0);
        tool.tool = true;
        tool.output_tokens = 0;
        let rep = report(vec![tool]);
        assert_eq!(rep.total_tokens(), 0);
        assert_eq!(rep.joules_per_token(), 0.0, "guarded, not NaN");
        assert_eq!(rep.joules_per_token_class(Priority::Reactive), 0.0);
        assert_eq!(rep.frame_miss_rate(), 0.0, "no frames: rate 0, not NaN");
        let text = rep.to_json().to_string();
        assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
        Json::parse(&text).expect("tool-only report parses");

        // fully-empty run
        let empty = report(vec![]);
        assert_eq!(empty.joules_per_token(), 0.0);
        Json::parse(&empty.to_json().to_string()).expect("empty report parses");
    }

    #[test]
    fn report_json_carries_per_class_energy_and_graphics() {
        let mut rep = report(vec![req(1, Priority::Reactive, 0.0, 1000.0, 2000.0, 10, 5)]);
        rep.frames_scheduled = 10;
        rep.frames_missed = 3;
        let j = rep.to_json();
        let e = j.get("energy_by_class").unwrap();
        assert!((e.get("reactive_j").unwrap().as_f64().unwrap() - 4.0).abs() < 1e-9);
        assert!((e.get("idle_j").unwrap().as_f64().unwrap() - 1.0).abs() < 1e-9);
        // 4.0 reactive J over 5 reactive tokens
        assert!(
            (e.get("reactive_j_per_token").unwrap().as_f64().unwrap() - 0.8).abs() < 1e-9
        );
        let g = j.get("graphics").unwrap();
        assert_eq!(g.get("frames_scheduled").unwrap().as_usize().unwrap(), 10);
        assert!((g.get("frame_miss_rate").unwrap().as_f64().unwrap() - 0.3).abs() < 1e-9);
        Json::parse(&j.to_string()).expect("round-trips");
    }

    #[test]
    fn unfinished_requests_excluded_from_aggregates() {
        let mut m = req(1, Priority::Reactive, 0.0, 1.0, 2.0, 10, 5);
        m.first_token_us = None;
        m.done_us = None;
        let rep = report(vec![m]);
        let a = rep.class(Priority::Reactive);
        assert_eq!(a.count, 1);
        assert_eq!(a.finished, 0);
        assert!(a.mean_norm_latency_ms.is_nan());
    }

    #[test]
    fn report_serializes() {
        let rep = report(vec![req(1, Priority::Reactive, 0.0, 1000.0, 2000.0, 10, 5)]);
        let j = rep.to_json();
        assert_eq!(j.get("engine").unwrap().as_str().unwrap(), "test");
        assert!(j.get("reactive").unwrap().get("mean_ttft_ms").is_ok());
        assert!(j.get("flows").unwrap().get("prefix_cache_hit_rate").is_ok());
        assert!(j.get("flows").unwrap().get("mean_critical_path_ms").is_ok());
        assert!(j.get("kv_evictions").is_ok());
        assert!(j.get("dropped_reqs").is_ok(), "truncation is flagged, never silent");
    }

    #[test]
    fn report_json_is_parseable_even_without_flows() {
        // proactive-only run: reactive aggregates and all flow metrics
        // are undefined — they must serialize as null, not a bare NaN
        // that no JSON parser accepts
        let rep = report(vec![req(1, Priority::Proactive, 0.0, 1.0, 2.0, 10, 5)]);
        let text = rep.to_json().to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(*back.get("flows").unwrap().get("mean_e2e_ms").unwrap(), Json::Null);
        assert_eq!(
            *back.get("flows").unwrap().get("prefix_cache_hit_rate").unwrap(),
            Json::Null
        );
        assert_eq!(
            *back.get("reactive").unwrap().get("mean_ttft_ms").unwrap(),
            Json::Null
        );
    }

    #[test]
    fn flow_rollups_aggregate_turns() {
        let rep = report(vec![
            // flow 1: two turns, second reused 50 tokens
            flow_req(1, 1, 0, 0.0, 40_000.0, 60, 0),
            flow_req(2, 1, 1, 100_000.0, 150_000.0, 100, 50),
            // flow 2: single finished turn
            flow_req(3, 2, 0, 10_000.0, 30_000.0, 40, 0),
            // an unrelated single-shot request
            req(4, Priority::Proactive, 0.0, 1.0, 2.0, 20, 3),
        ]);
        let flows = rep.flows();
        assert_eq!(flows.len(), 2);
        let f1 = &flows[0];
        assert_eq!((f1.flow_id, f1.turns), (1, 2));
        assert!(f1.finished);
        // first arrival 0, last done 150_000
        assert!((f1.e2e_us.unwrap() - 150_000.0).abs() < 1e-9);
        assert_eq!(f1.reused_tokens, 50);
        assert_eq!(f1.recomputed_tokens, 60 + 50);
        // hit rate: one continuation turn, one hit
        assert!((rep.prefix_cache_hit_rate() - 1.0).abs() < 1e-9);
        assert_eq!(rep.reused_prefix_tokens(), 50);
        assert_eq!(rep.recomputed_prefill_tokens(), 60 + 50 + 40 + 20);
    }

    #[test]
    fn dag_rollup_computes_critical_path_and_tool_counts() {
        let n0 = flow_req(1, 1, 0, 0.0, 100_000.0, 60, 0);
        let mut n1 = flow_req(2, 1, 1, 110_000.0, 150_000.0, 80, 0);
        n1.think_time_us = 10_000.0;
        let mut n2 = flow_req(3, 1, 2, 110_000.0, 180_000.0, 8, 0);
        n2.deps = vec![0];
        n2.think_time_us = 10_000.0;
        n2.tool = true;
        let mut n3 = flow_req(4, 1, 3, 185_000.0, 220_000.0, 120, 0);
        n3.deps = vec![1, 2];
        n3.think_time_us = 5_000.0;
        let rep = report(vec![n0, n1, n2, n3]);
        let flows = rep.flows();
        assert_eq!(flows.len(), 1);
        let f = &flows[0];
        assert_eq!((f.turns, f.tool_turns), (4, 1));
        assert!(f.finished);
        assert!((f.e2e_us.unwrap() - 220_000.0).abs() < 1e-6);
        // longest chain: 0 (100k) →think 10k→ 2 (70k) →think 5k→ 3 (35k)
        assert!((f.critical_path_us.unwrap() - 220_000.0).abs() < 1e-6);
        assert!(f.e2e_us.unwrap() + 1e-6 >= f.critical_path_us.unwrap());
        assert!((rep.mean_flow_critical_path_ms() - 220.0).abs() < 1e-6);
    }

    #[test]
    fn tool_nodes_excluded_from_llm_aggregates_and_hit_rate() {
        let mut tool = flow_req(2, 1, 1, 10.0, 20.0, 8, 0);
        tool.tool = true;
        let rep = report(vec![
            flow_req(1, 1, 0, 0.0, 5.0, 60, 0),
            tool,
            flow_req(3, 1, 2, 30.0, 40.0, 80, 70),
        ]);
        let r = rep.class(Priority::Reactive);
        assert_eq!(r.count, 2, "a tool call is not an LLM request");
        // hit rate over LLM continuations only: one eligible, one hit
        assert!((rep.prefix_cache_hit_rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn hit_rate_counts_misses_and_skips_single_shots() {
        let rep = report(vec![
            flow_req(1, 1, 0, 0.0, 1.0, 60, 0),
            flow_req(2, 1, 1, 2.0, 3.0, 80, 0),  // continuation, missed
            flow_req(3, 1, 2, 4.0, 5.0, 90, 70), // continuation, hit
        ]);
        assert!((rep.prefix_cache_hit_rate() - 0.5).abs() < 1e-9);
        // no flows at all → NaN (undefined, not zero)
        let none = report(vec![req(1, Priority::Reactive, 0.0, 1.0, 2.0, 10, 2)]);
        assert!(none.prefix_cache_hit_rate().is_nan());
        assert!(none.flows().is_empty());
        assert!(none.mean_flow_e2e_ms().is_nan());
    }
}
