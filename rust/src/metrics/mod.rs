//! Serving metrics (paper §8.1): TTFT, TPOT, *normalized latency*
//! (mean TTFT / input length — the paper's headline per-request metric),
//! throughput, per-XPU utilization, and energy (peak W, J/token).

mod report;

pub use report::{Aggregate, ReqMetrics, RunReport, percentile};
