//! Serving metrics (paper §8.1): TTFT, TPOT, *normalized latency*
//! (mean TTFT / input length — the paper's headline per-request metric),
//! throughput, per-XPU utilization, energy (peak W, J/token), and
//! flow-level rollups (per-flow e2e latency, per-turn TTFT,
//! prefix-cache hit-rate, reused/recomputed prefill tokens).

mod report;

pub use report::{Aggregate, FlowStats, ReportAccumulator, ReqMetrics, RunReport, percentile};
