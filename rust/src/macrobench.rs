//! `agent-xpu bench macro` — the end-to-end scheduler throughput
//! harness behind the DESIGN.md §8 perf trajectory.
//!
//! Where `benches/sched_micro.rs` times isolated decision primitives
//! (dispatch_check, lane formation, resume ranking), this harness
//! drives **whole DES runs** through every registry policy at trace
//! sizes from 10k to 1M synthetic requests and reports what the paper's
//! §6.5 synchronization-cost argument actually needs: sustained
//! requests/s through the full submit → step → finish lifecycle, and
//! the per-step latency distribution (one `step()` is one dispatch
//! decision point — admissions, the policy pass, and the DES event
//! advance).
//!
//! Output is a strict-JSON `BENCH_sched.json` (non-finite values
//! serialize as `null` via [`Json::num_or_null`]) with one row per
//! (policy, trace size), so CI can parse-check it and gate the p99
//! step latency against the §8 dispatch budget.  Everything is seeded:
//! the same seed reproduces the same trace and therefore the same
//! schedule on every policy (per-step *timings* are measurements, the
//! schedules themselves are deterministic).

use anyhow::{Result, ensure};

use crate::config::{ModelGeometry, SchedulerConfig, SocConfig, default_soc, llama32_3b};
use crate::engine::{EngineClock, registry};
use crate::fleet::{Fleet, FleetConfig};
use crate::util::bench::{fmt_ns, percentile};
use crate::util::json::Json;
use crate::workload::{Flow, Priority, Request, UserFlow};

/// §8 budget the CI smoke gates on: p99 of one full `step()` — the
/// engine's dispatch decision point — must stay under this.
pub const P99_DISPATCH_BUDGET_US: f64 = 5.0;

/// Trace sizes for the full trajectory run (the smoke run stops at the
/// first one).
pub const TRACE_SIZES: [usize; 3] = [10_000, 100_000, 1_000_000];

/// DESIGN.md §9 budget: the fleet layer must be a near-free wrapper —
/// the per-step p99 of a 1-device fleet minus the bare engine's over
/// the identical trace stays under this.
pub const FLEET_OVERHEAD_BUDGET_NS: f64 = 1_000.0;

/// Splitmix-style LCG so the trace needs no external RNG crate.
struct Lcg(u64);

impl Lcg {
    fn new(seed: u64) -> Self {
        Self(seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1))
    }

    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    /// Uniform in `[lo, hi]`.
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo + 1)
    }
}

/// The bench geometry: paper model shapes with a shallow layer stack so
/// a 1M-request DES stays a per-request handful of kernel events (the
/// scheduler work we are measuring is per *decision*, not per layer).
pub fn bench_geometry() -> ModelGeometry {
    let mut g = llama32_3b();
    g.n_layers = 2;
    g
}

/// Seeded synthetic open-loop trace: ~25 % reactive arrivals mixed into
/// a proactive background stream, short prompts/outputs (the §6.5
/// regime where scheduling overhead, not kernel time, is the risk),
/// arrival gaps that keep the virtual SoC below saturation so the live
/// working set stays bounded and steady-state costs dominate.
pub fn synthetic_trace(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Lcg::new(seed);
    let mut arrival = 0.0f64;
    (0..n as u64)
        .map(|i| {
            arrival += rng.range(2_000, 10_000) as f64; // 2–10 ms gaps
            let reactive = rng.range(0, 3) == 0;
            Request {
                id: i,
                priority: if reactive { Priority::Reactive } else { Priority::Proactive },
                arrival_us: arrival,
                prompt: vec![1; rng.range(16, 48) as usize],
                max_new_tokens: rng.range(1, 2) as usize,
                profile: "macrobench".into(),
                flow: None,
            }
        })
        .collect()
}

/// One timed DES run: build the named policy, submit the whole trace,
/// step to completion timing every step, and report throughput + the
/// per-step latency distribution as a JSON row.
fn run_one(policy: &str, trace: Vec<Request>, soc: &SocConfig) -> Result<Json> {
    let n_reqs = trace.len();
    let mut eng = registry::build(
        policy,
        bench_geometry(),
        soc.clone(),
        SchedulerConfig::default(),
    )?;
    eng.start(EngineClock::Virtual)?;
    for r in trace {
        eng.submit(r)?;
    }
    // Per-step samples are pre-sized: the sampling itself must not
    // allocate mid-run and pollute the tail percentiles.
    let mut step_ns: Vec<f64> = Vec::with_capacity(n_reqs * 12);
    let t0 = std::time::Instant::now();
    while eng.has_work() {
        let t = std::time::Instant::now();
        eng.step()?;
        step_ns.push(t.elapsed().as_nanos() as f64);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let rep = eng.finish()?;
    let finished =
        rep.reqs.iter().filter(|m| m.finished()).count() + rep.dropped_reqs as usize;

    let steps = step_ns.len();
    step_ns.sort_by(|a, b| a.total_cmp(b));
    let p99_ns = percentile(&step_ns, 0.99);
    let mean_ns = if steps == 0 {
        f64::NAN
    } else {
        step_ns.iter().sum::<f64>() / steps as f64
    };
    println!(
        "{policy:<10} n={n_reqs:>9}  steps={steps:>9}  wall={wall_s:>7.3}s  \
         {:>12.0} reqs/s  step mean {} p99 {}",
        n_reqs as f64 / wall_s,
        fmt_ns(mean_ns),
        fmt_ns(p99_ns),
    );
    Ok(Json::obj()
        .set("policy", policy)
        .set("n_reqs", n_reqs)
        .set("finished", finished)
        .set("steps", steps)
        .set("wall_s", Json::num_or_null(wall_s))
        .set("reqs_per_s", Json::num_or_null(n_reqs as f64 / wall_s))
        .set("steps_per_s", Json::num_or_null(steps as f64 / wall_s))
        .set(
            "step_ns",
            Json::obj()
                .set("mean", Json::num_or_null(mean_ns))
                .set("p50", Json::num_or_null(percentile(&step_ns, 0.50)))
                .set("p99", Json::num_or_null(p99_ns))
                .set("max", Json::num_or_null(step_ns.last().copied().unwrap_or(f64::NAN)))),
    )
}

/// Per-step p99 (ns) of the bare `agent-xpu` engine over `trace`.
fn bare_step_p99_ns(trace: Vec<Request>, soc: &SocConfig) -> Result<f64> {
    let n = trace.len();
    let mut eng = registry::build(
        "agent-xpu",
        bench_geometry(),
        soc.clone(),
        SchedulerConfig::default(),
    )?;
    eng.start(EngineClock::Virtual)?;
    for r in trace {
        eng.submit(r)?;
    }
    let mut step_ns: Vec<f64> = Vec::with_capacity(n * 12);
    while eng.has_work() {
        let t = std::time::Instant::now();
        eng.step()?;
        step_ns.push(t.elapsed().as_nanos() as f64);
    }
    eng.finish()?;
    step_ns.sort_by(|a, b| a.total_cmp(b));
    Ok(percentile(&step_ns, 0.99))
}

/// The fleet-wrapper overhead row (DESIGN.md §9): the same synthetic
/// trace through the bare `agent-xpu` engine and through a 1-device
/// fleet (sticky router, unbounded gate — routing cost, not shedding),
/// reporting both per-step p99s and the delta CI gates against
/// [`FLEET_OVERHEAD_BUDGET_NS`].
pub fn fleet_overhead(seed: u64, n: usize) -> Result<Json> {
    let soc = default_soc();
    let bare_p99 = bare_step_p99_ns(synthetic_trace(n, seed), &soc)?;

    // Identical trace, wrapped as one single-shot flow per request so
    // every step goes through routing, gate, and ledger bookkeeping.
    let inputs: Vec<UserFlow> = synthetic_trace(n, seed)
        .into_iter()
        .map(|r| UserFlow {
            user: r.id % 64,
            flow: Flow {
                id: r.id,
                priority: r.priority,
                profile: r.profile.clone(),
                turns: vec![r],
            },
        })
        .collect();
    let mut cfg = FleetConfig::new(1, "sticky-session", bench_geometry(), soc);
    cfg.seed = seed;
    cfg.overload.max_queue_depth = 0;
    cfg.overload.max_live_flows = 0;
    let mut fleet = Fleet::new(cfg)?;
    fleet.enable_step_timing();
    let rep = fleet.run(inputs)?;
    ensure!(
        rep.finished() == n as u64,
        "fleet overhead run lost requests: {} of {n}",
        rep.finished()
    );
    let mut fleet_ns: Vec<f64> = fleet.step_samples().unwrap_or(&[]).to_vec();
    fleet_ns.sort_by(|a, b| a.total_cmp(b));
    let fleet_p99 = percentile(&fleet_ns, 0.99);
    let overhead = fleet_p99 - bare_p99;
    println!(
        "fleet-overhead n={n:>9}  bare p99 {}  fleet p99 {}  overhead {}",
        fmt_ns(bare_p99),
        fmt_ns(fleet_p99),
        fmt_ns(overhead),
    );
    Ok(Json::obj()
        .set("n_reqs", n)
        .set("bare_p99_ns", Json::num_or_null(bare_p99))
        .set("fleet_p99_ns", Json::num_or_null(fleet_p99))
        .set("overhead_p99_ns", Json::num_or_null(overhead))
        .set("budget_ns", FLEET_OVERHEAD_BUDGET_NS))
}

/// The whole macro bench: every registry policy at each trace size
/// (`smoke` = smallest size only, the CI tier-1 gate) plus the
/// fleet-wrapper overhead row.  Returns the `BENCH_sched` JSON
/// document.
pub fn bench_sched(seed: u64, smoke: bool) -> Result<Json> {
    let soc = default_soc();
    let sizes: &[usize] = if smoke { &TRACE_SIZES[..1] } else { &TRACE_SIZES[..] };
    let mut rows: Vec<Json> = vec![];
    for &n in sizes {
        for &policy in registry::names() {
            rows.push(run_one(policy, synthetic_trace(n, seed), &soc)?);
        }
    }
    Ok(Json::obj()
        .set("name", "BENCH_sched")
        .set("seed", seed as i64)
        .set("smoke", smoke)
        .set("budget_p99_dispatch_us", P99_DISPATCH_BUDGET_US)
        .set("budget_fleet_overhead_ns", FLEET_OVERHEAD_BUDGET_NS)
        .set("sizes", sizes.to_vec())
        .set("rows", rows)
        .set("fleet_overhead", fleet_overhead(seed, TRACE_SIZES[0])?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_seeded_and_shaped() {
        let a = synthetic_trace(500, 11);
        let b = synthetic_trace(500, 11);
        assert_eq!(a.len(), 500);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.arrival_us, y.arrival_us);
            assert_eq!(x.prompt.len(), y.prompt.len());
            assert_eq!(x.priority, y.priority);
        }
        let c = synthetic_trace(500, 12);
        assert!(
            a.iter().zip(&c).any(|(x, y)| x.arrival_us != y.arrival_us),
            "different seeds must differ"
        );
        // arrivals strictly increase (open-loop stream)
        assert!(a.windows(2).all(|w| w[0].arrival_us < w[1].arrival_us));
        // both classes present
        assert!(a.iter().any(|r| r.priority == Priority::Reactive));
        assert!(a.iter().any(|r| r.priority == Priority::Proactive));
    }

    /// A miniature end-to-end pass over every registry policy: the
    /// emitted document parses back strictly, every row completes its
    /// whole trace, and the row shape CI gates on is present.
    #[test]
    fn bench_rows_complete_and_serialize() {
        let soc = default_soc();
        for &policy in registry::names() {
            let row = run_one(policy, synthetic_trace(60, 7), &soc).unwrap();
            let j = Json::parse(&row.to_string()).unwrap();
            assert_eq!(j.get("policy").unwrap().as_str().unwrap(), policy);
            assert_eq!(
                j.get("finished").unwrap().as_usize().unwrap(),
                60,
                "{policy}: every request must finish"
            );
            assert!(j.get("steps").unwrap().as_usize().unwrap() > 0);
            assert!(j.get("step_ns").unwrap().get("p99").unwrap().as_f64().is_ok());
        }
    }

    /// The fleet-overhead row completes its whole trace through the
    /// 1-device fleet and serializes the fields CI gates on (the
    /// budget comparison itself runs at bench scale, not here).
    #[test]
    fn fleet_overhead_row_completes_and_serializes() {
        let row = fleet_overhead(7, 80).unwrap();
        let j = Json::parse(&row.to_string()).unwrap();
        assert_eq!(j.get("n_reqs").unwrap().as_usize().unwrap(), 80);
        assert!(j.get("bare_p99_ns").unwrap().as_f64().is_ok());
        assert!(j.get("fleet_p99_ns").unwrap().as_f64().is_ok());
        assert!(j.get("overhead_p99_ns").unwrap().as_f64().is_ok());
    }
}
