//! The unit of work every engine schedules: one LLM call from an agent.

use super::flow::FlowBinding;

pub type ReqId = u64;

/// Workload tag for per-profile reporting.  Owned and cheaply clonable
/// (`Arc<str>`) so the serving frontend can tag dynamically created
/// flows/sessions without a static profile table.
pub type ProfileTag = std::sync::Arc<str>;

/// The paper's workload dichotomy (§1): reactive requests are
/// user-initiated and latency-critical; proactive requests are
/// event-driven, background, throughput-oriented.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Priority {
    Reactive,
    Proactive,
}

impl Priority {
    pub fn is_reactive(&self) -> bool {
        matches!(self, Priority::Reactive)
    }

    pub fn label(&self) -> &'static str {
        match self {
            Priority::Reactive => "reactive",
            Priority::Proactive => "proactive",
        }
    }
}

/// One LLM request.  The engine is non-clairvoyant (§4): it sees only
/// the priority tag and the prompt at arrival; `max_new_tokens` stands
/// in for the EOS the real agent would produce (identical across engines
/// so comparisons are fair — DESIGN.md §1).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: ReqId,
    pub priority: Priority,
    /// Virtual arrival time (µs).  For flow turns after the first this
    /// is a placeholder: the driver re-stamps it to `predecessor
    /// completion + think_time` when the turn is released.
    pub arrival_us: f64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Which trace profile generated it (for per-workload reporting).
    pub profile: ProfileTag,
    /// Flow membership: `None` for single-shot requests, `Some` for a
    /// turn of a multi-turn session (see [`crate::workload::Flow`]).
    pub flow: Option<FlowBinding>,
}

impl Request {
    pub fn prompt_len(&self) -> usize {
        self.prompt.len()
    }

    /// Flow this request belongs to, if any.
    pub fn flow_id(&self) -> Option<super::flow::FlowId> {
        self.flow.as_ref().map(|f| f.flow_id)
    }

    /// Turn index within its flow (0 for single-shot requests).
    pub fn turn_idx(&self) -> usize {
        self.flow.as_ref().map(|f| f.turn_idx).unwrap_or(0)
    }

    /// True for CPU tool-call workflow nodes (never prefilled/decoded;
    /// the driver runs them as one kernel on the SoC's CPU).
    pub fn is_tool(&self) -> bool {
        self.flow.as_ref().map(|f| f.is_tool()).unwrap_or(false)
    }

    /// Resolved DAG predecessors within this request's flow (empty for
    /// single-shot requests and flow roots).
    pub fn dep_indices(&self) -> Vec<usize> {
        self.flow.as_ref().map(|f| f.dep_indices()).unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_labels() {
        assert!(Priority::Reactive.is_reactive());
        assert!(!Priority::Proactive.is_reactive());
        assert_eq!(Priority::Proactive.label(), "proactive");
    }

    #[test]
    fn single_shot_requests_have_no_flow() {
        let r = Request {
            id: 1,
            priority: Priority::Reactive,
            arrival_us: 0.0,
            prompt: vec![1, 2, 3],
            max_new_tokens: 4,
            profile: "test".into(),
            flow: None,
        };
        assert_eq!(r.flow_id(), None);
        assert_eq!(r.turn_idx(), 0);
        assert_eq!(r.prompt_len(), 3);
    }
}
