//! The unit of work every engine schedules: one LLM call from an agent.

pub type ReqId = u64;

/// The paper's workload dichotomy (§1): reactive requests are
/// user-initiated and latency-critical; proactive requests are
/// event-driven, background, throughput-oriented.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Priority {
    Reactive,
    Proactive,
}

impl Priority {
    pub fn is_reactive(&self) -> bool {
        matches!(self, Priority::Reactive)
    }

    pub fn label(&self) -> &'static str {
        match self {
            Priority::Reactive => "reactive",
            Priority::Proactive => "proactive",
        }
    }
}

/// One LLM request.  The engine is non-clairvoyant (§4): it sees only
/// the priority tag and the prompt at arrival; `max_new_tokens` stands
/// in for the EOS the real agent would produce (identical across engines
/// so comparisons are fair — DESIGN.md §1).
#[derive(Debug, Clone)]
pub struct Request {
    pub id: ReqId,
    pub priority: Priority,
    /// Virtual arrival time (µs).
    pub arrival_us: f64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Which trace profile generated it (for per-workload reporting).
    pub profile: &'static str,
}

impl Request {
    pub fn prompt_len(&self) -> usize {
        self.prompt.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn priority_labels() {
        assert!(Priority::Reactive.is_reactive());
        assert!(!Priority::Proactive.is_reactive());
        assert_eq!(Priority::Proactive.label(), "proactive");
    }
}
