//! Flow-level sessions, generalized to **workflow DAGs** (paper §1, §4;
//! DESIGN.md §3): a *flow* is the unit of agentic work — a dependency
//! DAG of *nodes* sharing a session id, a growing conversation context,
//! and one priority class.  A node is either an **LLM turn** (prefill +
//! decode on the accelerators) or a **CPU tool call** (retrieval, code
//! execution, file I/O — cost-modeled on the SoC's CPU roofline,
//! contending for DDR like any kernel).  Edges are explicit
//! dependencies; fan-out/join is allowed, e.g. a monitor digest that
//! spawns three parallel retrieval/summarize subtasks and joins them
//! into a final turn.
//!
//! A node never starts before *all* its DAG predecessors complete plus
//! its think-time.  The DES driver enforces this for every engine — it
//! holds nodes until their predecessors finish, releases them one
//! think-time later, and stitches the *actual* generated context (the
//! first predecessor's conversation plus the other branches'
//! contributions, in dependency order) over the generator's placeholder
//! prefix.  Engines with session-cache reuse enabled additionally seed
//! continuation turns from the retained KV so only the delta is
//! prefilled (DESIGN.md §3).
//!
//! Linear multi-turn chains — the pre-DAG flow shape — are the special
//! case `deps == [turn_idx - 1]`, which an empty `deps` vector implies,
//! so chain traces and the online serving path are unchanged.

use super::request::{Priority, ProfileTag, Request};

/// Session identity shared by every node of one flow.
pub type FlowId = u64;

/// What a workflow node executes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NodeKind {
    /// An LLM call: prefill the prompt, decode `max_new_tokens`.
    Llm,
    /// A CPU-side tool call (retrieval, code run, file I/O), modeled as
    /// one kernel on the SoC's CPU roofline: `flops` of compute and
    /// `bytes` of DDR traffic (contending with accelerator kernels).
    Tool { flops: f64, bytes: f64 },
}

impl NodeKind {
    pub fn is_tool(&self) -> bool {
        matches!(self, NodeKind::Tool { .. })
    }
}

impl Default for NodeKind {
    fn default() -> Self {
        NodeKind::Llm
    }
}

/// Per-request flow membership, carried on [`Request::flow`].
#[derive(Debug, Clone, PartialEq)]
pub struct FlowBinding {
    pub flow_id: FlowId,
    /// Position of this node within the flow (0-based; also its DAG
    /// node index — dependencies always point at lower indices).
    pub turn_idx: usize,
    /// Nodes the flow was generated with (the driver trusts the actual
    /// DAG it observes, so a truncated trace still drains cleanly).
    pub total_turns: usize,
    /// Think-time gap (µs) between the completion of the *last* DAG
    /// predecessor and this node's arrival — user reading/typing for
    /// reactive chats, event inter-arrival for proactive monitors,
    /// ~zero for tool invocations and fan-out spawns (paper §8.1).
    pub think_time_us: f64,
    /// Offset into `prompt` where this node's fresh tokens start; the
    /// prefix `[..delta_start]` is the generator's *estimate* of the
    /// merged predecessor context, which the driver replaces with the
    /// actual one before admission.  0 = self-contained prompt (roots,
    /// tool args, and the online serving path).
    pub delta_start: usize,
    /// Explicit DAG predecessors (node indices `< turn_idx`).  Empty
    /// means the implicit linear chain: `[turn_idx - 1]` for any node
    /// after the first — see [`FlowBinding::dep_indices`].
    pub deps: Vec<usize>,
    /// LLM turn or CPU tool call.
    pub node: NodeKind,
    /// Length (in nodes) of the longest dependency chain from this node
    /// to any sink of its flow, this node included — the scheduler's
    /// critical-path priority key ([`Flow::annotate_critical_paths`]).
    /// 1 for sinks and single-shot requests.
    pub crit_path: usize,
}

impl FlowBinding {
    /// A node of a plain linear chain (turn k depends on turn k-1) —
    /// the pre-DAG flow shape and the online serving path.
    pub fn linear(
        flow_id: FlowId,
        turn_idx: usize,
        total_turns: usize,
        think_time_us: f64,
        delta_start: usize,
    ) -> Self {
        let crit_path = if total_turns == usize::MAX {
            1 // open-ended serving session: remaining length unknown
        } else {
            total_turns.saturating_sub(turn_idx).max(1)
        };
        Self {
            flow_id,
            turn_idx,
            total_turns,
            think_time_us,
            delta_start,
            deps: vec![],
            node: NodeKind::Llm,
            crit_path,
        }
    }

    /// Nodes after the first reuse the session's conversation context.
    pub fn is_continuation(&self) -> bool {
        self.turn_idx > 0
    }

    pub fn is_tool(&self) -> bool {
        self.node.is_tool()
    }

    /// Resolved DAG predecessors: the explicit `deps`, or the implicit
    /// linear chain (`[turn_idx - 1]`) when none were given.  Indices
    /// `>= turn_idx` would make the DAG cyclic and are dropped — so a
    /// deliberately self-referencing `deps: vec![turn_idx]` is the
    /// explicit "no predecessors" form (distinct from an empty list,
    /// which means the linear chain).
    pub fn dep_indices(&self) -> Vec<usize> {
        if self.deps.is_empty() {
            if self.turn_idx > 0 { vec![self.turn_idx - 1] } else { vec![] }
        } else {
            self.deps.iter().copied().filter(|&d| d < self.turn_idx).collect()
        }
    }

    /// Critical-path priority key (≥ 1 even when unannotated).
    pub fn crit_path_len(&self) -> usize {
        self.crit_path.max(1)
    }
}

/// A multi-node agentic workflow: the workload-level object the
/// generators emit and the engines consume (flattened into per-node
/// [`Request`]s whose `flow` bindings carry the session linkage and the
/// dependency edges).
#[derive(Debug, Clone)]
pub struct Flow {
    pub id: FlowId,
    pub priority: Priority,
    pub profile: ProfileTag,
    /// Nodes indexed by `turn_idx`; every element carries a
    /// `FlowBinding` with this flow's id and its own index, and
    /// dependencies only point at lower indices (topological order).
    pub turns: Vec<Request>,
}

impl Flow {
    pub fn total_turns(&self) -> usize {
        self.turns.len()
    }

    /// LLM nodes (tool calls excluded).
    pub fn llm_turns(&self) -> usize {
        self.turns.iter().filter(|t| !t.is_tool()).count()
    }

    /// Arrival time of the opening node (later nodes are released by
    /// the driver relative to their predecessors' completion).
    pub fn first_arrival_us(&self) -> f64 {
        self.turns.first().map(|t| t.arrival_us).unwrap_or(0.0)
    }

    /// Total delta tokens across all LLM nodes — the prefill work a
    /// session-cache-aware engine performs (a full-recompute engine
    /// prefills the whole growing context every turn instead).
    pub fn delta_tokens(&self) -> usize {
        self.turns
            .iter()
            .filter(|t| !t.is_tool())
            .map(|t| {
                let ds = t.flow.as_ref().map(|f| f.delta_start).unwrap_or(0);
                t.prompt_len().saturating_sub(ds)
            })
            .sum()
    }

    /// Stamp every node's `crit_path` with the length (in nodes) of the
    /// longest dependency chain from that node to any sink.  Nodes are
    /// in topological order (deps point at lower indices), so a single
    /// reverse sweep suffices.
    pub fn annotate_critical_paths(&mut self) {
        let n = self.turns.len();
        let mut cp = vec![1usize; n];
        for i in (0..n).rev() {
            let deps = self
                .turns[i]
                .flow
                .as_ref()
                .map(|f| f.dep_indices())
                .unwrap_or_default();
            for d in deps {
                if d < i {
                    cp[d] = cp[d].max(cp[i] + 1);
                }
            }
        }
        for (i, t) in self.turns.iter_mut().enumerate() {
            if let Some(fb) = t.flow.as_mut() {
                fb.crit_path = cp[i];
            }
        }
    }
}

/// Flatten flows into one arrival-ordered request trace (the form every
/// `Engine::run` takes; `merge_traces` applies the final global sort).
pub fn flatten_flows(flows: Vec<Flow>) -> Vec<Request> {
    let mut all: Vec<Request> = flows.into_iter().flat_map(|f| f.turns).collect();
    all.sort_by(|a, b| a.arrival_us.total_cmp(&b.arrival_us).then(a.id.cmp(&b.id)));
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    fn turn(flow_id: u64, idx: usize, total: usize, plen: usize, ds: usize) -> Request {
        Request {
            id: flow_id * 100 + idx as u64,
            priority: Priority::Reactive,
            arrival_us: idx as f64,
            prompt: vec![1; plen],
            max_new_tokens: 4,
            profile: "test".into(),
            flow: Some(FlowBinding {
                flow_id,
                turn_idx: idx,
                total_turns: total,
                think_time_us: 1e6,
                delta_start: ds,
                deps: vec![],
                node: NodeKind::Llm,
                crit_path: 1,
            }),
        }
    }

    #[test]
    fn flow_accessors() {
        let f = Flow {
            id: 3,
            priority: Priority::Reactive,
            profile: "chat".into(),
            turns: vec![turn(3, 0, 2, 10, 0), turn(3, 1, 2, 20, 14)],
        };
        assert_eq!(f.total_turns(), 2);
        assert_eq!(f.llm_turns(), 2);
        assert_eq!(f.first_arrival_us(), 0.0);
        // 10 (whole first prompt) + 6 (20 - delta_start 14)
        assert_eq!(f.delta_tokens(), 16);
        assert!(!f.turns[0].flow.as_ref().unwrap().is_continuation());
        assert!(f.turns[1].flow.as_ref().unwrap().is_continuation());
    }

    #[test]
    fn flatten_orders_by_arrival() {
        let a = Flow {
            id: 1,
            priority: Priority::Reactive,
            profile: "chat".into(),
            turns: vec![turn(1, 0, 1, 8, 0)],
        };
        let mut b = Flow {
            id: 2,
            priority: Priority::Reactive,
            profile: "chat".into(),
            turns: vec![turn(2, 0, 1, 8, 0)],
        };
        b.turns[0].arrival_us = -5.0;
        let t = flatten_flows(vec![a, b]);
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].id, 200);
    }

    #[test]
    fn empty_deps_imply_the_linear_chain() {
        let fb = FlowBinding::linear(1, 0, 3, 0.0, 0);
        assert!(fb.dep_indices().is_empty(), "roots have no predecessors");
        assert_eq!(fb.crit_path, 3);
        let fb = FlowBinding::linear(1, 2, 3, 5.0, 10);
        assert_eq!(fb.dep_indices(), vec![1]);
        assert_eq!(fb.crit_path, 1);
        // open-ended serving sessions don't pretend to know their depth
        let fb = FlowBinding::linear(1, 4, usize::MAX, 0.0, 0);
        assert_eq!(fb.crit_path, 1);
        assert_eq!(fb.dep_indices(), vec![3]);
    }

    #[test]
    fn explicit_deps_express_fan_out_and_join() {
        let mut join = FlowBinding::linear(1, 3, 4, 0.0, 50);
        join.deps = vec![1, 2];
        assert_eq!(join.dep_indices(), vec![1, 2]);
        // forward/self references would be cyclic — dropped
        join.deps = vec![1, 3, 7];
        assert_eq!(join.dep_indices(), vec![1]);
        // a pure self-reference is the explicit "no predecessors" form
        // (the serving path uses it when every referenced generation
        // was forgotten) — distinct from empty = implicit linear chain
        join.deps = vec![3];
        assert!(join.dep_indices().is_empty());
    }

    #[test]
    fn tool_nodes_are_flagged() {
        let mut fb = FlowBinding::linear(1, 1, 3, 0.0, 0);
        assert!(!fb.is_tool());
        fb.node = NodeKind::Tool { flops: 1e9, bytes: 1e8 };
        assert!(fb.is_tool());
    }

    #[test]
    fn critical_path_annotation_walks_the_dag() {
        // diamond: 0 → {1, 2} → 3, plus a dangling short branch 0 → 4
        let mut turns: Vec<Request> = (0..5).map(|i| turn(9, i, 5, 10, 0)).collect();
        let set = |t: &mut Request, deps: Vec<usize>| {
            t.flow.as_mut().unwrap().deps = deps;
        };
        set(&mut turns[1], vec![0]);
        set(&mut turns[2], vec![0]);
        set(&mut turns[3], vec![1, 2]);
        set(&mut turns[4], vec![0]);
        let mut f = Flow {
            id: 9,
            priority: Priority::Reactive,
            profile: "dag".into(),
            turns,
        };
        f.annotate_critical_paths();
        let cp: Vec<usize> = f
            .turns
            .iter()
            .map(|t| t.flow.as_ref().unwrap().crit_path)
            .collect();
        assert_eq!(cp, vec![3, 2, 2, 1, 1]);
    }
}
