//! Flow-level sessions (paper §1, §4): a *flow* is the unit of agentic
//! work — an ordered sequence of LLM-call turns that share a session
//! id, a growing conversation prefix, and one priority class.  Reactive
//! flows are multi-turn chats (user think-time between turns);
//! proactive flows are long-lived monitors that wake on events and
//! digest them into the same running context.
//!
//! A flow turn `k+1` never exists independently of turn `k`: its prompt
//! is the conversation so far plus a fresh *delta* (the new user
//! message / the new event batch), and it arrives one think-time after
//! turn `k` completes.  The DES driver enforces both properties — it
//! holds later turns until their predecessor finishes, stitches the
//! *actual* generated conversation into the successor prompt, and (for
//! engines with session-cache reuse enabled) seeds the turn's serving
//! state from the retained KV so only the delta is prefilled
//! (DESIGN.md §3).

use super::request::{Priority, ProfileTag, Request};

/// Session identity shared by every turn of one flow.
pub type FlowId = u64;

/// Per-request flow membership, carried on [`Request::flow`].
#[derive(Debug, Clone, PartialEq)]
pub struct FlowBinding {
    pub flow_id: FlowId,
    /// Position of this turn within the flow (0-based).
    pub turn_idx: usize,
    /// Turns the flow was generated with (the driver trusts the actual
    /// chain it observes, so a truncated trace still drains cleanly).
    pub total_turns: usize,
    /// Think-time gap (µs) between the previous turn's completion and
    /// this turn's arrival — user reading/typing for reactive chats,
    /// event inter-arrival for proactive monitors (paper §8.1).
    pub think_time_us: f64,
    /// Offset into `prompt` where this turn's fresh tokens start; the
    /// prefix `[..delta_start]` is the generator's *estimate* of the
    /// conversation so far, which the driver replaces with the actual
    /// one before admission.
    pub delta_start: usize,
}

impl FlowBinding {
    /// Turns after the first reuse the session's conversation prefix.
    pub fn is_continuation(&self) -> bool {
        self.turn_idx > 0
    }
}

/// An ordered multi-turn agentic flow: the workload-level object the
/// generators emit and the engines consume (flattened into per-turn
/// [`Request`]s whose `flow` bindings carry the session linkage).
#[derive(Debug, Clone)]
pub struct Flow {
    pub id: FlowId,
    pub priority: Priority,
    pub profile: ProfileTag,
    /// Turns in order; every element carries a `FlowBinding` with this
    /// flow's id and its own `turn_idx`.
    pub turns: Vec<Request>,
}

impl Flow {
    pub fn total_turns(&self) -> usize {
        self.turns.len()
    }

    /// Arrival time of the opening turn (later turns are released by
    /// the driver relative to their predecessor's completion).
    pub fn first_arrival_us(&self) -> f64 {
        self.turns.first().map(|t| t.arrival_us).unwrap_or(0.0)
    }

    /// Total delta tokens across all turns — the prefill work a
    /// session-cache-aware engine performs (a full-recompute engine
    /// prefills the whole growing prefix every turn instead).
    pub fn delta_tokens(&self) -> usize {
        self.turns
            .iter()
            .map(|t| {
                let ds = t.flow.as_ref().map(|f| f.delta_start).unwrap_or(0);
                t.prompt_len().saturating_sub(ds)
            })
            .sum()
    }
}

/// Flatten flows into one arrival-ordered request trace (the form every
/// `Engine::run` takes; `merge_traces` applies the final global sort).
pub fn flatten_flows(flows: Vec<Flow>) -> Vec<Request> {
    let mut all: Vec<Request> = flows.into_iter().flat_map(|f| f.turns).collect();
    all.sort_by(|a, b| a.arrival_us.total_cmp(&b.arrival_us).then(a.id.cmp(&b.id)));
    all
}

#[cfg(test)]
mod tests {
    use super::*;

    fn turn(flow_id: u64, idx: usize, total: usize, plen: usize, ds: usize) -> Request {
        Request {
            id: flow_id * 100 + idx as u64,
            priority: Priority::Reactive,
            arrival_us: idx as f64,
            prompt: vec![1; plen],
            max_new_tokens: 4,
            profile: "test".into(),
            flow: Some(FlowBinding {
                flow_id,
                turn_idx: idx,
                total_turns: total,
                think_time_us: 1e6,
                delta_start: ds,
            }),
        }
    }

    #[test]
    fn flow_accessors() {
        let f = Flow {
            id: 3,
            priority: Priority::Reactive,
            profile: "chat".into(),
            turns: vec![turn(3, 0, 2, 10, 0), turn(3, 1, 2, 20, 14)],
        };
        assert_eq!(f.total_turns(), 2);
        assert_eq!(f.first_arrival_us(), 0.0);
        // 10 (whole first prompt) + 6 (20 - delta_start 14)
        assert_eq!(f.delta_tokens(), 16);
        assert!(!f.turns[0].flow.as_ref().unwrap().is_continuation());
        assert!(f.turns[1].flow.as_ref().unwrap().is_continuation());
    }

    #[test]
    fn flatten_orders_by_arrival() {
        let a = Flow {
            id: 1,
            priority: Priority::Reactive,
            profile: "chat".into(),
            turns: vec![turn(1, 0, 1, 8, 0)],
        };
        let mut b = Flow {
            id: 2,
            priority: Priority::Reactive,
            profile: "chat".into(),
            turns: vec![turn(2, 0, 1, 8, 0)],
        };
        b.turns[0].arrival_us = -5.0;
        let t = flatten_flows(vec![a, b]);
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].id, 200);
    }
}
