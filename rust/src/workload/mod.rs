//! Agentic workload generation (paper §8.1).
//!
//! Real datasets (ProactiveBench, SAMSum, CNN/DailyMail, LMSys-chat-1M,
//! MTRAG, BFCL) are not available offline, so each is replaced by a
//! *trace profile* matching its published prompt/output length
//! statistics (DESIGN.md §1).  Arrival processes follow the paper:
//! Poisson for proactive requests, exponential inter-arrival (user
//! think-time) for reactive requests.  Everything is seeded.

mod gen;
mod profiles;
mod request;

pub use gen::{WorkloadSpec, merge_traces, proactive_trace, reactive_trace};
pub use profiles::{TraceProfile, profile, profiles};
pub use request::{Priority, ReqId, Request};
