//! Agentic workload generation (paper §8.1).
//!
//! Real datasets (ProactiveBench, SAMSum, CNN/DailyMail, LMSys-chat-1M,
//! MTRAG, BFCL) are not available offline, so each is replaced by a
//! *trace profile* matching its published prompt/output length
//! statistics (DESIGN.md §1).  Arrival processes follow the paper:
//! Poisson for proactive requests, exponential inter-arrival (user
//! think-time) for reactive requests.  Everything is seeded.
//!
//! Three workload shapes are emitted:
//! - single-shot streams (`proactive_trace`/`reactive_trace`) — one
//!   isolated `Request` per agent call;
//! - multi-turn **flows** (`flow_trace`) — linear turn chains sharing a
//!   session id and a growing conversation prefix, the paper's
//!   "long-lived, stateful LLM flows" (§1; DESIGN.md §3);
//! - workflow **DAGs** (`dag_flow_trace`) — dependency graphs mixing
//!   LLM turns with CPU tool-call nodes, with fan-out/join (tool
//!   agents, map-reduce research, monitors with tool fetches).

mod flow;
mod gen;
mod profiles;
mod request;

pub use flow::{Flow, FlowBinding, FlowId, NodeKind, flatten_flows};
pub use gen::{
    DagShape, DagSpec, FleetSpec, FlowSpec, UserFlow, WorkloadSpec, dag_flow_trace,
    fleet_user_flows, flow_trace, merge_traces, proactive_trace, reactive_trace,
};
pub use profiles::{TraceProfile, profile, profiles};
pub use request::{Priority, ProfileTag, ReqId, Request};
