//! Agentic workload generation (paper §8.1).
//!
//! Real datasets (ProactiveBench, SAMSum, CNN/DailyMail, LMSys-chat-1M,
//! MTRAG, BFCL) are not available offline, so each is replaced by a
//! *trace profile* matching its published prompt/output length
//! statistics (DESIGN.md §1).  Arrival processes follow the paper:
//! Poisson for proactive requests, exponential inter-arrival (user
//! think-time) for reactive requests.  Everything is seeded.
//!
//! Two workload shapes are emitted:
//! - single-shot streams (`proactive_trace`/`reactive_trace`) — one
//!   isolated `Request` per agent call;
//! - multi-turn **flows** (`flow_trace`) — ordered turn sequences
//!   sharing a session id and a growing conversation prefix, the
//!   paper's "long-lived, stateful LLM flows" (§1; DESIGN.md §3).

mod flow;
mod gen;
mod profiles;
mod request;

pub use flow::{Flow, FlowBinding, FlowId, flatten_flows};
pub use gen::{
    FlowSpec, WorkloadSpec, flow_trace, merge_traces, proactive_trace, reactive_trace,
};
pub use profiles::{TraceProfile, profile, profiles};
pub use request::{Priority, ProfileTag, ReqId, Request};
