//! Dataset-analog trace profiles.
//!
//! Each profile reproduces the *length statistics* of one of the paper's
//! six workloads (§8.1).  Lengths are drawn from clamped log-normals;
//! the (median, spread) pairs below come from the datasets' published
//! statistics, scaled into the serving model's context budget by
//! `TraceProfile::sample_*` (prompt+output must fit `max_seq`).

use crate::util::rng::Rng;

/// Length distribution profile of one agentic workload.
#[derive(Debug, Clone, Copy)]
pub struct TraceProfile {
    pub name: &'static str,
    /// Proactive daemons vs reactive assistants (which side of Fig. 6/7
    /// the paper uses the dataset for).
    pub proactive: bool,
    /// Median prompt length (tokens) and log-normal sigma.
    pub prompt_median: f64,
    pub prompt_sigma: f64,
    /// Median output length and log-normal sigma.
    pub out_median: f64,
    pub out_sigma: f64,
    /// Follow-up turns of a multi-turn flow carry a fresh *delta*
    /// prompt this fraction of the opening prompt's median (chat
    /// follow-ups are shorter than openers; monitor events are smaller
    /// than the initial briefing).
    pub follow_up_frac: f64,
}

impl TraceProfile {
    fn sample(r: &mut Rng, median: f64, sigma: f64, lo: usize, hi: usize) -> usize {
        let v = r.lognormal(median.ln(), sigma);
        (v.round() as usize).clamp(lo, hi)
    }

    /// Sample a (prompt_len, out_len) pair fitting a `max_seq` context.
    pub fn sample_lengths(&self, r: &mut Rng, max_seq: usize) -> (usize, usize) {
        // keep at least 1/8 of the context for generation
        let p_hi = max_seq - (max_seq / 8).max(8);
        let p = Self::sample(r, self.prompt_median, self.prompt_sigma, 4, p_hi);
        let o_hi = max_seq - p;
        let o = Self::sample(r, self.out_median, self.out_sigma, 1, o_hi.max(1));
        (p, o)
    }

    /// Sample the (delta_len, out_len) of a *follow-up* flow turn whose
    /// conversation so far already occupies `ctx` tokens of a `max_seq`
    /// context.  Returns `None` when the remaining budget cannot fit a
    /// minimal turn (the flow is then truncated).
    pub fn sample_turn_delta(
        &self,
        r: &mut Rng,
        max_seq: usize,
        ctx: usize,
    ) -> Option<(usize, usize)> {
        let left = max_seq.saturating_sub(ctx);
        if left < 8 {
            return None;
        }
        let d_hi = left - (left / 4).max(4);
        let d_median = (self.prompt_median * self.follow_up_frac).max(4.0);
        let d = Self::sample(r, d_median, self.prompt_sigma, 2, d_hi.max(2));
        let o = Self::sample(r, self.out_median, self.out_sigma, 1, (left - d).max(1));
        if d + o > left { None } else { Some((d, o)) }
    }
}

/// The six dataset analogs (paper §8.1).  Medians are relative to the
/// paper's Llama-3.2-3B context use; they get clamped into the model's
/// `max_seq` at sampling time, preserving the *relative* workload shape.
pub const PROFILES: [TraceProfile; 6] = [
    // Proactive: ambient event digestion → medium prompts, short outputs.
    TraceProfile {
        name: "proactivebench",
        proactive: true,
        prompt_median: 260.0,
        prompt_sigma: 0.45,
        out_median: 48.0,
        out_sigma: 0.5,
        follow_up_frac: 0.35,
    },
    // SAMSum group-chat summarization: short dialogues, short drafts.
    TraceProfile {
        name: "samsum",
        proactive: true,
        prompt_median: 180.0,
        prompt_sigma: 0.5,
        out_median: 32.0,
        out_sigma: 0.4,
        follow_up_frac: 0.4,
    },
    // CNN/DailyMail news summarization: long articles, medium summaries.
    TraceProfile {
        name: "cnn_dailymail",
        proactive: true,
        prompt_median: 420.0,
        prompt_sigma: 0.35,
        out_median: 56.0,
        out_sigma: 0.35,
        follow_up_frac: 0.3,
    },
    // Reactive: LMSys chat — medium prompts, long answers.
    TraceProfile {
        name: "lmsys",
        proactive: false,
        prompt_median: 120.0,
        prompt_sigma: 0.7,
        out_median: 160.0,
        out_sigma: 0.6,
        follow_up_frac: 0.45,
    },
    // MTRAG multi-turn RAG: long retrieved context, medium answers.
    TraceProfile {
        name: "mtrag",
        proactive: false,
        prompt_median: 360.0,
        prompt_sigma: 0.4,
        out_median: 96.0,
        out_sigma: 0.5,
        follow_up_frac: 0.35,
    },
    // Berkeley Function-Calling: structured call outputs — short.
    TraceProfile {
        name: "bfcl",
        proactive: false,
        prompt_median: 220.0,
        prompt_sigma: 0.45,
        out_median: 24.0,
        out_sigma: 0.35,
        follow_up_frac: 0.5,
    },
];

pub fn profiles() -> &'static [TraceProfile] {
    &PROFILES
}

pub fn profile(name: &str) -> Option<&'static TraceProfile> {
    PROFILES.iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_profiles_three_per_class() {
        assert_eq!(PROFILES.len(), 6);
        assert_eq!(PROFILES.iter().filter(|p| p.proactive).count(), 3);
    }

    #[test]
    fn lookup_by_name() {
        assert!(profile("samsum").is_some());
        assert!(profile("nope").is_none());
    }

    #[test]
    fn samples_fit_context() {
        let mut r = Rng::new(1);
        for p in profiles() {
            for _ in 0..500 {
                let (pl, ol) = p.sample_lengths(&mut r, 512);
                assert!(pl >= 4 && ol >= 1);
                assert!(pl + ol <= 512, "{}: {pl}+{ol}", p.name);
            }
        }
    }

    #[test]
    fn medians_roughly_respected() {
        // with a generous context, the sample median should be within
        // ~25% of the profile median
        let mut r = Rng::new(2);
        let p = profile("cnn_dailymail").unwrap();
        let mut lens: Vec<usize> =
            (0..4000).map(|_| p.sample_lengths(&mut r, 4096).0).collect();
        lens.sort_unstable();
        let med = lens[lens.len() / 2] as f64;
        assert!((med - p.prompt_median).abs() / p.prompt_median < 0.25, "median {med}");
    }

    #[test]
    fn follow_up_deltas_are_shorter_and_fit_remaining_budget() {
        let mut r = Rng::new(5);
        for p in profiles() {
            assert!(p.follow_up_frac > 0.0 && p.follow_up_frac < 1.0, "{}", p.name);
            for ctx in [32usize, 200, 400, 480, 504] {
                if let Some((d, o)) = p.sample_turn_delta(&mut r, 512, ctx) {
                    assert!(d >= 2 && o >= 1);
                    assert!(ctx + d + o <= 512, "{}: ctx {ctx} + {d} + {o}", p.name);
                }
            }
            // no budget left → turn refused
            assert!(p.sample_turn_delta(&mut r, 512, 508).is_none());
        }
    }

    #[test]
    fn reactive_profiles_generate_longer_outputs_than_bfcl() {
        let mut r = Rng::new(3);
        let lmsys = profile("lmsys").unwrap();
        let bfcl = profile("bfcl").unwrap();
        let avg = |p: &TraceProfile, r: &mut Rng| -> f64 {
            (0..2000).map(|_| p.sample_lengths(r, 512).1 as f64).sum::<f64>() / 2000.0
        };
        assert!(avg(lmsys, &mut r) > 2.0 * avg(bfcl, &mut r));
    }
}
