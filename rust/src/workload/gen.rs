//! Trace synthesis: arrival processes + length sampling → `Vec<Request>`
//! (single-shot streams) and `Vec<Flow>` (multi-turn session flows).

use crate::util::rng::Rng;

use super::flow::{Flow, FlowBinding, FlowId, NodeKind};
use super::profiles::{TraceProfile, profile};
use super::request::{Priority, ReqId, Request};

/// Parameters of one generated workload stream.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub profile: &'static TraceProfile,
    /// Proactive: Poisson request rate (req/s).  Reactive: 1 / mean
    /// think-time interval (req/s) — the paper sweeps the *interval*.
    pub rate_per_s: f64,
    pub duration_s: f64,
    pub seed: u64,
    /// Context budget (the model's max_seq).
    pub max_seq: usize,
}

fn prompt_tokens(r: &mut Rng, len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|_| r.usize(0, vocab) as i32).collect()
}

/// Event-driven proactive stream: Poisson arrivals (exponential gaps).
pub fn proactive_trace(spec: &WorkloadSpec, vocab: usize, first_id: ReqId) -> Vec<Request> {
    let mut r = Rng::new(spec.seed);
    let mut out = vec![];
    let mut t_s = 0.0f64;
    let mut id = first_id;
    loop {
        t_s += r.exponential(spec.rate_per_s);
        if t_s >= spec.duration_s {
            return out;
        }
        let (pl, ol) = spec.profile.sample_lengths(&mut r, spec.max_seq);
        out.push(Request {
            id,
            priority: Priority::Proactive,
            arrival_us: t_s * 1e6,
            prompt: prompt_tokens(&mut r, pl, vocab),
            max_new_tokens: ol,
            profile: spec.profile.name.into(),
            flow: None,
        });
        id += 1;
    }
}

/// User-driven reactive stream: the next question arrives one
/// exponential think-time after the previous one (paper §8.1), with at
/// most one outstanding conversation (the §4 workload assumption is
/// enforced by spacing, not by dropping).
pub fn reactive_trace(spec: &WorkloadSpec, vocab: usize, first_id: ReqId) -> Vec<Request> {
    let mut r = Rng::new(spec.seed);
    let mut out = vec![];
    let mut t_s = r.exponential(spec.rate_per_s);
    let mut id = first_id;
    while t_s < spec.duration_s {
        let (pl, ol) = spec.profile.sample_lengths(&mut r, spec.max_seq);
        out.push(Request {
            id,
            priority: Priority::Reactive,
            arrival_us: t_s * 1e6,
            prompt: prompt_tokens(&mut r, pl, vocab),
            max_new_tokens: ol,
            profile: spec.profile.name.into(),
            flow: None,
        });
        id += 1;
        t_s += r.exponential(spec.rate_per_s);
    }
    out
}

/// Parameters of one generated *flow* stream (multi-turn sessions).
#[derive(Debug, Clone)]
pub struct FlowSpec {
    pub profile: &'static TraceProfile,
    /// Poisson rate of flow *starts* (flows/s).
    pub flow_rate_per_s: f64,
    /// Mean think-time between a turn's completion and the next turn's
    /// arrival (s) — user reading/typing for chats, event inter-arrival
    /// for monitors (paper §8.1).  Exponentially distributed per gap.
    pub think_time_s: f64,
    /// Turns per flow, sampled uniformly from this inclusive range
    /// (flows truncate early if the conversation outgrows `max_seq`).
    pub turns: (usize, usize),
    pub duration_s: f64,
    pub seed: u64,
    /// Context budget (the model's max_seq).
    pub max_seq: usize,
}

/// Generate multi-turn flows: reactive chat sessions
/// (`Priority::Reactive`) or proactive monitor sessions
/// (`Priority::Proactive`).  Turn 0 carries the opening prompt; every
/// later turn's prompt is the conversation-so-far estimate (prior
/// prompt + `max_new_tokens` placeholder reply tokens) plus a fresh
/// delta, with `FlowBinding::delta_start` marking the boundary so the
/// driver can stitch in the *actual* generated reply at release time.
pub fn flow_trace(
    spec: &FlowSpec,
    priority: Priority,
    vocab: usize,
    first_id: ReqId,
    first_flow: FlowId,
) -> Vec<Flow> {
    assert!(spec.turns.0 >= 1 && spec.turns.0 <= spec.turns.1, "bad turn range");
    let mut r = Rng::new(spec.seed);
    let mut flows = vec![];
    let mut t_s = 0.0f64;
    let mut id = first_id;
    let mut flow_id = first_flow;
    loop {
        t_s += r.exponential(spec.flow_rate_per_s);
        if t_s >= spec.duration_s {
            break;
        }
        let want_turns = r.usize(spec.turns.0, spec.turns.1 + 1);
        let (pl, ol) = spec.profile.sample_lengths(&mut r, spec.max_seq);
        // conversation so far: turn-k prompt + its (placeholder) reply
        let mut convo = prompt_tokens(&mut r, pl, vocab);
        let mut turns = vec![Request {
            id,
            priority,
            arrival_us: t_s * 1e6,
            prompt: convo.clone(),
            max_new_tokens: ol,
            profile: spec.profile.name.into(),
            flow: None, // bindings filled below once total_turns is known
        }];
        id += 1;
        convo.extend(prompt_tokens(&mut r, ol, vocab));
        let mut think_times = vec![0.0f64];
        while turns.len() < want_turns {
            let Some((dl, ol)) =
                spec.profile.sample_turn_delta(&mut r, spec.max_seq, convo.len())
            else {
                break; // context budget exhausted: truncate the flow
            };
            let mut prompt = convo.clone();
            prompt.extend(prompt_tokens(&mut r, dl, vocab));
            turns.push(Request {
                id,
                priority,
                // placeholder — the driver re-stamps on release
                arrival_us: t_s * 1e6,
                prompt: prompt.clone(),
                max_new_tokens: ol,
                profile: spec.profile.name.into(),
                flow: None,
            });
            id += 1;
            think_times.push(r.exponential(1.0 / spec.think_time_s) * 1e6);
            convo = prompt;
            convo.extend(prompt_tokens(&mut r, ol, vocab));
        }
        let total = turns.len();
        // fill bindings (delta_start = previous turn's prompt+reply len)
        let mut prior = 0usize;
        for (k, t) in turns.iter_mut().enumerate() {
            t.flow = Some(FlowBinding {
                flow_id,
                turn_idx: k,
                total_turns: total,
                think_time_us: think_times[k],
                delta_start: if k == 0 { 0 } else { prior },
                deps: vec![], // implicit linear chain
                node: NodeKind::Llm,
                crit_path: total - k,
            });
            prior = t.prompt_len() + t.max_new_tokens;
        }
        flows.push(Flow {
            id: flow_id,
            priority,
            profile: spec.profile.name.into(),
            turns,
        });
        flow_id += 1;
    }
    flows
}

/// Workflow-DAG shapes (DESIGN.md §3): which agentic scenario a
/// [`DagSpec`] stream generates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DagShape {
    /// ReAct-style tool agent: LLM turn → CPU tool call → LLM digest,
    /// `rounds` times, closed by one user follow-up turn.
    ToolAgent { rounds: usize },
    /// Map-reduce research: a root digest fans out `fanout` parallel
    /// (tool → summarize) branches, joined by a final synthesis turn.
    MapReduce { fanout: usize },
    /// Long-lived monitor: each of `wakeups` events is a tool fetch
    /// feeding an LLM digest into the running context.
    MonitorTools { wakeups: usize },
}

/// Parameters of one generated workflow-DAG stream.
#[derive(Debug, Clone)]
pub struct DagSpec {
    pub profile: &'static TraceProfile,
    /// Poisson rate of flow *starts* (flows/s).
    pub flow_rate_per_s: f64,
    /// Mean think-time (s) on user/event-facing edges, exponentially
    /// distributed per gap; tool invocations and fan-out spawns release
    /// immediately.
    pub think_time_s: f64,
    pub shape: DagShape,
    pub duration_s: f64,
    pub seed: u64,
    /// Context budget (the model's max_seq).
    pub max_seq: usize,
}

/// Incremental DAG construction mirroring the driver's stitching rules,
/// so every placeholder prompt has exactly the length the stitched one
/// will have: an LLM node's context is its prompt plus its reply
/// budget; a tool node passes its first predecessor's context through;
/// a join sees the first predecessor's context plus the other branches'
/// contributions (delta + reply) in dependency order.
struct DagBuilder<'a> {
    r: &'a mut Rng,
    vocab: usize,
    max_seq: usize,
    flow_id: FlowId,
    next_id: ReqId,
    priority: Priority,
    profile: &'static str,
    arrival_us: f64,
    turns: Vec<Request>,
    /// Estimated conversation context after each node.
    ctx: Vec<Vec<i32>>,
    /// Estimated branch contribution (delta + reply) of each node.
    contrib: Vec<Vec<i32>>,
}

impl<'a> DagBuilder<'a> {
    /// Append an LLM node; returns its index, or `None` when the
    /// context budget is exhausted (the flow truncates cleanly).
    fn llm(
        &mut self,
        deps: Vec<usize>,
        delta_len: usize,
        out: usize,
        think_us: f64,
    ) -> Option<usize> {
        let merged: Vec<i32> = match deps.first() {
            None => vec![],
            Some(&d0) => {
                let mut m = self.ctx[d0].clone();
                for &d in &deps[1..] {
                    m.extend_from_slice(&self.contrib[d]);
                }
                m
            }
        };
        let budget = self.max_seq.saturating_sub(merged.len() + out);
        if budget < 2 {
            return None; // context budget exhausted
        }
        let dl = delta_len.clamp(1, budget - 1);
        let mut prompt = merged.clone();
        prompt.extend(prompt_tokens(self.r, dl, self.vocab));
        let idx = self.turns.len();
        self.turns.push(Request {
            id: self.next_id,
            priority: self.priority,
            // placeholder for non-roots — the driver re-stamps on release
            arrival_us: self.arrival_us,
            prompt: prompt.clone(),
            max_new_tokens: out,
            profile: self.profile.into(),
            flow: Some(FlowBinding {
                flow_id: self.flow_id,
                turn_idx: idx,
                total_turns: 0, // fixed in finish()
                think_time_us: think_us,
                delta_start: merged.len(),
                deps,
                node: NodeKind::Llm,
                crit_path: 1, // annotated in finish()
            }),
        });
        self.next_id += 1;
        let mut c = prompt;
        c.extend(prompt_tokens(self.r, out, self.vocab));
        let contrib = c[merged.len()..].to_vec();
        self.ctx.push(c);
        self.contrib.push(contrib);
        Some(idx)
    }

    /// Append a CPU tool-call node depending on `dep`; returns its
    /// index.  Cost is sampled per call: a few to tens of milliseconds
    /// of CPU compute with real DDR traffic (retrieval, code execution,
    /// file I/O — DESIGN.md §3).
    fn tool(&mut self, dep: usize, think_us: f64) -> usize {
        let args = self.r.usize(4, 17);
        let flops = 1e9 * (2.0 + 30.0 * self.r.f64());
        let bytes = 1e8 * (1.0 + 5.0 * self.r.f64());
        let idx = self.turns.len();
        self.turns.push(Request {
            id: self.next_id,
            priority: self.priority,
            arrival_us: self.arrival_us,
            prompt: prompt_tokens(self.r, args, self.vocab),
            max_new_tokens: 0,
            profile: self.profile.into(),
            flow: Some(FlowBinding {
                flow_id: self.flow_id,
                turn_idx: idx,
                total_turns: 0,
                think_time_us: think_us,
                delta_start: 0, // tool args are self-contained
                deps: vec![dep],
                node: NodeKind::Tool { flops, bytes },
                crit_path: 1,
            }),
        });
        self.next_id += 1;
        self.ctx.push(self.ctx[dep].clone());
        self.contrib.push(vec![]);
        idx
    }

    fn finish(mut self) -> (Flow, ReqId) {
        let total = self.turns.len();
        for t in self.turns.iter_mut() {
            if let Some(fb) = t.flow.as_mut() {
                fb.total_turns = total;
            }
        }
        let mut flow = Flow {
            id: self.flow_id,
            priority: self.priority,
            profile: self.profile.into(),
            turns: self.turns,
        };
        flow.annotate_critical_paths();
        (flow, self.next_id)
    }
}

/// Generate workflow-DAG flows of the given shape: Poisson flow starts,
/// per-flow node graphs with explicit dependency edges, tool-call
/// nodes, and fan-out/join (DESIGN.md §3).
pub fn dag_flow_trace(
    spec: &DagSpec,
    priority: Priority,
    vocab: usize,
    first_id: ReqId,
    first_flow: FlowId,
) -> Vec<Flow> {
    let mut r = Rng::new(spec.seed);
    let mut flows = vec![];
    let mut t_s = 0.0f64;
    let mut id = first_id;
    let mut flow_id = first_flow;
    loop {
        t_s += r.exponential(spec.flow_rate_per_s);
        if t_s >= spec.duration_s {
            break;
        }
        let (pl, ol) = spec.profile.sample_lengths(&mut r, spec.max_seq);
        let pl = pl.clamp(8, spec.max_seq / 3);
        let think = |r: &mut Rng| r.exponential(1.0 / spec.think_time_s) * 1e6;
        let mut b = DagBuilder {
            r: &mut r,
            vocab,
            max_seq: spec.max_seq,
            flow_id,
            next_id: id,
            priority,
            profile: spec.profile.name,
            arrival_us: t_s * 1e6,
            turns: vec![],
            ctx: vec![],
            contrib: vec![],
        };
        match spec.shape {
            DagShape::ToolAgent { rounds } => {
                let root = b.llm(vec![], pl, ol.clamp(4, 48), 0.0).expect("root fits");
                let mut prev = root;
                for _ in 0..rounds {
                    let t = b.tool(prev, 0.0);
                    let dl = b.r.usize(32, 129);
                    let out = b.r.usize(8, 33);
                    match b.llm(vec![t], dl, out, 0.0) {
                        Some(l) => prev = l,
                        None => break,
                    }
                }
                // the user reads the result and follows up
                let dl = b.r.usize(16, 65);
                let out = b.r.usize(8, 33);
                let tt = think(&mut *b.r);
                let _ = b.llm(vec![prev], dl, out, tt);
            }
            DagShape::MapReduce { fanout } => {
                let root = b.llm(vec![], pl, ol.clamp(4, 32), 0.0).expect("root fits");
                let mut branches = vec![];
                for _ in 0..fanout.max(1) {
                    let t = b.tool(root, 0.0);
                    let dl = b.r.usize(32, 97);
                    let out = b.r.usize(8, 25);
                    if let Some(l) = b.llm(vec![t], dl, out, 0.0) {
                        branches.push(l);
                    }
                }
                if branches.len() >= 2 {
                    let dl = b.r.usize(16, 49);
                    let out = b.r.usize(16, 49);
                    let _ = b.llm(branches, dl, out, 0.0);
                } else if let Some(&l) = branches.first() {
                    let dl = b.r.usize(16, 49);
                    let out = b.r.usize(16, 49);
                    let _ = b.llm(vec![l], dl, out, 0.0);
                }
            }
            DagShape::MonitorTools { wakeups } => {
                let root = b.llm(vec![], pl, ol.clamp(4, 32), 0.0).expect("root fits");
                let mut prev = root;
                for _ in 0..wakeups {
                    let tt = think(&mut *b.r);
                    let t = b.tool(prev, tt);
                    let dl = b.r.usize(24, 97);
                    let out = b.r.usize(4, 25);
                    match b.llm(vec![t], dl, out, 0.0) {
                        Some(l) => prev = l,
                        None => break,
                    }
                }
            }
        }
        let (flow, next_id) = b.finish();
        id = next_id;
        flows.push(flow);
        flow_id += 1;
    }
    flows
}

/// Merge streams into one arrival-ordered trace.
pub fn merge_traces(mut streams: Vec<Vec<Request>>) -> Vec<Request> {
    let mut all: Vec<Request> = streams.drain(..).flatten().collect();
    all.sort_by(|a, b| a.arrival_us.total_cmp(&b.arrival_us));
    all
}

/// One user's multi-turn flow — the fleet router's unit of input
/// (DESIGN.md §9): routing decisions key on `user`, session affinity
/// keys on `flow.id`.
#[derive(Debug, Clone)]
pub struct UserFlow {
    pub user: u64,
    pub flow: Flow,
}

/// Parameters of a multi-user fleet trace: `users` users with
/// Zipf-skewed activity (user `u` opens flows at a rate ∝
/// `(u+1)^-zipf_exponent`, normalised so the *mean* per-user rate is
/// the configured one).  Each user mixes reactive chat flows (LMSys
/// lengths, ~8 s think) with proactive monitor flows (ProactiveBench
/// lengths, ~20 s event gaps) — the same mix as `fig workflows`, but
/// attributed to users so a router can observe the skew.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub users: usize,
    /// Zipf exponent of the user-activity skew; 0 = uniform users.
    pub zipf_exponent: f64,
    /// Mean chat-flow starts per user per second.
    pub chat_rate_per_s: f64,
    /// Mean monitor-flow starts per user per second.
    pub monitor_rate_per_s: f64,
    pub duration_s: f64,
    pub seed: u64,
    /// Context budget (the model's max_seq).
    pub max_seq: usize,
}

/// Generate the fleet trace: per-user chat + monitor flows, returned
/// sorted by root arrival (then flow id).  Ids are globally unique
/// across users and streams.
pub fn fleet_user_flows(spec: &FleetSpec, vocab: usize) -> Vec<UserFlow> {
    assert!(spec.users > 0, "fleet trace needs at least one user");
    let chat = profile("lmsys").expect("lmsys profile");
    let monitor = profile("proactivebench").expect("proactivebench profile");
    // Zipf-ish weights, normalised to mean 1 so total fleet load is
    // independent of the skew exponent.
    let raw: Vec<f64> =
        (0..spec.users).map(|u| 1.0 / ((u + 1) as f64).powf(spec.zipf_exponent)).collect();
    let mean = raw.iter().sum::<f64>() / spec.users as f64;
    let mut out: Vec<UserFlow> = vec![];
    let mut next_id: ReqId = 0;
    let mut next_flow: FlowId = 0;
    for (u, w) in raw.iter().enumerate() {
        let weight = w / mean;
        // Distinct deterministic seed per (user, stream).
        let mix = |salt: u64| {
            spec.seed ^ (u as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(salt)
        };
        for (prof, rate, think_s, turns, prio, salt) in [
            (chat, spec.chat_rate_per_s, 8.0, (2, 5), Priority::Reactive, 1),
            (monitor, spec.monitor_rate_per_s, 20.0, (2, 4), Priority::Proactive, 2),
        ] {
            let flows = flow_trace(
                &FlowSpec {
                    profile: prof,
                    flow_rate_per_s: rate * weight,
                    think_time_s: think_s,
                    turns,
                    duration_s: spec.duration_s,
                    seed: mix(salt),
                    max_seq: spec.max_seq,
                },
                prio,
                vocab,
                next_id,
                next_flow,
            );
            for f in flows {
                next_id += f.total_turns() as ReqId;
                next_flow += 1;
                out.push(UserFlow { user: u as u64, flow: f });
            }
        }
    }
    out.sort_by(|a, b| {
        a.flow
            .first_arrival_us()
            .total_cmp(&b.flow.first_arrival_us())
            .then(a.flow.id.cmp(&b.flow.id))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::profiles::profile;

    fn spec(name: &str, rate: f64, seed: u64) -> WorkloadSpec {
        WorkloadSpec {
            profile: profile(name).unwrap(),
            rate_per_s: rate,
            duration_s: 100.0,
            seed,
            max_seq: 512,
        }
    }

    #[test]
    fn poisson_rate_approximates_spec() {
        let t = proactive_trace(&spec("samsum", 2.0, 1), 2048, 0);
        // 2 req/s over 100 s → ~200 requests
        assert!((150..260).contains(&t.len()), "{}", t.len());
        assert!(t.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
        assert!(t.iter().all(|q| q.priority == Priority::Proactive));
    }

    #[test]
    fn traces_are_seeded() {
        let a = proactive_trace(&spec("samsum", 1.0, 7), 2048, 0);
        let b = proactive_trace(&spec("samsum", 1.0, 7), 2048, 0);
        let c = proactive_trace(&spec("samsum", 1.0, 8), 2048, 0);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.arrival_us == y.arrival_us
            && x.prompt == y.prompt));
        assert!(a.len() != c.len()
            || a.iter().zip(&c).any(|(x, y)| x.arrival_us != y.arrival_us));
    }

    #[test]
    fn reactive_trace_is_reactive_and_sparser() {
        let t = reactive_trace(&spec("lmsys", 0.1, 2), 2048, 100);
        assert!(t.iter().all(|q| q.priority == Priority::Reactive));
        assert!(t.len() < 30, "{}", t.len());
        assert_eq!(t[0].id, 100);
    }

    #[test]
    fn prompts_in_vocab_and_budget() {
        let t = proactive_trace(&spec("cnn_dailymail", 1.0, 3), 512, 0);
        for q in &t {
            assert!(q.prompt.iter().all(|&x| (0..512).contains(&x)));
            assert!(q.prompt_len() + q.max_new_tokens <= 512);
            assert!(q.max_new_tokens >= 1);
        }
    }

    fn flow_spec(seed: u64) -> FlowSpec {
        FlowSpec {
            profile: profile("lmsys").unwrap(),
            flow_rate_per_s: 0.05,
            think_time_s: 8.0,
            turns: (2, 4),
            duration_s: 200.0,
            seed,
            max_seq: 512,
        }
    }

    #[test]
    fn flow_traces_have_coherent_turn_structure() {
        let flows = flow_trace(&flow_spec(9), Priority::Reactive, 2048, 0, 100);
        assert!(!flows.is_empty());
        let mut next_id = 0u64;
        for f in &flows {
            assert!((1..=4).contains(&f.total_turns()));
            for (k, t) in f.turns.iter().enumerate() {
                let fb = t.flow.as_ref().unwrap();
                assert_eq!((fb.flow_id, fb.turn_idx, fb.total_turns), (f.id, k, f.total_turns()));
                assert_eq!(t.id, next_id);
                next_id += 1;
                assert!(t.prompt_len() + t.max_new_tokens <= 512);
                assert!(t.priority == Priority::Reactive);
                if k == 0 {
                    assert_eq!(fb.delta_start, 0);
                } else {
                    let prev = &f.turns[k - 1];
                    // delta starts right after the prior conversation
                    // (prev prompt + its reply-token budget)
                    assert_eq!(fb.delta_start, prev.prompt_len() + prev.max_new_tokens);
                    assert!(fb.delta_start < t.prompt_len());
                    // the new prompt literally extends the old one
                    assert_eq!(&t.prompt[..prev.prompt_len()], &prev.prompt[..]);
                    assert!(fb.think_time_us > 0.0);
                }
            }
        }
        // seeded: identical regeneration
        let again = flow_trace(&flow_spec(9), Priority::Reactive, 2048, 0, 100);
        assert_eq!(flows.len(), again.len());
        assert!(flows.iter().zip(&again).all(|(a, b)| {
            a.turns.len() == b.turns.len()
                && a.turns.iter().zip(&b.turns).all(|(x, y)| x.prompt == y.prompt)
        }));
        let other = flow_trace(&flow_spec(10), Priority::Reactive, 2048, 0, 100);
        assert!(
            flows.len() != other.len()
                || flows
                    .iter()
                    .zip(&other)
                    .any(|(a, b)| a.first_arrival_us() != b.first_arrival_us())
        );
    }

    fn dag_spec(shape: DagShape, seed: u64) -> DagSpec {
        DagSpec {
            profile: profile("lmsys").unwrap(),
            flow_rate_per_s: 0.05,
            think_time_s: 6.0,
            shape,
            duration_s: 200.0,
            seed,
            max_seq: 2048,
        }
    }

    #[test]
    fn dag_traces_have_coherent_structure() {
        for shape in [
            DagShape::ToolAgent { rounds: 2 },
            DagShape::MapReduce { fanout: 3 },
            DagShape::MonitorTools { wakeups: 3 },
        ] {
            let flows = dag_flow_trace(&dag_spec(shape, 3), Priority::Proactive, 2048, 0, 50);
            assert!(!flows.is_empty(), "{shape:?}");
            let mut next_id = 0u64;
            for f in &flows {
                for (k, t) in f.turns.iter().enumerate() {
                    let fb = t.flow.as_ref().unwrap();
                    assert_eq!((fb.flow_id, fb.turn_idx, fb.total_turns), (f.id, k, f.total_turns()));
                    assert_eq!(t.id, next_id);
                    next_id += 1;
                    assert!(t.prompt_len() + t.max_new_tokens <= 2048);
                    // deps are a DAG in topological order
                    for d in fb.dep_indices() {
                        assert!(d < k, "{shape:?}: dep {d} >= node {k}");
                    }
                    if fb.is_tool() {
                        assert_eq!(t.max_new_tokens, 0, "tools generate no tokens");
                        assert_eq!(fb.delta_start, 0, "tool args are self-contained");
                        assert_eq!(fb.dep_indices().len(), 1);
                    }
                    if k == 0 {
                        assert!(!fb.is_tool(), "flows open with an LLM turn");
                        assert_eq!(fb.delta_start, 0);
                    } else if !fb.is_tool() {
                        assert!(fb.delta_start > 0, "continuations carry a context estimate");
                        assert!(fb.delta_start < t.prompt_len());
                    }
                    // critical path: annotated, and ≤ the dep's by at least 1
                    assert!(fb.crit_path >= 1);
                    for d in fb.dep_indices() {
                        let dep_cp = f.turns[d].flow.as_ref().unwrap().crit_path;
                        assert!(dep_cp >= fb.crit_path + 1, "{shape:?}: cp not monotone");
                    }
                }
                assert!(f.turns.iter().any(|t| t.is_tool()), "{shape:?}: no tool node");
            }
            // seeded: identical regeneration
            let again = dag_flow_trace(&dag_spec(shape, 3), Priority::Proactive, 2048, 0, 50);
            assert_eq!(flows.len(), again.len());
            assert!(flows.iter().zip(&again).all(|(a, b)| {
                a.turns.len() == b.turns.len()
                    && a.turns.iter().zip(&b.turns).all(|(x, y)| x.prompt == y.prompt)
            }));
        }
    }

    #[test]
    fn map_reduce_joins_merge_branch_contributions() {
        let flows = dag_flow_trace(
            &dag_spec(DagShape::MapReduce { fanout: 3 }, 7),
            Priority::Proactive,
            2048,
            0,
            0,
        );
        let f = flows.iter().find(|f| f.total_turns() == 1 + 3 * 2 + 1).expect("full fan-out");
        let join = f.turns.last().unwrap();
        let jb = join.flow.as_ref().unwrap();
        assert_eq!(jb.dep_indices().len(), 3, "join waits on every branch");
        // the join's context estimate = first branch's conversation +
        // the other branches' (delta + reply) contributions
        let first_branch = &f.turns[jb.dep_indices()[0]];
        let fb0 = first_branch.flow.as_ref().unwrap();
        let mut expect = first_branch.prompt_len() + first_branch.max_new_tokens;
        for &d in &jb.dep_indices()[1..] {
            let b = &f.turns[d];
            let bb = b.flow.as_ref().unwrap();
            expect += b.prompt_len() - bb.delta_start + b.max_new_tokens;
        }
        assert_eq!(jb.delta_start, expect);
        // the join placeholder literally extends the first branch's prompt
        assert_eq!(
            &join.prompt[..fb0.delta_start],
            &first_branch.prompt[..fb0.delta_start]
        );
        // fan-out branches share the root as (transitive) ancestor
        assert!(f.llm_turns() < f.total_turns(), "tool nodes present");
    }

    #[test]
    fn merge_orders_by_arrival_with_unique_ids() {
        let a = proactive_trace(&spec("samsum", 1.0, 1), 2048, 0);
        let b = reactive_trace(&spec("lmsys", 0.2, 2), 2048, 10_000);
        let n = a.len() + b.len();
        let m = merge_traces(vec![a, b]);
        assert_eq!(m.len(), n);
        assert!(m.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
        let mut ids: Vec<_> = m.iter().map(|q| q.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "ids must be unique");
    }
}
