//! Trace synthesis: arrival processes + length sampling → `Vec<Request>`.

use crate::util::rng::Rng;

use super::profiles::TraceProfile;
use super::request::{Priority, ReqId, Request};

/// Parameters of one generated workload stream.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub profile: &'static TraceProfile,
    /// Proactive: Poisson request rate (req/s).  Reactive: 1 / mean
    /// think-time interval (req/s) — the paper sweeps the *interval*.
    pub rate_per_s: f64,
    pub duration_s: f64,
    pub seed: u64,
    /// Context budget (the model's max_seq).
    pub max_seq: usize,
}

fn prompt_tokens(r: &mut Rng, len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|_| r.usize(0, vocab) as i32).collect()
}

/// Event-driven proactive stream: Poisson arrivals (exponential gaps).
pub fn proactive_trace(spec: &WorkloadSpec, vocab: usize, first_id: ReqId) -> Vec<Request> {
    let mut r = Rng::new(spec.seed);
    let mut out = vec![];
    let mut t_s = 0.0f64;
    let mut id = first_id;
    loop {
        t_s += r.exponential(spec.rate_per_s);
        if t_s >= spec.duration_s {
            return out;
        }
        let (pl, ol) = spec.profile.sample_lengths(&mut r, spec.max_seq);
        out.push(Request {
            id,
            priority: Priority::Proactive,
            arrival_us: t_s * 1e6,
            prompt: prompt_tokens(&mut r, pl, vocab),
            max_new_tokens: ol,
            profile: spec.profile.name,
        });
        id += 1;
    }
}

/// User-driven reactive stream: the next question arrives one
/// exponential think-time after the previous one (paper §8.1), with at
/// most one outstanding conversation (the §4 workload assumption is
/// enforced by spacing, not by dropping).
pub fn reactive_trace(spec: &WorkloadSpec, vocab: usize, first_id: ReqId) -> Vec<Request> {
    let mut r = Rng::new(spec.seed);
    let mut out = vec![];
    let mut t_s = r.exponential(spec.rate_per_s);
    let mut id = first_id;
    while t_s < spec.duration_s {
        let (pl, ol) = spec.profile.sample_lengths(&mut r, spec.max_seq);
        out.push(Request {
            id,
            priority: Priority::Reactive,
            arrival_us: t_s * 1e6,
            prompt: prompt_tokens(&mut r, pl, vocab),
            max_new_tokens: ol,
            profile: spec.profile.name,
        });
        id += 1;
        t_s += r.exponential(spec.rate_per_s);
    }
    out
}

/// Merge streams into one arrival-ordered trace.
pub fn merge_traces(mut streams: Vec<Vec<Request>>) -> Vec<Request> {
    let mut all: Vec<Request> = streams.drain(..).flatten().collect();
    all.sort_by(|a, b| a.arrival_us.total_cmp(&b.arrival_us));
    all
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::profiles::profile;

    fn spec(name: &str, rate: f64, seed: u64) -> WorkloadSpec {
        WorkloadSpec {
            profile: profile(name).unwrap(),
            rate_per_s: rate,
            duration_s: 100.0,
            seed,
            max_seq: 512,
        }
    }

    #[test]
    fn poisson_rate_approximates_spec() {
        let t = proactive_trace(&spec("samsum", 2.0, 1), 2048, 0);
        // 2 req/s over 100 s → ~200 requests
        assert!((150..260).contains(&t.len()), "{}", t.len());
        assert!(t.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
        assert!(t.iter().all(|q| q.priority == Priority::Proactive));
    }

    #[test]
    fn traces_are_seeded() {
        let a = proactive_trace(&spec("samsum", 1.0, 7), 2048, 0);
        let b = proactive_trace(&spec("samsum", 1.0, 7), 2048, 0);
        let c = proactive_trace(&spec("samsum", 1.0, 8), 2048, 0);
        assert_eq!(a.len(), b.len());
        assert!(a.iter().zip(&b).all(|(x, y)| x.arrival_us == y.arrival_us
            && x.prompt == y.prompt));
        assert!(a.len() != c.len()
            || a.iter().zip(&c).any(|(x, y)| x.arrival_us != y.arrival_us));
    }

    #[test]
    fn reactive_trace_is_reactive_and_sparser() {
        let t = reactive_trace(&spec("lmsys", 0.1, 2), 2048, 100);
        assert!(t.iter().all(|q| q.priority == Priority::Reactive));
        assert!(t.len() < 30, "{}", t.len());
        assert_eq!(t[0].id, 100);
    }

    #[test]
    fn prompts_in_vocab_and_budget() {
        let t = proactive_trace(&spec("cnn_dailymail", 1.0, 3), 512, 0);
        for q in &t {
            assert!(q.prompt.iter().all(|&x| (0..512).contains(&x)));
            assert!(q.prompt_len() + q.max_new_tokens <= 512);
            assert!(q.max_new_tokens >= 1);
        }
    }

    #[test]
    fn merge_orders_by_arrival_with_unique_ids() {
        let a = proactive_trace(&spec("samsum", 1.0, 1), 2048, 0);
        let b = reactive_trace(&spec("lmsys", 0.2, 2), 2048, 10_000);
        let n = a.len() + b.len();
        let m = merge_traces(vec![a, b]);
        assert_eq!(m.len(), n);
        assert!(m.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
        let mut ids: Vec<_> = m.iter().map(|q| q.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "ids must be unique");
    }
}
