//! Per-kernel FLOP/byte accounting.
//!
//! Conventions:
//! - all weights/activations are f32 (4 bytes) — see DESIGN.md §9;
//! - a matmul `[m,k]@[k,n]` counts `2*m*k*n` FLOPs;
//! - memory traffic counts DDR-visible bytes: weight streaming,
//!   activation in/out, and KV-cache read/write.  On-chip reuse within a
//!   fused kernel is already excluded (the paper's op-group fusion is
//!   what makes this the right accounting granularity, §5.2);
//! - `gemm_flops` vs `attn_flops` are separated because op-XPU affinity
//!   differs (§3.1): NPUs run static GEMM near peak but collapse on
//!   dynamic attention.

use crate::config::ModelGeometry;

pub const BYTES_F32: f64 = 4.0;

/// Cost annotation for one HEG kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelCost {
    /// Dense token-level matmul work (static-shape compilable).
    pub gemm_flops: f64,
    /// Sequence-level attention work (dynamic-shape).
    pub attn_flops: f64,
    /// DDR traffic (bytes): weights + activations + KV.
    pub bytes: f64,
    /// Transient working-set bytes while the kernel runs (activations +
    /// scratch; weights are resident and accounted separately).
    pub footprint_bytes: f64,
    /// True if the kernel shape is not one of the precompiled static
    /// variants (margin chunks, odd batches) — NPU pays JIT (§3.1).
    pub is_dynamic: bool,
}

impl KernelCost {
    pub fn zero() -> Self {
        Self {
            gemm_flops: 0.0,
            attn_flops: 0.0,
            bytes: 0.0,
            footprint_bytes: 0.0,
            is_dynamic: false,
        }
    }

    pub fn total_flops(&self) -> f64 {
        self.gemm_flops + self.attn_flops
    }

    /// Arithmetic intensity (FLOPs / byte) — the roofline x-axis.
    pub fn arithmetic_intensity(&self) -> f64 {
        if self.bytes == 0.0 { 0.0 } else { self.total_flops() / self.bytes }
    }

    fn add(mut self, o: &KernelCost) -> Self {
        self.gemm_flops += o.gemm_flops;
        self.attn_flops += o.attn_flops;
        self.bytes += o.bytes;
        self.footprint_bytes = self.footprint_bytes.max(o.footprint_bytes);
        self.is_dynamic |= o.is_dynamic;
        self
    }
}

/// Per-layer weight bytes (streamed from DDR once per kernel).
fn layer_weight_bytes(g: &ModelGeometry) -> f64 {
    let kvd = g.n_kv_heads * g.head_dim;
    let params = g.d_model * g.d_model            // wq
        + 2 * g.d_model * kvd                     // wk, wv
        + g.d_model * g.d_model                   // wo
        + 3 * g.d_model * g.d_ffn                 // wg, wu, wd
        + 2 * g.d_model;                          // norms
    params as f64 * g.weight_bytes
}

/// Raw dense GEMM `[m,k]@[k,n]` (used by the §3.1 affinity/contention
/// micro-benchmarks, mirroring the paper's profiled op shapes).
pub fn gemm_cost(m: usize, k: usize, n: usize) -> KernelCost {
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    let bytes = (m * k + k * n + m * n) as f64 * BYTES_F32;
    KernelCost {
        gemm_flops: flops,
        attn_flops: 0.0,
        bytes,
        footprint_bytes: (m * k + m * n) as f64 * BYTES_F32,
        is_dynamic: false,
    }
}

/// GEMV = GEMM with m=1 (the decode-time op of the paper's Fig. 3).
pub fn gemv_cost(k: usize, n: usize) -> KernelCost {
    gemm_cost(1, k, n)
}

/// Standalone GQA attention of `c` query tokens against `ctx` cached
/// positions (the paper's MHA op; always dynamic-shape).
pub fn mha_cost(g: &ModelGeometry, c: usize, ctx: usize) -> KernelCost {
    let qh = g.n_q_heads as f64;
    let hd = g.head_dim as f64;
    // scores (c x ctx per q-head) + probs @ V
    let flops = 2.0 * 2.0 * c as f64 * ctx as f64 * qh * hd;
    let kv_bytes = 2.0 * ctx as f64 * (g.n_kv_heads * g.head_dim) as f64 * BYTES_F32;
    let qo_bytes = 2.0 * c as f64 * qh * hd * BYTES_F32;
    KernelCost {
        gemm_flops: 0.0,
        attn_flops: flops,
        bytes: kv_bytes + qo_bytes,
        footprint_bytes: (c * ctx * g.n_q_heads) as f64 * BYTES_F32,
        is_dynamic: true,
    }
}

/// One transformer layer over a prefill chunk: `valid` real tokens at
/// positions `pos..pos+valid`, run as static chunk variant `chunk`
/// (padded) or as a dynamic margin kernel when `valid < chunk`.
///
/// Includes the (tiny) embed share for layer 0 — embed is fused into the
/// chunk's first kernel in the HEG.
pub fn prefill_layer_cost(
    g: &ModelGeometry,
    chunk: usize,
    valid: usize,
    pos: usize,
    is_dynamic: bool,
) -> KernelCost {
    // A static kernel computes all `chunk` rows (padding included); a
    // dynamic margin kernel computes only `valid` rows.
    let c = if is_dynamic { valid } else { chunk };
    let d = g.d_model as f64;
    let kvd = (g.n_kv_heads * g.head_dim) as f64;
    let f = g.d_ffn as f64;
    let cf = c as f64;
    // qkv + o + swiglu(mlp): 2*c*d*(d + 2kvd + d) + 2*c*(2*d*f + f*d)
    let gemm = 2.0 * cf * d * (2.0 * d + 2.0 * kvd) + 2.0 * cf * 3.0 * d * f;
    let attn = mha_cost(g, valid, pos + valid);
    let act_bytes = 2.0 * cf * d * BYTES_F32; // x in + out
    let kv_write = 2.0 * cf * kvd * BYTES_F32;
    KernelCost {
        gemm_flops: gemm,
        attn_flops: attn.attn_flops,
        bytes: layer_weight_bytes(g) + act_bytes + kv_write + attn.bytes,
        footprint_bytes: (cf * d * 4.0 + attn.footprint_bytes).max(cf * f * 2.0 * BYTES_F32),
        is_dynamic,
    }
}

/// One batched decode iteration: head (sampling) + embed + all layers
/// for `lanes` sequences with mean context length `avg_ctx`.
///
/// This is the composite iGPU kernel the scheduler treats as one unit —
/// backfill joins happen only at iteration boundaries (§6.3).
pub fn decode_iter_cost(g: &ModelGeometry, lanes: usize, avg_ctx: usize) -> KernelCost {
    let d = g.d_model as f64;
    let kvd = (g.n_kv_heads * g.head_dim) as f64;
    let f = g.d_ffn as f64;
    let b = lanes as f64;
    let mut total = KernelCost::zero();

    // head: logits GEMV [b,d]@[d,V] — weights stream the whole embedding
    let v = g.vocab as f64;
    total = total.add(&KernelCost {
        gemm_flops: 2.0 * b * d * v,
        attn_flops: 0.0,
        bytes: v * d * g.weight_bytes + (b * v + b * d) * BYTES_F32,
        footprint_bytes: b * v * BYTES_F32,
        is_dynamic: false,
    });

    // per layer: GEMV-shaped linear ops (weight-streaming dominated) +
    // single-token attention over the cache
    for _ in 0..g.n_layers {
        let gemm = 2.0 * b * d * (2.0 * d + 2.0 * kvd) + 2.0 * b * 3.0 * d * f;
        let attn = mha_cost(g, 1, avg_ctx);
        total = total.add(&KernelCost {
            gemm_flops: gemm,
            attn_flops: attn.attn_flops * b,
            bytes: layer_weight_bytes(g)
                + 2.0 * b * d * BYTES_F32
                + b * (attn.bytes + 2.0 * kvd * BYTES_F32),
            footprint_bytes: b * d * 4.0 * BYTES_F32,
            is_dynamic: false, // iGPU-batched variants are precompiled
        });
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> ModelGeometry {
        ModelGeometry {
            name: "small".into(),
            vocab: 2048,
            d_model: 256,
            n_layers: 6,
            n_q_heads: 8,
            n_kv_heads: 2,
            head_dim: 32,
            d_ffn: 704,
            max_seq: 512,
            chunk_sizes: vec![16, 32, 64, 128],
            batch_sizes: vec![1, 2, 4, 8],
            rope_theta: 10000.0,
            weight_bytes: 4.0,
        }
    }

    #[test]
    fn gemm_is_compute_heavy_gemv_is_memory_heavy() {
        // The paper's Fig. 3 premise: GEMM has high AI, GEMV low AI.
        let gemm = gemm_cost(4096, 4096, 4096);
        let gemv = gemv_cost(4096, 4096);
        assert!(gemm.arithmetic_intensity() > 500.0, "{}", gemm.arithmetic_intensity());
        assert!(gemv.arithmetic_intensity() < 1.0, "{}", gemv.arithmetic_intensity());
    }

    #[test]
    fn prefill_gemm_flops_scale_with_chunk() {
        let g = geo();
        let c64 = prefill_layer_cost(&g, 64, 64, 0, false);
        let c128 = prefill_layer_cost(&g, 128, 128, 0, false);
        let ratio = c128.gemm_flops / c64.gemm_flops;
        assert!((ratio - 2.0).abs() < 1e-9, "ratio {ratio}");
    }

    #[test]
    fn attention_flops_grow_with_position() {
        let g = geo();
        let early = prefill_layer_cost(&g, 64, 64, 0, false);
        let late = prefill_layer_cost(&g, 64, 64, 448, false);
        assert!(late.attn_flops > 5.0 * early.attn_flops);
        assert_eq!(late.gemm_flops, early.gemm_flops);
    }

    #[test]
    fn margin_kernel_is_dynamic_and_cheaper() {
        let g = geo();
        let full = prefill_layer_cost(&g, 64, 64, 0, false);
        let margin = prefill_layer_cost(&g, 64, 10, 0, true);
        assert!(margin.is_dynamic);
        assert!(margin.gemm_flops < full.gemm_flops / 5.0);
    }

    #[test]
    fn decode_iter_is_memory_bound() {
        let g = geo();
        let c = decode_iter_cost(&g, 1, 256);
        // decode AI must be tiny (weight streaming per token)
        assert!(c.arithmetic_intensity() < 2.0, "{}", c.arithmetic_intensity());
    }

    #[test]
    fn batching_decode_amortizes_weights() {
        let g = geo();
        let b1 = decode_iter_cost(&g, 1, 256);
        let b8 = decode_iter_cost(&g, 8, 256);
        // 8 lanes: ~8x flops but far less than 8x bytes (weights shared)
        assert!(b8.total_flops() / b1.total_flops() > 7.0);
        assert!(b8.bytes / b1.bytes < 3.0);
    }

    #[test]
    fn prefill_chunk_dominated_by_gemm() {
        let g = geo();
        let c = prefill_layer_cost(&g, 128, 128, 0, false);
        assert!(c.gemm_flops > 10.0 * c.attn_flops);
    }

    #[test]
    fn costs_are_positive_and_finite() {
        let g = geo();
        for c in [
            prefill_layer_cost(&g, 16, 3, 0, true),
            decode_iter_cost(&g, 4, 1),
            mha_cost(&g, 1, 1),
            gemm_cost(1, 1, 1),
        ] {
            assert!(c.total_flops() > 0.0 && c.total_flops().is_finite());
            assert!(c.bytes > 0.0 && c.bytes.is_finite());
        }
    }
}
