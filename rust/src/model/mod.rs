//! Analytic model-kernel cost model: FLOPs, memory traffic, and
//! footprints per HEG kernel, derived from the model geometry.
//!
//! This is the substrate behind the paper's *per-kernel predictive
//! annotation* (§5.3): LLM kernels are idempotent dense linear algebra,
//! so their op counts and byte traffic are exact functions of
//! (geometry, chunk/batch, position) — which is what makes standalone
//! execution time, bandwidth utilization, footprint, and power
//! predictable enough to schedule against.

mod cost;

pub use cost::{
    KernelCost, decode_iter_cost, gemm_cost, gemv_cost, mha_cost,
    prefill_layer_cost,
};
