//! Per-request KV caches and decode-batch assembly.
//!
//! Each request owns one `[max_seq, kv_heads, head_dim]` K and V buffer
//! per layer, in host memory — the unified-memory design that makes the
//! paper's kernel-boundary preemption checkpoints free (§6.2): a
//! preempted request's context is just these buffers plus a position.
//!
//! Batched decode kernels take `[b, max_seq, kv_heads, head_dim]`
//! tensors; `assemble_batch` / `scatter_batch` convert between the
//! per-request and batched layouts at batch-membership changes.

use crate::config::ModelGeometry;

/// KV cache for one request: `k[layer]`, `v[layer]`, each
/// `max_seq * kv_heads * head_dim` f32s.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// Valid cached tokens (the next write position).
    pub pos: usize,
    cache_elems: usize,
}

impl KvCache {
    pub fn new(geo: &ModelGeometry) -> Self {
        let n = geo.cache_elems();
        Self {
            k: vec![vec![0.0; n]; geo.n_layers],
            v: vec![vec![0.0; n]; geo.n_layers],
            pos: 0,
            cache_elems: n,
        }
    }

    /// Bytes of host memory held by this cache (footprint accounting for
    /// the kernel-level garbage collector / memory estimator).
    pub fn bytes(&self) -> usize {
        2 * self.k.len() * self.cache_elems * 4
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }
}

/// Gather lane `i` of each request's layer-`l` cache into one
/// `[b, s, kh, hd]` buffer (b = `caches.len()`).
pub fn assemble_batch(caches: &[&KvCache], layer: usize, which_v: bool) -> Vec<f32> {
    let per = caches.first().map(|c| c.cache_elems).unwrap_or(0);
    let mut out = Vec::with_capacity(per * caches.len());
    for c in caches {
        let src = if which_v { &c.v[layer] } else { &c.k[layer] };
        out.extend_from_slice(src);
    }
    out
}

/// Scatter an updated `[b, s, kh, hd]` buffer back to per-request caches.
pub fn scatter_batch(
    caches: &mut [&mut KvCache],
    layer: usize,
    which_v: bool,
    batch: &[f32],
) {
    let per = caches.first().map(|c| c.cache_elems).unwrap_or(0);
    assert_eq!(batch.len(), per * caches.len(), "batch size mismatch");
    for (i, c) in caches.iter_mut().enumerate() {
        let dst = if which_v { &mut c.v[layer] } else { &mut c.k[layer] };
        dst.copy_from_slice(&batch[i * per..(i + 1) * per]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> ModelGeometry {
        ModelGeometry {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_q_heads: 2,
            n_kv_heads: 1,
            head_dim: 4,
            d_ffn: 16,
            max_seq: 4,
            chunk_sizes: vec![2],
            batch_sizes: vec![1, 2],
            rope_theta: 10000.0,
            weight_bytes: 4.0,
        }
    }

    #[test]
    fn new_cache_is_zeroed() {
        let c = KvCache::new(&geo());
        assert_eq!(c.n_layers(), 2);
        assert_eq!(c.pos, 0);
        assert!(c.k[0].iter().all(|&x| x == 0.0));
        // 2 (k+v) * 2 layers * 16 elems * 4 bytes
        assert_eq!(c.bytes(), 256);
    }

    #[test]
    fn assemble_scatter_roundtrip() {
        let g = geo();
        let mut a = KvCache::new(&g);
        let mut b = KvCache::new(&g);
        for (i, x) in a.k[0].iter_mut().enumerate() {
            *x = i as f32;
        }
        for (i, x) in b.k[0].iter_mut().enumerate() {
            *x = 100.0 + i as f32;
        }
        let batch = assemble_batch(&[&a, &b], 0, false);
        assert_eq!(batch.len(), 32);
        assert_eq!(batch[0], 0.0);
        assert_eq!(batch[16], 100.0);

        let mut batch2 = batch.clone();
        for x in &mut batch2 {
            *x += 1.0;
        }
        scatter_batch(&mut [&mut a, &mut b], 0, false, &batch2);
        assert_eq!(a.k[0][0], 1.0);
        assert_eq!(b.k[0][15], 116.0);
        // v untouched
        assert!(a.v[0].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn assemble_v_reads_v() {
        let g = geo();
        let mut a = KvCache::new(&g);
        a.v[1][3] = 9.0;
        let batch = assemble_batch(&[&a], 1, true);
        assert_eq!(batch[3], 9.0);
    }
}
