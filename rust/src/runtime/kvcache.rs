//! Per-request KV caches and decode-batch assembly.
//!
//! Each request owns one `[max_seq, kv_heads, head_dim]` K and V buffer
//! per layer, in host memory — the unified-memory design that makes the
//! paper's kernel-boundary preemption checkpoints free (§6.2): a
//! preempted request's context is just these buffers plus a position.
//!
//! Batched decode kernels take `[b, max_seq, kv_heads, head_dim]`
//! tensors; `assemble_batch` / `scatter_batch` convert between the
//! per-request and batched layouts at batch-membership changes.

use crate::config::ModelGeometry;

/// KV cache for one request: `k[layer]`, `v[layer]`, each
/// `max_seq * kv_heads * head_dim` f32s.
#[derive(Debug, Clone)]
pub struct KvCache {
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    /// Valid cached tokens (the next write position).
    pub pos: usize,
    cache_elems: usize,
}

impl KvCache {
    pub fn new(geo: &ModelGeometry) -> Self {
        let n = geo.cache_elems();
        Self {
            k: vec![vec![0.0; n]; geo.n_layers],
            v: vec![vec![0.0; n]; geo.n_layers],
            pos: 0,
            cache_elems: n,
        }
    }

    /// Bytes of host memory held by this cache (footprint accounting for
    /// the kernel-level garbage collector / memory estimator).
    pub fn bytes(&self) -> usize {
        2 * self.k.len() * self.cache_elems * 4
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }
}

/// Gather lane `i` of each request's layer-`l` cache into one
/// `[b, s, kh, hd]` buffer (b = `caches.len()`).
pub fn assemble_batch(caches: &[&KvCache], layer: usize, which_v: bool) -> Vec<f32> {
    let per = caches.first().map(|c| c.cache_elems).unwrap_or(0);
    let mut out = Vec::with_capacity(per * caches.len());
    for c in caches {
        let src = if which_v { &c.v[layer] } else { &c.k[layer] };
        out.extend_from_slice(src);
    }
    out
}

/// Scatter an updated `[b, s, kh, hd]` buffer back to per-request caches.
pub fn scatter_batch(
    caches: &mut [&mut KvCache],
    layer: usize,
    which_v: bool,
    batch: &[f32],
) {
    let per = caches.first().map(|c| c.cache_elems).unwrap_or(0);
    assert_eq!(batch.len(), per * caches.len(), "batch size mismatch");
    for (i, c) in caches.iter_mut().enumerate() {
        let dst = if which_v { &mut c.v[layer] } else { &mut c.k[layer] };
        dst.copy_from_slice(&batch[i * per..(i + 1) * per]);
    }
}

/// Serving state a finished flow turn leaves behind for its successor.
///
/// In real-compute mode `cache` is the turn's KV buffers; in
/// timing-only DES mode it is `None` but the entry still models the
/// *logical* KV residency (the memory governor charges one KV slot per
/// retained session either way).  `prefix` holds the actual
/// conversation tokens (prompt + generated reply) so a match can verify
/// the new prompt really extends what the cache contains.
#[derive(Debug, Clone)]
pub struct SessionEntry {
    pub cache: Option<KvCache>,
    /// Actual conversation tokens this session's KV was built from.
    pub prefix: Vec<i32>,
    /// Valid cached positions (≤ `prefix.len()`: the final generated
    /// token was emitted but never fed back through the model).
    pub pos: usize,
    /// Last touch (virtual µs for the DES, wall µs for the RT server) —
    /// the LRU eviction key.
    pub last_used_us: f64,
}

/// What a session match seeds a new turn's `ReqState` with.
#[derive(Debug)]
pub struct SessionSeed {
    pub cache: Option<KvCache>,
    /// Prompt tokens already covered by the retained KV — the turn
    /// prefills only `prompt_len - reuse` delta tokens.
    pub reuse: usize,
}

/// Cross-turn KV retention (paper §1 "long-lived, stateful LLM flows"):
/// a finished turn's cache stays resident keyed by flow/session id so
/// turn *k+1* prefills only its delta tokens instead of recomputing the
/// whole conversation prefix.  Capacity-bounded; least-recently-used
/// sessions are dropped first (and the coordinator's memory governor
/// may evict further under DRAM pressure — idle sessions go before any
/// in-flight prefill).
#[derive(Debug, Default)]
pub struct SessionCachePool {
    capacity: usize,
    entries: std::collections::HashMap<u64, SessionEntry>,
    /// Sessions dropped by capacity or external (governor) eviction.
    pub evicted: u64,
    /// Matches served / continuation lookups that found nothing usable.
    pub hits: u64,
    pub misses: u64,
}

impl SessionCachePool {
    pub fn new(capacity: usize) -> Self {
        Self { capacity: capacity.max(1), ..Default::default() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Retain a finished turn's serving state for its successor.
    /// Evicts LRU entries beyond capacity.
    pub fn retain(
        &mut self,
        session: u64,
        cache: Option<KvCache>,
        prefix: Vec<i32>,
        pos: usize,
        now_us: f64,
    ) {
        let pos = pos.min(prefix.len());
        self.entries
            .insert(session, SessionEntry { cache, prefix, pos, last_used_us: now_us });
        while self.entries.len() > self.capacity {
            if self.evict_lru().is_none() {
                break;
            }
        }
    }

    /// Claim the retained state for `session` if it actually covers a
    /// prefix of `prompt`.  The entry is removed either way (a stale
    /// mismatch is useless; the turn that claimed it owns the KV now).
    /// Returns `None` — a recorded miss — when nothing usable exists.
    pub fn take_match(&mut self, session: u64, prompt: &[i32]) -> Option<SessionSeed> {
        let Some(e) = self.entries.remove(&session) else {
            self.misses += 1;
            return None;
        };
        // longest common prefix between what the KV contains and what
        // the new turn wants; at least the final prompt token must be
        // recomputed to produce first-token logits
        let lcp = e.prefix.iter().zip(prompt).take_while(|(a, b)| a == b).count();
        let reuse = lcp.min(e.pos).min(prompt.len().saturating_sub(1));
        if reuse == 0 {
            self.misses += 1;
            return None;
        }
        self.hits += 1;
        Some(SessionSeed { cache: e.cache, reuse })
    }

    /// Drop a session (flow ended; nothing to reuse).
    pub fn drop_session(&mut self, session: u64) {
        self.entries.remove(&session);
    }

    /// Evict the least-recently-used session; returns its id.
    pub fn evict_lru(&mut self) -> Option<u64> {
        let victim = self
            .entries
            .iter()
            .min_by(|a, b| {
                a.1.last_used_us.total_cmp(&b.1.last_used_us).then(a.0.cmp(b.0))
            })
            .map(|(k, _)| *k)?;
        self.entries.remove(&victim);
        self.evicted += 1;
        Some(victim)
    }

    /// Host bytes held by retained *real* caches (0 in timing-only mode;
    /// the memory governor accounts logical slots separately).
    pub fn bytes(&self) -> usize {
        self.entries.values().filter_map(|e| e.cache.as_ref()).map(|c| c.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> ModelGeometry {
        ModelGeometry {
            name: "t".into(),
            vocab: 16,
            d_model: 8,
            n_layers: 2,
            n_q_heads: 2,
            n_kv_heads: 1,
            head_dim: 4,
            d_ffn: 16,
            max_seq: 4,
            chunk_sizes: vec![2],
            batch_sizes: vec![1, 2],
            rope_theta: 10000.0,
            weight_bytes: 4.0,
        }
    }

    #[test]
    fn new_cache_is_zeroed() {
        let c = KvCache::new(&geo());
        assert_eq!(c.n_layers(), 2);
        assert_eq!(c.pos, 0);
        assert!(c.k[0].iter().all(|&x| x == 0.0));
        // 2 (k+v) * 2 layers * 16 elems * 4 bytes
        assert_eq!(c.bytes(), 256);
    }

    #[test]
    fn assemble_scatter_roundtrip() {
        let g = geo();
        let mut a = KvCache::new(&g);
        let mut b = KvCache::new(&g);
        for (i, x) in a.k[0].iter_mut().enumerate() {
            *x = i as f32;
        }
        for (i, x) in b.k[0].iter_mut().enumerate() {
            *x = 100.0 + i as f32;
        }
        let batch = assemble_batch(&[&a, &b], 0, false);
        assert_eq!(batch.len(), 32);
        assert_eq!(batch[0], 0.0);
        assert_eq!(batch[16], 100.0);

        let mut batch2 = batch.clone();
        for x in &mut batch2 {
            *x += 1.0;
        }
        scatter_batch(&mut [&mut a, &mut b], 0, false, &batch2);
        assert_eq!(a.k[0][0], 1.0);
        assert_eq!(b.k[0][15], 116.0);
        // v untouched
        assert!(a.v[0].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn assemble_v_reads_v() {
        let g = geo();
        let mut a = KvCache::new(&g);
        a.v[1][3] = 9.0;
        let batch = assemble_batch(&[&a], 1, true);
        assert_eq!(batch[3], 9.0);
    }

    #[test]
    fn session_pool_matches_extending_prompts() {
        let mut p = SessionCachePool::new(4);
        // conversation [1,2,3,4] with 3 cached positions
        p.retain(7, None, vec![1, 2, 3, 4], 3, 10.0);
        assert_eq!(p.len(), 1);
        // next turn extends the conversation → reuse the cached 3
        let seed = p.take_match(7, &[1, 2, 3, 4, 5, 6]).unwrap();
        assert_eq!(seed.reuse, 3);
        assert_eq!((p.hits, p.misses), (1, 0));
        assert!(p.is_empty(), "a claimed session is consumed");
        // unknown session → miss
        assert!(p.take_match(7, &[1, 2]).is_none());
        assert_eq!(p.misses, 1);
    }

    #[test]
    fn session_pool_rejects_diverged_prompts_and_caps_reuse() {
        let mut p = SessionCachePool::new(4);
        p.retain(1, None, vec![1, 2, 3], 3, 0.0);
        // diverges at position 0 → nothing reusable
        assert!(p.take_match(1, &[9, 9, 9, 9]).is_none());
        // at least one prompt token must remain to prefill
        p.retain(2, None, vec![1, 2, 3], 3, 0.0);
        let seed = p.take_match(2, &[1, 2, 3]).unwrap();
        assert_eq!(seed.reuse, 2, "last token recomputed for logits");
    }

    #[test]
    fn session_pool_evicts_lru_beyond_capacity() {
        let mut p = SessionCachePool::new(2);
        p.retain(1, None, vec![1], 1, 10.0);
        p.retain(2, None, vec![1], 1, 20.0);
        p.retain(3, None, vec![1], 1, 5.0); // oldest touch, arrives last
        assert_eq!(p.len(), 2);
        assert_eq!(p.evicted, 1);
        // session 3 (last_used 5.0) was the LRU victim
        assert!(p.take_match(3, &[1, 2]).is_none());
        assert!(p.take_match(1, &[1, 2]).is_some());
        // explicit LRU eviction picks the remaining entry
        assert_eq!(p.evict_lru(), Some(2));
        assert_eq!(p.evict_lru(), None);
    }

    #[test]
    fn session_pool_accounts_real_cache_bytes() {
        let g = geo();
        let mut p = SessionCachePool::new(4);
        p.retain(1, Some(KvCache::new(&g)), vec![1, 2], 2, 0.0);
        p.retain(2, None, vec![1, 2], 2, 0.0);
        assert_eq!(p.bytes(), 256, "one real cache resident");
        let seed = p.take_match(1, &[1, 2, 3]).unwrap();
        assert!(seed.cache.is_some());
        assert_eq!(p.bytes(), 0);
    }
}
