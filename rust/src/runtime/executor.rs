//! Kernel executor: compiles the manifest's HLO modules once, keeps the
//! weights resident, and exposes the per-kernel operations the HEG
//! schedules (embed / layer_prefill / layer_decode / head).

use std::collections::HashMap;

use anyhow::{Context, Result, anyhow, bail};
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::config::{KernelKind, Manifest, ModelGeometry};

use super::kvcache::{KvCache, assemble_batch, scatter_batch};
use super::tensor::{HostTensor, literal_i32};

/// Compiled artifacts + resident weights on the PJRT CPU client.
pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    pub geo: ModelGeometry,
    exes: HashMap<String, PjRtLoadedExecutable>,
    /// Weights resident as device buffers (§Perf: uploaded once at
    /// load, never re-transferred on the request path — on the paper's
    /// unified-memory SoC this mirrors weights pinned in shared DRAM).
    weight_bufs: HashMap<String, PjRtBuffer>,
    /// Available variant sizes per kernel kind, sorted ascending.
    variants: HashMap<KernelKind, Vec<usize>>,
}

// SAFETY: the PJRT CPU client and its compiled executables are
// internally thread-safe (XLA's PjRt API contract); `Literal`s stored
// here are only read after construction.  The xla crate merely forgot
// the markers on its opaque pointer wrappers.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// Load manifest + weights and compile every artifact.
    pub fn load(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifact_dir)?;
        let geo = manifest.config.clone();
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e}"))?;

        let mut exes = HashMap::new();
        let mut variants: HashMap<KernelKind, Vec<usize>> = HashMap::new();
        for (name, meta) in &manifest.artifacts {
            let path = manifest.artifact_path(name)?;
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse HLO {path:?}: {e}"))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {name}: {e}"))?;
            exes.insert(name.clone(), exe);
            variants.entry(meta.kind).or_default().push(meta.n);
        }
        for v in variants.values_mut() {
            v.sort_unstable();
            v.dedup();
        }

        let weights_path = manifest.weights_path();
        let loaded = Literal::read_npz(&weights_path, &())
            .map_err(|e| anyhow!("read weights {weights_path:?}: {e}"))?;
        let expected = geo.n_layers * manifest.layer_weight_names.len() + 2;
        if loaded.len() != expected {
            bail!("weights.npz has {} arrays, expected {expected}", loaded.len());
        }
        let mut weight_bufs = HashMap::new();
        for (name, lit) in loaded {
            // buffer_from_host_buffer copies synchronously
            // (kImmutableOnlyDuringCall), so the literal may drop after
            // this call; BufferFromHostLiteral would copy *async* and
            // read freed memory.
            let shape = lit.array_shape().map_err(|e| anyhow!("{e}"))?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
            let buf = client
                .buffer_from_host_buffer(&data, &dims, None)
                .map_err(|e| anyhow!("upload weight {name}: {e}"))?;
            weight_bufs.insert(name, buf);
        }

        Ok(Self { client, manifest, geo, exes, weight_bufs, variants })
    }

    /// Smallest precompiled variant of `kind` covering `n` tokens/lanes.
    pub fn variant_for(&self, kind: KernelKind, n: usize) -> Result<usize> {
        self.variants
            .get(&kind)
            .and_then(|v| v.iter().copied().find(|&s| s >= n))
            .with_context(|| format!("no {kind:?} variant covers n={n}"))
    }

    /// All precompiled variants of `kind`, ascending.
    pub fn variants_of(&self, kind: KernelKind) -> &[usize] {
        self.variants.get(&kind).map(|v| v.as_slice()).unwrap_or(&[])
    }

    fn weight(&self, name: &str) -> Result<&PjRtBuffer> {
        self.weight_bufs
            .get(name)
            .with_context(|| format!("weight {name:?} missing"))
    }

    /// Debug/bench helper: public view of the per-layer weight buffers.
    pub fn layer_weight_args_dbg(&self, layer: usize) -> Result<Vec<&PjRtBuffer>> {
        self.layer_weight_args(layer)
    }

    fn layer_weight_args(&self, layer: usize) -> Result<Vec<&PjRtBuffer>> {
        self.manifest
            .layer_weight_names
            .iter()
            .map(|w| self.weight(&format!("l{layer}.{w}")))
            .collect()
    }

    /// Upload host f32 data as a transient device buffer.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload f32 {dims:?}: {e}"))
    }

    /// Upload host i32 data as a transient device buffer.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload i32 {dims:?}: {e}"))
    }

    /// Execute artifact `name` over device buffers; returns the
    /// decomposed output tuple (host literals).
    pub fn execute_bufs(&self, name: &str, args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        let exe = self
            .exes
            .get(name)
            .with_context(|| format!("artifact {name:?} not compiled"))?;
        let out = exe
            .execute_b::<&PjRtBuffer>(args)
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e}"))
    }

    /// Execute artifact `name` from host literals (uploads each arg).
    /// Compatibility path for tests; the hot path uses `execute_bufs`.
    /// The upload is synchronous, so the literals may drop afterwards.
    pub fn execute(&self, name: &str, args: &[&Literal]) -> Result<Vec<Literal>> {
        let bufs: Vec<PjRtBuffer> = args
            .iter()
            .map(|l| {
                let shape = l.array_shape().map_err(|e| anyhow!("{e}"))?;
                let dims: Vec<usize> =
                    shape.dims().iter().map(|&d| d as usize).collect();
                match l.ty().map_err(|e| anyhow!("{e}"))? {
                    xla::ElementType::S32 => {
                        let data = l.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?;
                        self.upload_i32(&data, &dims)
                    }
                    _ => {
                        let data = l.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
                        self.upload_f32(&data, &dims)
                    }
                }
            })
            .collect::<Result<_>>()?;
        let refs: Vec<&PjRtBuffer> = bufs.iter().collect();
        self.execute_bufs(name, &refs)
    }
}

/// High-level per-kernel model operations over a [`Runtime`] — the
/// compute backend every engine (Agent.xpu and baselines) shares.
pub struct ModelExecutor {
    pub rt: std::sync::Arc<Runtime>,
}

impl ModelExecutor {
    pub fn new(rt: std::sync::Arc<Runtime>) -> Self {
        Self { rt }
    }

    pub fn geo(&self) -> &ModelGeometry {
        &self.rt.geo
    }

    /// Embed `tokens`, padding to the chosen precompiled size `n`.
    /// Returns `[n, d]` (caller tracks how many rows are valid).
    pub fn embed(&self, tokens: &[i32], n: usize) -> Result<HostTensor> {
        let mut padded = tokens.to_vec();
        padded.resize(n, 0);
        let toks = self.rt.upload_i32(&padded, &[n])?;
        let emb = self.rt.weight("emb")?;
        let outs = self.rt.execute_bufs(&format!("embed_n{n}"), &[&toks, emb])?;
        HostTensor::from_literal(&outs[0])
    }

    /// One transformer layer over a prefill chunk.  `x` is `[c, d]`,
    /// `pos` is the number of tokens already cached; updates the
    /// request's layer-`layer` cache in place and returns the new `x`.
    pub fn layer_prefill(
        &self,
        chunk: usize,
        layer: usize,
        x: &HostTensor,
        cache: &mut KvCache,
        pos: usize,
    ) -> Result<HostTensor> {
        let geo = &self.rt.geo;
        let cdims = [geo.max_seq, geo.n_kv_heads, geo.head_dim];
        let xl = self.rt.upload_f32(&x.data, &x.shape)?;
        let kl = self.rt.upload_f32(&cache.k[layer], &cdims)?;
        let vl = self.rt.upload_f32(&cache.v[layer], &cdims)?;
        let pl = self.rt.upload_i32(&[pos as i32], &[1])?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&xl, &kl, &vl, &pl];
        let wargs = self.rt.layer_weight_args(layer)?;
        args.extend(wargs);
        let outs = self
            .rt
            .execute_bufs(&format!("layer_prefill_c{chunk}"), &args)?;
        cache.k[layer] = outs[1].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        cache.v[layer] = outs[2].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        HostTensor::from_literal(&outs[0])
    }

    /// One transformer layer of a batched decode step.  `x` is `[b, d]`
    /// with `b == caches.len()` valid lanes (padded internally to the
    /// precompiled batch variant); updates each request's cache in place.
    pub fn layer_decode(
        &self,
        layer: usize,
        x: &HostTensor,
        caches: &mut [&mut KvCache],
    ) -> Result<HostTensor> {
        let geo = &self.rt.geo;
        let b = caches.len();
        let bv = self.rt.variant_for(KernelKind::LayerDecode, b)?;
        let d = geo.d_model;
        let per = geo.cache_elems();

        // Assemble [bv, d] activations and [bv, s, kh, hd] caches with
        // zero-padded scratch lanes.
        let mut xd = x.data.clone();
        xd.resize(bv * d, 0.0);
        let ro_caches: Vec<&KvCache> = caches.iter().map(|c| &**c).collect();
        let mut kb = assemble_batch(&ro_caches, layer, false);
        let mut vb = assemble_batch(&ro_caches, layer, true);
        kb.resize(bv * per, 0.0);
        vb.resize(bv * per, 0.0);
        let mut pos: Vec<i32> = ro_caches.iter().map(|c| c.pos as i32).collect();
        pos.resize(bv, 0);

        let cdims = [bv, geo.max_seq, geo.n_kv_heads, geo.head_dim];
        let xl = self.rt.upload_f32(&xd, &[bv, d])?;
        let kl = self.rt.upload_f32(&kb, &cdims)?;
        let vl = self.rt.upload_f32(&vb, &cdims)?;
        let plit = self.rt.upload_i32(&pos, &[bv])?;
        let mut args: Vec<&xla::PjRtBuffer> = vec![&xl, &kl, &vl, &plit];
        let wargs = self.rt.layer_weight_args(layer)?;
        args.extend(wargs);
        let outs = self.rt.execute_bufs(&format!("layer_decode_b{bv}"), &args)?;

        let knew = outs[1].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let vnew = outs[2].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        scatter_batch(caches, layer, false, &knew[..b * per]);
        scatter_batch(caches, layer, true, &vnew[..b * per]);
        let y = HostTensor::from_literal(&outs[0])?;
        // Drop padded lanes.
        Ok(HostTensor::new(y.data[..b * d].to_vec(), &[b, d]))
    }

    /// Greedy next-token head over `[b, d]` hidden states.
    pub fn head(&self, x: &HostTensor) -> Result<Vec<i32>> {
        let b = x.shape[0];
        let bv = self.rt.variant_for(KernelKind::Head, b)?;
        let d = x.shape[1];
        let mut xd = x.data.clone();
        xd.resize(bv * d, 0.0);
        let xl = self.rt.upload_f32(&xd, &[bv, d])?;
        let norm = self.rt.weight("final_norm")?;
        let emb = self.rt.weight("emb")?;
        let outs = self.rt.execute_bufs(&format!("head_b{bv}"), &[&xl, norm, emb])?;
        let toks = literal_i32(&outs[0])?;
        Ok(toks[..b].to_vec())
    }

    /// Convenience: full sequential prefill of `prompt` with fixed
    /// `chunk`, returning the last valid hidden row `[1, d]`.
    pub fn prefill(
        &self,
        prompt: &[i32],
        chunk: usize,
        cache: &mut KvCache,
    ) -> Result<HostTensor> {
        let n_layers = self.rt.geo.n_layers;
        let mut last = None;
        let mut pos = 0usize;
        while pos < prompt.len() {
            let m = chunk.min(prompt.len() - pos);
            let mut x = self.embed(&prompt[pos..pos + m], chunk)?;
            for layer in 0..n_layers {
                x = self.layer_prefill(chunk, layer, &x, cache, pos)?;
            }
            last = Some(x.row(m - 1));
            pos += m;
        }
        cache.pos = prompt.len();
        last.context("empty prompt")
    }

    /// Convenience: greedy single-sequence decode of `steps` tokens.
    pub fn decode(
        &self,
        mut hidden: HostTensor,
        cache: &mut KvCache,
        steps: usize,
    ) -> Result<Vec<i32>> {
        let n_layers = self.rt.geo.n_layers;
        let mut out = Vec::with_capacity(steps);
        for _ in 0..steps {
            let tok = self.head(&hidden)?[0];
            out.push(tok);
            let mut x = self.embed(&[tok], 1)?;
            for layer in 0..n_layers {
                x = self.layer_decode(layer, &x, &mut [cache])?;
            }
            cache.pos += 1;
            hidden = x;
        }
        Ok(out)
    }

    /// Convenience: prefill + decode (the golden-trajectory replay).
    pub fn generate(&self, prompt: &[i32], chunk: usize, steps: usize) -> Result<Vec<i32>> {
        let mut cache = KvCache::new(&self.rt.geo);
        let hidden = self.prefill(prompt, chunk, &mut cache)?;
        self.decode(hidden, &mut cache, steps)
    }
}
