//! Host-side tensor helpers: plain `Vec`-backed tensors plus conversions
//! to/from `xla::Literal`.  All activation and KV-cache state lives on
//! the host (the SoC's unified memory — DESIGN.md §1); PJRT copies are
//! made at kernel-execution boundaries.

#[cfg(feature = "real-pjrt")]
use anyhow::{Result, anyhow};
#[cfg(feature = "real-pjrt")]
use xla::{ElementType, Literal};

/// A host f32 tensor with an explicit shape.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl HostTensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Self { data: vec![0.0; n], shape: shape.to_vec() }
    }

    pub fn new(data: Vec<f32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Self { data, shape: shape.to_vec() }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row `i` of a 2-D tensor, as a new `[1, cols]` tensor.
    pub fn row(&self, i: usize) -> HostTensor {
        assert_eq!(self.shape.len(), 2, "row() needs a 2-D tensor");
        let cols = self.shape[1];
        HostTensor::new(self.data[i * cols..(i + 1) * cols].to_vec(), &[1, cols])
    }

    #[cfg(feature = "real-pjrt")]
    pub fn to_literal(&self) -> Result<Literal> {
        f32_literal(&self.data, &self.shape)
    }

    #[cfg(feature = "real-pjrt")]
    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(Self { data: lit.to_vec::<f32>()?, shape: dims })
    }
}

/// Build an f32 literal from host data.
#[cfg(feature = "real-pjrt")]
pub fn f32_literal(data: &[f32], dims: &[usize]) -> Result<Literal> {
    // SAFETY: reinterpreting a live `&[f32]` as its own bytes — the
    // pointer is valid for `len * 4` bytes for the borrow's lifetime,
    // u8 has no alignment requirement, and f32 has no padding or
    // invalid bit patterns.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("f32 literal: {e}"))
}

/// Build an i32 literal from host data.
#[cfg(feature = "real-pjrt")]
pub fn i32_literal(data: &[i32], dims: &[usize]) -> Result<Literal> {
    // SAFETY: same as `f32_literal` — a live `&[i32]` viewed as its
    // own `len * 4` bytes; u8 is unaligned and i32 has no padding.
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, bytes)
        .map_err(|e| anyhow!("i32 literal: {e}"))
}

/// Read an f32 literal back to host.
#[cfg(feature = "real-pjrt")]
pub fn literal_f32(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal->f32: {e}"))
}

/// Read an i32 literal back to host.
#[cfg(feature = "real-pjrt")]
pub fn literal_i32(lit: &Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>().map_err(|e| anyhow!("literal->i32: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_zeros_and_row() {
        let t = HostTensor::zeros(&[3, 4]);
        assert_eq!(t.numel(), 12);
        let mut t = t;
        t.data[4] = 1.5;
        t.data[7] = -2.0;
        let r = t.row(1);
        assert_eq!(r.shape, vec![1, 4]);
        assert_eq!(r.data, vec![1.5, 0.0, 0.0, -2.0]);
    }

    #[cfg(feature = "real-pjrt")]
    #[test]
    fn f32_literal_roundtrip() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = f32_literal(&data, &[2, 3]).unwrap();
        assert_eq!(lit.element_count(), 6);
        assert_eq!(literal_f32(&lit).unwrap(), data);
        let t = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t.shape, vec![2, 3]);
        assert_eq!(t.data, data);
    }

    #[cfg(feature = "real-pjrt")]
    #[test]
    fn i32_literal_roundtrip() {
        let data = vec![7i32, -1, 0, 42];
        let lit = i32_literal(&data, &[4]).unwrap();
        assert_eq!(literal_i32(&lit).unwrap(), data);
    }
}
