//! Stub runtime used when the crate is built **without** the
//! `real-pjrt` feature: the API surface of `executor.rs` with every
//! entry point failing fast.  Timing-only DES engines never touch this
//! (their `ExecBridge` has no executor); the stub only exists so the
//! serving binary, examples, and integration tests compile unchanged
//! and degrade to a clear runtime error instead of a build break when
//! the `xla` bindings are unavailable.

use anyhow::{Result, bail};

use crate::config::{Manifest, ModelGeometry};

use super::kvcache::KvCache;
use super::tensor::HostTensor;

const NO_PJRT: &str = "built without the `real-pjrt` feature: real compute is \
     unavailable (enable the feature and provide the `xla` bindings crate; \
     timing-only DES mode needs no artifacts)";

/// Compiled artifacts + resident weights — unavailable in this build.
pub struct Runtime {
    pub manifest: Manifest,
    pub geo: ModelGeometry,
}

impl Runtime {
    pub fn load(_artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        bail!(NO_PJRT)
    }
}

/// High-level per-kernel model operations over a [`Runtime`].
pub struct ModelExecutor {
    pub rt: std::sync::Arc<Runtime>,
}

impl ModelExecutor {
    pub fn new(rt: std::sync::Arc<Runtime>) -> Self {
        Self { rt }
    }

    pub fn geo(&self) -> &ModelGeometry {
        &self.rt.geo
    }

    pub fn embed(&self, _tokens: &[i32], _n: usize) -> Result<HostTensor> {
        bail!(NO_PJRT)
    }

    pub fn layer_prefill(
        &self,
        _chunk: usize,
        _layer: usize,
        _x: &HostTensor,
        _cache: &mut KvCache,
        _pos: usize,
    ) -> Result<HostTensor> {
        bail!(NO_PJRT)
    }

    pub fn layer_decode(
        &self,
        _layer: usize,
        _x: &HostTensor,
        _caches: &mut [&mut KvCache],
    ) -> Result<HostTensor> {
        bail!(NO_PJRT)
    }

    pub fn head(&self, _x: &HostTensor) -> Result<Vec<i32>> {
        bail!(NO_PJRT)
    }

    pub fn prefill(
        &self,
        _prompt: &[i32],
        _chunk: usize,
        _cache: &mut KvCache,
    ) -> Result<HostTensor> {
        bail!(NO_PJRT)
    }

    pub fn decode(
        &self,
        _hidden: HostTensor,
        _cache: &mut KvCache,
        _steps: usize,
    ) -> Result<Vec<i32>> {
        bail!(NO_PJRT)
    }

    pub fn generate(&self, _prompt: &[i32], _chunk: usize, _steps: usize) -> Result<Vec<i32>> {
        bail!(NO_PJRT)
    }
}
