//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, keeps model weights resident, and executes
//! kernels on the CPU PJRT client.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.
//! Interchange is HLO *text* because xla_extension 0.5.1 rejects
//! jax≥0.5's 64-bit-id serialized protos.

mod executor;
mod kvcache;
mod tensor;

pub use executor::{ModelExecutor, Runtime};
pub use kvcache::{KvCache, assemble_batch, scatter_batch};
pub use tensor::{HostTensor, f32_literal, i32_literal, literal_f32, literal_i32};
