//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, keeps model weights resident, and executes
//! kernels on the CPU PJRT client.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`.
//! Interchange is HLO *text* because xla_extension 0.5.1 rejects
//! jax≥0.5's 64-bit-id serialized protos.
//!
//! Real PJRT compute sits behind the `real-pjrt` cargo feature (it
//! needs the `xla` bindings crate, which is not vendored — see
//! Cargo.toml).  Without the feature, [`Runtime::load`] returns an
//! error and every engine runs in timing-only DES mode; the rest of
//! the API (including [`KvCache`] and the [`SessionCachePool`] used
//! for cross-turn flow reuse) is always available.

#[cfg(feature = "real-pjrt")]
mod executor;
#[cfg(not(feature = "real-pjrt"))]
#[path = "executor_stub.rs"]
mod executor;
mod kvcache;
mod tensor;

pub use executor::{ModelExecutor, Runtime};
pub use kvcache::{
    KvCache, SessionCachePool, SessionEntry, SessionSeed, assemble_batch, scatter_batch,
};
pub use tensor::HostTensor;
#[cfg(feature = "real-pjrt")]
pub use tensor::{f32_literal, i32_literal, literal_f32, literal_i32};
