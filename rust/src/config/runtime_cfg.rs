//! Runtime configuration (JSON): virtual-SoC parameters and scheduler
//! knobs.  Defaults mirror the paper's testbed — an Intel Core Ultra 5
//! 125H (Arc iGPU 18 peak TOPS, AI-Boost NPU 11.5 peak TOPS, 32 GB
//! DDR5-5600 ≈ 89.6 GB/s) — so the regenerated figures land in the same
//! regime as the paper's.  (The paper's own frontend uses a custom JSON
//! interface, §7 — we follow suit.)

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// One virtual accelerator of the hetero-SoC.
#[derive(Debug, Clone, PartialEq)]
pub struct XpuConfig {
    pub name: String,
    /// Peak dense-GEMM throughput (effective TOPS; the scheduler treats
    /// these as f32-equivalent ops/s).
    pub peak_tflops: f64,
    /// Fraction of peak achievable on well-tiled static GEMM kernels.
    pub gemm_efficiency: f64,
    /// Fraction of peak achievable on dynamic attention kernels
    /// (NPUs struggle here — the paper's op-XPU affinity gap, §3.1).
    pub attn_efficiency: f64,
    /// Max DDR bandwidth this XPU can draw when running alone (GB/s).
    pub max_bw_gbps: f64,
    /// Per-kernel launch/dispatch overhead (µs).
    pub launch_overhead_us: f64,
    /// Whether dynamic-shape kernels run natively (iGPU) or need a JIT
    /// compile (NPU; amortized cost below).
    pub supports_dynamic: bool,
    /// Amortized JIT-compilation cost charged to each *dynamic* kernel
    /// when `supports_dynamic` is false (ms; paper §3.1 footnote 2).
    pub jit_compile_ms: f64,
    /// Utilization bound (the paper caps iGPU use to preserve graphics).
    pub util_cap: f64,
    /// Dynamic power at full utilization (W).
    pub active_power_w: f64,
    pub idle_power_w: f64,
}

impl XpuConfig {
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            peak_tflops: v.get("peak_tflops")?.as_f64()?,
            gemm_efficiency: v.get("gemm_efficiency")?.as_f64()?,
            attn_efficiency: v.get("attn_efficiency")?.as_f64()?,
            max_bw_gbps: v.get("max_bw_gbps")?.as_f64()?,
            launch_overhead_us: v.get("launch_overhead_us")?.as_f64()?,
            supports_dynamic: v.get("supports_dynamic")?.as_bool()?,
            jit_compile_ms: v.get("jit_compile_ms")?.as_f64()?,
            util_cap: v.get("util_cap")?.as_f64()?,
            active_power_w: v.get("active_power_w")?.as_f64()?,
            idle_power_w: v.get("idle_power_w")?.as_f64()?,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.as_str())
            .set("peak_tflops", self.peak_tflops)
            .set("gemm_efficiency", self.gemm_efficiency)
            .set("attn_efficiency", self.attn_efficiency)
            .set("max_bw_gbps", self.max_bw_gbps)
            .set("launch_overhead_us", self.launch_overhead_us)
            .set("supports_dynamic", self.supports_dynamic)
            .set("jit_compile_ms", self.jit_compile_ms)
            .set("util_cap", self.util_cap)
            .set("active_power_w", self.active_power_w)
            .set("idle_power_w", self.idle_power_w)
    }
}

/// The shared-memory SoC.
#[derive(Debug, Clone, PartialEq)]
pub struct SocConfig {
    pub xpus: Vec<XpuConfig>,
    /// Peak shared DDR bandwidth (GB/s); co-executing kernels contend
    /// for this (paper §3.1 memory contention analysis).
    pub ddr_bw_gbps: f64,
    /// Physical memory (GB) — bounds model + KV-cache residency.
    pub dram_gb: f64,
}

impl SocConfig {
    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            xpus: v
                .get("xpus")?
                .as_arr()?
                .iter()
                .map(XpuConfig::from_json)
                .collect::<Result<Vec<_>>>()?,
            ddr_bw_gbps: v.get("ddr_bw_gbps")?.as_f64()?,
            dram_gb: v.get("dram_gb")?.as_f64()?,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("xpus", Json::Arr(self.xpus.iter().map(|x| x.to_json()).collect()))
            .set("ddr_bw_gbps", self.ddr_bw_gbps)
            .set("dram_gb", self.dram_gb)
    }

    pub fn xpu(&self, name: &str) -> Option<&XpuConfig> {
        self.xpus.iter().find(|x| x.name == name)
    }
}

/// Scheduler knobs (paper §6).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerConfig {
    /// Max decode batch formed by adaptive batching / intra-XPU backfill.
    pub b_max: usize,
    /// Memory-pressure tier boundaries (Algorithm 1): below `low` =
    /// aggressive co-scheduling, below `high` = selective pairing,
    /// at/above `high` = serialize with reactive priority.
    pub pressure_low: f64,
    pub pressure_high: f64,
    /// Proactive tasks pending longer than this are promoted (anti-
    /// starvation aging, §6.5), in virtual milliseconds.
    pub starvation_age_ms: f64,
    /// Enable slack-aware backfill (§6.3). Ablation switch.
    pub backfill: bool,
    /// Enable kernel-level preemption (§6.2). Ablation switch — when
    /// false, reactive requests wait for the running task (FCFS-ish).
    pub preemption: bool,
    /// Enable hetero-disaggregation (prefill→NPU / decode→iGPU, §5.2).
    /// When false, everything runs on a single XPU (colocated).
    pub disaggregation: bool,
    /// Target per-kernel execution bound used by chunk planning (ms);
    /// the paper keeps prefill kernels under 100 ms for preemption
    /// latency (§6.2).
    pub chunk_latency_budget_ms: f64,
    /// Hung-kernel watchdog (virtual ms); exceeded kernels are retried
    /// (failure handling, §6.5).
    pub kernel_timeout_ms: f64,
    /// Max idle flow sessions whose KV stays resident between turns
    /// (cross-turn prefix reuse, DESIGN.md §3).  0 disables retention:
    /// every turn recomputes its full conversation prefix.
    pub session_capacity: usize,
    /// Among unstarved same-class resume candidates, prefer the node
    /// with the longest remaining dependency chain in its workflow DAG
    /// (`FlowBinding::crit_path`) so the scheduler finishes the deepest
    /// chain first (DESIGN.md §3).  Ablation switch — `false` falls
    /// back to the plain FIFO/ETC turn order.
    pub critical_path_priority: bool,
    /// iGPU duty governor (the paper's "controlled iGPU usage", §8.1):
    /// cap on the iGPU's windowed *agentic* duty cycle that
    /// opportunistic proactive placements (decode joins, whole
    /// proactive decode batches, proactive margin chunks, inter-XPU
    /// backfill) must stay under.  Reactive work is never gated and
    /// starved proactive candidates bypass the cap (§6.5 aging), so the
    /// governor defers, never starves.  `>= 1.0` (the default)
    /// disables it — schedules are bit-for-bit the ungoverned ones.
    ///
    /// Designed for virtual-clock (DES) runs: the duty window lives on
    /// the simulated SoC clock, which a wall-clock server only
    /// advances while kernels execute — an engaged cap there relaxes
    /// through the starvation valve (coarse, `starvation_age_ms`
    /// granularity) rather than through window decay.
    pub igpu_duty_cap: f64,
    /// With a graphics workload present, additionally veto proactive
    /// iGPU kernels that would run past the next frame's vsync due
    /// instant.  Off by default (no schedule change).
    pub yield_to_graphics: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        Self {
            b_max: 8,
            pressure_low: 0.4,
            pressure_high: 0.7,
            starvation_age_ms: 2_000.0,
            backfill: true,
            preemption: true,
            disaggregation: true,
            chunk_latency_budget_ms: 100.0,
            kernel_timeout_ms: 10_000.0,
            session_capacity: 32,
            critical_path_priority: true,
            igpu_duty_cap: 1.0,
            yield_to_graphics: false,
        }
    }
}

impl SchedulerConfig {
    pub fn from_json(v: &Json) -> Result<Self> {
        let d = Self::default();
        let f = |k: &str, dv: f64| -> Result<f64> {
            v.opt(k).map(|x| x.as_f64()).unwrap_or(Ok(dv))
        };
        let b = |k: &str, dv: bool| -> Result<bool> {
            v.opt(k).map(|x| x.as_bool()).unwrap_or(Ok(dv))
        };
        Ok(Self {
            b_max: v.opt("b_max").map(|x| x.as_usize()).unwrap_or(Ok(d.b_max))?,
            pressure_low: f("pressure_low", d.pressure_low)?,
            pressure_high: f("pressure_high", d.pressure_high)?,
            starvation_age_ms: f("starvation_age_ms", d.starvation_age_ms)?,
            backfill: b("backfill", d.backfill)?,
            preemption: b("preemption", d.preemption)?,
            disaggregation: b("disaggregation", d.disaggregation)?,
            chunk_latency_budget_ms: f("chunk_latency_budget_ms", d.chunk_latency_budget_ms)?,
            kernel_timeout_ms: f("kernel_timeout_ms", d.kernel_timeout_ms)?,
            session_capacity: v
                .opt("session_capacity")
                .map(|x| x.as_usize())
                .unwrap_or(Ok(d.session_capacity))?,
            critical_path_priority: b("critical_path_priority", d.critical_path_priority)?,
            igpu_duty_cap: f("igpu_duty_cap", d.igpu_duty_cap)?,
            yield_to_graphics: b("yield_to_graphics", d.yield_to_graphics)?,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("b_max", self.b_max)
            .set("pressure_low", self.pressure_low)
            .set("pressure_high", self.pressure_high)
            .set("starvation_age_ms", self.starvation_age_ms)
            .set("backfill", self.backfill)
            .set("preemption", self.preemption)
            .set("disaggregation", self.disaggregation)
            .set("chunk_latency_budget_ms", self.chunk_latency_budget_ms)
            .set("kernel_timeout_ms", self.kernel_timeout_ms)
            .set("session_capacity", self.session_capacity)
            .set("critical_path_priority", self.critical_path_priority)
            .set("igpu_duty_cap", self.igpu_duty_cap)
            .set("yield_to_graphics", self.yield_to_graphics)
    }
}

/// Overload-protection knobs for the serving frontend (DESIGN.md §7):
/// admission control, priority-aware load shedding, and the write-ahead
/// journal's group-commit policy.  All defaults keep the pre-overload
/// behaviour observable: bounded queues large enough that light traffic
/// never rejects, and TTFT-based shedding disabled until an SLO is set.
#[derive(Debug, Clone, PartialEq)]
pub struct OverloadConfig {
    /// Max requests queued ahead of the engine (admission bound).  A
    /// full queue rejects proactive arrivals with a `retry_after`
    /// frame; reactive arrivals displace the newest queued proactive
    /// request first.  0 = unbounded (the legacy behaviour).
    pub max_queue_depth: usize,
    /// Max distinct live flows (session tags + untagged singles) the
    /// server admits concurrently.  0 = unbounded.
    pub max_live_flows: usize,
    /// Reactive TTFT service-level objective (ms).  0 disables the
    /// TTFT leg of the overload detector — shedding then reacts to
    /// queue depth only.
    pub reactive_ttft_slo_ms: f64,
    /// Measured reactive p99 TTFT above `slo × slo_multiple` drives
    /// the detector to its strongest response (park running proactive
    /// decodes).
    pub slo_multiple: f64,
    /// Hint clients receive on `retry_after` / `done.shed` frames (ms).
    pub retry_after_ms: f64,
    /// Journal group-commit: fsync after this many appended records
    /// (1 = every record durable before its ack; higher batches the
    /// barrier).  0 is treated as 1.
    pub fsync_every: usize,
}

impl Default for OverloadConfig {
    fn default() -> Self {
        Self {
            max_queue_depth: 256,
            max_live_flows: 1024,
            reactive_ttft_slo_ms: 0.0,
            slo_multiple: 4.0,
            retry_after_ms: 250.0,
            fsync_every: 8,
        }
    }
}

impl OverloadConfig {
    pub fn from_json(v: &Json) -> Result<Self> {
        let d = Self::default();
        let u = |k: &str, dv: usize| -> Result<usize> {
            v.opt(k).map(|x| x.as_usize()).unwrap_or(Ok(dv))
        };
        let f = |k: &str, dv: f64| -> Result<f64> {
            v.opt(k).map(|x| x.as_f64()).unwrap_or(Ok(dv))
        };
        Ok(Self {
            max_queue_depth: u("max_queue_depth", d.max_queue_depth)?,
            max_live_flows: u("max_live_flows", d.max_live_flows)?,
            reactive_ttft_slo_ms: f("reactive_ttft_slo_ms", d.reactive_ttft_slo_ms)?,
            slo_multiple: f("slo_multiple", d.slo_multiple)?,
            retry_after_ms: f("retry_after_ms", d.retry_after_ms)?,
            fsync_every: u("fsync_every", d.fsync_every)?,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("max_queue_depth", self.max_queue_depth)
            .set("max_live_flows", self.max_live_flows)
            .set("reactive_ttft_slo_ms", self.reactive_ttft_slo_ms)
            .set("slo_multiple", self.slo_multiple)
            .set("retry_after_ms", self.retry_after_ms)
            .set("fsync_every", self.fsync_every)
    }
}

/// Top-level runtime configuration.
#[derive(Debug, Clone)]
pub struct RuntimeConfig {
    /// Artifact directory (contains manifest.json).
    pub artifacts: String,
    pub soc: SocConfig,
    pub scheduler: SchedulerConfig,
    /// Overload protection for the serving frontend.
    pub overload: OverloadConfig,
    /// Execute kernels for real on PJRT (`true`) or timing-only DES
    /// (`false`) — big sweeps use timing-only.
    pub real_compute: bool,
}

impl RuntimeConfig {
    pub fn new(artifacts: impl Into<String>) -> Self {
        Self {
            artifacts: artifacts.into(),
            soc: default_soc(),
            scheduler: SchedulerConfig::default(),
            overload: OverloadConfig::default(),
            real_compute: true,
        }
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {:?}", path.as_ref()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            artifacts: v.get("artifacts")?.as_str()?.to_string(),
            soc: match v.opt("soc") {
                Some(s) => SocConfig::from_json(s)?,
                None => default_soc(),
            },
            scheduler: match v.opt("scheduler") {
                Some(s) => SchedulerConfig::from_json(s)?,
                None => SchedulerConfig::default(),
            },
            overload: match v.opt("overload") {
                Some(s) => OverloadConfig::from_json(s)?,
                None => OverloadConfig::default(),
            },
            real_compute: v
                .opt("real_compute")
                .map(|x| x.as_bool())
                .unwrap_or(Ok(true))?,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("artifacts", self.artifacts.as_str())
            .set("soc", self.soc.to_json())
            .set("scheduler", self.scheduler.to_json())
            .set("overload", self.overload.to_json())
            .set("real_compute", self.real_compute)
    }
}

/// The paper's testbed as the default virtual SoC.
pub fn default_soc() -> SocConfig {
    SocConfig {
        xpus: vec![
            XpuConfig {
                name: "npu".into(),
                peak_tflops: 11.5,
                gemm_efficiency: 0.75,
                // NPU attention pays JIT + poor dynamic-dataflow mapping.
                attn_efficiency: 0.15,
                max_bw_gbps: 60.0,
                launch_overhead_us: 30.0,
                supports_dynamic: false,
                jit_compile_ms: 12.0,
                util_cap: 1.0,
                active_power_w: 3.5,
                idle_power_w: 0.1,
            },
            XpuConfig {
                name: "igpu".into(),
                peak_tflops: 18.0,
                gemm_efficiency: 0.55,
                attn_efficiency: 0.45,
                // calibrated so a lone decode stream sits in the medium
                // pressure band (0.61): the paper's flagship inter-XPU
                // backfill (proactive NPU prefill under reactive iGPU
                // decode) must pass Algorithm 1's selective pairing.
                max_bw_gbps: 55.0,
                launch_overhead_us: 15.0,
                supports_dynamic: true,
                jit_compile_ms: 0.0,
                // paper: "<30% iGPU utilization" preserved for graphics
                util_cap: 0.6,
                active_power_w: 19.0,
                idle_power_w: 0.6,
            },
            XpuConfig {
                name: "cpu".into(),
                peak_tflops: 1.2,
                gemm_efficiency: 0.60,
                attn_efficiency: 0.50,
                max_bw_gbps: 55.0,
                launch_overhead_us: 2.0,
                supports_dynamic: true,
                jit_compile_ms: 0.0,
                util_cap: 1.0,
                active_power_w: 28.0,
                idle_power_w: 2.0,
            },
        ],
        ddr_bw_gbps: 89.6,
        dram_gb: 32.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_soc_matches_paper_testbed() {
        let soc = default_soc();
        assert_eq!(soc.xpus.len(), 3);
        let npu = soc.xpu("npu").unwrap();
        assert!((npu.peak_tflops - 11.5).abs() < 1e-9);
        assert!(!npu.supports_dynamic);
        let igpu = soc.xpu("igpu").unwrap();
        assert!((igpu.peak_tflops - 18.0).abs() < 1e-9);
        assert!(igpu.supports_dynamic);
        assert!(igpu.util_cap < 1.0, "iGPU must be utilization-bounded");
        assert!((soc.ddr_bw_gbps - 89.6).abs() < 1e-9);
    }

    #[test]
    fn scheduler_defaults_match_paper() {
        let s = SchedulerConfig::default();
        assert!((s.pressure_low - 0.4).abs() < 1e-9);
        assert!((s.pressure_high - 0.7).abs() < 1e-9);
        assert!(s.backfill && s.preemption && s.disaggregation);
        assert!((s.chunk_latency_budget_ms - 100.0).abs() < 1e-9);
        assert!(s.session_capacity > 0, "session retention on by default");
        assert!(s.critical_path_priority, "critical-path priority on by default");
        assert!(s.igpu_duty_cap >= 1.0, "duty governor off by default");
        assert!(!s.yield_to_graphics, "vsync yield off by default");
    }

    #[test]
    fn duty_governor_knobs_roundtrip_and_default_off() {
        let v = Json::parse(
            r#"{"artifacts": "a", "scheduler": {"igpu_duty_cap": 0.4, "yield_to_graphics": true}}"#,
        )
        .unwrap();
        let cfg = RuntimeConfig::from_json(&v).unwrap();
        assert!((cfg.scheduler.igpu_duty_cap - 0.4).abs() < 1e-9);
        assert!(cfg.scheduler.yield_to_graphics);
        let back = SchedulerConfig::from_json(&cfg.scheduler.to_json()).unwrap();
        assert_eq!(back, cfg.scheduler);
    }

    #[test]
    fn config_json_roundtrip() {
        let cfg = RuntimeConfig {
            artifacts: "artifacts/small".into(),
            soc: default_soc(),
            scheduler: SchedulerConfig::default(),
            real_compute: false,
        };
        let back = RuntimeConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.artifacts, cfg.artifacts);
        assert!(!back.real_compute);
        assert_eq!(back.soc, cfg.soc);
        assert_eq!(back.scheduler, cfg.scheduler);
    }

    #[test]
    fn overload_knobs_roundtrip_and_default_sane() {
        let d = OverloadConfig::default();
        assert!(d.max_queue_depth > 0 && d.max_live_flows > 0);
        assert_eq!(d.reactive_ttft_slo_ms, 0.0, "TTFT shedding off by default");
        let v = Json::parse(
            r#"{"artifacts": "a", "overload": {"max_queue_depth": 4,
                "reactive_ttft_slo_ms": 50.0, "fsync_every": 1}}"#,
        )
        .unwrap();
        let cfg = RuntimeConfig::from_json(&v).unwrap();
        assert_eq!(cfg.overload.max_queue_depth, 4);
        assert!((cfg.overload.reactive_ttft_slo_ms - 50.0).abs() < 1e-9);
        assert_eq!(cfg.overload.fsync_every, 1);
        assert_eq!(cfg.overload.max_live_flows, d.max_live_flows, "default preserved");
        let back = OverloadConfig::from_json(&cfg.overload.to_json()).unwrap();
        assert_eq!(back, cfg.overload);
    }

    #[test]
    fn minimal_config_uses_defaults() {
        let v = Json::parse(r#"{"artifacts": "artifacts/tiny"}"#).unwrap();
        let cfg = RuntimeConfig::from_json(&v).unwrap();
        assert!(cfg.real_compute);
        assert_eq!(cfg.scheduler.b_max, 8);
        assert_eq!(cfg.soc.xpus.len(), 3);
    }

    #[test]
    fn partial_scheduler_overrides() {
        let v = Json::parse(
            r#"{"artifacts": "a", "scheduler": {"b_max": 4, "backfill": false}}"#,
        )
        .unwrap();
        let cfg = RuntimeConfig::from_json(&v).unwrap();
        assert_eq!(cfg.scheduler.b_max, 4);
        assert!(!cfg.scheduler.backfill);
        assert!(cfg.scheduler.preemption); // default preserved
    }
}
