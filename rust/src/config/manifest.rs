//! The AOT artifact manifest: the contract between the Python compile
//! path (`python/compile/aot.py`) and the Rust serving path.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result, bail};

use crate::util::json::Json;

/// Why a [`ModelGeometry::chunk_sizes`] list was rejected at config
/// load.  Structured (not a bare `anyhow!`) so callers and tests can
/// match on the exact failure instead of a message substring — and so
/// the failure happens at the config boundary rather than as a silent
/// `best = 1` in `max_chunk_within_budget` followed by a panic deep
/// inside `plan_chunks_from`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GeometryError {
    /// No precompiled chunk variants at all.
    EmptyChunkSizes,
    /// Adjacent pair out of ascending order.
    UnsortedChunkSizes { prev: usize, next: usize },
    /// The same variant listed twice.
    DuplicateChunkSize { size: usize },
    /// A zero-token variant can never cover anything.
    ZeroChunkSize,
}

impl std::fmt::Display for GeometryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GeometryError::EmptyChunkSizes => {
                write!(f, "chunk_sizes is empty: at least one precompiled variant is required")
            }
            GeometryError::UnsortedChunkSizes { prev, next } => {
                write!(f, "chunk_sizes not ascending: {next} follows {prev}")
            }
            GeometryError::DuplicateChunkSize { size } => {
                write!(f, "chunk_sizes lists variant {size} twice")
            }
            GeometryError::ZeroChunkSize => write!(f, "chunk_sizes contains 0"),
        }
    }
}

impl std::error::Error for GeometryError {}

/// Model geometry, mirroring `python/compile/configs.py::ModelConfig`.
#[derive(Debug, Clone)]
pub struct ModelGeometry {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ffn: usize,
    pub max_seq: usize,
    pub chunk_sizes: Vec<usize>,
    pub batch_sizes: Vec<usize>,
    pub rope_theta: f64,
    /// Bytes per weight element streamed from DDR.  AOT artifacts are
    /// f32 (4); the paper-scale DES preset models the paper's W8A16
    /// round-to-nearest quantization (1 byte weights, §8.1).
    pub weight_bytes: f64,
}

impl ModelGeometry {
    pub fn from_json(v: &Json) -> Result<Self> {
        let g = Self {
            name: v.get("name")?.as_str()?.to_string(),
            vocab: v.get("vocab")?.as_usize()?,
            d_model: v.get("d_model")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            n_q_heads: v.get("n_q_heads")?.as_usize()?,
            n_kv_heads: v.get("n_kv_heads")?.as_usize()?,
            head_dim: v.get("head_dim")?.as_usize()?,
            d_ffn: v.get("d_ffn")?.as_usize()?,
            max_seq: v.get("max_seq")?.as_usize()?,
            chunk_sizes: v.get("chunk_sizes")?.as_usize_vec()?,
            batch_sizes: v.get("batch_sizes")?.as_usize_vec()?,
            rope_theta: v.get("rope_theta")?.as_f64()?,
            weight_bytes: v.opt("weight_bytes").map(|x| x.as_f64()).unwrap_or(Ok(4.0))?,
        };
        g.validate()
            .with_context(|| format!("invalid geometry for model {:?}", g.name))?;
        Ok(g)
    }

    /// Reject chunk-size lists the planner cannot work with: empty,
    /// unsorted, duplicated, or containing 0.  Called by `from_json`
    /// so every config-loaded geometry is planner-safe by construction.
    pub fn validate(&self) -> std::result::Result<(), GeometryError> {
        if self.chunk_sizes.is_empty() {
            return Err(GeometryError::EmptyChunkSizes);
        }
        for w in self.chunk_sizes.windows(2) {
            if w[1] < w[0] {
                return Err(GeometryError::UnsortedChunkSizes { prev: w[0], next: w[1] });
            }
            if w[1] == w[0] {
                return Err(GeometryError::DuplicateChunkSize { size: w[0] });
            }
        }
        if self.chunk_sizes[0] == 0 {
            return Err(GeometryError::ZeroChunkSize);
        }
        Ok(())
    }

    /// Elements in one layer's KV cache (one of K or V): `s * kh * hd`.
    pub fn cache_elems(&self) -> usize {
        self.max_seq * self.n_kv_heads * self.head_dim
    }

    /// Total parameter count (matches the Python formula).
    pub fn n_params(&self) -> usize {
        let kvd = self.n_kv_heads * self.head_dim;
        let per_layer = self.d_model * self.d_model
            + 2 * self.d_model * kvd
            + self.d_model * self.d_model
            + 3 * self.d_model * self.d_ffn
            + 2 * self.d_model;
        self.n_layers * per_layer + self.vocab * self.d_model + self.d_model
    }

    /// Largest precompiled chunk size.
    pub fn max_chunk(&self) -> usize {
        self.chunk_sizes.iter().copied().max().unwrap_or(1)
    }

    /// Largest precompiled decode batch.
    pub fn max_batch(&self) -> usize {
        self.batch_sizes.iter().copied().max().unwrap_or(1)
    }

    /// Smallest precompiled chunk size >= `n`, if any.
    pub fn chunk_for(&self, n: usize) -> Option<usize> {
        self.chunk_sizes.iter().copied().filter(|&c| c >= n).min()
    }

    /// Smallest precompiled batch size >= `n`, if any.
    pub fn batch_for(&self, n: usize) -> Option<usize> {
        self.batch_sizes.iter().copied().filter(|&b| b >= n).min()
    }
}

/// Dtype + shape of one artifact argument.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// What role an artifact plays in the HEG.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    Embed,
    LayerPrefill,
    LayerDecode,
    Head,
}

impl KernelKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "embed" => Self::Embed,
            "layer_prefill" => Self::LayerPrefill,
            "layer_decode" => Self::LayerDecode,
            "head" => Self::Head,
            _ => bail!("unknown kernel kind {s:?}"),
        })
    }
}

/// One AOT-compiled HLO module.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub file: String,
    pub args: Vec<ArgSpec>,
    pub kind: KernelKind,
    /// Chunk size (prefill/embed) or batch size (decode/head/embed).
    pub n: usize,
}

/// `artifacts/<config>/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ModelGeometry,
    pub seed: u64,
    pub weights: String,
    pub layer_weight_names: Vec<String>,
    pub artifacts: HashMap<String, ArtifactMeta>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?}"))?;
        let v = Json::parse(&text).context("parsing manifest.json")?;

        let mut artifacts = HashMap::new();
        for (name, meta) in v.get("artifacts")?.as_obj()? {
            let args = meta
                .get("args")?
                .as_arr()?
                .iter()
                .map(|a| {
                    Ok(ArgSpec {
                        dtype: a.get("dtype")?.as_str()?.to_string(),
                        shape: a.get("shape")?.as_usize_vec()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    file: meta.get("file")?.as_str()?.to_string(),
                    args,
                    kind: KernelKind::parse(meta.get("kind")?.as_str()?)?,
                    n: meta.get("n")?.as_usize()?,
                },
            );
        }

        Ok(Manifest {
            config: ModelGeometry::from_json(v.get("config")?)?,
            seed: v.get("seed")?.as_i64()? as u64,
            weights: v.get("weights")?.as_str()?.to_string(),
            layer_weight_names: v
                .get("layer_weight_names")?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?,
            artifacts,
            dir,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    pub fn artifact_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.artifact(name)?.file))
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join(&self.weights)
    }

    pub fn golden_path(&self) -> PathBuf {
        self.dir.join("golden.json")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tiny_geo() -> ModelGeometry {
        ModelGeometry {
            name: "t".into(),
            vocab: 512,
            d_model: 128,
            n_layers: 2,
            n_q_heads: 4,
            n_kv_heads: 2,
            head_dim: 32,
            d_ffn: 256,
            max_seq: 128,
            chunk_sizes: vec![16, 32],
            batch_sizes: vec![1, 2, 4],
            rope_theta: 10000.0,
            weight_bytes: 4.0,
        }
    }

    #[test]
    fn chunk_for_picks_smallest_covering() {
        let g = tiny_geo();
        assert_eq!(g.chunk_for(1), Some(16));
        assert_eq!(g.chunk_for(16), Some(16));
        assert_eq!(g.chunk_for(17), Some(32));
        assert_eq!(g.chunk_for(33), None);
    }

    #[test]
    fn batch_for_picks_smallest_covering() {
        let g = tiny_geo();
        assert_eq!(g.batch_for(1), Some(1));
        assert_eq!(g.batch_for(3), Some(4));
        assert_eq!(g.batch_for(5), None);
    }

    #[test]
    fn param_count_matches_python_tiny() {
        // python: CONFIGS['tiny'].n_params
        assert_eq!(tiny_geo().n_params(), 361_088);
    }

    #[test]
    fn kernel_kind_parses() {
        assert_eq!(KernelKind::parse("layer_prefill").unwrap(), KernelKind::LayerPrefill);
        assert!(KernelKind::parse("bogus").is_err());
    }

    #[test]
    fn chunk_sizes_validated_at_load() {
        let mk = |sizes: &str| {
            let j = Json::parse(&format!(
                r#"{{"name":"x","vocab":16,"d_model":8,"n_layers":1,
                    "n_q_heads":2,"n_kv_heads":1,"head_dim":4,"d_ffn":16,
                    "max_seq":8,"chunk_sizes":{sizes},"batch_sizes":[1],
                    "rope_theta":10000.0}}"#
            ))
            .unwrap();
            ModelGeometry::from_json(&j)
        };
        assert!(mk("[2,4]").is_ok());
        for (sizes, want) in [
            ("[]", GeometryError::EmptyChunkSizes),
            ("[4,2]", GeometryError::UnsortedChunkSizes { prev: 4, next: 2 }),
            ("[2,2,4]", GeometryError::DuplicateChunkSize { size: 2 }),
            ("[0,2]", GeometryError::ZeroChunkSize),
        ] {
            let err = mk(sizes).unwrap_err();
            assert_eq!(
                err.downcast_ref::<GeometryError>(),
                Some(&want),
                "chunk_sizes {sizes}"
            );
        }
        // the structured error also validates directly
        let mut g = tiny_geo();
        g.chunk_sizes.clear();
        assert_eq!(g.validate(), Err(GeometryError::EmptyChunkSizes));
    }

    #[test]
    fn geometry_from_json() {
        let j = Json::parse(
            r#"{"name":"x","vocab":16,"d_model":8,"n_layers":1,
                "n_q_heads":2,"n_kv_heads":1,"head_dim":4,"d_ffn":16,
                "max_seq":8,"chunk_sizes":[2,4],"batch_sizes":[1],
                "rope_theta":10000.0}"#,
        )
        .unwrap();
        let g = ModelGeometry::from_json(&j).unwrap();
        assert_eq!(g.d_model, 8);
        assert_eq!(g.chunk_sizes, vec![2, 4]);
        assert_eq!(g.cache_elems(), 32);
    }
}
