//! Paper-scale model geometry presets for timing-only (DES) experiments.
//!
//! The AOT artifacts use small geometries (real PJRT compute on CPU);
//! the figure harnesses instead simulate the paper's actual serving
//! model so the regenerated curves land in the paper's regime.

use super::manifest::ModelGeometry;

/// Llama-3.2-3B-Instruct, the paper's evaluation model (§8.1), with
/// W8A16 round-to-nearest quantization (1 byte/weight streamed).
pub fn llama32_3b() -> ModelGeometry {
    ModelGeometry {
        name: "llama32-3b".into(),
        vocab: 128_256,
        d_model: 3072,
        n_layers: 28,
        n_q_heads: 24,
        n_kv_heads: 8,
        head_dim: 128,
        d_ffn: 8192,
        max_seq: 2048,
        chunk_sizes: vec![64, 128, 256, 512],
        batch_sizes: vec![1, 2, 4, 8],
        rope_theta: 500_000.0,
        weight_bytes: 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_is_about_3b() {
        let g = llama32_3b();
        let p = g.n_params() as f64;
        assert!((2.8e9..3.4e9).contains(&p), "{p}");
    }

    #[test]
    fn chunks_divide_max_seq() {
        let g = llama32_3b();
        for c in &g.chunk_sizes {
            assert_eq!(g.max_seq % c, 0);
        }
    }
}
