//! Configuration system: the AOT manifest written by `python/compile/aot.py`
//! plus the TOML-based runtime configuration (SoC parameters, scheduler
//! knobs, workload specs).

mod manifest;
mod presets;
mod runtime_cfg;

pub use manifest::{ArgSpec, ArtifactMeta, GeometryError, KernelKind, Manifest, ModelGeometry};
pub use presets::llama32_3b;
pub use runtime_cfg::{
    OverloadConfig, RuntimeConfig, SchedulerConfig, SocConfig, XpuConfig, default_soc,
};
