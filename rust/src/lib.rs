//! # Agent.xpu — agentic LLM serving on a heterogeneous SoC
//!
//! Reproduction of *Agent.xpu: Efficient Scheduling of Agentic LLM
//! Workloads on Heterogeneous SoC* (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas stack.  This crate is Layer 3: the coordinator
//! that owns the event loop, the heterogeneous execution graph, the
//! dual-queue scheduler with kernel-level preemption and slack-aware
//! backfill, the virtual-SoC substrate, and the PJRT runtime that
//! executes the AOT-compiled model kernels.
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! - [`config`] — manifest + TOML configuration system.
//! - [`model`] — model geometry and the analytic kernel cost model
//!   (FLOPs / bytes / footprint) that feeds predictive annotation.
//! - [`soc`] — the hetero-SoC substrate: virtual NPU/iGPU/CPU rooflines,
//!   the shared-DDR bandwidth arbiter, the power model with per-class
//!   energy attribution (reactive / proactive / graphics / idle), the
//!   synthetic display workload with frame-deadline (jank) accounting,
//!   and the discrete-event clock.
//! - [`runtime`] — PJRT CPU client wrapper: loads `artifacts/*.hlo.txt`,
//!   owns weights and KV caches, executes kernels.
//! - [`heg`] — the heterogeneous execution graph (paper §5): elastic
//!   chunked kernels, affinity constraints, predictive annotation.
//! - [`coordinator`] — the online scheduler (paper §6) as a
//!   *policy*: the reusable `XpuCoordinator` decision pipeline (dual
//!   queues, kernel-level preemption, slack-aware backfill,
//!   memory-aware dispatch) behind `AgentXpuPolicy`, plus the
//!   `deadline` EDF policy built on the same hooks.
//! - [`engine`] — the streaming `EngineCore` API (`submit`/`step`/
//!   `cancel`/`drain`) over a clock-abstracted driver; the
//!   `SchedPolicy` trait + one generic `PolicyEngine<P>` that owns the
//!   whole lifecycle for every policy; and the named policy
//!   `registry` the CLI, figures, server, and tests select engines
//!   from.  The batch `run(trace)` the figure harnesses use is a
//!   provided method, so simulation and serving share one policy code
//!   path.
//! - [`baselines`] — llama.cpp-like CPU FCFS and the Fig. 4
//!   co-scheduling schemes (a)/(b)/(c), each one policy file.
//! - [`workload`] — agentic workload generators (Poisson proactive,
//!   exponential-think-time reactive, dataset-analog trace profiles)
//!   and workflow **DAGs**: dependency graphs of LLM turns and CPU
//!   tool-call nodes sharing a session id and a growing conversation
//!   context, with fan-out/join; multi-turn flows are the linear case
//!   (paper §1, DESIGN.md §3).
//! - [`fleet`] — the layer above a single SoC: N per-device engines
//!   behind a pluggable `RoutePolicy` (sticky-session / least-loaded /
//!   energy-budget / random), stepped in shared-virtual-clock event
//!   order, with overload re-placement and conservation ledgers.
//! - [`metrics`] — TTFT/TPOT/normalized latency, throughput, energy,
//!   per-flow rollups (DAG makespan vs critical-path lower bound,
//!   prefix-cache hit-rate).
//! - [`server`] — UDS JSON-lines frontend (paper §7) driving the shared
//!   engine core against wall-clock time, with `session` tags that keep
//!   KV alive across calls, a `deps` field for online workflow DAGs,
//!   and a `cancel` verb for in-flight aborts.
//! - [`trace`] — kernel-level execution traces for figures + debugging.
//! - [`lint`] — the repo-native architectural lint pass (`agent-xpu
//!   lint`, DESIGN.md §10): statically enforces the determinism,
//!   lock-hygiene, panic-freedom, SAFETY-comment, JSON-hygiene, and
//!   registry-coverage invariants the fingerprint gates assume.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod figures;
pub mod fleet;
pub mod heg;
pub mod lint;
pub mod macrobench;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod server;
pub mod soc;
pub mod trace;
pub mod util;
pub mod workload;

pub use config::{Manifest, ModelGeometry};

