//! Baseline engines the paper compares against (§3.3 Fig. 4, §8.1):
//!
//! - [`CpuFcfsEngine`] — the llama.cpp-like industrial baseline: CPU
//!   only, no batching, no priorities, bounded concurrency with
//!   time-slice multiplexing.
//! - [`SingleXpuEngine`] — the three single-accelerator co-scheduling
//!   schemes of Fig. 4: (a) instant preemption that discards prefill
//!   context, (b) time-sharing with duplicated buffers, (c) standard
//!   continuous batching at iteration granularity.
//!
//! Both are [`crate::engine::SchedPolicy`] implementations behind the
//! one generic `PolicyEngine`, running on the same DES + numerics
//! bridge as Agent.xpu — every comparison isolates *scheduling policy*
//! and costs one policy file, not an engine fork.

mod cpu_fcfs;
mod single_xpu;

pub use cpu_fcfs::{CpuFcfsEngine, CpuFcfsPolicy};
pub use single_xpu::{Scheme, SingleXpuEngine, SingleXpuPolicy};
