//! The llama.cpp-like baseline (paper §8.1): a latency-optimized
//! CPU-only policy with **no batching support** and **no priority
//! scheduling** — the agent frontend "simply notifies them about the
//! arrival of each request and leaves the scheduling decision to their
//! internal schedulers."
//!
//! Modeled behaviour: at most `concurrency` admitted requests multiplex
//! the CPU cores (llama.cpp relies on OS multitasking), served
//! round-robin at kernel granularity, FCFS admission, decode strictly
//! b=1 per request.
//!
//! Since the `SchedPolicy` redesign this file is only the per-step
//! decision; the engine lifecycle lives in `PolicyEngine`
//! (`CpuFcfsEngine` is the alias the harnesses name).

use crate::config::{ModelGeometry, SocConfig};
use crate::engine::{
    Action, ExecBridge, KernelTag, Phase, PolicyCtx, PolicyEngine, SchedPolicy,
};
use crate::heg::Annotator;
use crate::soc::XpuModel;
use crate::workload::ReqId;

/// The llama.cpp-like engine behind the one generic [`PolicyEngine`].
pub type CpuFcfsEngine = PolicyEngine<CpuFcfsPolicy>;

impl PolicyEngine<CpuFcfsPolicy> {
    pub fn new(geo: ModelGeometry, soc: SocConfig, concurrency: usize) -> Self {
        let bridge = ExecBridge::synthetic(geo.clone());
        PolicyEngine::with_policy(CpuFcfsPolicy::new(geo, &soc, concurrency), soc, bridge)
    }
}

/// CPU-only FCFS round-robin (no batching, no priorities).
pub struct CpuFcfsPolicy {
    ann: Annotator,
    geo: ModelGeometry,
    cpu: usize,
    /// Max requests multiplexing the CPU (paper: "we limit the maximum
    /// concurrency degree to avoid memory overflow").
    pub concurrency: usize,
    /// Round-robin cursor.
    cursor: usize,
}

impl CpuFcfsPolicy {
    pub fn new(geo: ModelGeometry, soc: &SocConfig, concurrency: usize) -> Self {
        let xpus: Vec<XpuModel> = soc.xpus.iter().cloned().map(XpuModel::new).collect();
        let ann = Annotator::new(geo.clone(), xpus);
        let cpu = ann.xpu_index("cpu").expect("soc needs a cpu");
        Self { ann, geo, cpu, concurrency, cursor: 0 }
    }

    fn schedule(&mut self, ctx: &mut PolicyCtx<'_>) {
        if ctx.busy(self.cpu) {
            return;
        }
        // Active set = the `concurrency` oldest unfinished requests
        // (FCFS admission; no priority awareness at all).
        let mut active: Vec<ReqId> = ctx
            .states()
            .values()
            .filter(|s| s.phase != Phase::Done)
            .map(|s| s.id())
            .collect();
        {
            let states = ctx.states();
            active.sort_by(|a, b| {
                states[a]
                    .req
                    .arrival_us
                    .total_cmp(&states[b].req.arrival_us)
                    .then(a.cmp(b))
            });
        }
        active.truncate(self.concurrency);
        if active.is_empty() {
            return;
        }
        // Round-robin over the active set at kernel granularity — the
        // OS-multitasking analogue.
        for k in 0..active.len() {
            let id = active[(self.cursor + k) % active.len()];
            let (running, phase) = {
                let st = ctx.state(id);
                (st.running, st.phase)
            };
            if running {
                continue;
            }
            self.cursor = (self.cursor + k + 1) % active.len().max(1);
            match phase {
                Phase::Prefilling => {
                    let chunk = *ctx.state(id).current_chunk().unwrap();
                    let a = self.ann.prefill_kernel(&chunk);
                    let t = *a.timing_on(self.cpu);
                    ctx.launch(self.cpu, t, false, KernelTag::Prefill { req: id });
                }
                Phase::Decoding => {
                    // no batching: a lone-lane decode iteration
                    let pos = ctx.state(id).pos.max(1);
                    let a = self.ann.decode_iter(1, pos);
                    let t = *a.timing_on(self.cpu);
                    ctx.launch(self.cpu, t, false, KernelTag::DecodeIter { lanes: vec![id] });
                }
                Phase::Done => continue,
            }
            return;
        }
    }
}

impl SchedPolicy for CpuFcfsPolicy {
    fn label(&self) -> String {
        format!("llama.cpp-like(c={})", self.concurrency)
    }

    fn max_chunk(&self) -> usize {
        self.geo.max_chunk()
    }

    fn on_start(&mut self) {
        self.cursor = 0;
    }

    fn decide(&mut self, mut ctx: PolicyCtx<'_>) -> Vec<Action> {
        self.schedule(&mut ctx);
        ctx.take_actions()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{default_soc, llama32_3b};
    use crate::engine::Engine;
    use crate::workload::{Priority, Request};

    fn geo() -> ModelGeometry {
        let mut g = llama32_3b();
        g.n_layers = 4;
        g
    }

    fn req(id: u64, prio: Priority, arrival: f64, plen: usize, out: usize) -> Request {
        Request {
            id,
            priority: prio,
            arrival_us: arrival,
            prompt: vec![1; plen],
            max_new_tokens: out,
            profile: "test".into(),
            flow: None,
        }
    }

    #[test]
    fn completes_all_requests() {
        let mut e = CpuFcfsEngine::new(geo(), default_soc(), 4);
        let trace: Vec<Request> = (0..5)
            .map(|i| req(i, Priority::Proactive, i as f64 * 10_000.0, 200, 6))
            .collect();
        let rep = e.run(trace).unwrap();
        assert_eq!(rep.reqs.iter().filter(|m| m.finished()).count(), 5);
        // only the CPU did work
        assert!(rep.utilization("cpu") > 0.0);
        assert_eq!(rep.utilization("npu"), 0.0);
        assert_eq!(rep.utilization("igpu"), 0.0);
        // trace retention now covers baselines too (redesign satellite)
        assert!(e.last_trace().is_some());
    }

    #[test]
    fn no_priority_reactive_waits_behind_queue() {
        // The 4.6x story: reactive latency degrades behind proactive work.
        let mut e = CpuFcfsEngine::new(geo(), default_soc(), 2);
        let mut trace: Vec<Request> = (0..6)
            .map(|i| req(i, Priority::Proactive, 0.0, 512, 30))
            .collect();
        trace.push(req(100, Priority::Reactive, 1_000.0, 128, 4));
        let rep = e.run(trace).unwrap();
        let rt = rep.reqs.iter().find(|m| m.id == 100).unwrap();
        // solo reactive for comparison
        let mut e2 = CpuFcfsEngine::new(geo(), default_soc(), 2);
        let solo = e2.run(vec![req(100, Priority::Reactive, 1_000.0, 128, 4)]).unwrap();
        let solo_ttft = solo.reqs[0].ttft_us().unwrap();
        assert!(
            rt.ttft_us().unwrap() > 3.0 * solo_ttft,
            "queueing must hurt reactive: {} vs {}",
            rt.ttft_us().unwrap(),
            solo_ttft
        );
    }

    #[test]
    fn concurrency_bound_respected_one_at_a_time() {
        // c=1 serves strictly FCFS: completion order == arrival order
        let mut e = CpuFcfsEngine::new(geo(), default_soc(), 1);
        let trace: Vec<Request> = (0..3)
            .map(|i| req(i, Priority::Proactive, i as f64, 128, 3))
            .collect();
        let rep = e.run(trace).unwrap();
        let mut done: Vec<(u64, f64)> =
            rep.reqs.iter().map(|m| (m.id, m.done_us.unwrap())).collect();
        done.sort_by(|a, b| a.1.total_cmp(&b.1));
        assert_eq!(done.iter().map(|d| d.0).collect::<Vec<_>>(), vec![0, 1, 2]);
    }
}
