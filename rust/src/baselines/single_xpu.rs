//! The three single-XPU co-scheduling schemes of the paper's Fig. 4 —
//! the homogeneous strawmen Agent.xpu's scheme (d) is designed against.
//!
//! All run on the iGPU alone:
//!
//! - **(a) PreemptRestart** — a reactive arrival *instantly* cancels the
//!   running proactive kernel and discards the victim's prefill context
//!   (recompute-from-scratch on resume).  Fast reactive response, heavy
//!   throughput loss.
//! - **(b) TimeShare** — multitasking/multi-stream analogue: all active
//!   tasks round-robin the XPU at kernel granularity with duplicated
//!   intermediate buffers; nobody is prioritized.
//! - **(c) ContinuousBatching** — standard iteration-level batching
//!   (Orca-style): FCFS prefill runs un-preemptible, decodes batch
//!   between prefills; a reactive request waits for the proactive
//!   prefill ahead of it.

use anyhow::{Context, Result};

use crate::config::{ModelGeometry, SocConfig};
use crate::engine::{
    Driver, EngineClock, EngineCore, EngineEvent, ExecBridge, KernelTag, Phase,
};
use crate::heg::Annotator;
use crate::metrics::RunReport;
use crate::soc::XpuModel;
use crate::workload::{ReqId, Request};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    PreemptRestart,
    TimeShare,
    ContinuousBatching,
}

impl Scheme {
    pub fn label(&self) -> &'static str {
        match self {
            Scheme::PreemptRestart => "scheme-a/preempt-restart",
            Scheme::TimeShare => "scheme-b/time-share",
            Scheme::ContinuousBatching => "scheme-c/continuous-batching",
        }
    }
}

pub struct SingleXpuEngine {
    soc: SocConfig,
    ann: Annotator,
    geo: ModelGeometry,
    pub scheme: Scheme,
    xpu: usize,
    b_max: usize,
    cursor: usize,
    /// Kernel trace of the last `run` (Fig. 4 Gantt).
    pub last_trace: Option<crate::trace::Trace>,
    /// The open run, if `start` has been called (EngineCore lifecycle).
    active: Option<Driver>,
    /// The last `step` made no progress (run idle).
    stalled: bool,
}

impl SingleXpuEngine {
    pub fn new(geo: ModelGeometry, soc: SocConfig, scheme: Scheme) -> Self {
        let xpus: Vec<XpuModel> = soc.xpus.iter().cloned().map(XpuModel::new).collect();
        let ann = Annotator::new(geo.clone(), xpus);
        let xpu = ann.xpu_index("igpu").expect("soc needs an igpu");
        Self {
            soc, ann, geo, scheme, xpu, b_max: 8, cursor: 0, last_trace: None,
            active: None, stalled: false,
        }
    }

    fn launch_prefill(&self, d: &mut Driver, id: ReqId, reactive: bool) {
        let chunk = *d.states[&id].current_chunk().unwrap();
        let a = self.ann.prefill_kernel(&chunk);
        let t = *a.timing_on(self.xpu);
        d.launch(self.xpu, t, reactive, KernelTag::Prefill { req: id });
    }

    fn launch_decode(&self, d: &mut Driver, lanes: Vec<ReqId>, reactive: bool) {
        let avg = (lanes.iter().map(|id| d.states[id].pos).sum::<usize>() / lanes.len())
            .max(1);
        let a = self.ann.decode_iter(lanes.len(), avg);
        let t = *a.timing_on(self.xpu);
        d.launch(self.xpu, t, reactive, KernelTag::DecodeIter { lanes });
    }

    /// Scheme (a): reactive runs exclusively; an arrival cancels the
    /// in-flight proactive kernel and wipes the victim's prefill context.
    fn schedule_preempt_restart(&mut self, d: &mut Driver) {
        let reactive_waiting: Vec<ReqId> = {
            let mut v: Vec<ReqId> = d
                .states
                .values()
                .filter(|s| s.is_reactive() && s.phase != Phase::Done)
                .map(|s| s.id())
                .collect();
            v.sort_unstable();
            v
        };
        // Instant preemption: cancel proactive work the moment a
        // reactive request exists.
        if !reactive_waiting.is_empty() && d.sim.busy(self.xpu) {
            let victim_is_proactive = d
                .states
                .values()
                .filter(|s| s.running)
                .all(|s| !s.is_reactive());
            if victim_is_proactive {
                if let Some(tag) = d.cancel(self.xpu) {
                    d.note_preemption(tag.reqs()[0]);
                    for vid in tag.reqs() {
                        let st = d.states.get_mut(&vid).unwrap();
                        // "without saving the prefill context": all
                        // prefill progress is recomputed
                        if st.phase == Phase::Prefilling {
                            let geo = self.geo.clone();
                            st.restart_prefill(&geo);
                        }
                    }
                }
            }
        }
        if d.sim.busy(self.xpu) {
            return;
        }
        // Reactive exclusively first, then proactive FCFS.
        let pick_phasewise = |d: &Driver, ids: &[ReqId]| -> Option<(ReqId, Phase)> {
            ids.first().map(|&id| (id, d.states[&id].phase))
        };
        let runnable_reactive: Vec<ReqId> = reactive_waiting
            .iter()
            .copied()
            .filter(|id| !d.states[id].running)
            .collect();
        if let Some((id, phase)) = pick_phasewise(d, &runnable_reactive) {
            match phase {
                Phase::Prefilling => self.launch_prefill(d, id, true),
                Phase::Decoding => self.launch_decode(d, vec![id], true),
                Phase::Done => {}
            }
            return;
        }
        let mut proactive: Vec<ReqId> = d
            .states
            .values()
            .filter(|s| !s.is_reactive() && s.phase != Phase::Done && !s.running)
            .map(|s| s.id())
            .collect();
        proactive.sort_by(|a, b| {
            d.states[a]
                .req
                .arrival_us
                .total_cmp(&d.states[b].req.arrival_us)
                .then(a.cmp(b))
        });
        if let Some((id, phase)) = pick_phasewise(d, &proactive) {
            match phase {
                Phase::Prefilling => self.launch_prefill(d, id, false),
                Phase::Decoding => self.launch_decode(d, vec![id], false),
                Phase::Done => {}
            }
        }
    }

    /// Scheme (b): round-robin kernels across all active tasks; decode
    /// runs per-task (duplicated buffers — no batching).
    fn schedule_time_share(&mut self, d: &mut Driver) {
        if d.sim.busy(self.xpu) {
            return;
        }
        let mut active: Vec<ReqId> = d
            .states
            .values()
            .filter(|s| s.phase != Phase::Done && !s.running)
            .map(|s| s.id())
            .collect();
        active.sort_unstable();
        if active.is_empty() {
            return;
        }
        let id = active[self.cursor % active.len()];
        self.cursor = self.cursor.wrapping_add(1);
        let st = &d.states[&id];
        let reactive = st.is_reactive();
        match st.phase {
            Phase::Prefilling => self.launch_prefill(d, id, reactive),
            Phase::Decoding => self.launch_decode(d, vec![id], reactive),
            Phase::Done => {}
        }
    }

    /// Scheme (c): continuous batching — FCFS prefill without
    /// preemption; decodes batch together between prefill iterations.
    fn schedule_continuous_batching(&mut self, d: &mut Driver) {
        if d.sim.busy(self.xpu) {
            return;
        }
        let mut prefilling: Vec<ReqId> = d
            .states
            .values()
            .filter(|s| s.phase == Phase::Prefilling && !s.running)
            .map(|s| s.id())
            .collect();
        prefilling.sort_by(|a, b| {
            d.states[a]
                .req
                .arrival_us
                .total_cmp(&d.states[b].req.arrival_us)
                .then(a.cmp(b))
        });
        // Iteration-level FCFS: the oldest prefill monopolizes the XPU
        // until done (no priority; the Fig. 4(c) pathology).
        if let Some(&id) = prefilling.first() {
            let reactive = d.states[&id].is_reactive();
            self.launch_prefill(d, id, reactive);
            return;
        }
        let mut lanes: Vec<ReqId> = d
            .states
            .values()
            .filter(|s| s.phase == Phase::Decoding && !s.running)
            .map(|s| s.id())
            .collect();
        lanes.sort_unstable();
        lanes.truncate(self.b_max);
        if !lanes.is_empty() {
            let reactive = lanes.iter().any(|id| d.states[id].is_reactive());
            self.launch_decode(d, lanes, reactive);
        }
    }

    fn schedule(&mut self, d: &mut Driver) {
        match self.scheme {
            Scheme::PreemptRestart => self.schedule_preempt_restart(d),
            Scheme::TimeShare => self.schedule_time_share(d),
            Scheme::ContinuousBatching => self.schedule_continuous_batching(d),
        }
    }
}

impl EngineCore for SingleXpuEngine {
    fn name(&self) -> String {
        self.scheme.label().to_string()
    }

    fn start(&mut self, clock: EngineClock) -> Result<()> {
        self.cursor = 0;
        self.active = Some(Driver::open(
            &self.soc,
            ExecBridge::synthetic(self.geo.clone()),
            clock,
        ));
        self.stalled = false;
        Ok(())
    }

    fn submit(&mut self, req: Request) -> Result<()> {
        self.active
            .as_mut()
            .context("single-xpu: submit before start")?
            .submit(req);
        self.stalled = false;
        Ok(())
    }

    fn cancel(&mut self, id: ReqId) -> Result<bool> {
        let hit = self
            .active
            .as_mut()
            .context("single-xpu: cancel before start")?
            .cancel_request(id);
        if hit {
            // wake a stalled run so the Cancelled event flushes
            self.stalled = false;
        }
        Ok(hit)
    }

    fn step(&mut self) -> Result<Vec<EngineEvent>> {
        let mut d = self.active.take().context("single-xpu: step before start")?;
        d.admit_ready(self.geo.max_chunk());
        self.schedule(&mut d);
        let progressed = d.step()?;
        self.stalled = !progressed;
        let events = d.take_events();
        self.active = Some(d);
        Ok(events)
    }

    fn has_work(&self) -> bool {
        self.active.is_some() && !self.stalled
    }

    fn finish(&mut self) -> Result<RunReport> {
        let d = self.active.take().context("single-xpu: finish before start")?;
        self.last_trace = Some(d.trace.clone());
        d.finish(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{default_soc, llama32_3b};
    use crate::workload::Priority;

    fn geo() -> ModelGeometry {
        let mut g = llama32_3b();
        g.n_layers = 4;
        g
    }

    fn req(id: u64, prio: Priority, arrival: f64, plen: usize, out: usize) -> Request {
        Request {
            id,
            priority: prio,
            arrival_us: arrival,
            prompt: vec![1; plen],
            max_new_tokens: out,
            profile: "test".into(),
            flow: None,
        }
    }

    fn mixed_trace() -> Vec<Request> {
        let mut t = vec![req(0, Priority::Proactive, 0.0, 1024, 16)];
        t.push(req(1, Priority::Reactive, 60_000.0, 256, 8));
        t.push(req(2, Priority::Proactive, 80_000.0, 512, 8));
        t
    }

    #[test]
    fn all_schemes_complete_mixed_load() {
        for scheme in
            [Scheme::PreemptRestart, Scheme::TimeShare, Scheme::ContinuousBatching]
        {
            let mut e = SingleXpuEngine::new(geo(), default_soc(), scheme);
            let rep = e.run(mixed_trace()).unwrap();
            assert_eq!(
                rep.reqs.iter().filter(|m| m.finished()).count(),
                3,
                "{scheme:?}"
            );
            // single-XPU: NPU and CPU stay idle
            assert_eq!(rep.utilization("npu"), 0.0, "{scheme:?}");
            assert_eq!(rep.utilization("cpu"), 0.0, "{scheme:?}");
        }
    }

    #[test]
    fn scheme_a_reactive_fastest_but_wastes_proactive_work() {
        let mut a = SingleXpuEngine::new(geo(), default_soc(), Scheme::PreemptRestart);
        let mut c =
            SingleXpuEngine::new(geo(), default_soc(), Scheme::ContinuousBatching);
        let ra = a.run(mixed_trace()).unwrap();
        let rc = c.run(mixed_trace()).unwrap();
        let ttft = |r: &crate::metrics::RunReport, id: u64| {
            r.reqs.iter().find(|m| m.id == id).unwrap().ttft_us().unwrap()
        };
        // (a) restarts the long proactive prefill → reactive is much
        // faster than under (c), where it queues behind the prefill.
        assert!(ttft(&ra, 1) < ttft(&rc, 1));
        assert!(ra.preemptions >= 1);
        // ... and the preempted proactive task finishes later under (a)
        let done = |r: &crate::metrics::RunReport, id: u64| {
            r.reqs.iter().find(|m| m.id == id).unwrap().done_us.unwrap()
        };
        assert!(done(&ra, 0) > done(&rc, 0));
    }

    #[test]
    fn scheme_b_slows_everyone() {
        let mut b = SingleXpuEngine::new(geo(), default_soc(), Scheme::TimeShare);
        let rb = b.run(mixed_trace()).unwrap();
        let mut a = SingleXpuEngine::new(geo(), default_soc(), Scheme::PreemptRestart);
        let ra = a.run(mixed_trace()).unwrap();
        let ttft = |r: &crate::metrics::RunReport, id: u64| {
            r.reqs.iter().find(|m| m.id == id).unwrap().ttft_us().unwrap()
        };
        // time-sharing gives the reactive task no priority → slower
        // reactive TTFT than instant preemption
        assert!(ttft(&rb, 1) > ttft(&ra, 1));
    }

    #[test]
    fn scheme_c_reactive_blocked_by_proactive_prefill() {
        let mut c =
            SingleXpuEngine::new(geo(), default_soc(), Scheme::ContinuousBatching);
        // reactive arrives right after a long proactive prefill starts
        let trace = vec![
            req(0, Priority::Proactive, 0.0, 2048, 4),
            req(1, Priority::Reactive, 10_000.0, 128, 4),
        ];
        let rep = c.run(trace).unwrap();
        let rt = rep.reqs.iter().find(|m| m.id == 1).unwrap();
        let pro = rep.reqs.iter().find(|m| m.id == 0).unwrap();
        // the reactive first token comes after the proactive prefill ends
        assert!(rt.first_token_us.unwrap() > pro.first_token_us.unwrap());
    }
}
