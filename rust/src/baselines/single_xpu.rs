//! The three single-XPU co-scheduling schemes of the paper's Fig. 4 —
//! the homogeneous strawmen Agent.xpu's scheme (d) is designed against.
//!
//! All run on the iGPU alone:
//!
//! - **(a) PreemptRestart** — a reactive arrival *instantly* cancels the
//!   running proactive kernel and discards the victim's prefill context
//!   (recompute-from-scratch on resume).  Fast reactive response, heavy
//!   throughput loss.
//! - **(b) TimeShare** — multitasking/multi-stream analogue: all active
//!   tasks round-robin the XPU at kernel granularity with duplicated
//!   intermediate buffers; nobody is prioritized.
//! - **(c) ContinuousBatching** — standard iteration-level batching
//!   (Orca-style): FCFS prefill runs un-preemptible, decodes batch
//!   between prefills; a reactive request waits for the proactive
//!   prefill ahead of it.
//!
//! Since the `SchedPolicy` redesign this file is only the per-step
//! decisions; the engine lifecycle lives in `PolicyEngine`
//! (`SingleXpuEngine` is the alias the harnesses name).

use crate::config::{ModelGeometry, SocConfig};
use crate::engine::{
    Action, ExecBridge, KernelTag, Phase, PolicyCtx, PolicyEngine, SchedPolicy,
};
use crate::heg::Annotator;
use crate::soc::XpuModel;
use crate::workload::ReqId;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    PreemptRestart,
    TimeShare,
    ContinuousBatching,
}

impl Scheme {
    pub fn label(&self) -> &'static str {
        match self {
            Scheme::PreemptRestart => "scheme-a/preempt-restart",
            Scheme::TimeShare => "scheme-b/time-share",
            Scheme::ContinuousBatching => "scheme-c/continuous-batching",
        }
    }
}

/// The single-XPU engine behind the one generic [`PolicyEngine`].
pub type SingleXpuEngine = PolicyEngine<SingleXpuPolicy>;

impl PolicyEngine<SingleXpuPolicy> {
    pub fn new(geo: ModelGeometry, soc: SocConfig, scheme: Scheme) -> Self {
        let bridge = ExecBridge::synthetic(geo.clone());
        PolicyEngine::with_policy(SingleXpuPolicy::new(geo, &soc, scheme), soc, bridge)
    }
}

/// One of the Fig. 4 single-accelerator schemes.
pub struct SingleXpuPolicy {
    ann: Annotator,
    geo: ModelGeometry,
    pub scheme: Scheme,
    xpu: usize,
    b_max: usize,
    cursor: usize,
}

impl SingleXpuPolicy {
    pub fn new(geo: ModelGeometry, soc: &SocConfig, scheme: Scheme) -> Self {
        let xpus: Vec<XpuModel> = soc.xpus.iter().cloned().map(XpuModel::new).collect();
        let ann = Annotator::new(geo.clone(), xpus);
        let xpu = ann.xpu_index("igpu").expect("soc needs an igpu");
        Self { ann, geo, scheme, xpu, b_max: 8, cursor: 0 }
    }

    fn launch_prefill(&self, ctx: &mut PolicyCtx<'_>, id: ReqId, reactive: bool) {
        let chunk = *ctx.state(id).current_chunk().unwrap();
        let a = self.ann.prefill_kernel(&chunk);
        let t = *a.timing_on(self.xpu);
        ctx.launch(self.xpu, t, reactive, KernelTag::Prefill { req: id });
    }

    fn launch_decode(&self, ctx: &mut PolicyCtx<'_>, lanes: Vec<ReqId>, reactive: bool) {
        let avg = (lanes.iter().map(|id| ctx.state(*id).pos).sum::<usize>()
            / lanes.len())
        .max(1);
        let a = self.ann.decode_iter(lanes.len(), avg);
        let t = *a.timing_on(self.xpu);
        ctx.launch(self.xpu, t, reactive, KernelTag::DecodeIter { lanes });
    }

    /// Scheme (a): reactive runs exclusively; an arrival cancels the
    /// in-flight proactive kernel and wipes the victim's prefill context.
    fn schedule_preempt_restart(&mut self, ctx: &mut PolicyCtx<'_>) {
        let reactive_waiting: Vec<ReqId> = {
            let mut v: Vec<ReqId> = ctx
                .states()
                .values()
                .filter(|s| s.is_reactive() && s.phase != Phase::Done)
                .map(|s| s.id())
                .collect();
            v.sort_unstable();
            v
        };
        // Instant preemption: cancel proactive work the moment a
        // reactive request exists.
        if !reactive_waiting.is_empty() && ctx.busy(self.xpu) {
            let victim_is_proactive = ctx
                .states()
                .values()
                .filter(|s| s.running)
                .all(|s| !s.is_reactive());
            if victim_is_proactive {
                if let Some(tag) = ctx.abort(self.xpu) {
                    ctx.note_preemption(tag.reqs()[0]);
                    for vid in tag.reqs() {
                        // "without saving the prefill context": all
                        // prefill progress is recomputed
                        ctx.restart_prefill(vid, &self.geo);
                    }
                }
            }
        }
        if ctx.busy(self.xpu) {
            return;
        }
        // Reactive exclusively first, then proactive FCFS.
        let runnable_reactive: Vec<ReqId> = reactive_waiting
            .iter()
            .copied()
            .filter(|id| !ctx.state(*id).running)
            .collect();
        if let Some(&id) = runnable_reactive.first() {
            match ctx.state(id).phase {
                Phase::Prefilling => self.launch_prefill(ctx, id, true),
                Phase::Decoding => self.launch_decode(ctx, vec![id], true),
                Phase::Done => {}
            }
            return;
        }
        let mut proactive: Vec<ReqId> = ctx
            .states()
            .values()
            .filter(|s| !s.is_reactive() && s.phase != Phase::Done && !s.running)
            .map(|s| s.id())
            .collect();
        {
            let states = ctx.states();
            proactive.sort_by(|a, b| {
                states[a]
                    .req
                    .arrival_us
                    .total_cmp(&states[b].req.arrival_us)
                    .then(a.cmp(b))
            });
        }
        if let Some(&id) = proactive.first() {
            match ctx.state(id).phase {
                Phase::Prefilling => self.launch_prefill(ctx, id, false),
                Phase::Decoding => self.launch_decode(ctx, vec![id], false),
                Phase::Done => {}
            }
        }
    }

    /// Scheme (b): round-robin kernels across all active tasks; decode
    /// runs per-task (duplicated buffers — no batching).
    fn schedule_time_share(&mut self, ctx: &mut PolicyCtx<'_>) {
        if ctx.busy(self.xpu) {
            return;
        }
        let mut active: Vec<ReqId> = ctx
            .states()
            .values()
            .filter(|s| s.phase != Phase::Done && !s.running)
            .map(|s| s.id())
            .collect();
        active.sort_unstable();
        if active.is_empty() {
            return;
        }
        let id = active[self.cursor % active.len()];
        self.cursor = self.cursor.wrapping_add(1);
        let (phase, reactive) = {
            let st = ctx.state(id);
            (st.phase, st.is_reactive())
        };
        match phase {
            Phase::Prefilling => self.launch_prefill(ctx, id, reactive),
            Phase::Decoding => self.launch_decode(ctx, vec![id], reactive),
            Phase::Done => {}
        }
    }

    /// Scheme (c): continuous batching — FCFS prefill without
    /// preemption; decodes batch together between prefill iterations.
    fn schedule_continuous_batching(&mut self, ctx: &mut PolicyCtx<'_>) {
        if ctx.busy(self.xpu) {
            return;
        }
        let mut prefilling: Vec<ReqId> = ctx
            .states()
            .values()
            .filter(|s| s.phase == Phase::Prefilling && !s.running)
            .map(|s| s.id())
            .collect();
        {
            let states = ctx.states();
            prefilling.sort_by(|a, b| {
                states[a]
                    .req
                    .arrival_us
                    .total_cmp(&states[b].req.arrival_us)
                    .then(a.cmp(b))
            });
        }
        // Iteration-level FCFS: the oldest prefill monopolizes the XPU
        // until done (no priority; the Fig. 4(c) pathology).
        if let Some(&id) = prefilling.first() {
            let reactive = ctx.state(id).is_reactive();
            self.launch_prefill(ctx, id, reactive);
            return;
        }
        let mut lanes: Vec<ReqId> = ctx
            .states()
            .values()
            .filter(|s| s.phase == Phase::Decoding && !s.running)
            .map(|s| s.id())
            .collect();
        lanes.sort_unstable();
        lanes.truncate(self.b_max);
        if !lanes.is_empty() {
            let reactive = lanes.iter().any(|id| ctx.state(*id).is_reactive());
            self.launch_decode(ctx, lanes, reactive);
        }
    }

    fn schedule(&mut self, ctx: &mut PolicyCtx<'_>) {
        match self.scheme {
            Scheme::PreemptRestart => self.schedule_preempt_restart(ctx),
            Scheme::TimeShare => self.schedule_time_share(ctx),
            Scheme::ContinuousBatching => self.schedule_continuous_batching(ctx),
        }
    }
}

impl SchedPolicy for SingleXpuPolicy {
    fn label(&self) -> String {
        self.scheme.label().to_string()
    }

    fn max_chunk(&self) -> usize {
        self.geo.max_chunk()
    }

    fn on_start(&mut self) {
        self.cursor = 0;
    }

    fn decide(&mut self, mut ctx: PolicyCtx<'_>) -> Vec<Action> {
        self.schedule(&mut ctx);
        ctx.take_actions()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{default_soc, llama32_3b};
    use crate::engine::Engine;
    use crate::workload::{Priority, Request};

    fn geo() -> ModelGeometry {
        let mut g = llama32_3b();
        g.n_layers = 4;
        g
    }

    fn req(id: u64, prio: Priority, arrival: f64, plen: usize, out: usize) -> Request {
        Request {
            id,
            priority: prio,
            arrival_us: arrival,
            prompt: vec![1; plen],
            max_new_tokens: out,
            profile: "test".into(),
            flow: None,
        }
    }

    fn mixed_trace() -> Vec<Request> {
        let mut t = vec![req(0, Priority::Proactive, 0.0, 1024, 16)];
        t.push(req(1, Priority::Reactive, 60_000.0, 256, 8));
        t.push(req(2, Priority::Proactive, 80_000.0, 512, 8));
        t
    }

    #[test]
    fn all_schemes_complete_mixed_load() {
        for scheme in
            [Scheme::PreemptRestart, Scheme::TimeShare, Scheme::ContinuousBatching]
        {
            let mut e = SingleXpuEngine::new(geo(), default_soc(), scheme);
            let rep = e.run(mixed_trace()).unwrap();
            assert_eq!(
                rep.reqs.iter().filter(|m| m.finished()).count(),
                3,
                "{scheme:?}"
            );
            // single-XPU: NPU and CPU stay idle
            assert_eq!(rep.utilization("npu"), 0.0, "{scheme:?}");
            assert_eq!(rep.utilization("cpu"), 0.0, "{scheme:?}");
            // every policy's trace is retained by the shared engine
            assert!(e.last_trace().is_some(), "{scheme:?}");
        }
    }

    #[test]
    fn scheme_a_reactive_fastest_but_wastes_proactive_work() {
        let mut a = SingleXpuEngine::new(geo(), default_soc(), Scheme::PreemptRestart);
        let mut c =
            SingleXpuEngine::new(geo(), default_soc(), Scheme::ContinuousBatching);
        let ra = a.run(mixed_trace()).unwrap();
        let rc = c.run(mixed_trace()).unwrap();
        let ttft = |r: &crate::metrics::RunReport, id: u64| {
            r.reqs.iter().find(|m| m.id == id).unwrap().ttft_us().unwrap()
        };
        // (a) restarts the long proactive prefill → reactive is much
        // faster than under (c), where it queues behind the prefill.
        assert!(ttft(&ra, 1) < ttft(&rc, 1));
        assert!(ra.preemptions >= 1);
        // ... and the preempted proactive task finishes later under (a)
        let done = |r: &crate::metrics::RunReport, id: u64| {
            r.reqs.iter().find(|m| m.id == id).unwrap().done_us.unwrap()
        };
        assert!(done(&ra, 0) > done(&rc, 0));
    }

    #[test]
    fn scheme_b_slows_everyone() {
        let mut b = SingleXpuEngine::new(geo(), default_soc(), Scheme::TimeShare);
        let rb = b.run(mixed_trace()).unwrap();
        let mut a = SingleXpuEngine::new(geo(), default_soc(), Scheme::PreemptRestart);
        let ra = a.run(mixed_trace()).unwrap();
        let ttft = |r: &crate::metrics::RunReport, id: u64| {
            r.reqs.iter().find(|m| m.id == id).unwrap().ttft_us().unwrap()
        };
        // time-sharing gives the reactive task no priority → slower
        // reactive TTFT than instant preemption
        assert!(ttft(&rb, 1) > ttft(&ra, 1));
    }

    #[test]
    fn scheme_c_reactive_blocked_by_proactive_prefill() {
        let mut c =
            SingleXpuEngine::new(geo(), default_soc(), Scheme::ContinuousBatching);
        // reactive arrives right after a long proactive prefill starts
        let trace = vec![
            req(0, Priority::Proactive, 0.0, 2048, 4),
            req(1, Priority::Reactive, 10_000.0, 128, 4),
        ];
        let rep = c.run(trace).unwrap();
        let rt = rep.reqs.iter().find(|m| m.id == 1).unwrap();
        let pro = rep.reqs.iter().find(|m| m.id == 0).unwrap();
        // the reactive first token comes after the proactive prefill ends
        assert!(rt.first_token_us.unwrap() > pro.first_token_us.unwrap());
    }
}
