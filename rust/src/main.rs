//! `agent-xpu` — launcher CLI.
//!
//! ```text
//! agent-xpu fig <affinity|contention|batching|schemes|proactive|mixed|flows|workflows|elastic|energy|overload|fleet|ablation|all>
//!           [--out results/] [--duration 120] [--seed 7] [--smoke]
//! agent-xpu bench macro [--smoke] [--seed 42] [--out results/]
//! agent-xpu run --rate 1.5 --interval 12 --duration 60 [--engine <policy>]
//! agent-xpu serve --artifacts artifacts/small [--socket /tmp/agent-xpu.sock]
//!           [--config runtime.json] [--b-max 8] [--session-capacity 32]
//!           [--policy agent-xpu|deadline|cpu-fcfs|scheme-a|b|c]
//!           [--synthetic] [--journal path.waj]
//!           [--max-queue-depth 256] [--max-live-flows 1024]
//! agent-xpu policies
//! agent-xpu routers
//! agent-xpu lint [--json] [paths…]
//! agent-xpu inspect --artifacts artifacts/small
//! agent-xpu soc-probe
//! ```
//!
//! Engines are selected from the policy registry
//! (`engine::registry`) — `agent-xpu policies` lists every registered
//! name; `run --engine` and `serve --policy` accept names or aliases
//! (`agent.xpu`, `llamacpp`, `edf`, …).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result, bail};

use agent_xpu::config::{
    OverloadConfig, RuntimeConfig, SchedulerConfig, default_soc, llama32_3b,
};
use agent_xpu::engine::{EngineCore, ExecBridge, registry};
use agent_xpu::figures;
use agent_xpu::runtime::{ModelExecutor, Runtime};
use agent_xpu::server::Server;
use agent_xpu::util::cli::Args;
use agent_xpu::util::json::Json;
use agent_xpu::workload::Priority;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("fig") => cmd_fig(&args),
        Some("bench") => cmd_bench(&args),
        Some("run") => cmd_run(&args),
        Some("serve") => cmd_serve(&args),
        Some("policies") => cmd_policies(),
        Some("routers") => cmd_routers(),
        Some("lint") => cmd_lint(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("soc-probe") => cmd_soc_probe(),
        _ => {
            eprintln!(
                "usage: agent-xpu <fig|bench|run|serve|policies|routers|lint|inspect|soc-probe> [flags]\n\
                 see `rust/src/main.rs` docs for flags"
            );
            Ok(())
        }
    }
}

fn cmd_policies() -> Result<()> {
    println!("registered scheduling policies (engine::registry):");
    for name in registry::names() {
        println!("  {name}");
    }
    println!("aliases: agent.xpu, llamacpp, preempt-restart, time-share,");
    println!("         continuous-batching, edf");
    Ok(())
}

/// `agent-xpu routers` — the fleet-layer session routers, listed
/// alongside the per-device scheduling policies they compose with
/// (`FleetConfig { router, policy }`).
fn cmd_routers() -> Result<()> {
    println!("registered fleet routers (fleet::route):");
    for name in agent_xpu::fleet::route::names() {
        println!("  {name}");
    }
    println!("per-device scheduling policies (engine::registry):");
    for name in registry::names() {
        println!("  {name}");
    }
    Ok(())
}

/// `agent-xpu lint [--json] [paths…]` — the architectural lint pass
/// (DESIGN.md §10).  Walks `src` and `tests` (or the given paths,
/// relative to the crate dir) under the checked-in `lint.json` config
/// and exits nonzero on any un-allowlisted violation.  `--json` emits
/// the strict RFC 8259 report CI parses; human-readable
/// `file:line rule message` diagnostics go to stderr in that mode.
fn cmd_lint(args: &Args) -> Result<()> {
    use agent_xpu::lint;
    // `--json src` parses as json="src" under the flag grammar; treat
    // any non-boolean value as both the flag and a scan path.
    let mut json_out = false;
    let mut paths: Vec<String> = args.positional[1..].to_vec();
    if let Some(v) = args.get("json") {
        json_out = v != "false" && v != "0";
        if !matches!(v, "true" | "false" | "0" | "1") {
            paths.insert(0, v.to_string());
        }
    }
    // the crate dir (where lint.json and src/ live), whether invoked
    // from rust/ or the repo root
    let root = if Path::new("lint.json").exists() || Path::new("src").is_dir() {
        PathBuf::from(".")
    } else {
        PathBuf::from("rust")
    };
    let cfg = lint::LintConfig::load_or_default(&root)?;
    if paths.is_empty() {
        paths = cfg.paths.clone();
    }
    let rep = lint::run(&root, &paths, &cfg)?;
    if json_out {
        println!("{}", rep.to_json());
        for v in &rep.violations {
            eprintln!("{}:{} {} {}", v.file, v.line, v.rule, v.msg);
        }
    } else {
        for v in &rep.violations {
            println!("{}:{} {} {}", v.file, v.line, v.rule, v.msg);
        }
        println!(
            "lint: {} file(s), {} violation(s), {} allow(s) ({} unused)",
            rep.files_scanned,
            rep.violations.len(),
            rep.allowed.len(),
            rep.unused_allows.len(),
        );
    }
    if !rep.clean() {
        bail!("{} un-allowlisted lint violation(s)", rep.violations.len());
    }
    Ok(())
}

fn write_result(out_dir: &str, name: &str, j: &Json) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let path = Path::new(out_dir).join(format!("{name}.json"));
    std::fs::write(&path, j.to_string())?;
    println!("[written {path:?}]");
    Ok(())
}

fn cmd_fig(args: &Args) -> Result<()> {
    let which = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .unwrap_or("all");
    let out = args.str_or("out", "results");
    let duration = args.f64_or("duration", 120.0)?;
    let seed = args.usize_or("seed", 7)? as u64;
    let soc = default_soc();

    let mut ran = false;
    let do_fig = |name: &str, j: Json| -> Result<()> { write_result(&out, name, &j) };
    if which == "affinity" || which == "all" {
        do_fig("fig_affinity", figures::fig_affinity(&soc))?;
        ran = true;
    }
    if which == "contention" || which == "all" {
        do_fig("fig_contention", figures::fig_contention(&soc))?;
        ran = true;
    }
    if which == "batching" || which == "all" {
        do_fig("fig_batching", figures::fig_batching(&soc))?;
        ran = true;
    }
    if which == "schemes" || which == "all" {
        do_fig("fig_schemes", figures::fig_schemes(&soc)?)?;
        ran = true;
    }
    if which == "proactive" || which == "all" {
        let rates = [0.25, 0.5, 1.0, 2.0, 3.0, 4.0];
        do_fig(
            "fig_proactive",
            figures::fig_proactive(&soc, &rates, duration, seed)?,
        )?;
        ran = true;
    }
    if which == "mixed" || which == "all" {
        let intervals = [6.0, 12.0, 24.0];
        let rates = [0.25, 0.5, 1.0, 2.0, 3.0];
        do_fig(
            "fig_mixed",
            figures::fig_mixed(&soc, &intervals, &rates, duration, seed)?,
        )?;
        ran = true;
    }
    if which == "flows" || which == "all" {
        do_fig("fig_flows", figures::fig_flows(&soc, duration, seed)?)?;
        ran = true;
    }
    if which == "workflows" || which == "all" {
        // --smoke: a short CI-sized run that still exercises every
        // engine family and the fan-out comparison
        let d = if args.bool_or("smoke", false) { 30.0 } else { duration };
        do_fig("fig_workflows", figures::fig_workflows(&soc, d, seed)?)?;
        ran = true;
    }
    if which == "elastic" || which == "all" {
        // --smoke: short run, still both scenarios (bare mixed trace +
        // 60 Hz display) across the elastic engine and every static
        // scheme
        let d = if args.bool_or("smoke", false) { 12.0 } else { duration.min(40.0) };
        do_fig("fig_elastic", figures::fig_elastic(&soc, d, seed)?)?;
        ran = true;
    }
    if which == "energy" || which == "all" {
        // --smoke: short run, still the full duty-cap × engine-family
        // sweep against the 60 Hz display workload
        let d = if args.bool_or("smoke", false) { 15.0 } else { duration };
        do_fig("fig_energy", figures::fig_energy(&soc, d, seed)?)?;
        ran = true;
    }
    if which == "overload" || which == "all" {
        // --smoke: two-point ramp (1x, 8x saturation) instead of the
        // full five-multiplier sweep; still governed vs un-governed on
        // every registry policy
        let d = if args.bool_or("smoke", false) { 12.0 } else { duration.min(30.0) };
        do_fig("fig_overload", figures::fig_overload(&soc, d, seed)?)?;
        ran = true;
    }
    if which == "fleet" || which == "all" {
        // --smoke: 2/4-device sweep at a short duration; still every
        // registered router across both arrival scenarios
        let d = if args.bool_or("smoke", false) { 10.0 } else { duration };
        do_fig("fig_fleet", figures::fig_fleet(&soc, d, seed)?)?;
        ran = true;
    }
    if which == "ablation" || which == "all" {
        do_fig("fig_ablation", figures::fig_ablation(&soc, duration, seed)?)?;
        ran = true;
    }
    if !ran {
        bail!("unknown figure {which:?}");
    }
    Ok(())
}

/// `agent-xpu bench macro [--smoke] [--seed 42] [--out results]` — the
/// DESIGN.md §8 perf-trajectory harness: full DES runs through every
/// registry policy at 10k/100k/1M synthetic requests (`--smoke`: 10k
/// only, the CI tier-1 gate), written as strict-JSON
/// `results/BENCH_sched.json`.
fn cmd_bench(args: &Args) -> Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("macro");
    let out = args.str_or("out", "results");
    let seed = args.usize_or("seed", 42)? as u64;
    let smoke = args.bool_or("smoke", false);
    match which {
        "macro" => {
            let j = agent_xpu::macrobench::bench_sched(seed, smoke)?;
            write_result(&out, "BENCH_sched", &j)
        }
        _ => bail!("unknown bench {which:?} (expected `macro`)"),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let rate = args.f64_or("rate", 1.5)?;
    let interval = args.f64_or("interval", 12.0)?;
    let duration = args.f64_or("duration", 60.0)?;
    let seed = args.usize_or("seed", 7)? as u64;
    let engine_name = args.str_or("engine", "agent.xpu");
    let geo = llama32_3b();
    let soc = default_soc();
    let trace = figures::mixed_trace(rate, interval, duration, seed, &geo);
    println!(
        "trace: {} requests over {duration}s (proactive {rate}/s, reactive interval {interval}s)",
        trace.len()
    );
    // Any registered policy (or alias) runs the same trace — the
    // registry replaces the old hardcoded constructor list.
    let mut engine =
        registry::build(&engine_name, geo, soc, SchedulerConfig::default())?;
    let rep = engine.run(trace)?;
    println!("{}", rep.to_json());
    let r = rep.class(Priority::Reactive);
    let p = rep.class(Priority::Proactive);
    println!(
        "\n{}: reactive norm-lat {:.1} ms/tok (ttft {:.0} ms), proactive {:.1} tok/s, \
         {:.2} J/tok, peak {:.1} W, npu util {:.0}%, igpu util {:.0}%",
        rep.engine,
        r.mean_norm_latency_ms,
        r.mean_ttft_ms,
        p.tokens_per_s,
        rep.joules_per_token(),
        rep.peak_power_w,
        rep.utilization("npu") * 100.0,
        rep.utilization("igpu") * 100.0,
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let synthetic = args.bool_or("synthetic", false);
    let artifacts = if synthetic {
        None
    } else {
        Some(args.get("artifacts").context(
            "--artifacts <dir> required (run `make artifacts` first), \
             or pass --synthetic to serve the calibrated cost model",
        )?)
    };
    let socket = args.str_or("socket", "/tmp/agent-xpu.sock");
    // Runtime config drives the serving loop: the server honors the
    // same SoC + scheduler knobs the simulated coordinator does, with
    // individual flag overrides on top.
    let (soc, mut sched) = match args.get("config") {
        Some(path) => {
            let cfg = RuntimeConfig::load(path)?;
            (cfg.soc, cfg.scheduler)
        }
        None => (default_soc(), SchedulerConfig::default()),
    };
    sched.b_max = args.usize_or("b-max", sched.b_max)?;
    sched.session_capacity =
        args.usize_or("session-capacity", sched.session_capacity)?;
    // --policy: serve any registered scheduling policy (default
    // agent-xpu) — the registry validates the name before artifacts
    // load so typos fail fast.
    let policy = args.str_or("policy", "agent-xpu");
    let policy = registry::canonical(&policy)?;
    // Overload / recovery knobs (DESIGN.md §7): bounded admission and
    // the optional write-ahead journal replayed on restart.
    let mut overload = OverloadConfig::default();
    overload.max_queue_depth =
        args.usize_or("max-queue-depth", overload.max_queue_depth)?;
    overload.max_live_flows =
        args.usize_or("max-live-flows", overload.max_live_flows)?;
    overload.reactive_ttft_slo_ms =
        args.f64_or("ttft-slo-ms", overload.reactive_ttft_slo_ms)?;
    let journal = args.get("journal").map(PathBuf::from);
    if let Some(p) = &journal {
        println!("write-ahead journal: {}", p.display());
    }
    let bridge = if let Some(artifacts) = artifacts {
        println!("loading artifacts from {artifacts} ...");
        let rt = Arc::new(Runtime::load(artifacts)?);
        println!(
            "model {} ({:.1}M params), {} artifacts compiled; policy {}, b_max {}, sessions {}",
            rt.geo.name,
            rt.geo.n_params() as f64 / 1e6,
            rt.manifest.artifacts.len(),
            policy,
            sched.b_max,
            sched.session_capacity,
        );
        Arc::new(ExecBridge::real(Arc::new(ModelExecutor::new(rt))))
    } else {
        // --synthetic: the calibrated cost model stands in for real
        // kernels — same scheduler, protocol, and journal machinery,
        // no artifacts needed (CI's crash-recovery smoke runs this).
        println!(
            "synthetic executor (calibrated cost model); policy {}, b_max {}, sessions {}",
            policy, sched.b_max, sched.session_capacity,
        );
        Arc::new(ExecBridge::synthetic(llama32_3b()))
    };
    Server::with_options(bridge, socket, soc, sched, policy, overload, journal)?.run()
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let artifacts = args.get("artifacts").context("--artifacts <dir> required")?;
    let rt = Runtime::load(artifacts)?;
    println!("config: {}", rt.geo.name);
    println!("  params:      {:.2}M", rt.geo.n_params() as f64 / 1e6);
    println!("  layers:      {}", rt.geo.n_layers);
    println!("  d_model:     {}", rt.geo.d_model);
    println!("  heads (q/kv):{}/{}", rt.geo.n_q_heads, rt.geo.n_kv_heads);
    println!("  max_seq:     {}", rt.geo.max_seq);
    println!("  chunks:      {:?}", rt.geo.chunk_sizes);
    println!("  batches:     {:?}", rt.geo.batch_sizes);
    let mut names: Vec<&String> = rt.manifest.artifacts.keys().collect();
    names.sort();
    println!("artifacts ({}):", names.len());
    for n in names {
        let a = &rt.manifest.artifacts[n];
        println!("  {n:<22} {:?} n={} args={}", a.kind, a.n, a.args.len());
    }
    Ok(())
}

fn cmd_soc_probe() -> Result<()> {
    let soc = default_soc();
    println!(
        "virtual SoC (paper testbed analog): DDR {:.1} GB/s, {} GB DRAM",
        soc.ddr_bw_gbps, soc.dram_gb
    );
    for x in &soc.xpus {
        println!(
            "  {:<5} {:>5.1} TOPS  gemm-eff {:.2}  attn-eff {:.2}  bw {:>4.0} GB/s  \
             launch {:>4.0} µs  dynamic {}  jit {:>4.1} ms  cap {:.2}  {:>4.1} W",
            x.name,
            x.peak_tflops,
            x.gemm_efficiency,
            x.attn_efficiency,
            x.max_bw_gbps,
            x.launch_overhead_us,
            x.supports_dynamic,
            x.jit_compile_ms,
            x.util_cap,
            x.active_power_w,
        );
    }
    figures::fig_affinity(&soc);
    Ok(())
}
