//! Kernel-level execution traces: who ran what, where, when.  Used by
//! the Fig. 4 scheme comparison (Gantt rendering), debugging, and the
//! scheduler's own introspection tests.

use crate::util::json::Json;

/// One executed kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub xpu: usize,
    pub start_us: f64,
    pub end_us: f64,
    pub label: String,
    pub reactive: bool,
}

/// An append-only execution trace.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn record(&mut self, xpu: usize, start_us: f64, end_us: f64, label: String, reactive: bool) {
        self.events.push(TraceEvent { xpu, start_us, end_us, label, reactive });
    }

    /// Events on one XPU, time-ordered.
    pub fn on_xpu(&self, xpu: usize) -> Vec<&TraceEvent> {
        let mut v: Vec<&TraceEvent> =
            self.events.iter().filter(|e| e.xpu == xpu).collect();
        v.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
        v
    }

    /// Verify the per-XPU serialization invariant: kernels on one XPU
    /// never overlap (the simulator's one-kernel-per-XPU contract).
    pub fn assert_serialized(&self) {
        let xpus: std::collections::BTreeSet<usize> =
            self.events.iter().map(|e| e.xpu).collect();
        for x in xpus {
            let evs = self.on_xpu(x);
            for w in evs.windows(2) {
                assert!(
                    w[1].start_us >= w[0].end_us - 1e-3,
                    "overlap on xpu {x}: {:?} then {:?}",
                    w[0],
                    w[1]
                );
            }
        }
    }

    /// Render an ASCII Gantt chart (one row per XPU) — the Fig. 4 view.
    pub fn gantt(&self, xpu_names: &[&str], width: usize) -> String {
        let t_end = self
            .events
            .iter()
            .map(|e| e.end_us)
            .fold(0.0f64, f64::max)
            .max(1.0);
        let mut out = String::new();
        for (x, name) in xpu_names.iter().enumerate() {
            let mut row = vec![' '; width];
            for e in self.on_xpu(x) {
                let a = ((e.start_us / t_end) * width as f64) as usize;
                let b = (((e.end_us / t_end) * width as f64) as usize).min(width);
                let ch = if e.reactive { 'R' } else { 'p' };
                for c in row.iter_mut().take(b).skip(a.min(width)) {
                    *c = ch;
                }
            }
            out.push_str(&format!("{name:>5} |{}|\n", row.iter().collect::<String>()));
        }
        out.push_str(&format!("       0 {:>w$.1} ms\n", t_end / 1e3, w = width - 2));
        out
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(
            self.events
                .iter()
                .map(|e| {
                    Json::obj()
                        .set("xpu", e.xpu)
                        .set("start_us", e.start_us)
                        .set("end_us", e.end_us)
                        .set("label", e.label.as_str())
                        .set("reactive", e.reactive)
                })
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_filters() {
        let mut t = Trace::default();
        t.record(0, 0.0, 10.0, "a".into(), false);
        t.record(1, 5.0, 15.0, "b".into(), true);
        t.record(0, 10.0, 20.0, "c".into(), false);
        assert_eq!(t.on_xpu(0).len(), 2);
        t.assert_serialized();
    }

    #[test]
    #[should_panic(expected = "overlap")]
    fn overlap_detected() {
        let mut t = Trace::default();
        t.record(0, 0.0, 10.0, "a".into(), false);
        t.record(0, 5.0, 15.0, "b".into(), false);
        t.assert_serialized();
    }

    #[test]
    fn gantt_renders() {
        let mut t = Trace::default();
        t.record(0, 0.0, 500.0, "p".into(), false);
        t.record(1, 500.0, 1000.0, "r".into(), true);
        let g = t.gantt(&["npu", "igpu"], 40);
        assert!(g.contains("npu"));
        assert!(g.contains('p'));
        assert!(g.contains('R'));
    }

    #[test]
    fn json_export() {
        let mut t = Trace::default();
        t.record(0, 0.0, 1.0, "k".into(), true);
        let j = t.to_json();
        assert_eq!(j.as_arr().unwrap().len(), 1);
    }
}
