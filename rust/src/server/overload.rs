//! Overload protection (DESIGN.md §7): admission control and
//! priority-aware load shedding for any [`EngineCore`].
//!
//! [`OverloadGate`] is the clock-agnostic bookkeeping both serving
//! paths share — the wall-clock UDS loop (`server::rt`) and the
//! virtual-clock harness ([`run_governed`], `fig overload`).  It
//! tracks the engine-live population split by priority and progress,
//! maps client flows (session tags or untagged singles) to a bounded
//! live-flow budget, keeps a sliding window of measured reactive TTFTs,
//! and answers two questions:
//!
//! - **admission** ([`OverloadGate::try_admit`]): admit, reject with
//!   `retry_after`, or — for a reactive arrival at a full queue —
//!   displace the newest queued proactive request instead;
//! - **detection** ([`OverloadGate::signal`]): the
//!   [`OverloadSignal`] handed to
//!   [`EngineCore::overload_response`], which every registry policy
//!   answers through its [`SchedPolicy::shed_level`] hook
//!   (pause proactive admissions → cancel queued proactive →
//!   preempt-and-park running proactive decodes).
//!
//! [`SchedPolicy::shed_level`]: crate::engine::SchedPolicy::shed_level

use std::collections::{BTreeSet, HashMap, VecDeque};

use anyhow::Result;

use crate::config::OverloadConfig;
use crate::engine::{EngineClock, EngineCore, EngineEvent, OverloadSignal, ShedLevel};
use crate::metrics::{RunReport, percentile};
use crate::workload::{Priority, ReqId, Request};

/// Reactive-TTFT observation window (µs): samples older than this no
/// longer drive the detector, so a cleared overload decays instead of
/// pinning the shed level forever.
const TTFT_WINDOW_US: f64 = 10e6;

/// Bound on retained TTFT samples (the p99 stays O(1) per pass).
const TTFT_SAMPLES_MAX: usize = 256;

/// Ids at/above this mark are parked-and-reinjected copies in the
/// virtual-clock harness; a copy parked *again* under sustained
/// overload is shed instead of cycling forever.
const PARK_ID_BASE: ReqId = 20_000_000;

/// The admission verdict for one arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionDecision {
    /// Admit the request.
    Admit,
    /// Queue full, but the arrival is reactive and this queued
    /// proactive request can make room: cancel it (it gets a
    /// `done.shed` frame), then admit the arrival.
    Displace(ReqId),
    /// Refuse the arrival (`retry_after` frame): queue full with no
    /// displaceable proactive work, live-flow budget exhausted, or
    /// proactive intake paused by the shedder.
    Reject,
}

/// Clock-agnostic admission + shedding bookkeeping.  Timestamps are
/// caller-supplied µs in whichever clock domain the engine runs.
pub struct OverloadGate {
    cfg: OverloadConfig,
    /// Engine-live requests (admitted, no terminal event yet).
    live: HashMap<ReqId, Priority>,
    /// Live proactive requests with no token emitted yet ("queued":
    /// cancelling one loses no generated work).
    waiting_proactive: BTreeSet<ReqId>,
    /// Live proactive requests past their first token ("running":
    /// shedding one is a preempt-and-park).
    running_proactive: BTreeSet<ReqId>,
    /// Request → live-flow key (session tag, or a per-id synthetic).
    flow_of: HashMap<ReqId, String>,
    /// Live-flow key → member count.
    flow_refs: HashMap<String, usize>,
    /// (at_us, ttft_ms) samples of completed reactive turns.
    ttft: VecDeque<(f64, f64)>,
    paused: bool,
}

impl OverloadGate {
    pub fn new(cfg: OverloadConfig) -> Self {
        Self {
            cfg,
            live: HashMap::new(),
            waiting_proactive: BTreeSet::new(),
            running_proactive: BTreeSet::new(),
            flow_of: HashMap::new(),
            flow_refs: HashMap::new(),
            ttft: VecDeque::new(),
            paused: false,
        }
    }

    pub fn cfg(&self) -> &OverloadConfig {
        &self.cfg
    }

    /// Engine-live request count — the detector's queue depth.
    pub fn live(&self) -> usize {
        self.live.len()
    }

    /// Distinct live flows.
    pub fn flows_live(&self) -> usize {
        self.flow_refs.len()
    }

    /// Proactive intake paused by the shedder?
    pub fn paused(&self) -> bool {
        self.paused
    }

    /// Shedder verdict → pause flag (level ≥ `PauseProactive`).
    pub fn set_paused(&mut self, paused: bool) {
        self.paused = paused;
    }

    fn flow_key(id: ReqId, session: Option<&str>) -> String {
        match session {
            Some(tag) => format!("tag:{tag}"),
            None => format!("#{id}"),
        }
    }

    /// Admission verdict for an arrival; pure — the caller applies it
    /// (cancel the displaced victim, then [`OverloadGate::admit`]).
    pub fn try_admit(&self, priority: Priority, session: Option<&str>) -> AdmissionDecision {
        // live-flow budget: only *new* flows consume it — a live
        // session's next turn always has a seat
        if self.cfg.max_live_flows > 0 {
            let new_flow = match session {
                Some(tag) => !self.flow_refs.contains_key(&format!("tag:{tag}")),
                None => true,
            };
            if new_flow && self.flow_refs.len() >= self.cfg.max_live_flows {
                return AdmissionDecision::Reject;
            }
        }
        if self.paused && priority == Priority::Proactive {
            return AdmissionDecision::Reject;
        }
        if self.cfg.max_queue_depth > 0 && self.live.len() >= self.cfg.max_queue_depth {
            if priority == Priority::Reactive {
                // newest queued proactive request dies first: it has
                // the least invested work
                if let Some(v) = self.waiting_proactive.last() {
                    return AdmissionDecision::Displace(*v);
                }
            }
            return AdmissionDecision::Reject;
        }
        AdmissionDecision::Admit
    }

    /// Record an admitted request.
    pub fn admit(&mut self, id: ReqId, priority: Priority, session: Option<&str>) {
        self.live.insert(id, priority);
        if priority == Priority::Proactive {
            self.waiting_proactive.insert(id);
        }
        let key = Self::flow_key(id, session);
        *self.flow_refs.entry(key.clone()).or_insert(0) += 1;
        self.flow_of.insert(id, key);
    }

    /// Take a queued-proactive victim out of the shed pool (its
    /// terminal event finishes the retirement).  Newest first.
    pub fn take_newest_waiting_proactive(&mut self) -> Option<ReqId> {
        self.waiting_proactive.pop_last()
    }

    /// Take a running-proactive park victim out of the pool.  Newest
    /// first — the least generated work is thrown away.
    pub fn take_newest_running_proactive(&mut self) -> Option<ReqId> {
        self.running_proactive.pop_last()
    }

    /// Remove a specific queued-proactive id (displacement victim).
    pub fn forget_waiting(&mut self, id: ReqId) {
        self.waiting_proactive.remove(&id);
    }

    fn retire(&mut self, id: ReqId) {
        self.live.remove(&id);
        self.waiting_proactive.remove(&id);
        self.running_proactive.remove(&id);
        if let Some(key) = self.flow_of.remove(&id) {
            if let Some(n) = self.flow_refs.get_mut(&key) {
                *n -= 1;
                if *n == 0 {
                    self.flow_refs.remove(&key);
                }
            }
        }
    }

    /// Fold one engine event into the gate's bookkeeping.
    pub fn on_event(&mut self, ev: &EngineEvent) {
        match ev {
            EngineEvent::TokenEmitted { id, .. } => {
                if self.waiting_proactive.remove(id) {
                    self.running_proactive.insert(*id);
                }
            }
            EngineEvent::TurnDone { id, at_us, arrival_us, first_token_us, .. } => {
                if self.live.get(id) == Some(&Priority::Reactive) {
                    self.note_reactive_ttft(*at_us, (first_token_us - arrival_us) / 1e3);
                }
                self.retire(*id);
            }
            EngineEvent::Cancelled { id, .. } => self.retire(*id),
            EngineEvent::Admitted { .. }
            | EngineEvent::Preempted { .. }
            | EngineEvent::Rebound { .. }
            | EngineEvent::KvEvicted { .. }
            | EngineEvent::SessionEvicted { .. } => {}
        }
    }

    /// Record one measured reactive TTFT (ms) at `at_us`.
    pub fn note_reactive_ttft(&mut self, at_us: f64, ttft_ms: f64) {
        self.ttft.push_back((at_us, ttft_ms));
        while self.ttft.len() > TTFT_SAMPLES_MAX {
            self.ttft.pop_front();
        }
    }

    /// What the detector measures right now.
    pub fn signal(&mut self, now_us: f64) -> OverloadSignal {
        while self.ttft.front().map(|(t, _)| *t < now_us - TTFT_WINDOW_US).unwrap_or(false)
        {
            self.ttft.pop_front();
        }
        let p99 = if self.ttft.is_empty() {
            f64::NAN
        } else {
            let mut xs: Vec<f64> = self.ttft.iter().map(|(_, v)| *v).collect();
            xs.sort_by(f64::total_cmp);
            percentile(&xs, 0.99)
        };
        OverloadSignal {
            queue_depth: self.live.len(),
            max_queue_depth: self.cfg.max_queue_depth,
            reactive_ttft_p99_ms: p99,
            reactive_ttft_slo_ms: self.cfg.reactive_ttft_slo_ms,
        }
    }
}

/// What one governed virtual-clock run did (the `fig overload`
/// harness): the engine's report plus the gate's shed ledger.
#[derive(Debug)]
pub struct GovernedOutcome {
    pub report: RunReport,
    pub submitted_reactive: usize,
    pub submitted_proactive: usize,
    pub rejected_reactive: usize,
    pub rejected_proactive: usize,
    /// Queued proactive requests displaced by reactive arrivals.
    pub displaced: usize,
    /// Queued proactive requests cancelled by the shedder
    /// (displacements included).
    pub shed: usize,
    /// Running proactive decodes preempted-and-parked (reinjected
    /// `retry_after` later; parked again under sustained overload =
    /// shed).
    pub parked: usize,
}

/// Drive a virtual-clock engine through `trace` with admission control
/// and priority-aware load shedding in the loop — the governed
/// counterpart of the un-governed `EngineCore::run(trace)` baseline.
///
/// Arrivals are submitted as virtual time passes them, each through
/// [`OverloadGate::try_admit`]; every pass recomputes the
/// [`OverloadSignal`] and applies the policy's shed level gradually
/// (at most one queued cancel + one park per pass, so degradation is a
/// slope, not a cliff).  Parked decodes are reinjected
/// `retry_after_ms` later as fresh submissions (cache-cold, new id) —
/// parked once more under sustained overload they are shed for good.
pub fn run_governed(
    core: &mut dyn EngineCore,
    mut trace: Vec<Request>,
    cfg: &OverloadConfig,
) -> Result<GovernedOutcome> {
    trace.sort_by(|a, b| a.arrival_us.total_cmp(&b.arrival_us).then(a.id.cmp(&b.id)));
    let mut pending: VecDeque<Request> = trace.into();
    let mut reinject: VecDeque<Request> = VecDeque::new();
    let mut gate = OverloadGate::new(cfg.clone());
    // proactive single-shot originals retained for park-and-reinject
    let mut originals: HashMap<ReqId, Request> = HashMap::new();
    let (mut submitted_reactive, mut submitted_proactive) = (0usize, 0usize);
    let (mut rejected_reactive, mut rejected_proactive) = (0usize, 0usize);
    let (mut displaced, mut shed, mut parked) = (0usize, 0usize, 0usize);
    let mut next_park_id = PARK_ID_BASE;
    let mut now = 0.0f64;

    core.start(EngineClock::Virtual)?;
    loop {
        // Admit every arrival virtual time has passed, oldest first
        // across the trace and the reinjection queue.
        loop {
            let from_trace = pending.front().map(|r| r.arrival_us);
            let from_park = reinject.front().map(|r| r.arrival_us);
            let due = match (from_trace, from_park) {
                (Some(a), Some(b)) => {
                    if a.min(b) > now {
                        break;
                    }
                    a <= b
                }
                (Some(a), None) if a <= now => true,
                (None, Some(b)) if b <= now => false,
                _ => break,
            };
            let req =
                if due { pending.pop_front().unwrap() } else { reinject.pop_front().unwrap() };
            match gate.try_admit(req.priority, None) {
                AdmissionDecision::Admit => {}
                AdmissionDecision::Displace(victim) => {
                    gate.forget_waiting(victim);
                    originals.remove(&victim);
                    core.cancel(victim)?;
                    displaced += 1;
                    shed += 1;
                }
                AdmissionDecision::Reject => {
                    match req.priority {
                        Priority::Reactive => rejected_reactive += 1,
                        Priority::Proactive => rejected_proactive += 1,
                    }
                    continue;
                }
            }
            match req.priority {
                Priority::Reactive => submitted_reactive += 1,
                Priority::Proactive => submitted_proactive += 1,
            }
            gate.admit(req.id, req.priority, None);
            if req.priority == Priority::Proactive && req.flow.is_none() {
                originals.insert(req.id, req.clone());
            }
            core.submit(req)?;
        }

        if !core.has_work() {
            let next = match (
                pending.front().map(|r| r.arrival_us),
                reinject.front().map(|r| r.arrival_us),
            ) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (Some(a), None) => Some(a),
                (None, Some(b)) => Some(b),
                (None, None) => None,
            };
            match next {
                Some(t) => {
                    now = now.max(t);
                    continue;
                }
                None => break,
            }
        }

        for ev in core.step()? {
            now = now.max(event_at_us(&ev));
            gate.on_event(&ev);
        }

        // One detector pass: pause / cancel one queued / park one
        // running — gradual by construction.
        let sig = gate.signal(now);
        let level = core.overload_response(&sig);
        gate.set_paused(level >= ShedLevel::PauseProactive);
        if level >= ShedLevel::CancelQueuedProactive {
            if let Some(v) = gate.take_newest_waiting_proactive() {
                originals.remove(&v);
                core.cancel(v)?;
                shed += 1;
            }
        }
        if level >= ShedLevel::ParkRunningProactive {
            if let Some(v) = gate.take_newest_running_proactive() {
                core.cancel(v)?;
                match originals.remove(&v) {
                    Some(orig) if v < PARK_ID_BASE => {
                        parked += 1;
                        let mut copy = orig;
                        copy.id = next_park_id;
                        next_park_id += 1;
                        copy.arrival_us = now + cfg.retry_after_ms * 1e3;
                        reinject.push_back(copy);
                    }
                    // a re-parked copy (or a flow turn) is shed for
                    // good: sustained overload must terminate
                    _ => shed += 1,
                }
            }
        }
    }
    Ok(GovernedOutcome {
        report: core.finish()?,
        submitted_reactive,
        submitted_proactive,
        rejected_reactive,
        rejected_proactive,
        displaced,
        shed,
        parked,
    })
}

fn event_at_us(ev: &EngineEvent) -> f64 {
    match ev {
        EngineEvent::Admitted { at_us, .. }
        | EngineEvent::TokenEmitted { at_us, .. }
        | EngineEvent::TurnDone { at_us, .. }
        | EngineEvent::Preempted { at_us, .. }
        | EngineEvent::Rebound { at_us, .. }
        | EngineEvent::KvEvicted { at_us, .. }
        | EngineEvent::SessionEvicted { at_us, .. }
        | EngineEvent::Cancelled { at_us, .. } => *at_us,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(depth: usize, flows: usize) -> OverloadConfig {
        OverloadConfig {
            max_queue_depth: depth,
            max_live_flows: flows,
            reactive_ttft_slo_ms: 0.0,
            slo_multiple: 4.0,
            retry_after_ms: 100.0,
            fsync_every: 1,
        }
    }

    #[test]
    fn queue_full_rejects_proactive_and_displaces_for_reactive() {
        let mut g = OverloadGate::new(cfg(2, 0));
        assert_eq!(g.try_admit(Priority::Proactive, None), AdmissionDecision::Admit);
        g.admit(1, Priority::Proactive, None);
        g.admit(2, Priority::Proactive, None);
        assert_eq!(g.try_admit(Priority::Proactive, None), AdmissionDecision::Reject);
        // reactive displaces the NEWEST queued proactive
        assert_eq!(
            g.try_admit(Priority::Reactive, None),
            AdmissionDecision::Displace(2)
        );
        // both proactive running (tokens out): nothing to displace
        g.on_event(&EngineEvent::TokenEmitted { id: 1, token: 0, n: 1, at_us: 1.0 });
        g.on_event(&EngineEvent::TokenEmitted { id: 2, token: 0, n: 1, at_us: 1.0 });
        assert_eq!(g.try_admit(Priority::Reactive, None), AdmissionDecision::Reject);
    }

    #[test]
    fn live_flow_budget_counts_sessions_once() {
        let mut g = OverloadGate::new(cfg(0, 2));
        g.admit(1, Priority::Reactive, Some("a"));
        g.admit(2, Priority::Reactive, Some("b"));
        // a live session's next turn is not a new flow
        assert_eq!(g.try_admit(Priority::Reactive, Some("a")), AdmissionDecision::Admit);
        // but a third flow is over budget
        assert_eq!(g.try_admit(Priority::Reactive, Some("c")), AdmissionDecision::Reject);
        assert_eq!(g.try_admit(Priority::Reactive, None), AdmissionDecision::Reject);
        // flows retire with their last member
        g.on_event(&EngineEvent::Cancelled { id: 2, at_us: 1.0 });
        assert_eq!(g.flows_live(), 1);
        assert_eq!(g.try_admit(Priority::Reactive, Some("c")), AdmissionDecision::Admit);
    }

    #[test]
    fn paused_gate_rejects_only_proactive() {
        let mut g = OverloadGate::new(cfg(8, 0));
        g.set_paused(true);
        assert_eq!(g.try_admit(Priority::Proactive, None), AdmissionDecision::Reject);
        assert_eq!(g.try_admit(Priority::Reactive, None), AdmissionDecision::Admit);
    }

    #[test]
    fn ttft_window_decays_so_shedding_can_clear() {
        let mut g = OverloadGate::new(OverloadConfig {
            reactive_ttft_slo_ms: 100.0,
            ..cfg(0, 0)
        });
        g.note_reactive_ttft(1.0, 500.0);
        let s = g.signal(2.0);
        assert!((s.reactive_ttft_p99_ms - 500.0).abs() < 1e-6);
        // 10 s later the sample has aged out: p99 undefined again
        let s = g.signal(2.0 + TTFT_WINDOW_US + 1.0);
        assert!(s.reactive_ttft_p99_ms.is_nan());
    }

    #[test]
    fn park_pool_tracks_first_token_progress() {
        let mut g = OverloadGate::new(cfg(0, 0));
        g.admit(1, Priority::Proactive, None);
        g.admit(2, Priority::Proactive, None);
        assert_eq!(g.take_newest_running_proactive(), None);
        g.on_event(&EngineEvent::TokenEmitted { id: 1, token: 7, n: 1, at_us: 1.0 });
        assert_eq!(g.take_newest_running_proactive(), Some(1));
        assert_eq!(g.take_newest_waiting_proactive(), Some(2));
        assert_eq!(g.take_newest_waiting_proactive(), None);
    }
}
