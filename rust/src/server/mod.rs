//! The serving frontend (paper §7): a JSON-lines protocol over Unix
//! Domain Sockets, backed by a *real-time* miniature of the XPU
//! coordinator running real PJRT compute.
//!
//! Wire protocol (one JSON object per line):
//!
//! ```text
//! → {"type":"generate","priority":"reactive","prompt":[1,2,3],"max_new_tokens":8}
//! ← {"type":"accepted","id":1}
//! ← {"type":"token","id":1,"token":42,"n":1}
//! ← ...
//! ← {"type":"done","id":1,"ttft_ms":12.3,"total_ms":80.1,"tokens":[...]}
//! → {"type":"stats"}
//! ← {"type":"stats","served":3,"queued_reactive":0,"queued_proactive":1}
//! ```

mod rt;
mod uds;

pub use rt::{RtRequest, RtScheduler, TokenEvent, spawn};
pub use uds::{Server, client_generate};
