//! The serving frontend (paper §7): a JSON-lines protocol over Unix
//! Domain Sockets, backed by the *same* engine cores the DES figure
//! harnesses run (any policy from `engine::registry` behind the
//! clock-abstracted `EngineCore` API, DESIGN.md §7) executing against
//! wall-clock time.  `agent-xpu serve --policy <name>` selects the
//! scheduler — `agent-xpu` (default), `deadline`, or any baseline —
//! without changing a byte of the wire protocol below.
//!
//! Wire protocol (one JSON object per line):
//!
//! ```text
//! → {"type":"generate","priority":"reactive","prompt":[1,2,3],"max_new_tokens":8}
//! ← {"type":"accepted","id":1}
//! ← {"type":"token","id":1,"token":42,"n":1}
//! ← ...
//! ← {"type":"done","id":1,"ttft_ms":12.3,"total_ms":80.1,"cached_prefix":0,"tokens":[...]}
//! → {"type":"cancel","id":2}
//! ← {"type":"cancel.ack","id":2}
//! ← {"type":"done.cancelled","id":2}
//! → {"type":"stats"}
//! ← {"type":"stats","served":3,"cancelled":1,"tokens":24,"reused_prefix_tokens":35,
//!    "preemptions":0,"rejected":0,"displaced":0,"shed":0,"parked":0,"resumed":0,
//!    "recovered":0,"mean_ttft_ms":1.9}
//! ```
//!
//! Overload frames (DESIGN.md §7): a submission refused at admission
//! (bounded queue full, live-flow budget exhausted, or proactive
//! intake paused by the shedder) ends immediately with
//! `{"type":"retry_after","id":N,"code":"overloaded","retry_after_ms":X}`;
//! a queued proactive generation shed — or displaced by a reactive
//! arrival at a full queue — ends with
//! `{"type":"done.shed","id":N,"retry_after_ms":X}`.  `error` frames
//! carry a structured `code` (`bad_request`, `unknown_id`,
//! `unknown_verb`) beside the human-readable `message`.
//!
//! Connections are full-duplex: `generate` frames stream from a writer
//! thread while the reader keeps accepting lines, so `cancel` (and
//! further `generate`s) work on the same connection.  `cancel` aborts
//! an in-flight generation wherever it is — queued, mid-prefill (the
//! kernel is aborted), or decoding (the lane retires at the iteration
//! boundary) — frees its KV, and ends the stream with a terminal
//! `done.cancelled` frame.  A connection may only cancel ids it issued
//! itself; foreign ids get an `error` frame with code `unknown_id`.
//!
//! The optional `"session":"<tag>"` field on `generate` keeps the KV
//! cache alive across calls (flow-level sessions, DESIGN.md §3): a
//! later call whose prompt extends the tagged conversation prefills
//! only the delta tokens, and `done.cached_prefix` reports how many
//! prompt tokens the retained KV covered.  Retention is bounded by
//! `SchedulerConfig::session_capacity` and shed LRU-first under memory
//! pressure — the same policy the simulated coordinator applies.
//!
//! The optional `"deps":[<id>, ...]` field (requires `session`) makes a
//! call a node of a workflow *DAG* (DESIGN.md §3): the engine holds it
//! until every referenced generation of the same session has finished,
//! so clients can fan out parallel subtasks and submit the join up
//! front.  Unknown or forgotten ids are ignored; without `deps`, calls
//! of a session form the implicit linear chain (each waits for the
//! previous one).
//!
//! Overload safety and crash recovery live in [`overload`] (the
//! admission gate + shed-level machinery shared by the wall-clock
//! server and the `fig overload` harness) and [`journal`] (the
//! write-ahead journal replayed on restart).  The serving invariant:
//! **no admitted turn is silently dropped** — it completes, cancels,
//! sheds with a frame, or survives restart.

pub mod journal;
mod overload;
mod rt;
mod uds;

pub use overload::{AdmissionDecision, GovernedOutcome, OverloadGate, run_governed};
pub use rt::{
    RtMsg, RtRequest, RtScheduler, TokenEvent, spawn, spawn_full, spawn_with_policy,
};
pub use uds::{GenerateResult, Server, client_generate, client_generate_session};
