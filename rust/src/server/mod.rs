//! The serving frontend (paper §7): a JSON-lines protocol over Unix
//! Domain Sockets, backed by a *real-time* miniature of the XPU
//! coordinator running real PJRT compute.
//!
//! Wire protocol (one JSON object per line):
//!
//! ```text
//! → {"type":"generate","priority":"reactive","prompt":[1,2,3],"max_new_tokens":8}
//! ← {"type":"accepted","id":1}
//! ← {"type":"token","id":1,"token":42,"n":1}
//! ← ...
//! ← {"type":"done","id":1,"ttft_ms":12.3,"total_ms":80.1,"cached_prefix":0,"tokens":[...]}
//! → {"type":"stats"}
//! ← {"type":"stats","served":3}
//! ```
//!
//! The optional `"session":"<tag>"` field on `generate` keeps the KV
//! cache alive across calls (flow-level sessions, DESIGN.md §3): a
//! later call whose prompt extends the tagged conversation prefills
//! only the delta tokens, and `done.cached_prefix` reports how many
//! prompt tokens the retained KV covered.

mod rt;
mod uds;

pub use rt::{RtRequest, RtScheduler, TokenEvent, spawn};
pub use uds::{GenerateResult, Server, client_generate, client_generate_session};
