//! Write-ahead journal for the serving loop (DESIGN.md §7): every
//! admitted turn is durable *before* its `accepted` frame, so a server
//! killed mid-flow restarts with no lost and no duplicated turns.
//!
//! On-disk format — append-only, length-prefixed, checksummed records:
//!
//! ```text
//! [u32 len LE][u32 crc32(payload) LE][payload: one JSON object]
//! ```
//!
//! Record kinds (the `t` field):
//!
//! - `submit` — an admitted generation (id, priority, prompt,
//!   max_new_tokens, session tag, deps).  Written before the client's
//!   `accepted` frame.
//! - `done` / `cancelled` / `shed` — terminal outcomes; a submit with
//!   no terminal is *pending* and is resubmitted on restart.
//! - `bind` — a session tag's registry state (flow id, call count,
//!   generation-id → turn-index map), written after each tagged submit
//!   so cross-turn KV bookkeeping survives a restart even after its
//!   completed submits compact away.
//!
//! Durability is group-commit: `append` fsyncs every
//! `fsync_every` records, and the serving loop calls [`Journal::sync`]
//! once per intake batch before acking any of it.  Replay
//! ([`Journal::open`]) tolerates a torn tail — a crash mid-append
//! truncates to the last whole, checksum-valid record; every record
//! before it replays.  Opening also compacts: terminally-resolved
//! submits are dropped and the file is rewritten as the latest binds
//! plus the pending submits (temp file + atomic rename).

use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result, bail};

use crate::util::json::Json;
use crate::workload::Priority;

/// Cap on a single record's payload; a longer length prefix means the
/// tail is garbage (torn or corrupt), not a real record.
const MAX_RECORD_LEN: u32 = 16 * 1024 * 1024;

/// CRC-32 (IEEE 802.3, reflected) — bitwise, no lookup table; journal
/// volumes are far too small for this to matter.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// One journaled submission — everything needed to resubmit the turn
/// after a restart (the KV is gone, so it re-prefills cache-cold).
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitRec {
    pub id: u64,
    pub priority: Priority,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub session: Option<String>,
    pub deps: Vec<u64>,
}

/// One session tag's registry state (`server::rt::SessionRegistry`).
#[derive(Debug, Clone, PartialEq)]
pub struct BindRec {
    pub tag: String,
    pub flow_id: u64,
    pub calls: usize,
    /// generation id → turn index within the flow.
    pub turn_of: Vec<(u64, usize)>,
}

/// One journal record.
#[derive(Debug, Clone, PartialEq)]
pub enum Record {
    Submit(SubmitRec),
    Done { id: u64 },
    Cancelled { id: u64 },
    Shed { id: u64 },
    Bind(BindRec),
}

impl Record {
    fn to_json(&self) -> Json {
        match self {
            Record::Submit(s) => {
                let mut j = Json::obj()
                    .set("t", "submit")
                    .set("id", s.id as usize)
                    .set("priority", s.priority.label())
                    .set("prompt", s.prompt.clone())
                    .set("max_new_tokens", s.max_new_tokens)
                    .set(
                        "deps",
                        s.deps.iter().map(|d| *d as usize).collect::<Vec<usize>>(),
                    );
                if let Some(tag) = &s.session {
                    j = j.set("session", tag.as_str());
                }
                j
            }
            Record::Done { id } => Json::obj().set("t", "done").set("id", *id as usize),
            Record::Cancelled { id } => {
                Json::obj().set("t", "cancelled").set("id", *id as usize)
            }
            Record::Shed { id } => Json::obj().set("t", "shed").set("id", *id as usize),
            Record::Bind(b) => Json::obj()
                .set("t", "bind")
                .set("tag", b.tag.as_str())
                .set("flow_id", b.flow_id as usize)
                .set("calls", b.calls)
                .set(
                    "turn_of",
                    Json::Arr(
                        b.turn_of
                            .iter()
                            .map(|(id, idx)| {
                                Json::Arr(vec![
                                    Json::Num(*id as f64),
                                    Json::Num(*idx as f64),
                                ])
                            })
                            .collect(),
                    ),
                ),
        }
    }

    fn from_json(v: &Json) -> Result<Record> {
        Ok(match v.get("t")?.as_str()? {
            "submit" => Record::Submit(SubmitRec {
                id: v.get("id")?.as_usize()? as u64,
                priority: match v.get("priority")?.as_str()? {
                    "proactive" => Priority::Proactive,
                    _ => Priority::Reactive,
                },
                prompt: v.get("prompt")?.as_i32_vec()?,
                max_new_tokens: v.get("max_new_tokens")?.as_usize()?,
                session: v
                    .opt("session")
                    .and_then(|s| s.as_str().ok())
                    .map(|s| s.to_string()),
                deps: v
                    .get("deps")?
                    .as_usize_vec()?
                    .into_iter()
                    .map(|d| d as u64)
                    .collect(),
            }),
            "done" => Record::Done { id: v.get("id")?.as_usize()? as u64 },
            "cancelled" => Record::Cancelled { id: v.get("id")?.as_usize()? as u64 },
            "shed" => Record::Shed { id: v.get("id")?.as_usize()? as u64 },
            "bind" => Record::Bind(BindRec {
                tag: v.get("tag")?.as_str()?.to_string(),
                flow_id: v.get("flow_id")?.as_usize()? as u64,
                calls: v.get("calls")?.as_usize()?,
                turn_of: v
                    .get("turn_of")?
                    .as_arr()?
                    .iter()
                    .map(|pair| {
                        let p = pair.as_arr()?;
                        if p.len() != 2 {
                            bail!("turn_of pair must have 2 elements");
                        }
                        Ok((p[0].as_usize()? as u64, p[1].as_usize()?))
                    })
                    .collect::<Result<Vec<_>>>()?,
            }),
            other => bail!("unknown journal record type {other:?}"),
        })
    }
}

/// Frame one record: `[len][crc][payload]`.
pub fn encode_record(rec: &Record) -> Vec<u8> {
    let payload = rec.to_json().to_string().into_bytes();
    let mut out = Vec::with_capacity(payload.len() + 8);
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&crc32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

/// Decode every whole, checksum-valid record from the head of `bytes`.
/// Returns the records and whether a torn/corrupt tail was dropped
/// (the decode stops there — everything after an invalid record is
/// unreachable by construction).
pub fn decode_records(bytes: &[u8]) -> (Vec<Record>, bool) {
    let mut out = vec![];
    let mut i = 0usize;
    while bytes.len() - i >= 8 {
        let len = u32::from_le_bytes([bytes[i], bytes[i + 1], bytes[i + 2], bytes[i + 3]]);
        let crc =
            u32::from_le_bytes([bytes[i + 4], bytes[i + 5], bytes[i + 6], bytes[i + 7]]);
        if len > MAX_RECORD_LEN {
            return (out, true);
        }
        let start = i + 8;
        let end = start + len as usize;
        if end > bytes.len() {
            return (out, true); // torn final record
        }
        let payload = &bytes[start..end];
        if crc32(payload) != crc {
            return (out, true); // corrupt record: stop at the last good one
        }
        let rec = match std::str::from_utf8(payload)
            .ok()
            .and_then(|s| Json::parse(s).ok())
            .and_then(|j| Record::from_json(&j).ok())
        {
            Some(r) => r,
            None => return (out, true),
        };
        out.push(rec);
        i = end;
    }
    (out, i < bytes.len())
}

/// The state a journal replays to: what a restarted server must
/// resubmit and how to rebuild its session registry.
#[derive(Debug, Default, Clone)]
pub struct Replay {
    /// Admitted submissions with no terminal record, in submit order.
    pub pending: Vec<SubmitRec>,
    /// Latest bind per session tag, in first-bind order.
    pub bindings: Vec<BindRec>,
    /// Highest generation id ever journaled (0 = none); the server's
    /// id counter restarts *above* this so ids never repeat.
    pub max_req_id: u64,
    /// One past the highest bound flow id (registry `next` floor).
    pub next_flow_id: u64,
    /// A torn or corrupt tail was dropped during decode.
    pub truncated: bool,
}

/// Pure fold: records → replay state.  Exposed so the crash property
/// test can replay arbitrary journal prefixes without touching disk.
pub fn replay_records(records: &[Record], truncated: bool) -> Replay {
    let mut pending: BTreeMap<u64, SubmitRec> = BTreeMap::new();
    let mut bind_order: Vec<String> = vec![];
    let mut binds: BTreeMap<String, BindRec> = BTreeMap::new();
    let mut max_req_id = 0u64;
    let mut next_flow_id = 0u64;
    for rec in records {
        match rec {
            Record::Submit(s) => {
                max_req_id = max_req_id.max(s.id);
                pending.insert(s.id, s.clone());
            }
            Record::Done { id } | Record::Cancelled { id } | Record::Shed { id } => {
                max_req_id = max_req_id.max(*id);
                pending.remove(id);
            }
            Record::Bind(b) => {
                next_flow_id = next_flow_id.max(b.flow_id + 1);
                if !binds.contains_key(&b.tag) {
                    bind_order.push(b.tag.clone());
                }
                binds.insert(b.tag.clone(), b.clone());
            }
        }
    }
    Replay {
        // BTreeMap iteration is id order == submit order (ids ascend)
        pending: pending.into_values().collect(),
        bindings: bind_order
            .into_iter()
            .filter_map(|t| binds.remove(&t))
            .collect(),
        max_req_id,
        next_flow_id,
        truncated,
    }
}

/// An open, append-mode write-ahead journal.
pub struct Journal {
    file: File,
    path: PathBuf,
    fsync_every: usize,
    unsynced: usize,
}

impl Journal {
    /// Open (or create) the journal at `path`: replay what is there,
    /// compact it (latest binds + pending submits only, torn tail
    /// dropped), and return the journal ready for appends plus the
    /// replayed state.
    pub fn open(path: impl AsRef<Path>, fsync_every: usize) -> Result<(Journal, Replay)> {
        let path = path.as_ref().to_path_buf();
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => vec![],
            Err(e) => return Err(e).with_context(|| format!("reading journal {path:?}")),
        };
        let (records, truncated) = decode_records(&bytes);
        let replay = replay_records(&records, truncated);
        // Compact: rewrite as binds + pending (drops resolved submits
        // and the torn tail) via temp + rename, so a crash during
        // compaction leaves either the old or the new file whole.
        let kept = replay.bindings.len() + replay.pending.len();
        if truncated || records.len() != kept {
            let tmp = path.with_extension("journal.tmp");
            {
                let mut f = File::create(&tmp)
                    .with_context(|| format!("creating {tmp:?}"))?;
                for b in &replay.bindings {
                    f.write_all(&encode_record(&Record::Bind(b.clone())))?;
                }
                for s in &replay.pending {
                    f.write_all(&encode_record(&Record::Submit(s.clone())))?;
                }
                f.sync_all()?;
            }
            std::fs::rename(&tmp, &path)
                .with_context(|| format!("replacing journal {path:?}"))?;
        }
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening journal {path:?}"))?;
        Ok((
            Journal { file, path, fsync_every: fsync_every.max(1), unsynced: 0 },
            replay,
        ))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record; fsyncs when the group-commit quota fills.
    pub fn append(&mut self, rec: &Record) -> Result<()> {
        self.file.write_all(&encode_record(rec))?;
        self.unsynced += 1;
        if self.unsynced >= self.fsync_every {
            self.sync()?;
        }
        Ok(())
    }

    /// Force the group-commit barrier: everything appended so far is
    /// durable when this returns.
    pub fn sync(&mut self) -> Result<()> {
        if self.unsynced > 0 {
            self.file.sync_all()?;
            self.unsynced = 0;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sub(id: u64, session: Option<&str>) -> SubmitRec {
        SubmitRec {
            id,
            priority: if id % 2 == 0 { Priority::Proactive } else { Priority::Reactive },
            prompt: vec![1, 2, 3, id as i32],
            max_new_tokens: 4 + id as usize,
            session: session.map(|s| s.to_string()),
            deps: if id > 2 { vec![id - 1] } else { vec![] },
        }
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("agent-xpu-journal-{name}-{}.wal", std::process::id()))
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // IEEE 802.3 check value for "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn records_roundtrip_through_the_frame() {
        let recs = vec![
            Record::Submit(sub(1, Some("chat"))),
            Record::Bind(BindRec {
                tag: "chat".into(),
                flow_id: 7,
                calls: 2,
                turn_of: vec![(1, 0), (4, 1)],
            }),
            Record::Done { id: 1 },
            Record::Submit(sub(2, None)),
            Record::Cancelled { id: 2 },
            Record::Shed { id: 3 },
        ];
        let mut bytes = vec![];
        for r in &recs {
            bytes.extend(encode_record(r));
        }
        let (back, truncated) = decode_records(&bytes);
        assert!(!truncated);
        assert_eq!(back, recs);
    }

    #[test]
    fn torn_tail_is_dropped_not_fatal() {
        let mut bytes = encode_record(&Record::Submit(sub(1, None)));
        let whole = bytes.len();
        bytes.extend(encode_record(&Record::Done { id: 1 }));
        // crash mid-append: cut the second record anywhere
        for cut in whole..bytes.len() {
            let (recs, truncated) = decode_records(&bytes[..cut]);
            assert_eq!(recs.len(), 1, "cut at {cut}");
            assert!(truncated == (cut != whole), "cut at {cut}");
        }
        // corrupt (bit-flipped) payload is also a clean stop
        let mut flipped = bytes.clone();
        let n = flipped.len();
        flipped[n - 1] ^= 0x40;
        let (recs, truncated) = decode_records(&flipped);
        assert_eq!(recs.len(), 1);
        assert!(truncated);
    }

    #[test]
    fn replay_resolves_terminals_and_keeps_latest_bind() {
        let recs = vec![
            Record::Submit(sub(1, Some("s"))),
            Record::Bind(BindRec {
                tag: "s".into(),
                flow_id: 0,
                calls: 1,
                turn_of: vec![(1, 0)],
            }),
            Record::Submit(sub(2, None)),
            Record::Done { id: 1 },
            Record::Submit(sub(3, Some("s"))),
            Record::Bind(BindRec {
                tag: "s".into(),
                flow_id: 0,
                calls: 2,
                turn_of: vec![(1, 0), (3, 1)],
            }),
            Record::Shed { id: 2 },
        ];
        let r = replay_records(&recs, false);
        assert_eq!(r.pending.len(), 1);
        assert_eq!(r.pending[0].id, 3);
        assert_eq!(r.bindings.len(), 1);
        assert_eq!(r.bindings[0].calls, 2, "latest bind wins");
        assert_eq!(r.max_req_id, 3);
        assert_eq!(r.next_flow_id, 1);
    }

    #[test]
    fn open_compacts_and_preserves_pending() {
        let path = tmp("compact");
        let _ = std::fs::remove_file(&path);
        {
            let (mut j, r) = Journal::open(&path, 1).unwrap();
            assert_eq!(r.pending.len(), 0);
            j.append(&Record::Submit(sub(1, Some("s")))).unwrap();
            j.append(&Record::Bind(BindRec {
                tag: "s".into(),
                flow_id: 3,
                calls: 1,
                turn_of: vec![(1, 0)],
            }))
            .unwrap();
            j.append(&Record::Done { id: 1 }).unwrap();
            j.append(&Record::Submit(sub(2, None))).unwrap();
            j.sync().unwrap();
        }
        let before = std::fs::metadata(&path).unwrap().len();
        let (_, r) = Journal::open(&path, 8).unwrap();
        assert_eq!(r.pending.len(), 1, "done submit compacted away");
        assert_eq!(r.pending[0].id, 2);
        assert_eq!(r.bindings.len(), 1);
        assert_eq!(r.max_req_id, 2);
        assert_eq!(r.next_flow_id, 4);
        let after = std::fs::metadata(&path).unwrap().len();
        assert!(after < before, "compaction must shrink the file");
        // reopening the compacted file replays identically
        let (_, r2) = Journal::open(&path, 8).unwrap();
        assert_eq!(r2.pending.len(), 1);
        assert_eq!(r2.bindings.len(), 1);
        // max_req_id shrinks to what compaction retained — callers
        // must not rely on it spanning compacted-away ids...
        assert_eq!(r2.max_req_id, 2, "id 2 still pending, still the max");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn open_tolerates_a_torn_file_on_disk() {
        let path = tmp("torn");
        let _ = std::fs::remove_file(&path);
        let mut bytes = encode_record(&Record::Submit(sub(1, None)));
        bytes.extend(&encode_record(&Record::Submit(sub(2, None)))[..9]);
        std::fs::write(&path, &bytes).unwrap();
        let (_, r) = Journal::open(&path, 1).unwrap();
        assert!(r.truncated);
        assert_eq!(r.pending.len(), 1);
        assert_eq!(r.pending[0].id, 1);
        // the compacted file is whole again
        let (_, r2) = Journal::open(&path, 1).unwrap();
        assert!(!r2.truncated);
        assert_eq!(r2.pending.len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
