//! Real-time serving loop: drives the *same* [`EngineCore`] the DES
//! figure harnesses run — by default `agent-xpu` with its dual queues,
//! kernel-level preemption, decode batching, backfill, and memory
//! governor — against a wall clock ([`EngineClock::wall`]).
//!
//! There is no scheduling policy in this file.  The loop only moves
//! bytes: channel messages in ([`RtMsg`]), engine events out
//! ([`TokenEvent`]).  The policy is selected *by name* from the
//! engine registry (`agent-xpu serve --policy`), so any registered
//! scheduler — `deadline`, a baseline, a future policy — serves the
//! same wire protocol.  Scheduler knobs (`b_max`, `session_capacity`,
//! preemption/backfill switches, …) come from the caller's
//! [`SchedulerConfig`] — the same configuration the simulated
//! coordinator honors.
//!
//! Sessions: a request carrying a `session` tag maps to a flow id; the
//! engine's session pool retains the conversation KV after completion,
//! and the session's next call prefills only the tokens beyond the
//! retained prefix (`done.cached_prefix` reports the reuse).  Retention
//! is bounded by `SchedulerConfig::session_capacity` and shed LRU-first
//! by the memory governor, exactly as in simulation.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::mpsc::{Receiver, Sender, TryRecvError, channel};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::{SchedulerConfig, SocConfig};
use crate::engine::{EngineClock, EngineCore, EngineEvent, ExecBridge, registry};
use crate::metrics::ReportAccumulator;
use crate::workload::{FlowBinding, NodeKind, Priority, ReqId, Request};

/// Max session *tags* remembered by the server.  Tags arrive from
/// clients, so the map must be bounded for a long-lived server; when
/// it overflows, the oldest tag is forgotten — that session's next
/// call simply starts cold (its retained KV ages out of the engine's
/// LRU-bounded pool on its own).
const SESSION_TAGS_MAX: usize = 1024;

/// Generation ids remembered per tag for `deps` resolution (a DAG edge
/// can only reference a recent call of the same session).
const SESSION_DEPS_MAX: usize = 64;

/// Per-tag session state: a stable flow id, the number of calls seen
/// (the next node index), and a bounded map from generation id to node
/// index so clients can express DAG dependencies between their calls.
#[derive(Default)]
struct SessionMeta {
    flow_id: u64,
    calls: usize,
    /// generation id → node (turn) index within the flow.
    turn_of: BTreeMap<u64, usize>,
}

/// Bounded session-tag registry: maps client tags to stable flow ids
/// and counts the calls seen per tag (the flow node index).  Ids are
/// monotonic (never reused), so a forgotten tag can never alias
/// another session's retained cache.
#[derive(Default)]
struct SessionRegistry {
    ids: HashMap<String, SessionMeta>,
    order: VecDeque<String>,
    next: u64,
}

impl SessionRegistry {
    /// Resolve a tag to `(flow_id, turn_idx)` for the call `req_id`,
    /// registering the tag if new; evicts the oldest tag beyond
    /// `SESSION_TAGS_MAX` and the oldest remembered generation ids
    /// beyond `SESSION_DEPS_MAX`.
    fn resolve(&mut self, tag: &str, req_id: u64) -> (u64, usize) {
        if let Some(e) = self.ids.get_mut(tag) {
            e.calls += 1;
            let idx = e.calls;
            e.turn_of.insert(req_id, idx);
            while e.turn_of.len() > SESSION_DEPS_MAX {
                let _ = e.turn_of.pop_first();
            }
            return (e.flow_id, idx);
        }
        let sid = self.next;
        self.next += 1;
        let mut meta = SessionMeta { flow_id: sid, calls: 0, turn_of: BTreeMap::new() };
        meta.turn_of.insert(req_id, 0);
        self.ids.insert(tag.to_string(), meta);
        self.order.push_back(tag.to_string());
        while self.order.len() > SESSION_TAGS_MAX {
            if let Some(old) = self.order.pop_front() {
                self.ids.remove(&old);
            }
        }
        (sid, 0)
    }

    /// Map generation ids to node indices within `tag`'s flow; unknown
    /// (or forgotten) ids are dropped — the submission merely waits on
    /// fewer predecessors.
    fn resolve_deps(&self, tag: &str, deps: &[u64]) -> Vec<usize> {
        let Some(e) = self.ids.get(tag) else { return vec![] };
        let mut out: Vec<usize> = deps
            .iter()
            .filter_map(|id| e.turn_of.get(id).copied())
            .collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    #[cfg(test)]
    fn get(&self, tag: &str) -> Option<u64> {
        self.ids.get(tag).map(|e| e.flow_id)
    }
}

/// A request submitted to the real-time serving loop.
pub struct RtRequest {
    pub id: ReqId,
    pub priority: Priority,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Session tag: calls sharing a tag reuse the retained KV of the
    /// previous call's conversation (`None` = single-shot).
    pub session: Option<String>,
    /// DAG predecessors within the same session: generation ids this
    /// call must wait for (fan-out/join workflows over the wire).
    /// Empty = the implicit linear chain (wait for the previous call).
    pub deps: Vec<u64>,
    /// Streamed token events land here.
    pub events: Sender<TokenEvent>,
}

/// Control messages into the serving loop.
pub enum RtMsg {
    Submit(RtRequest),
    /// Abort an in-flight generation; its KV is freed and the client
    /// receives a terminal [`TokenEvent::Cancelled`].
    Cancel(ReqId),
}

/// Streamed output.
#[derive(Debug, Clone, PartialEq)]
pub enum TokenEvent {
    Accepted { id: ReqId },
    Token { id: ReqId, token: i32, n: usize },
    Done {
        id: ReqId,
        ttft_ms: f64,
        total_ms: f64,
        tokens: Vec<i32>,
        /// Prompt tokens served from the session cache (0 = no reuse).
        cached_prefix: usize,
    },
    /// Terminal frame of a cancelled generation.
    Cancelled { id: ReqId },
    Error { id: ReqId, message: String },
}

/// The real-time serving loop.  Owns the engine core (and through it
/// the PJRT runtime); consumes [`RtMsg`]s from a channel until it
/// closes and all work drains.
pub struct RtScheduler {
    core: Box<dyn EngineCore + Send>,
    stats: Arc<Mutex<ReportAccumulator>>,
}

impl RtScheduler {
    /// Build the serving loop around the default coordinator policy
    /// (`agent-xpu`): real-compute when the bridge carries a PJRT
    /// executor, timing bridge otherwise.  `sched` is honored wholesale
    /// — `b_max`, `session_capacity`, preemption/backfill/
    /// disaggregation switches.
    pub fn new(bridge: Arc<ExecBridge>, soc: SocConfig, sched: SchedulerConfig) -> Self {
        Self::new_with_policy(bridge, soc, sched, "agent-xpu")
            .expect("the default policy is always registered")
    }

    /// Like [`RtScheduler::new`], but serving any policy registered in
    /// `engine::registry` (the `serve --policy` path).  Fails on an
    /// unknown policy name.
    pub fn new_with_policy(
        bridge: Arc<ExecBridge>,
        soc: SocConfig,
        sched: SchedulerConfig,
        policy: &str,
    ) -> Result<Self> {
        let core: Box<dyn EngineCore + Send> = match bridge.executor() {
            Some(exec) => registry::build_real(policy, exec, soc, sched)?,
            None => registry::build(policy, bridge.geo.clone(), soc, sched)?,
        };
        Ok(Self { core, stats: Arc::new(Mutex::new(ReportAccumulator::new())) })
    }

    /// Running serving statistics (shared with the `stats` verb).
    pub fn stats(&self) -> Arc<Mutex<ReportAccumulator>> {
        self.stats.clone()
    }

    /// Run until the request channel closes and all work drains.
    /// Returns the number of completed (non-cancelled) generations.
    pub fn serve(mut self, rx: Receiver<RtMsg>) -> Result<u64> {
        self.core.start(EngineClock::wall())?;
        let mut registry = SessionRegistry::default();
        let mut subs: HashMap<ReqId, Sender<TokenEvent>> = HashMap::new();
        let mut served = 0u64;
        let mut open = true;
        loop {
            // Intake — block only when there is nothing else to do.
            if open {
                if !self.core.has_work() {
                    match rx.recv() {
                        Ok(m) => self.handle_msg(m, &mut registry, &mut subs)?,
                        Err(_) => open = false,
                    }
                }
                loop {
                    match rx.try_recv() {
                        Ok(m) => self.handle_msg(m, &mut registry, &mut subs)?,
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            open = false;
                            break;
                        }
                    }
                }
            }
            if !self.core.has_work() {
                if !open {
                    return Ok(served);
                }
                continue;
            }
            // One decision point of the shared coordinator policy.
            for ev in self.core.step()? {
                self.stats.lock().unwrap().absorb(&ev);
                match ev {
                    EngineEvent::TokenEmitted { id, token, n, .. } => {
                        if let Some(tx) = subs.get(&id) {
                            let _ = tx.send(TokenEvent::Token { id, token, n });
                        }
                    }
                    EngineEvent::TurnDone {
                        id,
                        at_us,
                        arrival_us,
                        first_token_us,
                        tokens,
                        cached_prefix,
                    } => {
                        served += 1;
                        if let Some(tx) = subs.remove(&id) {
                            let _ = tx.send(TokenEvent::Done {
                                id,
                                ttft_ms: (first_token_us - arrival_us) / 1e3,
                                total_ms: (at_us - arrival_us) / 1e3,
                                tokens,
                                cached_prefix,
                            });
                        }
                    }
                    EngineEvent::Cancelled { id, .. } => {
                        if let Some(tx) = subs.remove(&id) {
                            let _ = tx.send(TokenEvent::Cancelled { id });
                        }
                    }
                    EngineEvent::Admitted { .. }
                    | EngineEvent::Preempted { .. }
                    | EngineEvent::KvEvicted { .. }
                    | EngineEvent::SessionEvicted { .. } => {}
                }
            }
        }
    }

    fn handle_msg(
        &mut self,
        m: RtMsg,
        registry: &mut SessionRegistry,
        subs: &mut HashMap<ReqId, Sender<TokenEvent>>,
    ) -> Result<()> {
        match m {
            RtMsg::Submit(r) => {
                // A session call is a node of an open-ended flow: the
                // engine's pool seeds its KV from the tag's previous
                // call and retains it again afterwards.  delta_start=0
                // marks the prompt self-contained (no trace stitching).
                // `deps` turns calls into DAG nodes: the engine holds
                // this one until every referenced generation finished.
                let flow = r.session.as_ref().map(|tag| {
                    let (flow_id, turn_idx) = registry.resolve(tag, r.id);
                    let mut deps = registry.resolve_deps(tag, &r.deps);
                    if !r.deps.is_empty() && deps.is_empty() {
                        // Every referenced generation is unknown or
                        // forgotten: run now ("waits on fewer
                        // predecessors"), instead of an empty list
                        // silently re-implying the linear chain.  A
                        // self-index is the explicit no-predecessors
                        // form (`FlowBinding::dep_indices`).
                        deps = vec![turn_idx];
                    }
                    FlowBinding {
                        flow_id,
                        turn_idx,
                        total_turns: usize::MAX,
                        think_time_us: 0.0,
                        delta_start: 0,
                        deps,
                        node: NodeKind::Llm,
                        crit_path: 1, // open-ended: depth unknown
                    }
                });
                let _ = r.events.send(TokenEvent::Accepted { id: r.id });
                subs.insert(r.id, r.events);
                self.core.submit(Request {
                    id: r.id,
                    priority: r.priority,
                    arrival_us: 0.0, // re-stamped to wall now on submit
                    prompt: r.prompt,
                    max_new_tokens: r.max_new_tokens,
                    profile: "uds".into(),
                    flow,
                })?;
            }
            RtMsg::Cancel(id) => {
                // Unknown / already-finished ids are a harmless no-op;
                // a hit streams a terminal Cancelled on the next step.
                let _ = self.core.cancel(id)?;
            }
        }
        Ok(())
    }
}

/// Convenience used by tests and the UDS layer: run a serving loop on
/// its own thread, returning the message sender and the live stats.
pub fn spawn(
    bridge: Arc<ExecBridge>,
    soc: SocConfig,
    sched: SchedulerConfig,
) -> (Sender<RtMsg>, Arc<Mutex<ReportAccumulator>>) {
    spawn_with_policy(bridge, soc, sched, "agent-xpu")
        .expect("the default policy is always registered")
}

/// Like [`spawn`], serving any registered policy by name.
pub fn spawn_with_policy(
    bridge: Arc<ExecBridge>,
    soc: SocConfig,
    sched: SchedulerConfig,
    policy: &str,
) -> Result<(Sender<RtMsg>, Arc<Mutex<ReportAccumulator>>)> {
    let (tx, rx) = channel();
    let sched = RtScheduler::new_with_policy(bridge, soc, sched, policy)?;
    let stats = sched.stats();
    std::thread::spawn(move || {
        let _ = sched.serve(rx);
    });
    Ok((tx, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{default_soc, llama32_3b};

    fn bridge() -> Arc<ExecBridge> {
        let mut geo = llama32_3b();
        geo.n_layers = 2;
        Arc::new(ExecBridge::synthetic(geo))
    }

    fn spawn_default() -> (Sender<RtMsg>, Arc<Mutex<ReportAccumulator>>) {
        spawn(bridge(), default_soc(), SchedulerConfig::default())
    }

    fn submit(
        tx: &Sender<RtMsg>,
        id: u64,
        priority: Priority,
        plen: usize,
        maxnew: usize,
    ) -> Receiver<TokenEvent> {
        let (etx, erx) = channel();
        tx.send(RtMsg::Submit(RtRequest {
            id,
            priority,
            prompt: vec![1; plen],
            max_new_tokens: maxnew,
            session: None,
            deps: vec![],
            events: etx,
        }))
        .unwrap();
        erx
    }

    fn submit_session(
        tx: &Sender<RtMsg>,
        id: u64,
        session: &str,
        prompt: Vec<i32>,
        maxnew: usize,
    ) -> Receiver<TokenEvent> {
        let (etx, erx) = channel();
        tx.send(RtMsg::Submit(RtRequest {
            id,
            priority: Priority::Reactive,
            prompt,
            max_new_tokens: maxnew,
            session: Some(session.into()),
            deps: vec![],
            events: etx,
        }))
        .unwrap();
        erx
    }

    fn done_of(events: &[TokenEvent]) -> (Vec<i32>, usize) {
        match events.last().unwrap() {
            TokenEvent::Done { tokens, cached_prefix, .. } => {
                (tokens.clone(), *cached_prefix)
            }
            e => panic!("expected Done, got {e:?}"),
        }
    }

    #[test]
    fn serves_a_request_with_streaming() {
        let (tx, _) = spawn_default();
        let erx = submit(&tx, 1, Priority::Reactive, 100, 5);
        drop(tx);
        let events: Vec<TokenEvent> = erx.iter().collect();
        assert!(matches!(events[0], TokenEvent::Accepted { id: 1 }));
        let toks: Vec<&TokenEvent> = events
            .iter()
            .filter(|e| matches!(e, TokenEvent::Token { .. }))
            .collect();
        assert_eq!(toks.len(), 5);
        match events.last().unwrap() {
            TokenEvent::Done { id, tokens, ttft_ms, total_ms, .. } => {
                assert_eq!(*id, 1);
                assert_eq!(tokens.len(), 5);
                assert!(*ttft_ms >= 0.0 && *total_ms >= *ttft_ms);
            }
            e => panic!("expected Done, got {e:?}"),
        }
    }

    #[test]
    fn session_calls_reuse_the_conversation_prefix() {
        // call 1 establishes the session; call 2 extends the exact
        // conversation (prompt + generated tokens) with new user input
        let (tx, stats) = spawn_default();
        let prompt1: Vec<i32> = vec![5; 40];
        let erx1 = submit_session(&tx, 1, "chat-1", prompt1.clone(), 4);
        let ev1: Vec<TokenEvent> = erx1.iter().collect();
        let (toks1, cached1) = done_of(&ev1);
        assert_eq!(cached1, 0, "first call has nothing to reuse");
        assert_eq!(toks1.len(), 4);

        let mut prompt2 = prompt1;
        prompt2.extend(&toks1);
        prompt2.extend(vec![6; 16]);
        let erx2 = submit_session(&tx, 2, "chat-1", prompt2.clone(), 3);
        let ev2: Vec<TokenEvent> = erx2.iter().collect();
        let (toks2, cached2) = done_of(&ev2);
        assert_eq!(toks2.len(), 3);
        // KV covers prompt1 + 3 of the 4 generated tokens
        assert_eq!(cached2, 43, "second call must reuse the session KV");

        // an unrelated session starts cold
        let erx3 = submit_session(&tx, 3, "chat-2", prompt2, 2);
        drop(tx);
        let (_, cached3) = done_of(&erx3.iter().collect::<Vec<_>>());
        assert_eq!(cached3, 0);
        // stats accumulated incrementally from the event stream
        let s = stats.lock().unwrap();
        assert_eq!(s.served, 3);
        assert_eq!(s.tokens, 4 + 3 + 2);
        assert_eq!(s.reused_prefix_tokens, 43);
    }

    #[test]
    fn session_registry_is_bounded_and_ids_are_stable() {
        let mut reg = SessionRegistry::default();
        let (a, t0) = reg.resolve("a", 1);
        assert_eq!(t0, 0);
        let (a2, t1) = reg.resolve("a", 2);
        assert_eq!((a2, t1), (a, 1), "same tag, same id, next turn");
        let (b, _) = reg.resolve("b", 3);
        assert_ne!(a, b);
        // generation ids resolve to node indices for DAG deps
        assert_eq!(reg.resolve_deps("a", &[1, 2]), vec![0, 1]);
        assert_eq!(reg.resolve_deps("a", &[99]), Vec::<usize>::new(), "unknown ids drop");
        // overflow the registry: oldest tags are forgotten...
        for i in 0..SESSION_TAGS_MAX {
            reg.resolve(&format!("t{i}"), 100 + i as u64);
        }
        assert!(reg.get("a").is_none(), "oldest tag evicted");
        // ...and ids are monotonic, so a re-registered tag can never
        // alias another session's retained cache
        let (a3, t) = reg.resolve("a", 9999);
        assert!(a3 > b);
        assert_eq!(t, 0, "a forgotten tag starts cold");
    }

    #[test]
    fn dag_deps_between_session_calls_complete_without_deadlock() {
        let (tx, stats) = spawn_default();
        let (etx0, erx0) = channel();
        tx.send(RtMsg::Submit(RtRequest {
            id: 1,
            priority: Priority::Reactive,
            prompt: vec![5; 120],
            max_new_tokens: 12,
            session: Some("wf".into()),
            deps: vec![],
            events: etx0,
        }))
        .unwrap();
        // two fan-out calls over the root + a join over both, submitted
        // immediately (the engine holds them until their deps finish)
        let submit_dep = |id: u64, deps: Vec<u64>| {
            let (etx, erx) = channel();
            tx.send(RtMsg::Submit(RtRequest {
                id,
                priority: Priority::Reactive,
                prompt: vec![6; 40],
                max_new_tokens: 4,
                session: Some("wf".into()),
                deps,
                events: etx,
            }))
            .unwrap();
            erx
        };
        let erx2 = submit_dep(2, vec![1]);
        let erx3 = submit_dep(3, vec![1]);
        let erx4 = submit_dep(4, vec![2, 3]);
        drop(tx);
        for erx in [erx0, erx2, erx3, erx4] {
            let events: Vec<TokenEvent> = erx.iter().collect();
            assert!(
                matches!(events.last().unwrap(), TokenEvent::Done { .. }),
                "DAG call must finish, got {:?}",
                events.last()
            );
        }
        assert_eq!(stats.lock().unwrap().served, 4);
    }

    #[test]
    fn diverged_session_prompt_recomputes() {
        let (tx, _) = spawn_default();
        let erx1 = submit_session(&tx, 1, "s", vec![5; 30], 3);
        let _ = erx1.iter().collect::<Vec<_>>();
        // same session, unrelated prompt → no usable prefix
        let erx2 = submit_session(&tx, 2, "s", vec![9; 30], 3);
        drop(tx);
        let (_, cached) = done_of(&erx2.iter().collect::<Vec<_>>());
        assert_eq!(cached, 0);
    }

    #[test]
    fn serves_concurrent_mixed_requests() {
        let (tx, _) = spawn_default();
        let rx1 = submit(&tx, 1, Priority::Proactive, 200, 8);
        let rx2 = submit(&tx, 2, Priority::Reactive, 64, 4);
        let rx3 = submit(&tx, 3, Priority::Proactive, 64, 4);
        drop(tx);
        for rx in [rx1, rx2, rx3] {
            let events: Vec<TokenEvent> = rx.iter().collect();
            assert!(
                matches!(events.last().unwrap(), TokenEvent::Done { .. }),
                "{events:?}"
            );
        }
    }

    #[test]
    fn cancel_aborts_an_inflight_generation() {
        let (tx, stats) = spawn_default();
        // a generation long enough that the cancel always lands first
        let erx = submit(&tx, 1, Priority::Reactive, 64, 200_000);
        tx.send(RtMsg::Cancel(1)).unwrap();
        drop(tx);
        let events: Vec<TokenEvent> = erx.iter().collect();
        assert!(matches!(events[0], TokenEvent::Accepted { id: 1 }));
        assert!(
            matches!(events.last().unwrap(), TokenEvent::Cancelled { id: 1 }),
            "terminal frame must be Cancelled, got {:?}",
            events.last()
        );
        assert_eq!(stats.lock().unwrap().cancelled, 1);
    }

    #[test]
    fn cancel_of_unknown_id_is_harmless() {
        let (tx, _) = spawn_default();
        tx.send(RtMsg::Cancel(999)).unwrap();
        let erx = submit(&tx, 1, Priority::Reactive, 64, 3);
        drop(tx);
        let events: Vec<TokenEvent> = erx.iter().collect();
        assert!(matches!(events.last().unwrap(), TokenEvent::Done { .. }));
    }

    #[test]
    fn any_registered_policy_serves_the_same_protocol() {
        // the serve --policy path: a baseline and the EDF policy drive
        // the identical wire loop
        for policy in ["deadline", "cpu-fcfs"] {
            let (tx, stats) = spawn_with_policy(
                bridge(),
                default_soc(),
                SchedulerConfig::default(),
                policy,
            )
            .unwrap();
            let erx = submit(&tx, 1, Priority::Reactive, 80, 3);
            drop(tx);
            let events: Vec<TokenEvent> = erx.iter().collect();
            assert!(
                matches!(events.last().unwrap(), TokenEvent::Done { .. }),
                "{policy}: {events:?}"
            );
            assert_eq!(stats.lock().unwrap().served, 1, "{policy}");
        }
        assert!(
            spawn_with_policy(
                bridge(),
                default_soc(),
                SchedulerConfig::default(),
                "no-such-policy",
            )
            .is_err(),
            "unknown policy names fail fast"
        );
    }

    #[test]
    fn session_capacity_zero_disables_serving_reuse() {
        // the config knob the simulated coordinator honors now reaches
        // the server too
        let mut sched = SchedulerConfig::default();
        sched.session_capacity = 0;
        let (tx, _) = spawn(bridge(), default_soc(), sched);
        let p: Vec<i32> = vec![5; 30];
        let erx1 = submit_session(&tx, 1, "s", p.clone(), 3);
        let (toks1, _) = done_of(&erx1.iter().collect::<Vec<_>>());
        let mut p2 = p;
        p2.extend(&toks1);
        p2.extend(vec![6; 8]);
        let erx2 = submit_session(&tx, 2, "s", p2, 2);
        drop(tx);
        let (_, cached) = done_of(&erx2.iter().collect::<Vec<_>>());
        assert_eq!(cached, 0, "capacity 0 must disable retention");
    }
}
